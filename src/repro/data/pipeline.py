"""Deterministic synthetic LM data pipeline.

Stateless-per-step: batch(step) is a pure function of (seed, step, shape),
so any host can (re)produce any shard -- this is the straggler/fault story:
a restarted or reassigned host needs no data-loader state, only the step
counter from the checkpoint manifest.

Per-host sharding: each JAX process materialises only its slice of the
global batch (process_index/process_count), which is what a real multi-pod
launch does; in this single-process container the slice is the whole batch.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    vocab_size: int = 32000


class SyntheticLM:
    """Zipf-ish token stream with a repeated-ngram structure so the loss
    actually decreases during the example training runs."""

    def __init__(self, dcfg: DataConfig, mcfg: ModelConfig,
                 shape: ShapeConfig):
        self.dcfg = dcfg
        self.mcfg = mcfg
        self.shape = shape
        self.process_index = jax.process_index()
        self.process_count = jax.process_count()

    def _host_batch(self) -> int:
        b = self.shape.global_batch
        assert b % self.process_count == 0
        return b // self.process_count

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.dcfg.seed * 1_000_003 + step) * 97 + self.process_index)
        b = self._host_batch()
        cfg, shape = self.mcfg, self.shape
        v = min(self.dcfg.vocab_size, cfg.vocab_size)
        text_len = shape.seq_len
        out = {}
        if cfg.family == "vlm":
            text_len = shape.seq_len - cfg.frontend_len
            out["patches"] = rng.standard_normal(
                (b, cfg.frontend_len, cfg.d_model)).astype(np.float32) * 0.02
        if cfg.family == "encdec":
            out["frames"] = rng.standard_normal(
                (b, cfg.frontend_len, cfg.d_model)).astype(np.float32) * 0.02
        # zipf-ish marginals + copied spans (learnable structure)
        ranks = np.arange(1, v + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(v, size=(b, text_len), p=probs).astype(np.int32)
        span = max(4, text_len // 8)
        toks[:, span:2 * span] = toks[:, :span]          # repeat an ngram
        out["tokens"] = toks
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
