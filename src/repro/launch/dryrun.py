import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

# Multi-pod dry-run: lower + compile every (arch x shape) cell on the
# production meshes and extract memory/cost/collective analyses for the
# roofline (EXPERIMENTS.md §Dry-run / §Roofline).
#
# MUST be run as its own process (jax locks the device count on first init):
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b \
#       --shape train_4k [--multi-pod] [--all] [--json out.json]
#
# (The XLA_FLAGS lines above must stay the first statements in the file,
# which is why this header is a comment rather than a docstring.)

import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, get_config, get_shape
from repro.dist import sharding as shlib
from repro.launch.mesh import make_production_mesh
from repro.models.api import build_model
from repro.train import optimizer as optlib
from repro.train.trainer import TrainConfig, make_train_step, shardings_for

# TPU v5e constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s /link /chip (~)

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*=\s*([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2,
}


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result-operand bytes of every collective op in the HLO."""
    out: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        kind, dtype, dims = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        numel = 1
        if dims:
            for d in dims.split(","):
                if d:
                    numel *= int(d)
        out[kind] = out.get(kind, 0.0) + numel * nbytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    model = build_model(cfg)
    spec = {"batch": model.batch_spec(shape)}
    if shape.kind == "decode":
        spec["cache"] = model.cache_spec(shape.global_batch, shape.seq_len)
    return spec


def _abstract_like(tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree)


def lower_cell(arch: str, shape_name: str, mesh, verbose: bool = True):
    """Lower + compile one cell; returns the result record."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return {"arch": arch, "shape": shape_name, "status":
                "SKIP(full-attention)"}
    model = build_model(cfg)
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            tstep = make_train_step(model, TrainConfig())
            batch_spec = model.batch_spec(shape)
            (p_sh, o_sh, b_sh), (p_shapes, o_shapes) = shardings_for(
                model, mesh, batch_spec)
            fn = jax.jit(tstep, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(p_shapes, o_shapes, batch_spec)
        else:
            batch_spec = model.batch_spec(shape)
            params_axes = model.axes()
            p_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            # serving: replicate weights over 'data' (no FSDP re-gathers)
            p_sh = shlib.tree_shardings(params_axes, p_shapes, mesh,
                                        inference=True)
            b_sh = shlib.batch_sharding(mesh, batch_spec)
            if shape.kind == "prefill":
                def prefill_fn(params, batch):
                    return model.prefill(
                        params, batch["tokens"],
                        prefix_embeds=batch.get("patches"),
                        frames=batch.get("frames"))
                cache_shapes = jax.eval_shape(
                    prefill_fn, p_shapes, batch_spec)[1]
                c_sh = shlib.tree_shardings(
                    model.cache_axes(), cache_shapes, mesh)
                fn = jax.jit(prefill_fn, in_shardings=(p_sh, b_sh),
                             out_shardings=(None, c_sh))
                lowered = fn.lower(p_shapes, batch_spec)
            else:  # decode
                cache_spec = model.cache_spec(shape.global_batch,
                                              shape.seq_len)
                c_sh = shlib.tree_shardings(
                    model.cache_axes(), cache_spec, mesh)

                def decode_fn(params, tokens, cache, position):
                    return model.decode_step(params, tokens, cache, position)

                fn = jax.jit(decode_fn,
                             in_shardings=(p_sh, b_sh["tokens"], c_sh,
                                           b_sh["position"]),
                             out_shardings=(None, c_sh),
                             donate_argnums=(2,))
                lowered = fn.lower(
                    p_shapes, batch_spec["tokens"], cache_spec,
                    batch_spec["position"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    n_dev = mesh.devices.size
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    # cost_analysis reports the PARTITIONED module, i.e. per-device values
    # (verified against a sharded matmul: flops scale as 1/n_dev).  The HLO
    # text is likewise one device's program, so parsed collective operand
    # sizes are per-device shard bytes.
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    hlo_text = compiled.as_text()
    coll_raw = collective_bytes(hlo_text)
    # Trip-count-aware structural analysis: cost_analysis counts while
    # (lax.scan) bodies ONCE, so scanned-layer models undercount by the
    # layer count -- hlo_analysis re-derives dot FLOPs, a memory-traffic
    # proxy, and collective bytes with loop multipliers applied.
    from repro.launch import hlo_analysis
    struct = hlo_analysis.analyze(hlo_text)
    c_flops = max(flops, struct["dot_flops"])
    c_bytes = max(bytes_accessed, struct["tensor_bytes"])
    c_coll = max(coll_raw["total"], struct["collective_bytes"])

    rec = {
        "arch": arch, "shape": shape_name, "status": "OK",
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        # memory_analysis is per-device on the host backend
        "bytes_per_device": {
            "argument": getattr(mem, "argument_size_in_bytes", None),
            "output": getattr(mem, "output_size_in_bytes", None),
            "temp": getattr(mem, "temp_size_in_bytes", None),
            "peak": getattr(mem, "peak_memory_in_bytes", None),
        },
        # raw cost_analysis (single loop iteration) -- kept for reference
        "hlo_flops_raw": flops,
        "hlo_bytes_raw": bytes_accessed,
        "collective_bytes_raw": coll_raw,
        # loop-corrected per-device quantities (primary)
        "hlo_flops_per_device": c_flops,
        "hlo_flops_global": c_flops * n_dev,
        "hlo_bytes_per_device": c_bytes,
        "collective_bytes_per_device": {**struct["collectives"],
                                        "total": c_coll},
        "while_trips": struct["while_trips"][:8],
        # roofline terms, seconds per executed step (per-chip quantities
        # over per-chip bandwidths == mesh-level step time bounds)
        "t_compute": c_flops / PEAK_FLOPS,
        "t_memory": c_bytes / HBM_BW,
        "t_collective": c_coll / ICI_BW,
    }
    terms = {k: rec[k] for k in ("t_compute", "t_memory", "t_collective")}
    rec["bottleneck"] = max(terms, key=terms.get)
    if verbose:
        print(json.dumps(rec, indent=1, default=str))
        sys.stdout.flush()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--json", help="append records to this JSONL file")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    cells = ([(a, s) for a in ARCH_IDS for s in SHAPES]
             if args.all else [(args.arch, args.shape)])
    records = []
    for arch, shape in cells:
        try:
            rec = lower_cell(arch, shape, mesh)
        except Exception as e:  # noqa: BLE001 - report, keep sweeping
            rec = {"arch": arch, "shape": shape, "status":
                   f"FAIL {type(e).__name__}: {e}"}
            print(json.dumps(rec), file=sys.stderr)
        records.append(rec)
        if args.json:
            with open(args.json, "a") as f:
                f.write(json.dumps(rec, default=str) + "\n")
    ok = sum(1 for r in records if r.get("status") == "OK")
    skip = sum(1 for r in records
               if str(r.get("status", "")).startswith("SKIP"))
    print(f"\n== dry-run: {ok} OK, {skip} SKIP, "
          f"{len(records) - ok - skip} FAIL / {len(records)} cells "
          f"on mesh {mesh.devices.shape} ==")
    return 0 if ok + skip == len(records) else 1


if __name__ == "__main__":
    sys.exit(main())
