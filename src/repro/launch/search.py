"""Standalone (mapping, layout) co-search CLI for GEMM/conv workloads —
the artifact's ``python -m minisa search [--layout-constrained]``.

    PYTHONPATH=src python -m repro.launch.search --m 2048 --k 2880 --n 4096
    PYTHONPATH=src python -m repro.launch.search \
        --conv 1,224,224,3,7,7,64,2 --ah 16 --aw 64
    PYTHONPATH=src python -m repro.launch.search --m 64 --k 40 --n 88 \
        --layout-constrained --fixed-vn 8 --fixed-order 4
"""

from __future__ import annotations

import argparse
import json

from repro.configs.feather import feather_config
from repro.core import mapper
from repro.core.conv import Conv2D


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int)
    ap.add_argument("--k", type=int)
    ap.add_argument("--n", type=int)
    ap.add_argument("--conv", help="N,H,W,Cin,KH,KW,Cout[,stride]")
    ap.add_argument("--ah", type=int, default=16)
    ap.add_argument("--aw", type=int, default=64)
    ap.add_argument("--layout-constrained", action="store_true")
    ap.add_argument("--fixed-vn", type=int, default=None)
    ap.add_argument("--fixed-order", type=int, default=None)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)

    if args.conv:
        parts = [int(x) for x in args.conv.split(",")]
        conv = Conv2D(n=parts[0], h=parts[1], w=parts[2], c_in=parts[3],
                      kh=parts[4], kw=parts[5], c_out=parts[6],
                      stride=parts[7] if len(parts) > 7 else 1)
        gemm = conv.to_gemm()
        print(f"conv lowered to GEMM {gemm.m}x{gemm.k}x{gemm.n} "
              f"({gemm.name})")
    else:
        assert args.m and args.k and args.n, "--m/--k/--n or --conv"
        gemm = mapper.Gemm(m=args.m, k=args.k, n=args.n)

    cfg = feather_config(args.ah, args.aw)
    kwargs = {}
    if args.layout_constrained:
        kwargs["fixed_input_vn"] = args.fixed_vn or cfg.ah
        if args.fixed_order is not None:
            kwargs["fixed_input_order"] = args.fixed_order
    plan = mapper.search(gemm, cfg, **kwargs)
    s = plan.summary()
    if args.json:
        print(json.dumps(s, indent=1, default=str))
        return
    ch = plan.choice
    print(f"best mapping: df={ch.df.name} vn={ch.vn} "
          f"tiles=({ch.m_t},{ch.k_t},{ch.n_t}) "
          f"groups=({ch.n_kg},{ch.n_nb}) dup={ch.dup} "
          f"orders=(W:{ch.order_w} I:{ch.order_i} O:{ch.order_o})")
    print(f"cycles {s['cycles_minisa']:.4g} | speedup vs micro "
          f"{s['speedup']:.2f}x | utilization {s['util_minisa']:.1%} | "
          f"instr reduction {s['instr_reduction']:.3g}x")


if __name__ == "__main__":
    main()
