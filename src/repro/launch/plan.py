"""MINISA planning CLI: plan FEATHER+ offload for an (arch x shape) cell.

    PYTHONPATH=src python -m repro.launch.plan --arch gemma-7b \
        --shape decode_32k --ah 16 --aw 256
"""

from __future__ import annotations

import argparse
import json

from repro.configs.feather import feather_config
from repro.configs.registry import ARCH_IDS, get_config, get_shape
from repro.core.model_gemms import gemm_workloads
from repro.core.planner import plan_model
from repro.configs.base import SHAPES


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma-7b")
    ap.add_argument("--shape", choices=list(SHAPES), default="decode_32k")
    ap.add_argument("--ah", type=int, default=16)
    ap.add_argument("--aw", type=int, default=256)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    shape = get_shape(args.shape)
    fcfg = feather_config(args.ah, args.aw)
    ops = gemm_workloads(cfg, shape)
    plan = plan_model(args.arch, args.shape, ops, fcfg)
    s = plan.summary()
    if args.json:
        print(json.dumps(s, indent=1))
        return
    print(f"== MINISA plan: {args.arch} x {args.shape} on FEATHER+ "
          f"{args.ah}x{args.aw} ==")
    print(f" GEMMs                {s['n_gemms']:>14,} ({s['n_unique']} unique shapes)")
    print(f" MACs                 {s['macs']:>14.3e}")
    print(f" cycles (MINISA)      {s['cycles_minisa']:>14.3e}")
    print(f" cycles (micro-inst)  {s['cycles_micro']:>14.3e}")
    print(f" end-to-end speedup   {s['speedup']:>14.2f}x")
    print(f" compute utilization  {s['utilization']:>14.1%}")
    print(f" instr bytes MINISA   {s['instr_bytes_minisa']:>14.3e}"
          f"  (instr:data = {s['instr_to_data_minisa']:.2e})")
    print(f" instr bytes micro    {s['instr_bytes_micro']:>14.3e}"
          f"  (instr:data = {s['instr_to_data_micro']:.2e})")
    print(f" instruction reduction{s['instr_reduction']:>14.1f}x")
    print(f" bytes saved by inter-layer layout elision "
          f"{s['elided_bytes']:.3e}")


if __name__ == "__main__":
    main()
