"""Serving launcher: batched prefill + decode with the Engine.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-7b --reduced \
        --batch 4 --prompt-len 32 --steps 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import reduced as reduce_cfg
from repro.configs.registry import ARCH_IDS, get_config
from repro.models.api import build_model
from repro.serve.engine import Engine, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg, layers=2, d_model=128, vocab=1024)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, ServeConfig(
        max_len=args.prompt_len + args.steps + 1,
        temperature=args.temperature))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["frames"] = np.asarray(
            rng.standard_normal((args.batch, cfg.frontend_len, cfg.d_model)),
            np.float32) * 0.02
    if cfg.family == "vlm":
        kwargs["prefix_embeds"] = np.asarray(
            rng.standard_normal((args.batch, cfg.frontend_len, cfg.d_model)),
            np.float32) * 0.02
    t0 = time.time()
    tokens = engine.generate(prompts, args.steps, **kwargs)
    dt = time.time() - t0
    print(f"generated {tokens.shape} in {dt:.2f}s "
          f"({args.batch * args.steps / dt:.1f} tok/s)")
    print(tokens[:, :12])


if __name__ == "__main__":
    main()
