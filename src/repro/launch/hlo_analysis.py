"""Trip-count-aware HLO analysis.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so for
scanned-layer models every in-loop quantity (FLOPs, bytes, collective
traffic) is undercounted by the trip count (verified: a lax.scan of 10
matmuls reports 1 matmul of FLOPs).  This module re-derives roofline
quantities from ``compiled.as_text()`` structurally:

  1. split the module into named computations;
  2. build the call graph (while body/condition, fusion calls, to_apply,
     conditional branches) and propagate an execution multiplier: a while
     body executes trip_count times (trip count = the integer constant
     compared against the induction variable in the condition);
  3. per computation, accumulate
       * dot FLOPs: 2 * numel(result) * contraction_size,
       * collective bytes: result bytes of all-gather / all-reduce /
         reduce-scatter / all-to-all / collective-permute,
       * a tensor-traffic proxy: operand + result bytes of top-level ops
         (not descending into fusions, which model on-chip reuse);
  4. total = sum over computations of multiplier * local quantity.

All quantities are per-device (the HLO is one partition's program).
"""

from __future__ import annotations

import dataclasses
import math
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_CALL_ATTR_RE = re.compile(
    r"(?:body|condition|to_apply|calls|branch_computations)="
    r"\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _first_shape(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return m.group(1), m.group(2)


@dataclasses.dataclass
class Computation:
    name: str
    lines: list[str]
    is_entry: bool = False
    # locally-accumulated quantities
    dot_flops: float = 0.0
    coll_bytes: dict = dataclasses.field(default_factory=dict)
    tensor_bytes: float = 0.0
    calls: list[tuple[str, str]] = dataclasses.field(default_factory=list)
    # (callee, kind) kind in {while_body, while_cond, fusion, call, branch}
    trip_count: int = 1  # meaningful when referenced as a while body


def _parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not raw.startswith((" ", "\t")):
            # computation header or closing brace at column 0
            if line.startswith("}"):
                cur = None
                continue
            m = re.match(r"(ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
            if m and line.endswith("{"):
                cur = Computation(name=m.group(2), lines=[],
                                  is_entry=bool(m.group(1)))
                comps[cur.name] = cur
            continue
        if cur is not None:
            cur.lines.append(line.strip())
    return comps


_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*([a-z0-9]+)"
                     r"\[([0-9,]*)\]")


def _dot_flops(line: str, symtab: dict[str, list[int]]) -> float:
    """FLOPs of a dot: 2 * numel(result) * contraction_size.

    Compiled HLO omits operand shapes on the op line, so the lhs shape is
    resolved through the computation's symbol table."""
    m = re.search(r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*\bdot\(", line)
    if not m:
        return 0.0
    res_elems = 1
    if m.group(2):
        for d in m.group(2).split(","):
            if d:
                res_elems *= int(d)
    lc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    # lhs operand name: first %-prefixed symbol in the argument list (newer
    # XLA prints operand shapes inline, e.g. "dot(f32[256,256]{1,0} %a, ...)",
    # older versions just "dot(%a, ...)")
    args = line[line.index("dot(") + 4:]
    m_lhs = re.search(r"%([\w\.\-]+)", args)
    lhs_name = (m_lhs.group(1) if m_lhs
                else args.split(",")[0].strip().lstrip("%"))
    lhs_dims = symtab.get(lhs_name)
    if lc is None or lhs_dims is None:
        return 2.0 * res_elems  # conservative fallback
    contract = 1
    for ax in (int(a) for a in lc.group(1).split(",") if a):
        if ax < len(lhs_dims):
            contract *= lhs_dims[ax]
    return 2.0 * res_elems * contract


def _analyze_computation(comp: Computation):
    # symbol table: op/parameter name -> (dtype_bytes, dims)
    symtab: dict[str, list[int]] = {}
    symdtype: dict[str, int] = {}
    for line in comp.lines:
        d = _DEF_RE.match(line)
        if d:
            symtab[d.group(1)] = [int(x) for x in d.group(3).split(",")
                                  if x]
            symdtype[d.group(1)] = _DTYPE_BYTES.get(d.group(2), 4)
    for line in comp.lines:
        # call edges
        if " while(" in line:
            m_body = re.search(r"body=%?([\w\.\-]+)", line)
            m_cond = re.search(r"condition=%?([\w\.\-]+)", line)
            if m_body:
                comp.calls.append((m_body.group(1), "while_body"))
            if m_cond:
                comp.calls.append((m_cond.group(1), "while_cond"))
        for attr, kind in (("calls", "fusion"), ("to_apply", "call"),
                           ("branch_computations", "branch")):
            m = re.search(attr + r"=\{?%?([\w\.\-]+(?:, ?%?[\w\.\-]+)*)\}?",
                          line)
            if m:
                for callee in re.split(r",\s*%?", m.group(1)):
                    comp.calls.append((callee, kind))
        # dot flops
        if "dot(" in line:
            comp.dot_flops += _dot_flops(line, symtab)
        # collectives
        for kind in _COLLECTIVES:
            if f" {kind}(" in line or f"{kind}-start(" in line:
                sh = _first_shape(line.split("=", 1)[1])
                if sh:
                    b = _shape_bytes(*sh)
                    comp.coll_bytes[kind] = comp.coll_bytes.get(kind, 0.0) + b
                break
        # tensor-traffic proxy: result + operand bytes per op (operand
        # shapes resolved through the symbol table; constants/params count
        # once as producers, reads are attributed at each consumer)
        if "=" in line and " tuple(" not in line \
                and "get-tuple-element" not in line \
                and " parameter(" not in line:
            rhs = line.split("=", 1)[1]
            sh = _first_shape(rhs)
            if sh:
                b = _shape_bytes(*sh)
                # operand reads
                paren = rhs.find("(")
                if paren != -1:
                    arg_text = rhs[paren + 1:rhs.find(")", paren)]
                    for name in re.findall(r"%([\w\.\-]+)", arg_text):
                        dims = symtab.get(name)
                        if dims is not None:
                            n = 1
                            for dd in dims:
                                n *= dd
                            b += n * symdtype.get(name, 4)
                comp.tensor_bytes += b


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition (the bound the
    induction variable is compared against)."""
    best = 1
    for line in cond.lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def analyze(hlo: str) -> dict:
    comps = _parse_computations(hlo)
    for c in comps.values():
        _analyze_computation(c)

    # resolve trip counts for while bodies
    trip_of_body: dict[str, int] = {}
    for c in comps.values():
        body = cond = None
        for callee, kind in c.calls:
            if kind == "while_body":
                body = callee
            elif kind == "while_cond":
                cond = callee
            if body and cond:
                if body in comps and cond in comps:
                    trip_of_body[body] = max(
                        trip_of_body.get(body, 1), _trip_count(comps[cond]))
                body = cond = None

    # propagate execution multipliers through the call graph; memory
    # multipliers stop at fusion boundaries (fusion internals model on-chip
    # reuse, not HBM traffic)
    mult: dict[str, float] = {}
    mult_mem: dict[str, float] = {}

    entries = [c.name for c in comps.values() if c.is_entry] or (
        [next(iter(comps))] if comps else [])

    def visit(name: str, m: float, mm: float, depth=0):
        if name not in comps or depth > 50:
            return
        mult[name] = mult.get(name, 0.0) + m
        mult_mem[name] = mult_mem.get(name, 0.0) + mm
        for callee, kind in comps[name].calls:
            if callee == name:
                continue
            child_m = m
            if kind == "while_body":
                child_m = m * trip_of_body.get(callee, 1)
            child_mm = 0.0 if kind in ("fusion", "call") else child_m
            visit(callee, child_m, child_mm, depth + 1)

    for e in entries:
        visit(e, 1.0, 1.0)

    out = {"dot_flops": 0.0, "tensor_bytes": 0.0, "collectives": {},
           "while_trips": sorted(trip_of_body.values(), reverse=True)}
    for name, c in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        out["dot_flops"] += m * c.dot_flops
        out["tensor_bytes"] += mult_mem.get(name, 0.0) * c.tensor_bytes
        for kind, b in c.coll_bytes.items():
            out["collectives"][kind] = (out["collectives"].get(kind, 0.0)
                                        + m * b)
    out["collective_bytes"] = sum(out["collectives"].values())
    return out
