"""Training launcher (end-to-end driver, deliverable (b)).

    PYTHONPATH=src python -m repro.launch.train --arch gemma-7b \
        --steps 300 --reduced --batch 8 --seq 256 --ckpt-dir /tmp/ckpt \
        --resume auto

On this CPU container use --reduced (family-preserving ~100M-and-below
models); on real hardware drop it and the production mesh/shardings apply
unchanged.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.configs.base import ShapeConfig, reduced as reduce_cfg
from repro.configs.registry import ARCH_IDS, get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.dist import elastic
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.api import build_model
from repro.train import optimizer as optlib
from repro.train.trainer import TrainConfig, make_train_step, shardings_for


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma-7b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--reduced-layers", type=int, default=4)
    ap.add_argument("--reduced-dmodel", type=int, default=256)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default=None, choices=[None, "auto"])
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg, layers=args.reduced_layers,
                         d_model=args.reduced_dmodel, vocab=2048)
    model = build_model(cfg)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())

    tcfg = TrainConfig(
        opt=optlib.OptimizerConfig(peak_lr=args.lr,
                                   warmup_steps=min(20, args.steps // 5 + 1),
                                   total_steps=args.steps),
        grad_accum=args.grad_accum)
    step_fn = make_train_step(model, tcfg)

    data = SyntheticLM(DataConfig(vocab_size=min(cfg.vocab_size, 4096)),
                       cfg, shape)
    batch0 = data.batch(0)
    batch_spec = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch0)
    with mesh:
        (p_sh, o_sh, b_sh), _ = shardings_for(model, mesh, batch_spec)
        jit_step = jax.jit(step_fn, in_shardings=(p_sh, o_sh, b_sh),
                           out_shardings=(p_sh, o_sh, None),
                           donate_argnums=(0, 1))
        params = jax.device_put(model.init(jax.random.PRNGKey(0)), p_sh)
        opt_state = jax.device_put(optlib.init(params), o_sh)

        start = 0
        manager = None
        if args.ckpt_dir:
            manager = CheckpointManager(args.ckpt_dir)
            if args.resume == "auto":
                state = {"params": params, "opt": opt_state}
                sh = {"params": p_sh, "opt": o_sh}
                restored, start = elastic.resume(manager, state, sh)
                if restored is not None:
                    params, opt_state = restored["params"], restored["opt"]
                    print(f"resumed from step {start}")

        t0 = time.time()
        for step in range(start, args.steps):
            batch = jax.tree.map(jnp.asarray, data.batch(step))
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                m = jax.tree.map(float, metrics)
                print(f"step {step:5d} loss {m['loss']:.4f} "
                      f"ppl {m.get('perplexity', float('nan')):.1f} "
                      f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e} "
                      f"({(time.time()-t0)/max(step-start+1,1):.2f}s/step)")
            if manager and args.ckpt_every and step and \
                    step % args.ckpt_every == 0:
                manager.save_async(step, {"params": params,
                                          "opt": opt_state})
        if manager:
            manager.save(args.steps, {"params": params, "opt": opt_state})
            manager.wait()
    print("done")


if __name__ == "__main__":
    main()
