"""Production mesh construction.

Single pod: 16 x 16 = 256 chips (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips (pod, data, model); the 'pod' axis
carries pure data parallelism (and is the gradient-compression hop).

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (smoke tests / examples): 1xN mesh."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
