"""Checkpointing: atomic, optionally async, elastic-restore-capable.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, written to a tmp dir
and atomically renamed (a crashed writer never corrupts the latest
checkpoint).  ``save_async`` snapshots to host memory synchronously (so the
training step can mutate buffers) and writes on a background thread.

Elastic restore: ``restore`` takes target shardings; arrays are loaded on
host and ``jax.device_put`` against the *new* mesh, so a job resumed on a
different topology (e.g. 256 -> 512 chips) re-shards transparently
(dist/elastic.py wires this to mesh construction).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ----- write path -----
    def save(self, step: int, tree: Any, blocking: bool = True):
        flat = _flatten(tree)          # host snapshot (sync)
        if blocking:
            self._write(step, flat)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, flat), daemon=True)
            self._thread.start()

    def save_async(self, step: int, tree: Any):
        self.save(step, tree, blocking=False)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict[str, np.ndarray]):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "time": time.time(),
                       "n_arrays": len(flat)}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ----- read path -----
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, shardings: Any = None) -> Any:
        """target: pytree with the desired structure (shapes validated).
        shardings: optional matching pytree of NamedShardings (elastic
        re-shard onto a possibly different mesh)."""
        path = os.path.join(self.dir, f"step_{step:08d}", "arrays.npz")
        data = np.load(path)
        paths, treedef = jax.tree_util.tree_flatten_with_path(target)
        leaves = []
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(paths))
        for (path_e, leaf), sh in zip(paths, shard_leaves):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path_e)
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"ckpt shape mismatch at {key}: "
                                 f"{arr.shape} vs {leaf.shape}")
            arr = arr.astype(leaf.dtype)
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)
