"""Distributed substrate: logical-axis sharding rules, gradient
compression, and elastic checkpoint resume.

  sharding     -- logical axis names -> PartitionSpecs / NamedShardings
  compression  -- int8 fake-quantisation + compressed DP all-reduce
  elastic      -- restore a checkpoint onto a (possibly different) mesh
"""
