"""Distributed substrate: logical-axis sharding rules, array meshes,
gradient compression, and elastic checkpoint resume.

Serves both worlds that need a scale-out axis:

  sharding     -- logical axis names -> PartitionSpecs / NamedShardings
                  (the models/ world) and the GEMM-rank axis policy the
                  Program spine's ``shard_program`` uses
  mesh         -- ArrayMesh: N logical FEATHER+ arrays, optionally backed
                  by JAX devices (shard_map execution on the Pallas
                  backend, per-array accounting everywhere)
  compression  -- int8 fake-quantisation + compressed DP all-reduce
  elastic      -- restore a checkpoint onto a (possibly different) mesh
"""

from repro.dist.mesh import ArrayMesh, host_device_flag

__all__ = ["ArrayMesh", "host_device_flag"]
