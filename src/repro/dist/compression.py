"""Gradient compression for cross-replica sync.

``fake_quantize_int8`` is the quantise->dequantise round trip (the error
model of int8-on-the-wire without needing int8 collectives on every
backend); ``compressed_dp_allreduce`` applies it inside a shard_map so the
mean over the 'data' axis sees only quantised values -- replicas exchange
at int8 fidelity, matching what a real compressed all-reduce delivers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def fake_quantize_int8(x):
    """Per-tensor symmetric int8 quantise -> dequantise (|err| <= amax/254
    plus representation noise; exactly 0 for the zero tensor)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return (q * scale).astype(x.dtype)


def compressed_dp_allreduce(grads, mesh):
    """Quantised mean of a gradient pytree over the mesh's 'data' axis.

    Each replica quantises its local (replicated-spec) gradients to int8
    fidelity before the pmean, so the wire format is int8 while the
    result stays in the original dtype.
    """
    def sync(tree):
        return jax.tree.map(
            lambda g: jax.lax.pmean(fake_quantize_int8(g), "data"), tree)

    return shard_map(sync, mesh=mesh, in_specs=P(), out_specs=P())(grads)
