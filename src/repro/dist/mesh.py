"""ArrayMesh: N logical FEATHER+ arrays as a first-class axis.

The MINISA results are per-array; production serving runs many arrays.
An :class:`ArrayMesh` names that scale-out dimension for everything a
``Program`` flows through:

  * ``core/program.shard_program`` splits a lowered Program's tile space
    into one sub-Program per array (axis policy from ``dist/sharding``);
  * ``backends`` execute the shards -- the interpreter drives one
    functional machine per array, the Pallas backend wraps its
    ``pallas_call`` in a ``shard_map`` over :meth:`jax_mesh` when enough
    JAX devices back the logical arrays;
  * the runtime (``ProgramCache`` keys, ``ModelExecutable``,
    ``Scheduler``) carries the mesh shape so per-array traffic, stall and
    load-imbalance numbers are reported everywhere.

Logical vs physical: an ArrayMesh is meaningful without JAX devices --
per-array accounting and the interpreter's per-shard execution only need
the *logical* count.  :meth:`jax_mesh` returns a real device mesh when
one is available and ``None`` otherwise, and callers degrade to
sequential per-shard execution (identical numerics).  For CPU CI, export

    XLA_FLAGS=--xla_force_host_platform_device_count=8

*before* the first JAX import to back an 8-array mesh with fake host
devices (see :func:`host_device_flag`).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArrayMesh:
    """N logical FEATHER+ arrays, optionally backed by JAX devices."""

    n_arrays: int = 1
    axis_name: str = "array"

    def __post_init__(self):
        if self.n_arrays < 1:
            raise ValueError(f"n_arrays must be >= 1, got {self.n_arrays}")

    @property
    def shape(self) -> tuple[int, ...]:
        return (self.n_arrays,)

    def jax_mesh(self):
        """A 1-D ``jax.sharding.Mesh`` over ``n_arrays`` devices, or
        ``None`` when this host has fewer devices than logical arrays
        (callers fall back to sequential per-shard execution)."""
        if self.n_arrays < 2:
            return None
        import jax

        if len(jax.devices()) < self.n_arrays:
            return None
        return jax.make_mesh((self.n_arrays,), (self.axis_name,))

    def degraded(self, n_down: int = 1) -> "ArrayMesh":
        """The mesh that survives ``n_down`` arrays going unhealthy --
        the failover target the scheduler re-lowers onto (never below
        one array: a fully-degraded mesh serves unsharded)."""
        return ArrayMesh(n_arrays=max(1, self.n_arrays - max(0, n_down)),
                         axis_name=self.axis_name)

    @classmethod
    def host(cls) -> "ArrayMesh":
        """One logical array per visible JAX device."""
        import jax

        return cls(n_arrays=len(jax.devices()))

    def __repr__(self) -> str:
        return f"ArrayMesh(n_arrays={self.n_arrays})"


def host_device_flag(n: int) -> str:
    """The ``XLA_FLAGS`` fragment that fakes ``n`` host CPU devices.

    Must be in the environment before the first JAX import; returned as a
    string (not applied) because setting it after ``jax`` initialises is a
    silent no-op."""
    return f"--xla_force_host_platform_device_count={n}"
