"""Elastic resume: restore the latest checkpoint onto explicit (possibly
different-topology) shardings, plus serving-state snapshots.

The checkpoint stores plain host arrays (ckpt.manager); re-sharding is a
``jax.device_put`` against the *new* mesh's NamedShardings, so a job can
resume on a different chip count without a conversion step.

The serving half (``save_serving_snapshot``/``load_serving_snapshot``)
persists a :class:`~repro.runtime.scheduler.Scheduler`'s request state --
pending + retired, the deterministic subset (in-flight requests replay
from their seeds) -- so a chaos-killed serve resumes and finishes with
checksums identical to the uninterrupted run (``tests/test_faults.py``
regresses exactly that).
"""

from __future__ import annotations

import os
import pickle
import tempfile

import jax


def resume(manager, abstract_tree, shardings):
    """(restored_tree | None, start_step).

    ``abstract_tree``: pytree of ShapeDtypeStructs (or arrays) giving the
    expected structure/shapes; ``shardings``: matching pytree of
    NamedShardings, or None to keep the restore on host-default devices.
    Returns (None, 0) when the directory holds no checkpoint.
    """
    step = manager.latest_step()
    if step is None:
        return None, 0
    restored = manager.restore(step, abstract_tree)
    if shardings is not None:
        restored = jax.tree.map(jax.device_put, restored, shardings)
    return restored, step


def save_serving_snapshot(path: str | os.PathLike, snapshot: dict) -> str:
    """Atomically persist a ``Scheduler.snapshot()`` dict (unique temp
    file in the destination directory, fsync, ``os.replace``) -- a kill
    mid-save leaves the previous snapshot intact, never a torn file."""
    path = os.fspath(path)
    dirname = os.path.dirname(path) or "."
    fd, tmp = tempfile.mkstemp(dir=dirname,
                               prefix=os.path.basename(path) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            pickle.dump(snapshot, f, protocol=pickle.HIGHEST_PROTOCOL)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path


def load_serving_snapshot(path: str | os.PathLike) -> dict | None:
    """The persisted snapshot dict, or None when the file is missing or
    unreadable (a torn/corrupt snapshot means a cold start, not a
    crash)."""
    path = os.fspath(path)
    if not os.path.exists(path):
        return None
    try:
        with open(path, "rb") as f:
            snap = pickle.load(f)
        return snap if isinstance(snap, dict) else None
    except (pickle.PickleError, EOFError, AttributeError, ImportError,
            IndexError, OSError):
        return None
