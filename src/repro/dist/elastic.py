"""Elastic resume: restore the latest checkpoint onto explicit (possibly
different-topology) shardings.

The checkpoint stores plain host arrays (ckpt.manager); re-sharding is a
``jax.device_put`` against the *new* mesh's NamedShardings, so a job can
resume on a different chip count without a conversion step.
"""

from __future__ import annotations

import jax


def resume(manager, abstract_tree, shardings):
    """(restored_tree | None, start_step).

    ``abstract_tree``: pytree of ShapeDtypeStructs (or arrays) giving the
    expected structure/shapes; ``shardings``: matching pytree of
    NamedShardings, or None to keep the restore on host-default devices.
    Returns (None, 0) when the directory holds no checkpoint.
    """
    step = manager.latest_step()
    if step is None:
        return None, 0
    restored = manager.restore(step, abstract_tree)
    if shardings is not None:
        restored = jax.tree.map(jax.device_put, restored, shardings)
    return restored, step
