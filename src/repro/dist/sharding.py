"""Logical-axis sharding rules (GSPMD partitioning policy).

Every parameter / activation names its dims with *logical* axes (see
``models.common.Maker``); this module maps those names onto mesh axes:

  train (RULES):
    batch            -> all pure-data axes, jointly: ('pod', 'data')
    heads/kv_heads,
    ffn/expert_ffn,
    vocab, ssm_inner -> 'model'   (tensor parallelism)
    embed            -> 'data'    (FSDP: shard weights over data, gather
                                   at use)
    kvseq/seq        -> 'model'   (sequence fallback when the preferred
                                   TP axis is taken or indivisible, e.g.
                                   kv_heads % model_size != 0)
    experts          -> unsharded (TP-inside-expert policy: each expert's
                                   ffn dim is TP-sharded instead, keeping
                                   dispatch/combine row-local)

  inference (INFERENCE_RULES): identical minus the FSDP entry -- serving
  replicates weights over 'data' (no gather-at-use on the decode path).

An axis is only assigned when the dim is divisible by the mesh axis size
and the mesh axis is not already used by another dim of the same tensor.
"""

from __future__ import annotations

import math

import jax
from jax.sharding import AbstractMesh, NamedSharding, PartitionSpec as P

# name -> (priority, candidate mesh axes).  Lower priority wins contended
# mesh axes; candidates are tried in order; tuple candidates are joint
# (multi-axis) shardings.
RULES: dict[str, tuple[int, tuple]] = {
    "batch":      (0, (("pod", "data"),)),
    "kv_heads":   (1, ("model",)),
    "heads":      (1, ("model",)),
    "vocab":      (1, ("model",)),
    "ffn":        (1, ("model",)),
    "expert_ffn": (1, ("model",)),
    "ssm_inner":  (1, ("model",)),
    "embed":      (2, ("data",)),
    "kvseq":      (3, ("model",)),
    "seq":        (3, ("model",)),
}

#: Serving drops FSDP: weight-bearing 'embed' dims replicate over 'data'.
INFERENCE_RULES: dict[str, tuple[int, tuple]] = {
    k: v for k, v in RULES.items() if k != "embed"
}

_DATA_AXES = ("pod", "data")

# ---------------------------------------------------------------------------
# Program-spine axis policy (GEMM rank -> array-mesh axis)
# ---------------------------------------------------------------------------

#: Which GEMM rank to split across an array mesh, in preference order.
#: This is the model-world policy above projected onto the one contraction
#: every lowered Program is: N is the weight's free rank (ffn / heads /
#: vocab -> 'model', i.e. tensor parallelism -- each array holds a weight
#: column slice), M is the streamed token rank (batch -> data
#: parallelism), and K is the contraction (splittable only with a
#: reduction epilogue, so it is the last resort).
GEMM_AXIS_RULES: tuple[str, ...] = ("n", "m", "k")


def gemm_shard_axis(m: int, k: int, n: int, n_arrays: int,
                    tiles: dict[str, int] | None = None,
                    rules: tuple[str, ...] = GEMM_AXIS_RULES) -> str:
    """Pick the host GEMM rank ('m' | 'n' | 'k') to split over
    ``n_arrays`` arrays.

    ``tiles`` optionally gives the lowered Program's tile count along
    each host rank.  Splitting a rank the tile loop barely iterates
    (e.g. N when the whole N extent fits one tile) *replicates* the
    other ranks' instruction and load traffic on every array instead of
    partitioning it, so ranks with at least ``n_arrays`` tiles are
    preferred -- that is what keeps per-array MINISA traffic summing to
    the single-array total.  Within the surviving candidates the policy
    mirrors :func:`spec_for`'s divisibility discipline: an exactly
    divisible rank first, then any rank wide enough to occupy every
    array, then the widest rank."""
    if n_arrays < 2:
        return rules[0]
    dims = {"m": m, "k": k, "n": n}
    order = list(rules)
    if tiles is not None:
        partitioning = [ax for ax in order
                        if tiles.get(ax, 0) >= n_arrays]
        if partitioning:
            order = partitioning
        elif max(tiles.values(), default=0) > 1:
            # no rank has a tile per array: the most-tiled rank still
            # partitions the largest share of the instruction stream
            # (ties resolve in rules order)
            best = max(tiles.values())
            order = [ax for ax in order if tiles.get(ax, 0) == best]
    for ax in order:
        if dims[ax] >= n_arrays and dims[ax] % n_arrays == 0:
            return ax
    for ax in order:
        if dims[ax] >= n_arrays:
            return ax
    return max(order, key=lambda ax: dims[ax])


def abstract_mesh(axis_sizes: tuple[int, ...],
                  axis_names: tuple[str, ...]) -> AbstractMesh:
    """Version-portable AbstractMesh constructor (the signature changed
    from ``(shape_tuple)`` to ``(axis_sizes, axis_names)`` across jax
    releases)."""
    try:
        return AbstractMesh(axis_sizes, axis_names)
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def _mesh_sizes(mesh) -> dict[str, int]:
    return {str(k): int(v) for k, v in dict(mesh.shape).items()}


def spec_for(axes: tuple[str, ...], shape: tuple[int, ...], mesh,
             rules: dict | None = None) -> P:
    """Logical axes + concrete shape -> PartitionSpec on ``mesh``."""
    rules = RULES if rules is None else rules
    sizes = _mesh_sizes(mesh)
    assign: list = [None] * len(axes)
    used: set[str] = set()
    order = sorted(range(len(axes)),
                   key=lambda i: (rules[axes[i]][0]
                                  if axes[i] in rules else 99, i))
    for i in order:
        name = axes[i]
        if name not in rules:
            continue
        for cand in rules[name][1]:
            cand = (cand,) if isinstance(cand, str) else tuple(cand)
            present = tuple(a for a in cand if a in sizes and a not in used)
            if not present:
                continue
            total = math.prod(sizes[a] for a in present)
            if total <= 0 or shape[i] % total:
                continue
            assign[i] = present[0] if len(present) == 1 else present
            used.update(present)
            break
    while assign and assign[-1] is None:
        assign.pop()
    return P(*assign)


# ---------------------------------------------------------------------------
# Pytree helpers (params / optimizer / batch shardings)
# ---------------------------------------------------------------------------

def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(a, str) for a in x)


def tree_shardings(axes_tree, shapes_tree, mesh, inference: bool = False):
    """Matching pytrees of logical axes + abstract shapes -> NamedShardings.

    The two trees come from running the same model-definition code in
    ``axes`` and ``eval_shape`` mode, so they are leaf-for-leaf aligned.
    """
    rules = INFERENCE_RULES if inference else RULES
    axes_leaves = jax.tree_util.tree_flatten(
        axes_tree, is_leaf=_is_axes_leaf)[0]
    shape_leaves, sdef = jax.tree_util.tree_flatten(shapes_tree)
    if len(axes_leaves) != len(shape_leaves):
        raise ValueError(
            f"axes/shape trees disagree: {len(axes_leaves)} vs "
            f"{len(shape_leaves)} leaves")
    out = [NamedSharding(mesh, spec_for(a, tuple(s.shape), mesh, rules))
           for a, s in zip(axes_leaves, shape_leaves)]
    return jax.tree_util.tree_unflatten(sdef, out)


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def _batch_spec(shape: tuple[int, ...], sizes: dict[str, int]) -> P:
    axes = tuple(a for a in _DATA_AXES if a in sizes)
    if not shape or not axes:
        return P()
    total = math.prod(sizes[a] for a in axes)
    if shape[0] % max(total, 1):
        return P()
    return P(axes if len(axes) > 1 else axes[0])


def batch_sharding(mesh, batch_spec):
    """Batch pytree (arrays or ShapeDtypeStructs) -> NamedShardings that
    shard the leading (batch) dim over the pure-data axes."""
    sizes = _mesh_sizes(mesh)
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh,
                                   _batch_spec(tuple(leaf.shape), sizes)),
        batch_spec)


# ---------------------------------------------------------------------------
# In-graph constraints (no-ops outside a mesh context)
# ---------------------------------------------------------------------------

def _current_mesh():
    try:
        from jax.interpreters import pxla
        m = pxla.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:  # noqa: BLE001 - mesh plumbing varies across versions
        pass
    return None


def _constrain(x, spec_fn):
    """Apply with_sharding_constraint(x, spec_fn(sizes)) under the ambient
    mesh; identity when no mesh is active (single-process smoke tests)."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    spec = spec_fn(_mesh_sizes(mesh))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_batch(x, extra: tuple = ()):
    """Anchor dim 0 to the data axes; ``extra`` names trailing dims after
    the batch dim ('' / None = unsharded)."""
    def spec_fn(sizes):
        entries = [_batch_spec(tuple(x.shape), sizes)[0]
                   if _batch_spec(tuple(x.shape), sizes) else None]
        for i, name in enumerate(extra):
            dim = 1 + i
            if (name and name in sizes and dim < x.ndim
                    and x.shape[dim] % sizes[name] == 0):
                entries.append(name)
            else:
                entries.append(None)
        return P(*entries)
    return _constrain(x, spec_fn)


def constrain_seq_scores(scores):
    """Attention-score anchor: batch over data, KV-sequence (last dim)
    over 'model' (decode-path sequence parallelism)."""
    def spec_fn(sizes):
        entries: list = [None] * scores.ndim
        bspec = _batch_spec(tuple(scores.shape), sizes)
        if bspec:
            entries[0] = bspec[0]
        if ("model" in sizes and scores.ndim > 1
                and scores.shape[-1] % sizes["model"] == 0):
            entries[-1] = "model"
        return P(*entries)
    return _constrain(scores, spec_fn)


def constrain_rows_model(table):
    """Anchor a (rows, feature) table to rows-sharded / feature-replicated
    before contractions (vocab-parallel embedding gather, §Perf iter 2)."""
    def spec_fn(sizes):
        if "model" in sizes and table.shape[0] % sizes["model"] == 0:
            return P("model")
        return P()
    return _constrain(table, spec_fn)
