"""Analytical performance model: the paper's "cycle-accurate analytical
model with a 5-engine asynchronous execution simulator" (paper §VI-A,
appendix).

Engines (inferred from Fig. 13's breakdown components):

  IFETCH      -- off-chip instruction interface, cfg.instr_bw B/cycle
  LOAD        -- off-chip input/weight loads, cfg.in_bw B/cycle
  COMPUTE     -- the NEST array (streaming + drain cycles per invocation)
  OUT2STREAM  -- OB -> streaming/stationary buffer commit (AW elems/cycle)
  STORE       -- off-chip output stores, cfg.out_bw B/cycle

Tiles execute in order.  Instruction fetch and operand loads for tile i+1
overlap with compute of tile i (double buffering); a tile's compute cannot
start until its instructions and operands have arrived, which is exactly how
instruction-fetch stalls emerge at scale (Tab. I).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.obs.trace import trace


@dataclasses.dataclass(frozen=True)
class TileCost:
    """Everything the engines need to know about one schedulable unit."""
    fetch_bytes: float = 0.0        # instruction bytes for this tile
    load_bytes: float = 0.0         # fresh off-chip operand bytes
    compute_cycles: float = 0.0     # NEST busy cycles
    out2stream_cycles: float = 0.0  # OB commit cycles (on-chip)
    store_bytes: float = 0.0        # off-chip output bytes
    macs: float = 0.0               # useful MACs (utilization numerator)


@dataclasses.dataclass(frozen=True)
class PerfResult:
    cycles: float
    macs: float
    peak_macs_per_cycle: float
    busy: dict[str, float]          # per-engine busy cycles
    stall_ifetch_frac: float        # fraction of total cycles attributable
                                    # to waiting on instruction fetch
    cycles_no_fetch: float

    @property
    def utilization(self) -> float:
        if self.cycles <= 0:
            return 0.0
        return self.macs / (self.peak_macs_per_cycle * self.cycles)

    def breakdown(self) -> dict[str, float]:
        out = dict(self.busy)
        out["total"] = self.cycles
        out["ifetch_stall"] = self.stall_ifetch_frac * self.cycles
        return out

    def publish_metrics(self, registry=None, **labels) -> None:
        """Publish the modelled cycle/stall figures into a metrics
        registry (default: the shared ``obs.metrics`` one) -- the
        paper's Tab. I fetch-stall fraction becomes the
        ``perf_stall_ifetch_frac`` gauge, labelled by the caller (e.g.
        ``control="minisa"``)."""
        from repro.obs import metrics as obs_metrics
        reg = registry if registry is not None else obs_metrics.REGISTRY
        reg.gauge("perf_cycles",
                  "modelled makespan cycles (5-engine model)").set(
                      self.cycles, **labels)
        reg.gauge("perf_stall_ifetch_frac",
                  "fraction of cycles stalled on instruction fetch "
                  "(Tab. I)").set(self.stall_ifetch_frac, **labels)
        reg.gauge("perf_utilization").set(self.utilization, **labels)
        for engine, cycles in self.busy.items():
            reg.gauge("perf_engine_busy_cycles",
                      "per-engine busy cycles").set(
                          cycles, engine=engine, **labels)


def _simulate(tiles: Sequence[TileCost], instr_bw: float, in_bw: float,
              out_bw: float, out2stream: bool = True) -> tuple[float, dict]:
    """Event-driven pass over the tile sequence; returns (makespan, busy)."""
    t_fetch = 0.0      # when the fetch engine becomes free
    t_load = 0.0
    t_compute = 0.0
    t_commit = 0.0
    t_store = 0.0
    busy = {"ifetch": 0.0, "load": 0.0, "compute": 0.0,
            "out2stream": 0.0, "store": 0.0}
    for tile in tiles:
        fetch_time = tile.fetch_bytes / instr_bw if instr_bw > 0 else 0.0
        load_time = tile.load_bytes / in_bw if in_bw > 0 else 0.0
        # fetch + load proceed independently and may prefetch ahead
        t_fetch = t_fetch + fetch_time
        t_load = t_load + load_time
        busy["ifetch"] += fetch_time
        busy["load"] += load_time
        start = max(t_compute, t_fetch, t_load)
        t_compute = start + tile.compute_cycles
        busy["compute"] += tile.compute_cycles
        if out2stream and tile.out2stream_cycles:
            t_commit = max(t_commit, t_compute) + tile.out2stream_cycles
            busy["out2stream"] += tile.out2stream_cycles
        if tile.store_bytes:
            store_time = tile.store_bytes / out_bw if out_bw > 0 else 0.0
            t_store = max(t_store, max(t_commit, t_compute)) + store_time
            busy["store"] += store_time
    makespan = max(t_compute, t_commit, t_store, t_fetch, t_load)
    return makespan, busy


def hbm_traffic(tiles: Sequence[TileCost]) -> dict[str, float]:
    """Off-chip byte totals of a tile stream (the quantities fused-segment
    execution elides: a chained commit moves store bytes into out2stream
    cycles, an elided input Load vanishes -- see Program.tile_costs)."""
    return {
        "load_bytes": sum(t.load_bytes for t in tiles),
        "store_bytes": sum(t.store_bytes for t in tiles),
        "fetch_bytes": sum(t.fetch_bytes for t in tiles),
        "data_bytes": sum(t.load_bytes + t.store_bytes for t in tiles),
    }


def simulate(tiles: Sequence[TileCost], cfg) -> PerfResult:
    """cfg: FeatherConfig."""
    with trace.span("perf.simulate", n_tiles=len(tiles)):
        return _simulate_result(tiles, cfg)


def _simulate_result(tiles: Sequence[TileCost], cfg) -> PerfResult:
    total, busy = _simulate(tiles, cfg.instr_bw, cfg.in_bw, cfg.out_bw)
    # Counterfactual run with free instruction delivery isolates the
    # fetch-stall share (the paper's "explicit stall of fetching
    # instructions", Tab. I).
    no_fetch, _ = _simulate(tiles, float("inf"), cfg.in_bw, cfg.out_bw)
    macs = sum(t.macs for t in tiles)
    stall = 0.0 if total <= 0 else max(0.0, (total - no_fetch) / total)
    return PerfResult(cycles=total, macs=macs,
                      peak_macs_per_cycle=cfg.peak_macs_per_cycle,
                      busy=busy, stall_ifetch_frac=stall,
                      cycles_no_fetch=no_fetch)


# ---------------------------------------------------------------------------
# Multi-array (mesh) view: one engine simulation per array
# ---------------------------------------------------------------------------

def load_imbalance(per_array_values) -> float:
    """Max-over-mean across the arrays that did any work (1.0 = perfectly
    balanced or idle) -- the one imbalance definition every mesh report
    shares."""
    active = [v for v in per_array_values if v > 0]
    if not active:
        return 1.0
    return max(active) / (sum(active) / len(active))


@dataclasses.dataclass(frozen=True)
class MeshPerfResult:
    """Per-array PerfResults of a ShardedProgram, arrays run in parallel.

    Makespan is the slowest array (plus the reduction epilogue for
    K-partitioned shards); traffic and MACs sum; ``load_imbalance`` is
    max-over-mean busy cycles across the arrays that did any work.
    """
    per_array: tuple[PerfResult, ...]
    reduce_cycles: float = 0.0      # K-split epilogue (psum over arrays)

    @property
    def cycles(self) -> float:
        busiest = max((r.cycles for r in self.per_array), default=0.0)
        return busiest + self.reduce_cycles

    @property
    def macs(self) -> float:
        return sum(r.macs for r in self.per_array)

    @property
    def stall_ifetch_frac(self) -> float:
        total = sum(r.cycles for r in self.per_array)
        if total <= 0:
            return 0.0
        return sum(r.stall_ifetch_frac * r.cycles
                   for r in self.per_array) / total

    @property
    def load_imbalance(self) -> float:
        return load_imbalance([r.cycles for r in self.per_array])

    @property
    def utilization(self) -> float:
        if self.cycles <= 0 or not self.per_array:
            return 0.0
        peak = self.per_array[0].peak_macs_per_cycle * len(self.per_array)
        return self.macs / (peak * self.cycles)


def simulate_sharded(sharded, cfg, control: str = "minisa"
                     ) -> MeshPerfResult:
    """Run the 5-engine model independently per array of a
    :class:`~repro.core.program.ShardedProgram` (each array has its own
    fetch/load/compute/store engines; they share nothing but the host).

    The K-split reduction epilogue is modelled as one pass over the
    output at the commit rate (AW elements/cycle) per combining array --
    the same cost shape as out2stream.
    """
    results = [simulate(costs, cfg)
               for costs in sharded.per_array_tile_costs(control)]
    reduce_cycles = 0.0
    if sharded.reduce and sharded.n_shards > 1:
        g = sharded.base.gemm
        reduce_cycles = (sharded.n_shards - 1) * (g.m * g.n) / cfg.aw
    return MeshPerfResult(per_array=tuple(results),
                          reduce_cycles=reduce_cycles)
