"""MINISA instruction set (paper §IV, Tab. II, Fig. 3, Fig. 5).

Eight instructions:

  SetIVNLayout / SetWVNLayout / SetOVNLayout  -- on-chip VN layouts
  ExecuteMapping                              -- stationary-VN placement
  ExecuteStreaming                            -- streaming schedule + dataflow
  Load / Write                                -- off-chip <-> buffer movement
  Activation                                  -- on-buffer activation function

Every instruction declares its encoding once, as a field ``spec``:
``(name, width(cfg), bias)`` triples.  Bitwidths (the instruction-traffic
numbers of Fig. 12 are sums of these), ``encode`` packing and ``decode``
unpacking are all derived from the same spec, so pack/unpack round-trips
never re-derive field widths by hand.
"""

from __future__ import annotations

import dataclasses
import enum
import functools
from typing import Callable, Iterable

from repro.configs.feather import FeatherConfig, _clog2


class Opcode(enum.IntEnum):
    SET_WVN_LAYOUT = 0b000
    SET_IVN_LAYOUT = 0b001
    SET_OVN_LAYOUT = 0b010
    EXECUTE_STREAMING = 0b011
    WRITE = 0b100
    LOAD = 0b101
    ACTIVATION = 0b110
    EXECUTE_MAPPING = 0b111


class Dataflow(enum.IntEnum):
    IOS = 0  # Input-Output stationary: inputs pinned in PEs, weights stream
    WOS = 1  # Weight-Output stationary: weights pinned, inputs stream


class BufferTarget(enum.IntEnum):
    STATIONARY = 0
    STREAMING = 1


# ---------------------------------------------------------------------------
# Field packing helpers
# ---------------------------------------------------------------------------

def _pack(fields: Iterable[tuple[int, int]]) -> int:
    """Pack (value, width) pairs MSB-first into one integer."""
    word = 0
    for value, width in fields:
        if value < 0 or (width < 64 and value >= (1 << width)):
            raise ValueError(f"field value {value} does not fit in {width} bits")
        word = (word << width) | value
    return word


# Fields holding enums: decoded raw ints are cast back through these.
_FIELD_CASTS: dict[str, Callable[[int], object]] = {
    "df": Dataflow,
    "target": BufferTarget,
}


@dataclasses.dataclass(frozen=True)
class Instruction:
    """Base class: subclasses implement spec(cfg) -> [(name, width, bias)].

    ``name`` is the dataclass field holding the value ("opcode" is implicit);
    ``bias`` is subtracted on encode and re-added on decode (the ISA stores
    1-based counts like G_r as value-1).
    """

    opcode: Opcode = dataclasses.field(init=False, default=None, repr=False)

    @classmethod
    def spec(cls, cfg: FeatherConfig) -> list[tuple[str, int, int]]:
        raise NotImplementedError

    def fields(self, cfg: FeatherConfig) -> list[tuple[int, int]]:
        out = []
        for name, width, bias in self.spec(cfg):
            if name == "opcode":
                out.append((int(self.opcode), width))
            else:
                out.append((max(int(getattr(self, name)) - bias, 0), width))
        return out

    def bitwidth(self, cfg: FeatherConfig) -> int:
        # field widths depend only on (class, cfg), never on field values
        return class_bitwidth(type(self), cfg)

    def encode(self, cfg: FeatherConfig) -> int:
        return _pack(self.fields(cfg))

    @classmethod
    def decode(cls, word: int, cfg: FeatherConfig) -> "Instruction":
        """Inverse of encode (exact for in-range field values)."""
        spec = cls.spec(cfg)
        pos = sum(w for _, w, _ in spec)
        kwargs = {}
        for name, width, bias in spec:
            pos -= width
            raw = (word >> pos) & ((1 << width) - 1)
            if name == "opcode":
                if raw != int(cls.opcode):
                    raise ValueError(
                        f"opcode mismatch: got {raw:#b}, "
                        f"expected {int(cls.opcode):#b} ({cls.__name__})")
                continue
            value = raw + bias
            kwargs[name] = _FIELD_CASTS.get(name, int)(value)
        return cls(**kwargs)

    @property
    def is_execute(self) -> bool:
        return False


@functools.lru_cache(maxsize=None)
def class_bitwidth(cls: type, cfg: FeatherConfig) -> int:
    """Encoded width of any instance of ``cls`` under ``cfg``."""
    return sum(w for _, w, _ in cls.spec(cfg))


def decode(word: int, nbits: int, cfg: FeatherConfig) -> Instruction:
    """Decode a packed word of known total width (the opcode occupies the
    top 3 bits; leading zeros make the width part of the wire format)."""
    opcode = Opcode((word >> (nbits - 3)) & 0b111)
    return OPCODE_TO_CLASS[opcode].decode(word, cfg)


# ---------------------------------------------------------------------------
# Layout instructions (Fig. 5).  A layout is (order permutation of the three
# free post-VN ranks) + (level-0 / level-1 partition factors).  The innermost
# reduction-rank factor is pinned at VN size and therefore not encoded.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SetLayoutBase(Instruction):
    order: int = 0        # permutation id in [0, 5] (Tab. III)
    nr_l0: int = 1        # level-0 factor of the non-reduction rank (<= AW)
    nr_l1: int = 1        # level-1 factor of the non-reduction rank
    red_l1: int = 1       # level-1 factor of the reduction rank (K_L1 etc.)

    @classmethod
    def spec(cls, cfg: FeatherConfig) -> list[tuple[str, int, int]]:
        slots = cfg.vn_slots_per_col
        return [
            ("opcode", 3, 0),
            ("order", 3, 0),
            ("nr_l0", _clog2(cfg.aw), 1),
            ("nr_l1", _clog2(slots), 1),
            ("red_l1", _clog2(slots), 1),
        ]

    @property
    def num_vns(self) -> int:
        return self.nr_l0 * self.nr_l1 * self.red_l1


@dataclasses.dataclass(frozen=True)
class SetWVNLayout(SetLayoutBase):
    """Weight VNs: ranks {K_L1, N_L0, N_L1}, K_L0 == VN size."""
    opcode = Opcode.SET_WVN_LAYOUT


@dataclasses.dataclass(frozen=True)
class SetIVNLayout(SetLayoutBase):
    """Input VNs: ranks {J_L1, M_L0, M_L1}, J_L0 == VN size."""
    opcode = Opcode.SET_IVN_LAYOUT


@dataclasses.dataclass(frozen=True)
class SetOVNLayout(SetLayoutBase):
    """Output VNs: ranks {Q_L1, P_L0, P_L1}; also zero-initialises the OB
    tile and, at tile end, commits OB -> streaming/stationary buffer."""
    opcode = Opcode.SET_OVN_LAYOUT


# ---------------------------------------------------------------------------
# Execute instructions (Fig. 3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExecuteMapping(Instruction):
    """Place stationary VN(r, c) onto PE(a_h, a_w):

        r = r0 + floor(a_w / G_r)
        c = c0 + s_r * a_h + s_c * (a_w mod G_c)

    (paper Eq. 1).  Out-of-bounds (r, c) are implicitly zero-padded.
    """
    opcode = Opcode.EXECUTE_MAPPING
    r0: int = 0
    c0: int = 0
    g_r: int = 1
    g_c: int = 1
    s_r: int = 0
    s_c: int = 0

    @classmethod
    def spec(cls, cfg: FeatherConfig) -> list[tuple[str, int, int]]:
        slots_col = cfg.vn_slots_per_col
        slots_tot = cfg.vn_slots_total
        return [
            ("opcode", 3, 0),
            ("g_r", _clog2(cfg.aw), 1),
            ("g_c", _clog2(cfg.aw), 1),
            ("r0", _clog2(slots_tot), 0),
            ("c0", _clog2(slots_tot), 0),
            ("s_r", _clog2(slots_col), 0),
            ("s_c", _clog2(slots_col), 0),
        ]

    @property
    def is_execute(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class ExecuteStreaming(Instruction):
    """Stream T VNs into each PE column; VN(m, j) entering column a_w at
    step t in [0, T):

        j = r0 + floor(a_w / G_r)
        m = m0 + s_m * t + floor((a_w mod G_r) / G_c)

    reusing the paired ExecuteMapping's (r0, G_r, G_c).  ``df`` swaps the
    dataflow between IO-S and WO-S; VN_size <= AH.
    """
    opcode = Opcode.EXECUTE_STREAMING
    m0: int = 0
    s_m: int = 1
    t: int = 1            # number of streamed VNs per column
    vn_size: int = 1
    df: Dataflow = Dataflow.WOS

    @classmethod
    def spec(cls, cfg: FeatherConfig) -> list[tuple[str, int, int]]:
        w = _clog2(cfg.vn_slots_per_col)
        return [
            ("opcode", 3, 0),
            ("df", 1, 0),
            ("m0", w, 0),
            ("s_m", w, 1),
            ("t", w, 1),
            ("vn_size", _clog2(cfg.ah), 1),
        ]

    @property
    def is_execute(self) -> bool:
        return True


# ---------------------------------------------------------------------------
# Memory movement + activation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MemAccess(Instruction):
    """Shared encoding of off-chip <-> buffer movement (Load and Write have
    identical field layouts; only the opcode differs)."""
    hbm_addr: int = 0
    length: int = 0          # elements
    target: BufferTarget = BufferTarget.STREAMING

    @classmethod
    def spec(cls, cfg: FeatherConfig) -> list[tuple[str, int, int]]:
        return [
            ("opcode", 3, 0),
            ("hbm_addr", 33, 0),
            ("length", _clog2(cfg.d_elems * cfg.aw) + 1, 0),
            ("target", 1, 0),
        ]


@dataclasses.dataclass(frozen=True)
class Load(MemAccess):
    opcode = Opcode.LOAD


@dataclasses.dataclass(frozen=True)
class Write(MemAccess):
    opcode = Opcode.WRITE


@dataclasses.dataclass(frozen=True)
class Activation(Instruction):
    """On-buffer activation (relu/gelu/silu/softmax-lut/none)."""
    opcode = Opcode.ACTIVATION
    function: int = 0
    length: int = 0
    target: BufferTarget = BufferTarget.STREAMING

    @classmethod
    def spec(cls, cfg: FeatherConfig) -> list[tuple[str, int, int]]:
        return [
            ("opcode", 3, 0),
            ("function", 4, 0),
            ("target", 1, 0),
            ("length", _clog2(cfg.d_elems * cfg.aw) + 1, 0),
        ]


ACTIVATION_FUNCS = {"none": 0, "relu": 1, "gelu": 2, "silu": 3,
                    "softmax": 4, "rmsnorm": 5, "layernorm": 6, "geglu": 7,
                    "swiglu": 8}

OPCODE_TO_CLASS: dict[Opcode, type[Instruction]] = {
    Opcode.SET_WVN_LAYOUT: SetWVNLayout,
    Opcode.SET_IVN_LAYOUT: SetIVNLayout,
    Opcode.SET_OVN_LAYOUT: SetOVNLayout,
    Opcode.EXECUTE_MAPPING: ExecuteMapping,
    Opcode.EXECUTE_STREAMING: ExecuteStreaming,
    Opcode.LOAD: Load,
    Opcode.WRITE: Write,
    Opcode.ACTIVATION: Activation,
}


# ---------------------------------------------------------------------------
# Trace-level accounting
# ---------------------------------------------------------------------------

def trace_bits(trace: Iterable[Instruction], cfg: FeatherConfig) -> int:
    return sum(inst.bitwidth(cfg) for inst in trace)


def trace_bytes(trace: Iterable[Instruction], cfg: FeatherConfig) -> float:
    return trace_bits(trace, cfg) / 8.0


def trace_summary(trace: Iterable[Instruction], cfg: FeatherConfig) -> dict:
    counts: dict[str, int] = {}
    bits = 0
    for inst in trace:
        name = type(inst).__name__
        counts[name] = counts.get(name, 0) + 1
        bits += inst.bitwidth(cfg)
    return {"counts": counts, "bits": bits, "bytes": bits / 8.0,
            "n_instructions": sum(counts.values())}
