"""MINISA instruction set (paper §IV, Tab. II, Fig. 3, Fig. 5).

Eight instructions:

  SetIVNLayout / SetWVNLayout / SetOVNLayout  -- on-chip VN layouts
  ExecuteMapping                              -- stationary-VN placement
  ExecuteStreaming                            -- streaming schedule + dataflow
  Load / Write                                -- off-chip <-> buffer movement
  Activation                                  -- on-buffer activation function

Every instruction knows its encoded bitwidth for a given FeatherConfig
(the instruction-traffic numbers of Fig. 12 are sums of these) and can be
packed to / unpacked from an integer for round-trip tests.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Iterable

from repro.configs.feather import FeatherConfig, _clog2


class Opcode(enum.IntEnum):
    SET_WVN_LAYOUT = 0b000
    SET_IVN_LAYOUT = 0b001
    SET_OVN_LAYOUT = 0b010
    EXECUTE_STREAMING = 0b011
    WRITE = 0b100
    LOAD = 0b101
    ACTIVATION = 0b110
    EXECUTE_MAPPING = 0b111


class Dataflow(enum.IntEnum):
    IOS = 0  # Input-Output stationary: inputs pinned in PEs, weights stream
    WOS = 1  # Weight-Output stationary: weights pinned, inputs stream


class BufferTarget(enum.IntEnum):
    STATIONARY = 0
    STREAMING = 1


# ---------------------------------------------------------------------------
# Field packing helpers
# ---------------------------------------------------------------------------

def _pack(fields: Iterable[tuple[int, int]]) -> int:
    """Pack (value, width) pairs MSB-first into one integer."""
    word = 0
    for value, width in fields:
        if value < 0 or (width < 64 and value >= (1 << width)):
            raise ValueError(f"field value {value} does not fit in {width} bits")
        word = (word << width) | value
    return word


@dataclasses.dataclass(frozen=True)
class Instruction:
    """Base class: subclasses implement fields(cfg) -> [(value, width), ...]."""

    opcode: Opcode = dataclasses.field(init=False, default=None, repr=False)

    def fields(self, cfg: FeatherConfig) -> list[tuple[int, int]]:
        raise NotImplementedError

    def bitwidth(self, cfg: FeatherConfig) -> int:
        return sum(w for _, w in self.fields(cfg))

    def encode(self, cfg: FeatherConfig) -> int:
        return _pack(self.fields(cfg))

    @property
    def is_execute(self) -> bool:
        return False


# ---------------------------------------------------------------------------
# Layout instructions (Fig. 5).  A layout is (order permutation of the three
# free post-VN ranks) + (level-0 / level-1 partition factors).  The innermost
# reduction-rank factor is pinned at VN size and therefore not encoded.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SetLayoutBase(Instruction):
    order: int = 0        # permutation id in [0, 5] (Tab. III)
    nr_l0: int = 1        # level-0 factor of the non-reduction rank (<= AW)
    nr_l1: int = 1        # level-1 factor of the non-reduction rank
    red_l1: int = 1       # level-1 factor of the reduction rank (K_L1 etc.)

    def fields(self, cfg: FeatherConfig) -> list[tuple[int, int]]:
        slots = cfg.vn_slots_per_col
        return [
            (int(self.opcode), 3),
            (self.order, 3),
            (max(self.nr_l0 - 1, 0), _clog2(cfg.aw)),
            (max(self.nr_l1 - 1, 0), _clog2(slots)),
            (max(self.red_l1 - 1, 0), _clog2(slots)),
        ]

    @property
    def num_vns(self) -> int:
        return self.nr_l0 * self.nr_l1 * self.red_l1


@dataclasses.dataclass(frozen=True)
class SetWVNLayout(SetLayoutBase):
    """Weight VNs: ranks {K_L1, N_L0, N_L1}, K_L0 == VN size."""
    opcode = Opcode.SET_WVN_LAYOUT


@dataclasses.dataclass(frozen=True)
class SetIVNLayout(SetLayoutBase):
    """Input VNs: ranks {J_L1, M_L0, M_L1}, J_L0 == VN size."""
    opcode = Opcode.SET_IVN_LAYOUT


@dataclasses.dataclass(frozen=True)
class SetOVNLayout(SetLayoutBase):
    """Output VNs: ranks {Q_L1, P_L0, P_L1}; also zero-initialises the OB
    tile and, at tile end, commits OB -> streaming/stationary buffer."""
    opcode = Opcode.SET_OVN_LAYOUT


# ---------------------------------------------------------------------------
# Execute instructions (Fig. 3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExecuteMapping(Instruction):
    """Place stationary VN(r, c) onto PE(a_h, a_w):

        r = r0 + floor(a_w / G_r)
        c = c0 + s_r * a_h + s_c * (a_w mod G_c)

    (paper Eq. 1).  Out-of-bounds (r, c) are implicitly zero-padded.
    """
    opcode = Opcode.EXECUTE_MAPPING
    r0: int = 0
    c0: int = 0
    g_r: int = 1
    g_c: int = 1
    s_r: int = 0
    s_c: int = 0

    def fields(self, cfg: FeatherConfig) -> list[tuple[int, int]]:
        slots_col = cfg.vn_slots_per_col
        slots_tot = cfg.vn_slots_total
        return [
            (int(self.opcode), 3),
            (max(self.g_r - 1, 0), _clog2(cfg.aw)),
            (max(self.g_c - 1, 0), _clog2(cfg.aw)),
            (self.r0, _clog2(slots_tot)),
            (self.c0, _clog2(slots_tot)),
            (self.s_r, _clog2(slots_col)),
            (self.s_c, _clog2(slots_col)),
        ]

    @property
    def is_execute(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class ExecuteStreaming(Instruction):
    """Stream T VNs into each PE column; VN(m, j) entering column a_w at
    step t in [0, T):

        j = r0 + floor(a_w / G_r)
        m = m0 + s_m * t + floor((a_w mod G_r) / G_c)

    reusing the paired ExecuteMapping's (r0, G_r, G_c).  ``df`` swaps the
    dataflow between IO-S and WO-S; VN_size <= AH.
    """
    opcode = Opcode.EXECUTE_STREAMING
    m0: int = 0
    s_m: int = 1
    t: int = 1            # number of streamed VNs per column
    vn_size: int = 1
    df: Dataflow = Dataflow.WOS

    def fields(self, cfg: FeatherConfig) -> list[tuple[int, int]]:
        slots = cfg.vn_slots_per_col
        w = _clog2(slots)
        return [
            (int(self.opcode), 3),
            (int(self.df), 1),
            (self.m0, w),
            (max(self.s_m - 1, 0), w),
            (max(self.t - 1, 0), w),
            (max(self.vn_size - 1, 0), _clog2(cfg.ah)),
        ]

    @property
    def is_execute(self) -> bool:
        return True


# ---------------------------------------------------------------------------
# Memory movement + activation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Load(Instruction):
    opcode = Opcode.LOAD
    hbm_addr: int = 0
    length: int = 0          # elements
    target: BufferTarget = BufferTarget.STREAMING

    def fields(self, cfg: FeatherConfig) -> list[tuple[int, int]]:
        return [
            (int(self.opcode), 3),
            (self.hbm_addr, 33),
            (self.length, _clog2(cfg.d_elems * cfg.aw) + 1),
            (int(self.target), 1),
        ]


@dataclasses.dataclass(frozen=True)
class Write(Instruction):
    opcode = Opcode.WRITE
    hbm_addr: int = 0
    length: int = 0
    target: BufferTarget = BufferTarget.STREAMING

    def fields(self, cfg: FeatherConfig) -> list[tuple[int, int]]:
        return [
            (int(self.opcode), 3),
            (self.hbm_addr, 33),
            (self.length, _clog2(cfg.d_elems * cfg.aw) + 1),
            (int(self.target), 1),
        ]


@dataclasses.dataclass(frozen=True)
class Activation(Instruction):
    """On-buffer activation (relu/gelu/silu/softmax-lut/none)."""
    opcode = Opcode.ACTIVATION
    function: int = 0
    length: int = 0
    target: BufferTarget = BufferTarget.STREAMING

    def fields(self, cfg: FeatherConfig) -> list[tuple[int, int]]:
        return [
            (int(self.opcode), 3),
            (self.function, 4),
            (int(self.target), 1),
            (self.length, _clog2(cfg.d_elems * cfg.aw) + 1),
        ]


ACTIVATION_FUNCS = {"none": 0, "relu": 1, "gelu": 2, "silu": 3,
                    "softmax": 4, "rmsnorm": 5, "layernorm": 6, "geglu": 7,
                    "swiglu": 8}


# ---------------------------------------------------------------------------
# Trace-level accounting
# ---------------------------------------------------------------------------

def trace_bits(trace: Iterable[Instruction], cfg: FeatherConfig) -> int:
    return sum(inst.bitwidth(cfg) for inst in trace)


def trace_bytes(trace: Iterable[Instruction], cfg: FeatherConfig) -> float:
    return trace_bits(trace, cfg) / 8.0


def trace_summary(trace: Iterable[Instruction], cfg: FeatherConfig) -> dict:
    counts: dict[str, int] = {}
    bits = 0
    for inst in trace:
        name = type(inst).__name__
        counts[name] = counts.get(name, 0) + 1
        bits += inst.bitwidth(cfg)
    return {"counts": counts, "bits": bits, "bytes": bits / 8.0,
            "n_instructions": sum(counts.values())}
