"""Extract the per-layer GEMM stream of an (architecture x shape) cell for
the MINISA planner (the framework-side analogue of ACT's graph analysis).

Included: every dense projection, MoE router + per-expert FFN GEMMs, MLA
low-rank projections, attention score/value batched GEMMs (FEATHER+'s
headline dynamic-operand case -- both operands arrive at runtime), and the
LM head.  Excluded (and routed to the paper's Activation instruction):
softmax, norms, rotary, SSM selective scans, embedding gathers.  See
DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.mapper import Gemm
from repro.core.planner import GemmOp


def _proj(name, m, k, n, count=1, chained=False, act="none", dynamic=False):
    return GemmOp(gemm=Gemm(m=m, k=k, n=n, name=name, count=count),
                  layer=name, chained=chained, activation=act,
                  dynamic=dynamic)


def _attn_gemms(cfg: ModelConfig, tokens: int, batch: int, s_q: int,
                s_kv: int, layers: int, prefix: str = "") -> list[GemmOp]:
    """Projections + batched score/value GEMMs for ``layers`` GQA layers."""
    h, kv, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    ops = [
        _proj(f"{prefix}wq", tokens, d, h * hd, layers),
        _proj(f"{prefix}wk", tokens, d, kv * hd, layers),
        _proj(f"{prefix}wv", tokens, d, kv * hd, layers),
        # scores: per (batch, head) GEMM  [s_q, hd] x [hd, s_kv]; both
        # operands arrive at runtime (FEATHER+'s dynamic-operand case)
        _proj(f"{prefix}qk", s_q, hd, s_kv, layers * batch * h,
              chained=True, act="softmax", dynamic=True),
        # values: [s_q, s_kv] x [s_kv, hd]
        _proj(f"{prefix}pv", s_q, s_kv, hd, layers * batch * h,
              chained=True, dynamic=True),
        _proj(f"{prefix}wo", tokens, h * hd, d, layers, chained=True),
    ]
    return ops


def _mla_gemms(cfg: ModelConfig, tokens: int, batch: int, s_q: int,
               s_kv: int, layers: int) -> list[GemmOp]:
    d, h = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return [
        _proj("mla.wq_a", tokens, d, qr, layers),
        _proj("mla.wq_b", tokens, qr, h * (dn + dr), layers, chained=True),
        _proj("mla.wkv_a", tokens, d, kvr + dr, layers),
        _proj("mla.wk_b", tokens, kvr, h * dn, layers, chained=True),
        _proj("mla.wv_b", tokens, kvr, h * dv, layers, chained=True),
        _proj("mla.qk", s_q, dn + dr, s_kv, layers * batch * h,
              chained=True, act="softmax", dynamic=True),
        _proj("mla.pv", s_q, s_kv, dv, layers * batch * h, chained=True,
              dynamic=True),
        _proj("mla.wo", tokens, h * dv, d, layers, chained=True),
    ]


def _mlp_gemms(cfg: ModelConfig, tokens: int, layers: int,
               d_ff: int | None = None, prefix: str = "") -> list[GemmOp]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    gated = cfg.mlp_act in ("swiglu", "geglu")
    ops = [_proj(f"{prefix}mlp.up", tokens, d, ff, layers)]
    if gated:
        ops.append(_proj(f"{prefix}mlp.gate", tokens, d, ff, layers))
    ops.append(_proj(f"{prefix}mlp.down", tokens, ff, d, layers,
                     chained=True, act=cfg.mlp_act))
    return ops


def _moe_gemms(cfg: ModelConfig, tokens: int, layers: int) -> list[GemmOp]:
    d, e, k, ff = (cfg.d_model, cfg.num_experts, cfg.experts_per_token,
                   cfg.moe_d_ff)
    per_expert = max(1, tokens * k // e)
    gated = cfg.mlp_act in ("swiglu", "geglu")
    ops = [_proj("moe.router", tokens, d, e, layers)]
    mats = 3 if gated else 2
    ops.append(_proj("moe.expert.up", per_expert, d, ff,
                     layers * e * (mats - 1)))
    ops.append(_proj("moe.expert.down", per_expert, ff, d, layers * e,
                     chained=True, act=cfg.mlp_act))
    if cfg.num_shared_experts:
        sf = cfg.shared_d_ff or ff * cfg.num_shared_experts
        ops += [_proj("moe.shared.up", tokens, d, sf, layers * 2),
                _proj("moe.shared.down", tokens, sf, d, layers,
                      chained=True)]
    return ops


def _ssm_gemms(cfg: ModelConfig, tokens: int, layers: int) -> list[GemmOp]:
    d, di, n = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    dt = cfg.ssm_dt_rank
    if cfg.ssm_version == 2:
        g, h = cfg.ssm_groups, cfg.ssm_heads
        width = 2 * di + 2 * g * n + h
        return [
            _proj("ssm2.in", tokens, d, width, layers),
            # selective scan itself: Activation instruction, not a GEMM
            _proj("ssm2.out", tokens, di, d, layers, chained=True,
                  act="silu"),
        ]
    return [
        _proj("ssm.in", tokens, d, 2 * di, layers),
        _proj("ssm.x_proj", tokens, di, dt + 2 * n, layers, chained=True),
        _proj("ssm.dt_proj", tokens, dt, di, layers, chained=True),
        _proj("ssm.out", tokens, di, d, layers, chained=True, act="silu"),
    ]


def gemm_workloads(cfg: ModelConfig, shape: ShapeConfig) -> list[GemmOp]:
    b = shape.global_batch
    if shape.kind == "decode":
        tokens, s_q, s_kv = b, 1, shape.seq_len
    else:
        tokens, s_q, s_kv = shape.tokens, shape.seq_len, shape.seq_len

    ops: list[GemmOp] = []
    L = cfg.num_layers

    if cfg.family == "encdec":
        enc_tokens = b * cfg.frontend_len
        if shape.kind != "decode":
            ops += _attn_gemms(cfg, enc_tokens, b, cfg.frontend_len,
                               cfg.frontend_len, cfg.encoder_layers, "enc.")
            ops += _mlp_gemms(cfg, enc_tokens, cfg.encoder_layers, prefix="enc.")
        ops += _attn_gemms(cfg, tokens, b, s_q, s_kv, L, "dec.")
        ops += _attn_gemms(cfg, tokens, b, s_q, cfg.frontend_len, L, "xattn.")
        ops += _mlp_gemms(cfg, tokens, L, prefix="dec.")
    elif cfg.family == "ssm":
        ops += _ssm_gemms(cfg, tokens, L)
    elif cfg.family == "hybrid":
        n_attn = L // cfg.attn_every
        ops += _ssm_gemms(cfg, tokens, L)
        ops += _attn_gemms(cfg, tokens, b, s_q, s_kv, n_attn, "shared.")
        ops += _mlp_gemms(cfg, tokens, n_attn, prefix="shared.")
    else:
        n_scan = L - cfg.first_k_dense
        if cfg.mla:
            ops += _mla_gemms(cfg, tokens, b, s_q, s_kv, L)
        else:
            ops += _attn_gemms(cfg, tokens, b, s_q, s_kv, L)
        if cfg.moe_enabled:
            ops += _moe_gemms(cfg, tokens, n_scan)
            if cfg.first_k_dense:
                ops += _mlp_gemms(cfg, tokens, cfg.first_k_dense,
                                  d_ff=cfg.dense_d_ff, prefix="dense.")
        else:
            ops += _mlp_gemms(cfg, tokens, L)

    ops.append(_proj("lm_head", tokens, cfg.d_model, cfg.vocab_size, 1,
                     chained=True))
    return ops
