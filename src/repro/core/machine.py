"""FEATHER+ functional machine: executes MINISA traces in JAX.

This module plays the role the cycle-accurate RTL plays in the paper:
it implements the *semantics* of every MINISA instruction so that a
(mapper-produced) trace can be validated end-to-end against the plain
einsum oracle.  Timing lives in ``core/perf.py``; this file is purely
functional.

Architecture state:

  streaming buffer   D_str x AW image      (single bank, FEATHER+ §III-B)
  stationary buffer  D_sta x AW image      (feeds PE local registers)
  output buffer      dense accumulator indexed by (streamed m, stationary c)
  layout registers   one VNLayout per operand
  theta_EM register  last ExecuteMapping (ExecuteStreaming reuses r0/G_r/G_c)

The compute tile (one ExecuteMapping + ExecuteStreaming pair) is a jitted
gather -> dot -> scatter-add over the (t, a_h, a_w) lattice, i.e. the
three-level reduction (temporal-in-PE, spatial-BIRRD, temporal-OB) collapses
to a masked scatter-add, which is its functional meaning.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.feather import FeatherConfig
from repro.core import isa
from repro.core.layout import VNLayout
from repro.core import vn as vnlib


@dataclasses.dataclass
class TraceOp:
    """An instruction plus simulation side-band metadata.

    The ISA encodes only what hardware needs (Fig. 3/5); the simulator
    additionally needs to know *which* host tensor a Load refers to and the
    bound VNLayout object.  ``meta`` keys used:

      Load:            tensor (str), layout (VNLayout), operand ('I'|'W')
      Set*VNLayout:    layout (VNLayout)
      SetOVNLayout:    m_extent, n_extent (accumulator shape), commit
                       (None | 'streaming' | 'stationary')
      Write:           tensor (str), transpose (bool)
      Activation:      fn (callable) applied to the committed output
    """
    inst: isa.Instruction
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# jitted tile kernel
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=(
    "ah", "aw", "t_steps", "vn_size",
    "r0", "c0", "g_r", "g_c", "s_r", "s_c", "m0", "s_m",
    "sta_red", "sta_free", "str_red", "str_free"))
def _tile(sta_buf, str_buf, o_acc, sta_first_rows, sta_cols,
          str_first_rows, str_cols, *, ah, aw, t_steps, vn_size,
          r0, c0, g_r, g_c, s_r, s_c, m0, s_m,
          sta_red, sta_free, str_red, str_free):
    """Execute one (E.Mapping, E.Streaming) pair.

    sta_first_rows/cols: [sta_red, sta_free] physical address tables derived
    from the stationary layout (likewise for streaming).  Address tables are
    precomputed host-side from the VNLayout (pure index math) so the jitted
    body is static-shape gathers + one einsum + one scatter-add.
    """
    a_w = jnp.arange(aw)
    a_h = jnp.arange(ah)
    t = jnp.arange(t_steps)

    r = r0 + a_w // g_r                                        # [AW]
    c = c0 + s_r * a_h[:, None] + s_c * (a_w % g_c)[None, :]   # [AH, AW]
    m = m0 + s_m * t[:, None] + ((a_w % g_r) // g_c)[None, :]  # [T, AW]

    # "FEATHER+ activates only VN_size x AW PEs" (paper §VI-D): rows beyond
    # vn_size are skipped -- without this mask, c-index aliasing across PE
    # rows would double-count products whenever vn_size < AH.
    row_active = a_h < vn_size                                 # [AH]
    valid_s = (row_active[:, None]
               & (r[None, :] >= 0) & (r[None, :] < sta_red)
               & (c >= 0) & (c < sta_free))                    # [AH, AW]
    valid_m = (m >= 0) & (m < str_free)                        # [T, AW]
    j_valid = (r >= 0) & (r < str_red)                         # [AW]

    rs = jnp.clip(r, 0, sta_red - 1)
    cs = jnp.clip(c, 0, sta_free - 1)
    ms = jnp.clip(m, 0, str_free - 1)

    e = jnp.arange(vn_size)
    # stationary VN elements: [AH, AW, vn]
    s_row = sta_first_rows[rs[None, :].repeat(ah, 0), cs]
    s_col = sta_cols[rs[None, :].repeat(ah, 0), cs]
    s_vals = sta_buf[s_row[..., None] + e, s_col[..., None]]
    s_vals = jnp.where(valid_s[..., None], s_vals, 0)
    # streaming VN elements: [T, AW, vn]
    js = jnp.clip(r, 0, str_red - 1)
    t_row = str_first_rows[js[None, :].repeat(t_steps, 0), ms]
    t_col = str_cols[js[None, :].repeat(t_steps, 0), ms]
    t_vals = str_buf[t_row[..., None] + e, t_col[..., None]]
    t_vals = jnp.where((valid_m & j_valid[None, :])[..., None], t_vals, 0)

    # psum[t, h, w] = dot over vn  (temporal reduction inside the PE)
    psums = jnp.einsum("twv,hwv->thw", t_vals.astype(o_acc.dtype),
                       s_vals.astype(o_acc.dtype))

    # BIRRD + OB reduction == scatter-add into (m, c)
    n_free = o_acc.shape[1]
    flat = ms[:, None, :] * n_free + cs[None, :, :]            # [T, AH, AW]
    mask = (valid_m[:, None, :] & valid_s[None, :, :])
    psums = jnp.where(mask, psums, 0)
    flat = jnp.where(mask, flat, 0)
    return o_acc.reshape(-1).at[flat.reshape(-1)].add(
        psums.reshape(-1)).reshape(o_acc.shape)


def _address_tables(lay: VNLayout, red: int, free: int):
    r_idx, c_idx = np.meshgrid(np.arange(red), np.arange(free), indexing="ij")
    first_row, col = lay.address(r_idx, c_idx)
    return jnp.asarray(first_row, jnp.int32), jnp.asarray(col, jnp.int32)


class FeatherMachine:
    """Executes a list of TraceOps against host tensors."""

    def __init__(self, cfg: FeatherConfig, max_depth: int | None = None):
        self.cfg = cfg
        # Simulated buffer depth: tests run tiny workloads; materialising the
        # full multi-hundred-K-row buffer would be wasteful.  The semantics
        # are unchanged (the mapper's capacity feasibility check still uses
        # the real depths).
        self.max_depth = max_depth
        self.reset()

    def reset(self):
        self.str_buf = None
        self.sta_buf = None
        self.layouts: dict[str, VNLayout] = {}
        self.layout_extents: dict[str, tuple[int, int]] = {}
        self.o_acc = None
        self.o_extents = None
        self.em: isa.ExecuteMapping | None = None
        self.df = isa.Dataflow.WOS
        self.outputs: dict[str, np.ndarray] = {}
        self._addr_cache: dict[str, tuple] = {}
        self._pending_commit: str | None = None
        self._pending_activation = None

    # -- helpers -------------------------------------------------------------
    def _depth(self, needed: int) -> int:
        cap = self.max_depth or max(needed, 1)
        return max(needed, 1) if self.max_depth is None else max(cap, needed)

    def _place(self, tensor: np.ndarray, operand: str, lay: VNLayout):
        """Convert a dense operand to VNs, place through the layout."""
        if operand == "I":
            vns = vnlib.to_input_vns(np.asarray(tensor), lay.vn_size)
        elif operand == "W":
            vns = vnlib.to_weight_vns(np.asarray(tensor), lay.vn_size)
        else:
            raise ValueError(operand)
        red, free = vns.shape[0], vns.shape[1]
        depth = self._depth(lay.rows_needed)
        buf = np.zeros((depth, lay.aw), dtype=np.float32)
        r_idx, c_idx = np.meshgrid(np.arange(red), np.arange(free),
                                   indexing="ij")
        first_row, col = lay.address(r_idx, c_idx)
        for e in range(lay.vn_size):
            buf[first_row + e, col] = vns[:, :, e]
        return jnp.asarray(buf), (red, free)

    def _role(self, operand: str) -> str:
        """Which physical buffer holds operand under the current dataflow."""
        if self.df == isa.Dataflow.WOS:
            return "stationary" if operand == "W" else "streaming"
        return "stationary" if operand == "I" else "streaming"

    # -- instruction semantics -------------------------------------------------
    def run(self, ops: list[TraceOp], tensors: dict[str, np.ndarray]):
        for op in ops:
            self._step(op, tensors)
        return self.outputs

    def _step(self, op: TraceOp, tensors):
        inst = op.inst
        if isinstance(inst, (isa.SetWVNLayout, isa.SetIVNLayout)):
            operand = "W" if isinstance(inst, isa.SetWVNLayout) else "I"
            self.layouts[operand] = op.meta["layout"]
        elif isinstance(inst, isa.SetOVNLayout):
            m_ext = op.meta["m_extent"]
            n_ext = op.meta["n_extent"]
            self.o_acc = jnp.zeros((m_ext, n_ext), dtype=jnp.float32)
            self.o_extents = (m_ext, n_ext)
            self.layouts["O"] = op.meta.get("layout")
            self._pending_commit = op.meta.get("commit")
        elif isinstance(inst, isa.Load):
            operand = op.meta["operand"]
            lay = op.meta.get("layout") or self.layouts[operand]
            self.layouts[operand] = lay
            # The stationary tensor is VN-ified along its reduction rank as a
            # [K, free] matrix regardless of dataflow; operand kind selects
            # the grouping convention.
            kind = "W" if operand == "W" else "I"
            buf, extents = self._place(tensors[op.meta["tensor"]], kind, lay)
            if inst.target == isa.BufferTarget.STATIONARY:
                self.sta_buf = buf
            else:
                self.str_buf = buf
            self.layout_extents[operand] = extents
        elif isinstance(inst, isa.ExecuteMapping):
            self.em = inst
        elif isinstance(inst, isa.ExecuteStreaming):
            self.df = inst.df
            self._execute(inst)
        elif isinstance(inst, isa.Activation):
            self._pending_activation = op.meta.get("fn")
        elif isinstance(inst, isa.Write):
            out = np.asarray(self.o_acc)
            if self._pending_activation is not None:
                out = np.asarray(self._pending_activation(out))
                self._pending_activation = None
            if op.meta.get("transpose"):
                out = out.T
            commit_to = op.meta.get("commit_to")
            if commit_to is not None:
                # paper §IV-G: layer i's OB commits on-chip to the next
                # operand buffer (IO-S: streaming, WO-S: stationary); the
                # output becomes layer i+1's input without an off-chip
                # round trip, and layer i+1's SetIVNLayout/Load are elided.
                lay = op.meta["layout"]
                buf, extents = self._place(out, "I", lay)
                if commit_to == "stationary":
                    self.sta_buf = buf
                else:
                    self.str_buf = buf
                self.layouts["I"] = lay
                self.layout_extents["I"] = extents
            self.outputs[op.meta["tensor"]] = out
        else:
            raise NotImplementedError(type(inst))

    def _execute(self, es: isa.ExecuteStreaming):
        if self.em is None:
            raise RuntimeError("ExecuteStreaming before ExecuteMapping")
        if self.o_acc is None:
            raise RuntimeError("ExecuteStreaming before SetOVNLayout")
        sta_operand = "W" if self.df == isa.Dataflow.WOS else "I"
        str_operand = "I" if self.df == isa.Dataflow.WOS else "W"
        sta_lay = self.layouts[sta_operand]
        str_lay = self.layouts[str_operand]
        sta_red, sta_free = self.layout_extents[sta_operand]
        str_red, str_free = self.layout_extents[str_operand]
        key_s = (sta_operand, id(sta_lay), sta_red, sta_free)
        key_t = (str_operand, id(str_lay), str_red, str_free)
        if key_s not in self._addr_cache:
            self._addr_cache[key_s] = _address_tables(sta_lay, sta_red, sta_free)
        if key_t not in self._addr_cache:
            self._addr_cache[key_t] = _address_tables(str_lay, str_red, str_free)
        sfr, scol = self._addr_cache[key_s]
        tfr, tcol = self._addr_cache[key_t]
        em = self.em
        self.o_acc = _tile(
            self.sta_buf, self.str_buf, self.o_acc, sfr, scol, tfr, tcol,
            ah=self.cfg.ah, aw=self.cfg.aw, t_steps=es.t,
            vn_size=es.vn_size,
            r0=em.r0, c0=em.c0, g_r=em.g_r, g_c=em.g_c,
            s_r=em.s_r, s_c=em.s_c, m0=es.m0, s_m=es.s_m,
            sta_red=sta_red, sta_free=sta_free,
            str_red=str_red, str_free=str_free)


def run_trace(cfg: FeatherConfig, ops: list[TraceOp],
              tensors: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    return FeatherMachine(cfg).run(ops, tensors)
