"""FEATHER+ functional machine: MINISA instruction *semantics* in JAX.

This module plays the role the cycle-accurate RTL plays in the paper:
it implements the semantics of every MINISA instruction so that a
(mapper-produced) Program can be validated end-to-end against the plain
einsum oracle.  Timing lives in ``core/perf.py``; this file is purely
functional.

The orchestration loop (walking a Program's TraceOp stream) lives in
``repro.backends.interpreter.InterpreterBackend``: the machine exposes
``step``/``flush`` and the backend drives them.  The module-level
``run_trace`` / ``run_program`` helpers remain as thin wrappers over that
backend for existing call sites.

Architecture state:

  streaming buffer   D_str x AW image      (single bank, FEATHER+ §III-B)
  stationary buffer  D_sta x AW image      (feeds PE local registers)
  output buffer      dense accumulator over the full (streamed m,
                     stationary c) extent; tiles drain slices of it
  layout registers   one VNLayout per operand (re-bound by each Load)
  theta_EM register  last ExecuteMapping (ExecuteStreaming reuses r0/G_r/G_c)

Execution is genuinely tiled: Loads place operand *slices* (under the
mapper's buffer-capacity bounds) and the Execute lattice addresses whatever
is resident, with the TraceOp side-band carrying each tile's global
offsets/bounds.  Consecutive ExecuteStreaming invocations that share every
static parameter (shapes, strides, layouts, buffer contents) are batched
into one ``jax.lax.scan`` over their dynamic scalars, so large GEMMs do not
pay a per-invocation dispatch.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.feather import FeatherConfig
from repro.core import isa
from repro.core.layout import VNLayout
from repro.core.program import Program, TraceOp  # noqa: F401 (re-export)

# dyn vector layout for one invocation: [r0, c0, m0, j_off, m_off, c_off,
# r_hi, c_hi, m_hi]
_DYN_WIDTH = 9

_STATICS = ("ah", "aw", "t_steps", "vn_size", "g_r", "g_c", "s_r", "s_c",
            "s_m", "sta_red", "sta_free", "str_red", "str_free")


def _invoke_core(sta_buf, str_buf, o_acc, sta_first_rows, sta_cols,
                 str_first_rows, str_cols, dyn, *, ah, aw, t_steps, vn_size,
                 g_r, g_c, s_r, s_c, s_m, sta_red, sta_free, str_red,
                 str_free):
    """One (E.Mapping, E.Streaming) pair: gather -> dot -> scatter-add.

    The three-level reduction (temporal-in-PE, spatial-BIRRD, temporal-OB)
    collapses to a masked scatter-add, which is its functional meaning.
    Address tables are precomputed host-side from the VNLayouts (pure index
    math) so the body is static-shape gathers + one einsum + a scatter-add;
    all per-invocation scalars live in ``dyn`` so one compilation serves
    every tile of the same shape class.
    """
    r0, c0, m0, j_off, m_off, c_off, r_hi, c_hi, m_hi = (
        dyn[i] for i in range(_DYN_WIDTH))
    a_w = jnp.arange(aw)
    a_h = jnp.arange(ah)
    t = jnp.arange(t_steps)

    r = r0 + a_w // g_r                                        # [AW]
    c = c0 + s_r * a_h[:, None] + s_c * (a_w % g_c)[None, :]   # [AH, AW]
    m = m0 + s_m * t[:, None] + ((a_w % g_r) // g_c)[None, :]  # [T, AW]
    j = r + j_off                                              # [AW]

    # "FEATHER+ activates only VN_size x AW PEs" (paper §VI-D): rows beyond
    # vn_size are skipped -- without this mask, c-index aliasing across PE
    # rows would double-count products whenever vn_size < AH.  The _hi
    # bounds are the current tile's extents: group-lattice overhang beyond
    # them is the paper's implicit zero padding.
    row_active = a_h < vn_size                                 # [AH]
    valid_s = (row_active[:, None]
               & (r[None, :] >= 0) & (r[None, :] < r_hi)
               & (c >= 0) & (c < c_hi))                        # [AH, AW]
    valid_m = (m >= 0) & (m < m_hi)                            # [T, AW]
    j_valid = (j >= 0) & (j < r_hi + j_off)                    # [AW]

    rs = jnp.clip(r, 0, sta_red - 1)
    cs = jnp.clip(c, 0, sta_free - 1)
    ms = jnp.clip(m, 0, str_free - 1)

    e = jnp.arange(vn_size)
    # stationary VN elements: [AH, AW, vn]
    s_row = sta_first_rows[rs[None, :].repeat(ah, 0), cs]
    s_col = sta_cols[rs[None, :].repeat(ah, 0), cs]
    s_vals = sta_buf[s_row[..., None] + e, s_col[..., None]]
    s_vals = jnp.where(valid_s[..., None], s_vals, 0)
    # streaming VN elements: [T, AW, vn]
    js = jnp.clip(j, 0, str_red - 1)
    t_row = str_first_rows[js[None, :].repeat(t_steps, 0), ms]
    t_col = str_cols[js[None, :].repeat(t_steps, 0), ms]
    t_vals = str_buf[t_row[..., None] + e, t_col[..., None]]
    t_vals = jnp.where((valid_m & j_valid[None, :])[..., None], t_vals, 0)

    # psum[t, h, w] = dot over vn  (temporal reduction inside the PE)
    psums = jnp.einsum("twv,hwv->thw", t_vals.astype(o_acc.dtype),
                       s_vals.astype(o_acc.dtype))

    # BIRRD + OB reduction == scatter-add into the global (m, c) cell
    n_free = o_acc.shape[1]
    mg = m + m_off
    cg = c + c_off
    flat = mg[:, None, :] * n_free + cg[None, :, :]            # [T, AH, AW]
    mask = valid_m[:, None, :] & valid_s[None, :, :]
    psums = jnp.where(mask, psums, 0)
    flat = jnp.where(mask, flat, 0)
    return o_acc.reshape(-1).at[flat.reshape(-1)].add(
        psums.reshape(-1)).reshape(o_acc.shape)


@partial(jax.jit, static_argnames=_STATICS)
def _invoke_batch(sta_buf, str_buf, o_acc, sta_first_rows, sta_cols,
                  str_first_rows, str_cols, dyn, **statics):
    """lax.scan over a [N, 9] batch of same-shaped invocations."""
    def body(acc, d):
        return _invoke_core(sta_buf, str_buf, acc, sta_first_rows, sta_cols,
                            str_first_rows, str_cols, d, **statics), None
    return jax.lax.scan(body, o_acc, dyn)[0]


def _address_tables(lay: VNLayout, red: int, free: int):
    r_idx, c_idx = np.meshgrid(np.arange(red), np.arange(free), indexing="ij")
    first_row, col = lay.address(r_idx, c_idx)
    return jnp.asarray(first_row, jnp.int32), jnp.asarray(col, jnp.int32)


#: Device-side activation twins, keyed by the Activation drain's registry
#: name.  Numerics mirror ``runtime.executable.ACTIVATIONS`` (same eps,
#: same max-subtraction), so a chained drain can apply its activation
#: without pulling the output block to the host.
def _jnp_softmax(x):
    z = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / jnp.sum(e, axis=-1, keepdims=True)


_JNP_ACTS = {
    "relu": lambda x: jnp.maximum(x, 0.0),
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "swiglu": jax.nn.silu,
    "geglu": jax.nn.gelu,
    "softmax": _jnp_softmax,
    "rmsnorm": lambda x: x / jnp.sqrt(
        jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6),
    "layernorm": lambda x: (x - jnp.mean(x, axis=-1, keepdims=True))
    / jnp.sqrt(jnp.var(x, axis=-1, keepdims=True) + 1e-6),
}


def _to_vns(src, operand: str, vn: int):
    """Device twin of ``vn.to_weight_vns`` / ``to_input_vns``: VN-ify the
    reduction rank with zero padding, without leaving the device."""
    src = jnp.asarray(src, jnp.float32)
    if operand == "W":                      # [K, N] -> [rows, N, vn]
        k, n = src.shape
        rows = -(-k // vn)
        sp = jnp.pad(src, ((0, rows * vn - k), (0, 0)))
        return jnp.transpose(sp.reshape(rows, vn, n), (0, 2, 1))
    m, k = src.shape                        # [M, K] -> [rows, M, vn]
    rows = -(-k // vn)
    sp = jnp.pad(src, ((0, 0), (0, rows * vn - k)))
    return jnp.transpose(sp.reshape(m, rows, vn), (1, 0, 2))


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length() if n > 1 else 1


class FeatherMachine:
    """MINISA architecture state + per-instruction semantics.

    Drive it with ``step(op, tensors)`` per TraceOp and a final ``flush()``
    (or use ``backends.InterpreterBackend``, which owns that loop)."""

    def __init__(self, cfg: FeatherConfig, max_depth: int | None = None):
        self.cfg = cfg
        # Simulated buffer depth: tests run tiny workloads; materialising the
        # full multi-hundred-K-row buffer would be wasteful.  The semantics
        # are unchanged (the mapper's capacity feasibility check still uses
        # the real depths).
        self.max_depth = max_depth
        self.reset()

    def reset(self):
        # operand buffers are DEVICE arrays: Loads scatter host slices in,
        # on-chip commits place straight from the device accumulator, so a
        # chained segment never round-trips through the host between layers
        self._bufs: dict[str, Any | None] = {"stationary": None,
                                             "streaming": None}
        self._buf_ver = {"stationary": 0, "streaming": 0}
        self.layouts: dict[str, VNLayout] = {}
        self.layout_extents: dict[str, tuple[int, int]] = {}
        self.o_acc = None
        self.o_extents: tuple[int, int] | None = None
        self._assembled = None              # device array (drained tiles)
        self.em: isa.ExecuteMapping | None = None
        self.df = isa.Dataflow.WOS
        self.outputs: dict[str, np.ndarray] = {}
        self._addr_cache: dict[tuple, tuple] = {}
        self._pending: list[list[int]] = []
        self._pending_key: tuple | None = None
        self._pending_activation = None

    # -- helpers -------------------------------------------------------------
    def _depth(self, needed: int) -> int:
        if self.max_depth is None:
            return max(needed, 1)
        return max(self.max_depth, needed)

    def _role(self, target: isa.BufferTarget) -> str:
        return ("stationary" if target == isa.BufferTarget.STATIONARY
                else "streaming")

    def _buf_device(self, role: str):
        return self._bufs[role]            # already device-resident

    # -- instruction semantics -----------------------------------------------
    def step(self, op: TraceOp, tensors):
        inst = op.inst
        if isinstance(inst, isa.ExecuteMapping):
            self.em = inst
            return
        if isinstance(inst, isa.ExecuteStreaming):
            self._enqueue(inst, op.meta)
            return
        self.flush()
        if isinstance(inst, (isa.SetWVNLayout, isa.SetIVNLayout)):
            operand = "W" if isinstance(inst, isa.SetWVNLayout) else "I"
            self.layouts[operand] = op.meta["layout"]
        elif isinstance(inst, isa.SetOVNLayout):
            m_ext = op.meta["m_extent"]
            n_ext = op.meta["n_extent"]
            self.o_acc = jnp.zeros((m_ext, n_ext), dtype=jnp.float32)
            self.o_extents = (m_ext, n_ext)
            self._assembled = jnp.zeros((m_ext, n_ext), dtype=jnp.float32)
            self.layouts["O"] = op.meta.get("layout")
        elif isinstance(inst, isa.Load):
            self._load(op, tensors)
        elif isinstance(inst, isa.Activation):
            self._pending_activation = (op.meta.get("fn"),
                                        op.meta.get("name"))
        elif isinstance(inst, isa.Write):
            self._write(op)
        else:
            raise NotImplementedError(type(inst))

    # -- VN placement shared by Load and on-chip commit ----------------------
    def _place(self, src, operand: str, lay: VNLayout,
               role: str, *, vn_row0: int = 0, col0: int = 0,
               reset: bool = True) -> tuple[int, int]:
        """VN-ify ``src`` and write it into ``role``'s buffer through
        ``lay`` at the given VN-array offset; returns the placed extents.

        ``src`` may be a host tensor (Load) or a device array (on-chip
        commit -- the whole placement stays on the device, so a chained
        segment reuses the accumulator without a host round trip).  The
        stationary tensor is VN-ified along its reduction rank as a
        [K, free] matrix regardless of dataflow; operand kind selects the
        grouping convention.
        """
        vns = _to_vns(src, "W" if operand == "W" else "I", lay.vn_size)
        depth = self._depth(lay.rows_needed)
        buf = self._bufs[role]
        if reset or buf is None or buf.shape != (depth, lay.aw):
            buf = jnp.zeros((depth, lay.aw), dtype=jnp.float32)
        red, free = vns.shape[0], vns.shape[1]
        r_idx, c_idx = np.meshgrid(np.arange(red), np.arange(free),
                                   indexing="ij")
        first_row, col = lay.address(r_idx + vn_row0, c_idx + col0)
        rows = first_row[..., None] + np.arange(lay.vn_size)
        cols = np.broadcast_to(col[..., None], rows.shape)
        buf = buf.at[rows, cols].set(vns)
        self._bufs[role] = buf
        self._buf_ver[role] += 1
        return red, free

    # -- Load: place a host-tensor slice through its layout ------------------
    def _load(self, op: TraceOp, tensors):
        meta = op.meta
        name = meta["tensor"]
        src = tensors.get(name) if tensors else None
        if src is None:
            src = self.outputs.get(name)
        if src is None:
            raise KeyError(f"Load refers to unknown tensor {name!r}")
        sl = meta.get("slice")
        if sl is not None:
            r0, r1, c0, c1 = sl
            src = src[r0:r1, c0:c1]
        operand = meta["operand"]
        lay = meta.get("layout") or self.layouts[operand]
        red, free = self._place(
            src, operand, lay, self._role(op.inst.target),
            vn_row0=meta.get("vn_row0", 0), col0=meta.get("col0", 0),
            reset=meta.get("reset", True))
        self.layouts[operand] = lay
        self.layout_extents[operand] = tuple(
            meta.get("extents", (red, free)))

    # -- Execute: batch same-shaped invocations into one lax.scan ------------
    def _enqueue(self, es: isa.ExecuteStreaming, meta: dict):
        if self.em is None:
            raise RuntimeError("ExecuteStreaming before ExecuteMapping")
        if self.o_acc is None:
            raise RuntimeError("ExecuteStreaming before SetOVNLayout")
        self.df = es.df
        em = self.em
        sta_operand = "W" if es.df == isa.Dataflow.WOS else "I"
        str_operand = "I" if es.df == isa.Dataflow.WOS else "W"
        sta_lay = self.layouts[sta_operand]
        str_lay = self.layouts[str_operand]
        sta_red, sta_free = self.layout_extents[sta_operand]
        str_red, str_free = self.layout_extents[str_operand]
        key = (es.t, es.vn_size, es.s_m, es.df, em.g_r, em.g_c, em.s_r,
               em.s_c, sta_lay, sta_red, sta_free, str_lay, str_red,
               str_free, self._buf_ver["stationary"],
               self._buf_ver["streaming"])
        if self._pending and key != self._pending_key:
            self.flush()
        self._pending_key = key
        self._pending.append([
            em.r0, em.c0, es.m0,
            meta.get("j_off", 0), meta.get("m_off", 0),
            meta.get("c_off", 0),
            meta.get("r_hi", sta_red), meta.get("c_hi", sta_free),
            meta.get("m_hi", str_free)])

    def flush(self):
        if not self._pending:
            return
        (t_steps, vn_size, s_m, df, g_r, g_c, s_r, s_c, sta_lay, sta_red,
         sta_free, str_lay, str_red, str_free, _, _) = self._pending_key
        for lay, red, free in ((sta_lay, sta_red, sta_free),
                               (str_lay, str_red, str_free)):
            ckey = (lay, red, free)
            if ckey not in self._addr_cache:
                self._addr_cache[ckey] = _address_tables(lay, red, free)
        sfr, scol = self._addr_cache[(sta_lay, sta_red, sta_free)]
        tfr, tcol = self._addr_cache[(str_lay, str_red, str_free)]
        dyn = np.asarray(self._pending, dtype=np.int32)
        self._pending = []
        self._pending_key = None
        # pad to the next power of two so scan lengths (compile keys) stay
        # bounded; sentinel rows have m_hi == 0 -> no contribution
        n = dyn.shape[0]
        n_pad = _next_pow2(n)
        if n_pad != n:
            pad = np.zeros((n_pad - n, _DYN_WIDTH), np.int32)
            dyn = np.concatenate([dyn, pad], axis=0)
        self.o_acc = _invoke_batch(
            self._buf_device("stationary"), self._buf_device("streaming"),
            self.o_acc, sfr, scol, tfr, tcol, jnp.asarray(dyn),
            ah=self.cfg.ah, aw=self.cfg.aw, t_steps=t_steps,
            vn_size=vn_size, g_r=g_r, g_c=g_c, s_r=s_r, s_c=s_c, s_m=s_m,
            sta_red=sta_red, sta_free=sta_free, str_red=str_red,
            str_free=str_free)

    # -- Write: drain an output-tile slice, assemble, maybe commit -----------
    def _write(self, op: TraceOp):
        meta = op.meta
        ms, ns = self.o_extents
        m0, m1, n0, n1 = meta.get("slice") or (0, ms, 0, ns)
        block = self.o_acc[m0:m1, n0:n1]        # device slice, no host pull
        if self._pending_activation is not None:
            # applied per drained tile: exact for elementwise activations;
            # row-wise ones (softmax/norms) need full-row tiles (n_n == 1).
            # Registry activations run their device twin; an unknown
            # callable is the one case that round-trips through the host.
            fn, name = self._pending_activation
            jfn = _JNP_ACTS.get(name)
            if jfn is not None:
                block = jfn(block)
            else:
                block = jnp.asarray(np.asarray(fn(np.asarray(block))),
                                    jnp.float32)
            self._pending_activation = None
        self._assembled = self._assembled.at[m0:m1, n0:n1].set(block)
        out = self._assembled
        if meta.get("transpose"):
            out = out.T
        self.outputs[meta["tensor"]] = out
        if meta.get("final", True) and meta.get("commit_to") is not None:
            # paper §IV-G: layer i's OB commits on-chip to the next operand
            # buffer (IO-S: stationary, WO-S: streaming); the output becomes
            # layer i+1's input without an off-chip round trip, and layer
            # i+1's SetIVNLayout/Load are elided.  ``out`` is a device
            # array, so the commit placement stays on the device end to end.
            lay = meta["layout"]
            red, free = self._place(out, "I", lay, meta["commit_to"])
            self.layouts["I"] = lay
            self.layout_extents["I"] = (red, free)


def run_trace(cfg: FeatherConfig, ops: Iterable[TraceOp],
              tensors: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    from repro.backends.interpreter import InterpreterBackend
    return InterpreterBackend(cfg).run_trace(ops, tensors)


def run_program(cfg: FeatherConfig, prog: Program,
                tensors: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    from repro.backends.interpreter import InterpreterBackend
    return InterpreterBackend(cfg).run_program(prog, tensors)
