"""FEATHER+ Mapper: analytical (mapping, layout) co-search (paper §V, Tab. VII).

Pipeline (paper Fig. 8/9):

  workload -> VNs -> tiles -> VN groups -> combined VN groups -> column
  duplication -> feasible layouts -> MINISA trace -> analytical latency

Search knobs (Tab. VII):
  dataflow      WO-S / IO-S (IO-S == transposed WO-S; §V-B "from the
                mapper's perspective")
  VN size       vn <= AH (balanced divisors of K considered; §VI-D)
  tiling        (M_t, K_t, N_t) bounded by buffer capacities
  grouping      n_kg x n_nb concurrent combined VN groups per invocation
  duplication   d copies of each group across columns (T shrinks by d)
  layout        Tab. III order per operand + level-0 factors
  patterns      block/strided stationary c-strides, interleaved/consecutive
                streaming (consecutive degenerates to interleaved when d>1,
                see ExecuteStreaming's m-offset form)

Mapping-first, layout-second: mapping candidates are scored with the
analytical perf model; for the best mappings we search a feasible layout
(single-bank streaming-row legality + OB bank legality + capacity).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

import numpy as np

from repro.configs.feather import FeatherConfig
from repro.core import isa, layout as layoutlib, perf
from repro.core.microinst import MicroModel


# ---------------------------------------------------------------------------
# Workloads
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Gemm:
    """O[M, N] = I[M, K] @ W[K, N]  (extended-einsum ranks of Fig. 1)."""
    m: int
    k: int
    n: int
    name: str = ""
    count: int = 1       # repeated layers with identical shape

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n

    @property
    def data_bytes(self) -> int:
        return self.m * self.k + self.k * self.n + self.m * self.n


# ---------------------------------------------------------------------------
# Mapping choice
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MappingChoice:
    df: isa.Dataflow
    vn: int                  # VN size (<= AH)
    m_t: int                 # tile extents in the *search* orientation
    k_t: int
    n_t: int
    n_kg: int                # concurrent reduction groups per invocation
    n_nb: int                # concurrent n-blocks per invocation
    dup: int                 # column duplication factor
    order_w: int = 0         # Tab. III layout orders
    order_i: int = 0
    order_o: int = 0
    strided: bool = False    # stationary c-stride pattern (Tab. VII)

    @property
    def concurrent(self) -> int:
        return self.n_kg * self.n_nb * self.dup


@dataclasses.dataclass
class Schedule:
    """Concrete per-tile cost streams for the perf model."""
    choice: MappingChoice
    gemm: Gemm
    cfg: FeatherConfig
    n_m: int
    n_n: int
    n_k: int
    invocations_per_tile: int
    t_steps: int             # streamed VNs per column per invocation
    cycles_per_invocation: float
    macs_total: int
    minisa_bits_per_tile: float
    minisa_layer_bits: float
    loads_i_bytes: float
    loads_w_bytes: float
    store_bytes: float

    @property
    def n_tiles(self) -> int:
        return self.n_m * self.n_n * self.n_k

    @property
    def total_invocations(self) -> int:
        return self.n_tiles * self.invocations_per_tile

    @property
    def compute_cycles(self) -> float:
        return self.total_invocations * self.cycles_per_invocation

    # -- instruction volumes -------------------------------------------------
    def minisa_storage_bytes(self) -> float:
        return (self.minisa_layer_bits
                + self.minisa_bits_per_tile * self.n_tiles) / 8.0

    def micro_storage_bytes(self) -> float:
        return MicroModel(self.cfg).storage_bytes(self.compute_cycles)

    def micro_fetch_bytes(self) -> float:
        return MicroModel(self.cfg).fetch_bytes(
            self.compute_cycles, self.total_invocations)

    # -- perf-model tile streams ----------------------------------------------
    def tiles(self, control: str = "minisa",
              max_tiles: int = 1024) -> list[perf.TileCost]:
        """control in {'minisa', 'micro'} selects the fetch stream.

        Tile streams longer than ``max_tiles`` are run-length merged (k
        identical tiles -> one tile with k-scaled costs); for a uniform
        stream the engine recurrence is linear, so merging preserves the
        makespan to within one tile's skew while keeping the discrete-event
        pass O(max_tiles).
        """
        micro = MicroModel(self.cfg)
        out: list[perf.TileCost] = []
        inv_cycles = self.cycles_per_invocation
        tile_cycles = self.invocations_per_tile * inv_cycles
        n_tiles = self.n_tiles
        # distribute loads over the tiles that consume fresh data
        loads_i_per = self.loads_i_bytes / max(n_tiles, 1)
        loads_w_per = self.loads_w_bytes / max(n_tiles, 1)
        macs_per = self.macs_total / max(n_tiles, 1)
        out_tiles = self.n_m * self.n_n
        store_per = self.store_bytes / max(out_tiles, 1)
        o2s_cycles = (self.m_eff * self.n_eff) / self.cfg.aw
        if control == "minisa":
            fetch = self.minisa_bits_per_tile / 8.0
        else:
            fetch = micro.fetch_bytes(tile_cycles,
                                      self.invocations_per_tile)

        if n_tiles <= max_tiles:
            k_period = self.n_k
            for idx in range(n_tiles):
                last_k = (idx + 1) % k_period == 0
                extra = (self.minisa_layer_bits / 8.0
                         if (idx == 0 and control == "minisa") else 0.0)
                out.append(perf.TileCost(
                    fetch_bytes=fetch + extra,
                    load_bytes=loads_i_per + loads_w_per,
                    compute_cycles=tile_cycles,
                    out2stream_cycles=o2s_cycles if last_k else 0.0,
                    store_bytes=store_per if last_k else 0.0,
                    macs=macs_per))
            return out

        # merged stream: spread stores/commits uniformly (store engine is
        # 4*AW B/cycle and almost never binding)
        groups = max_tiles
        base, rem = divmod(n_tiles, groups)
        o2s_total = o2s_cycles * out_tiles
        for gi in range(groups):
            k = base + (1 if gi < rem else 0)
            extra = (self.minisa_layer_bits / 8.0
                     if (gi == 0 and control == "minisa") else 0.0)
            out.append(perf.TileCost(
                fetch_bytes=fetch * k + extra,
                load_bytes=(loads_i_per + loads_w_per) * k,
                compute_cycles=tile_cycles * k,
                out2stream_cycles=o2s_total * k / n_tiles,
                store_bytes=self.store_bytes * k / n_tiles,
                macs=macs_per * k))
        return out

    @property
    def m_eff(self) -> int:
        return min(self.m_t, self.gemm_m)

    @property
    def n_eff(self) -> int:
        return min(self.n_t, self.gemm_n)

    @property
    def gemm_m(self) -> int:
        return self.gemm.n if self.choice.df == isa.Dataflow.IOS else self.gemm.m

    @property
    def gemm_n(self) -> int:
        return self.gemm.m if self.choice.df == isa.Dataflow.IOS else self.gemm.n

    @property
    def m_t(self) -> int:
        return self.choice.m_t

    @property
    def n_t(self) -> int:
        return self.choice.n_t


# ---------------------------------------------------------------------------
# Schedule construction
# ---------------------------------------------------------------------------

def make_schedule(gemm: Gemm, choice: MappingChoice,
                  cfg: FeatherConfig) -> Schedule | None:
    """Lower a mapping choice to tile/invocation counts + byte streams.

    Returns None if the choice is infeasible (capacity or shape).
    """
    ah, aw = cfg.ah, cfg.aw
    vn = choice.vn
    if vn > ah or vn < 1:
        return None
    # search orientation (IO-S transposes the GEMM)
    ms, ks, ns = ((gemm.n, gemm.k, gemm.m)
                  if choice.df == isa.Dataflow.IOS else
                  (gemm.m, gemm.k, gemm.n))
    m_t = min(choice.m_t, ms)
    k_t = min(choice.k_t, ks)
    n_t = min(choice.n_t, ns)
    if min(m_t, k_t, n_t) < 1:
        return None
    if choice.concurrent > aw:
        return None
    # capacity feasibility (bytes; elem_bytes == 1)
    if m_t * k_t > cfg.str_bytes:
        return None
    if k_t * n_t > cfg.sta_bytes:
        return None
    if m_t * n_t * cfg.acc_bytes > cfg.ob_bytes:
        return None

    n_m = math.ceil(ms / m_t)
    n_n = math.ceil(ns / n_t)
    n_k = math.ceil(ks / k_t)

    kg_tiles = math.ceil(k_t / vn)          # reduction groups per tile
    nb_tiles = math.ceil(n_t / vn)          # n-blocks per tile
    # Rounds iterate the group lattice; groups beyond the tile extent are
    # zero-padded (masked) columns, so rounds = ceil per axis.
    invocations = (math.ceil(kg_tiles / max(choice.n_kg, 1))
                   * math.ceil(nb_tiles / max(choice.n_nb, 1)))
    t_steps = math.ceil(m_t / choice.dup)
    # the ES T-field is bounded by D/AH; longer streams are expressed as
    # several ExecuteStreaming instructions sharing one ExecuteMapping
    # (sub-tiled execution, paper §IV-G)
    t_max = max(cfg.vn_slots_per_col, 1)
    es_per_invocation = math.ceil(t_steps / t_max)

    # per-invocation cycles: stream T VNs x vn cycles each; stationary
    # (re)load of vn VNs x vn elements per column is double-buffered and
    # only exposed when longer than the previous invocation's streaming.
    stream_cycles = t_steps * vn
    sta_load = vn * vn
    drain = vn + cfg.birrd_stages + 2
    cycles_per_invocation = max(stream_cycles, sta_load) + drain

    macs_total = gemm.macs  # useful MACs (padding excluded by definition)

    # MINISA instruction bits
    em_bits = cfg.bits_execute_mapping()
    es_bits = cfg.bits_execute_streaming()
    lay_bits = cfg.bits_set_layout()
    load_bits = cfg.bits_load_store()
    tile_bits = invocations * (em_bits + es_bits * es_per_invocation)
    # per-layer: 3 layouts + loads (one Load per operand tile) + final writes
    n_loads = n_m * n_k + n_n * n_k
    n_writes = n_m * n_n
    layer_bits = 3 * lay_bits + (n_loads + n_writes) * load_bits

    # off-chip data movement (reload factors from buffer residency; n-outer,
    # m-mid, k-inner loop order, OB accumulates over k)
    i_bytes = ms * ks * cfg.elem_bytes
    w_bytes = ks * ns * cfg.elem_bytes
    i_resident = ms * ks <= cfg.str_bytes
    w_panel_resident = ks * n_t <= cfg.sta_bytes
    loads_i = i_bytes * (1 if i_resident else n_n)
    loads_w = w_bytes * (1 if w_panel_resident else n_m)
    store_bytes = ms * ns * cfg.elem_bytes

    return Schedule(
        choice=choice, gemm=gemm, cfg=cfg,
        n_m=n_m, n_n=n_n, n_k=n_k,
        invocations_per_tile=invocations,
        t_steps=t_steps,
        cycles_per_invocation=cycles_per_invocation,
        macs_total=macs_total,
        minisa_bits_per_tile=tile_bits,
        minisa_layer_bits=layer_bits,
        loads_i_bytes=loads_i,
        loads_w_bytes=loads_w,
        store_bytes=store_bytes)


# ---------------------------------------------------------------------------
# Candidate enumeration (with Tab. VII pruning heuristics)
# ---------------------------------------------------------------------------

def _pow2_tiles(lo: int, hi: int) -> list[int]:
    out = []
    v = lo
    while v < hi:
        out.append(v)
        v *= 2
    out.append(hi)
    return sorted(set(out))


def _vn_candidates(k: int, ah: int) -> list[int]:
    """Balanced VN sizes: AH plus sizes that avoid zero-pad waste.

    For K <= AH the exact K is best; for K > AH the balanced size
    ceil(K / ceil(K / AH)) removes the ragged last VN (e.g. K=40, AH=16
    gives vn=14 over 3 tiles, or vn=10 over 4 exact tiles).
    """
    cands = {min(ah, k)}
    if k > ah:
        base_tiles = math.ceil(k / ah)
        for tiles in (base_tiles, base_tiles + 1):
            cands.add(math.ceil(k / tiles))
    return sorted(c for c in cands if 1 <= c <= ah)


def _divisors_pow2ish(n: int) -> list[int]:
    """Divisors of n (exact column coverage is required by Eq. 1)."""
    return [d for d in range(1, n + 1) if n % d == 0]


def enumerate_choices(gemm: Gemm, cfg: FeatherConfig,
                      max_candidates: int = 512) -> Iterable[MappingChoice]:
    ah, aw = cfg.ah, cfg.aw
    for df in (isa.Dataflow.WOS, isa.Dataflow.IOS):
        ms, ks, ns = ((gemm.n, gemm.k, gemm.m) if df == isa.Dataflow.IOS
                      else (gemm.m, gemm.k, gemm.n))
        # Heuristic from §III-C: IO-S when M > N, WO-S otherwise; we still
        # search both but the pruning keeps the promising one cheap.
        for vn in _vn_candidates(ks, ah):
            kg_full = math.ceil(ks / vn)
            # tiling: prefer the largest tiles that fit (fewer reloads)
            k_opts = _pow2_tiles(min(vn, ks), min(ks, cfg.sta_bytes))
            k_opts = [k for k in k_opts[-3:]]
            for k_t in k_opts:
                max_nt = max(1, cfg.sta_bytes // max(k_t, 1))
                n_opts = _pow2_tiles(min(vn, ns), min(ns, max_nt))
                for n_t in n_opts[-3:]:
                    max_mt = max(1, min(cfg.str_bytes // max(k_t, 1),
                                        cfg.ob_bytes // (max(n_t, 1)
                                                         * cfg.acc_bytes),
                                        cfg.vn_slots_per_col))
                    m_opts = _pow2_tiles(1, min(ms, max_mt))
                    for m_t in m_opts[-3:]:
                        kg = math.ceil(min(k_t, ks) / vn)
                        nb = math.ceil(min(n_t, ns) / vn)
                        # Group-formation knobs.  Eq. 1's index arithmetic
                        # forces exact column coverage: G_r = AW/n_kg,
                        # G_c = n_nb and the duplication factor is
                        # structurally d = G_r / G_c, so (n_kg, n_nb) must
                        # divide the column space exactly and d is derived.
                        for n_kg in _divisors_pow2ish(aw):
                            if n_kg > 2 * kg:
                                continue  # >half the columns masked: skip
                            g_r = aw // n_kg
                            for n_nb in _divisors_pow2ish(g_r):
                                if n_nb > 2 * nb:
                                    continue
                                dup = g_r // n_nb
                                yield MappingChoice(
                                    df=df, vn=vn, m_t=m_t, k_t=k_t,
                                    n_t=n_t, n_kg=n_kg, n_nb=n_nb,
                                    dup=dup)


# ---------------------------------------------------------------------------
# Layout feasibility (step 6)
# ---------------------------------------------------------------------------

def _layouts_for(schedule: Schedule) -> tuple[layoutlib.VNLayout,
                                              layoutlib.VNLayout,
                                              layoutlib.VNLayout] | None:
    """Derive (stationary, streaming, output) layouts realising the mapping
    without bank conflicts.

    FEATHER+'s all-to-all distribution makes the *stationary* side conflict-
    free by construction (any resident VN can reach any column, §III-B), so
    the binding constraints are:

      streaming: the single-bank buffer serves one row (AW elements) per
        cycle; at stream step t, element e, every column reads element e of
        I_VN(m[t,a_w], j[a_w]) -- all of those must live in one buffer row
        (multicast handles duplicates).  Satisfied by placing I_VNs with the
        reduction rank innermost across columns (order with nr_L0 outermost,
        red_L1 innermost) when n_kg*dup <= AW ... we *verify* by direct
        address simulation below instead of trusting the construction.

      output: the AW OB banks absorb one psum per bank per cycle; BIRRD can
        permute, so legality is "<= AW distinct banks per drain cycle",
        guaranteed when the O_VN layout's level-0 free factor >= concurrent
        n-block width.  Also verified directly.
    """
    ch = schedule.choice
    cfg = schedule.cfg
    vn = ch.vn
    kg = math.ceil(min(ch.k_t, schedule.gemm.k) / vn)
    m_eff = schedule.m_eff
    n_eff = schedule.n_eff
    nb = math.ceil(n_eff / vn)

    # candidate orders, most-promising first
    stream_orders = [0b100, 0b010, 0b000, 0b001, 0b011, 0b101]
    for o_i in stream_orders:
        lay_i = layoutlib.layout_for(kg, m_eff, vn, cfg.aw, order=o_i,
                                     nr_l0=min(cfg.aw, m_eff))
        if _stream_feasible(lay_i, schedule):
            break
    else:
        return None
    lay_w = layoutlib.layout_for(kg, n_eff, vn, cfg.aw, order=ch.order_w)
    lay_o = layoutlib.layout_for(math.ceil(n_eff / vn), m_eff, vn, cfg.aw,
                                 order=ch.order_o)
    if lay_w.rows_needed > cfg.d_sta or lay_i.rows_needed > cfg.d_str:
        return None
    if lay_o.rows_needed * cfg.acc_bytes > cfg.ob_bytes // cfg.aw * cfg.aw:
        pass  # OB sized in words; capacity already checked in make_schedule
    return lay_w, lay_i, lay_o


def _stream_feasible(lay_i: layoutlib.VNLayout, schedule: Schedule,
                     probe_steps: int = 4) -> bool:
    """Single-bank streaming legality by direct address simulation."""
    ch = schedule.choice
    cfg = schedule.cfg
    aw = cfg.aw
    g_r = max(1, (aw // max(ch.n_kg, 1)))
    g_c = max(1, ch.n_nb)
    a_w = np.arange(aw)
    j = a_w // g_r
    for t in range(min(probe_steps, schedule.t_steps)):
        m = ch.dup * t + (a_w % g_r) // g_c
        valid = (m < schedule.m_eff) & (j < lay_i.red_l1)
        if not valid.any():
            continue
        rows, _ = lay_i.address(np.where(valid, j, 0), np.where(valid, m, 0))
        rows = rows[valid]
        # all concurrent reads within one row -> single-bank OK (the vn
        # elements advance row-by-row in lockstep for every column)
        if np.unique(rows).size > 1:
            return False
    return True


# ---------------------------------------------------------------------------
# Top-level search
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Plan:
    gemm: Gemm
    cfg: FeatherConfig
    choice: MappingChoice
    schedule: Schedule
    layouts: tuple       # (W, I, O) VNLayouts
    perf_minisa: perf.PerfResult
    perf_micro: perf.PerfResult

    @property
    def speedup(self) -> float:
        return self.perf_micro.cycles / max(self.perf_minisa.cycles, 1e-9)

    def summary(self) -> dict:
        s = self.schedule
        return {
            "workload": self.gemm.name or f"{self.gemm.m}x{self.gemm.k}x{self.gemm.n}",
            "array": f"{self.cfg.ah}x{self.cfg.aw}",
            "df": self.choice.df.name,
            "vn": self.choice.vn,
            "tile": (s.n_m, s.n_n, s.n_k),
            "cycles_minisa": self.perf_minisa.cycles,
            "cycles_micro": self.perf_micro.cycles,
            "speedup": self.speedup,
            "util_minisa": self.perf_minisa.utilization,
            "stall_micro": self.perf_micro.stall_ifetch_frac,
            "stall_minisa": self.perf_minisa.stall_ifetch_frac,
            "instr_bytes_minisa": s.minisa_storage_bytes(),
            "instr_bytes_micro": s.micro_storage_bytes(),
            "instr_reduction": (s.micro_storage_bytes()
                                / max(s.minisa_storage_bytes(), 1e-9)),
            "data_bytes": self.gemm.data_bytes,
        }


def _prescore(sched: Schedule, cfg: FeatherConfig) -> float:
    """Closed-form lower-bound latency for candidate ranking (the full
    discrete-event pass runs only on the shortlist)."""
    return max(sched.compute_cycles,
               (sched.loads_i_bytes + sched.loads_w_bytes) / cfg.in_bw,
               sched.store_bytes / cfg.out_bw,
               sched.minisa_storage_bytes() / cfg.instr_bw)


def search(gemm: Gemm, cfg: FeatherConfig, top_k: int = 8,
           shortlist: int = 24,
           fixed_input_vn: int | None = None,
           fixed_input_order: int | None = None) -> Plan:
    """Mapping-first, layout-second co-search returning the best Plan.

    ``fixed_input_vn`` / ``fixed_input_order`` implement the paper's
    *layout-constrained* mode (artifact item 6, §V step 7's inter-layer
    compatibility): when layer i's output layout is already committed,
    layer i+1 may only consider mappings whose input VN size matches and
    whose input layout order equals the committed one.
    """
    candidates: list[tuple[float, MappingChoice, Schedule]] = []
    seen = set()
    for choice in enumerate_choices(gemm, cfg):
        if fixed_input_vn is not None and choice.vn != fixed_input_vn:
            continue
        if fixed_input_order is not None:
            choice = dataclasses.replace(choice,
                                         order_i=fixed_input_order)
        key = dataclasses.astuple(choice)
        if key in seen:
            continue
        seen.add(key)
        sched = make_schedule(gemm, choice, cfg)
        if sched is None:
            continue
        candidates.append((_prescore(sched, cfg), choice, sched))
    if not candidates:
        raise ValueError(f"no feasible mapping for {gemm} on "
                         f"{cfg.ah}x{cfg.aw}")
    candidates.sort(key=lambda x: x[0])
    scored = []
    for _, choice, sched in candidates[:shortlist]:
        res = perf.simulate(sched.tiles("minisa"), cfg)
        scored.append((res.cycles, choice, sched))
    scored.sort(key=lambda x: x[0])
    # layout-second: walk the best mappings until one has a feasible layout
    for cycles, choice, sched in scored[:max(top_k, 1)]:
        layouts = _layouts_for(sched)
        if layouts is None:
            continue
        res_minisa = perf.simulate(sched.tiles("minisa"), cfg)
        res_micro = perf.simulate(sched.tiles("micro"), cfg)
        return Plan(gemm=gemm, cfg=cfg, choice=choice, schedule=sched,
                    layouts=layouts, perf_minisa=res_minisa,
                    perf_micro=res_micro)
    # fall back: accept best mapping with default layouts (always functional;
    # perf model unchanged -- conflicts would cost extra cycles on silicon)
    cycles, choice, sched = scored[0]
    vn = choice.vn
    kg = math.ceil(min(choice.k_t, gemm.k) / vn)
    lay_w = layoutlib.layout_for(kg, sched.n_eff, vn, cfg.aw)
    lay_i = layoutlib.layout_for(kg, sched.m_eff, vn, cfg.aw)
    lay_o = layoutlib.layout_for(math.ceil(sched.n_eff / vn), sched.m_eff,
                                 vn, cfg.aw)
    return Plan(gemm=gemm, cfg=cfg, choice=choice, schedule=sched,
                layouts=(lay_w, lay_i, lay_o),
                perf_minisa=perf.simulate(sched.tiles("minisa"), cfg),
                perf_micro=perf.simulate(sched.tiles("micro"), cfg))
