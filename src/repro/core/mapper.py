"""FEATHER+ Mapper: analytical (mapping, layout) co-search (paper §V, Tab. VII).

Pipeline (paper Fig. 8/9):

  workload -> VNs -> tiles -> VN groups -> combined VN groups -> column
  duplication -> feasible layouts -> lowered Program -> simulated latency

Search knobs (Tab. VII):
  dataflow      WO-S / IO-S (IO-S == transposed WO-S; §V-B "from the
                mapper's perspective")
  VN size       vn <= AH (balanced divisors of K considered; §VI-D)
  tiling        (M_t, K_t, N_t) bounded by buffer capacities
  grouping      n_kg x n_nb concurrent combined VN groups per invocation
  duplication   d copies of each group across columns (T shrinks by d)
  layout        Tab. III order per operand + level-0 factors
  patterns      block/strided stationary c-strides, interleaved/consecutive
                streaming (consecutive degenerates to interleaved when d>1,
                see ExecuteStreaming's m-offset form)

Mapping-first, layout-second: candidates are ranked with a closed-form
lower bound, the shortlist is *lowered to a tiled Program* and scored with
the discrete-event model over the Program's actual tile stream, and for the
best mappings we search a feasible layout (single-bank streaming-row
legality + OB bank legality + capacity).  The winning Program is the one
artifact every consumer (machine, perf, byte accounting) shares.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

import numpy as np

from repro.configs.feather import FeatherConfig
from repro.core import isa, layout as layoutlib, perf
from repro.core import program as programlib


# ---------------------------------------------------------------------------
# Workloads
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Gemm:
    """O[M, N] = I[M, K] @ W[K, N]  (extended-einsum ranks of Fig. 1)."""
    m: int
    k: int
    n: int
    name: str = ""
    count: int = 1       # repeated layers with identical shape

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n

    @property
    def data_bytes(self) -> int:
        return self.m * self.k + self.k * self.n + self.m * self.n


# ---------------------------------------------------------------------------
# Mapping choice
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MappingChoice:
    df: isa.Dataflow
    vn: int                  # VN size (<= AH)
    m_t: int                 # tile extents in the *search* orientation
    k_t: int
    n_t: int
    n_kg: int                # concurrent reduction groups per invocation
    n_nb: int                # concurrent n-blocks per invocation
    dup: int                 # column duplication factor
    order_w: int = 0         # Tab. III layout orders
    order_i: int = 0
    order_o: int = 0
    strided: bool = False    # stationary c-stride pattern (Tab. VII)

    @property
    def concurrent(self) -> int:
        return self.n_kg * self.n_nb * self.dup


@dataclasses.dataclass(frozen=True)
class Tiling:
    """Tile/invocation counts of a feasible choice.

    Used for candidate *pruning* (the closed-form prescore) and layout
    legality only -- all reported cycle/byte numbers come from the lowered
    Program's actual tile stream, never from these counts.
    """
    ms: int
    ks: int
    ns: int
    m_t: int
    k_t: int
    n_t: int
    n_m: int
    n_n: int
    n_k: int
    t_steps: int
    invocations_per_tile: int
    cycles_per_invocation: float

    @property
    def n_tiles(self) -> int:
        return self.n_m * self.n_n * self.n_k

    @property
    def m_eff(self) -> int:
        return min(self.m_t, self.ms)

    @property
    def n_eff(self) -> int:
        return min(self.n_t, self.ns)


def tiling(gemm: Gemm, choice: MappingChoice,
           cfg: FeatherConfig) -> Tiling | None:
    """Feasibility (capacity + shape) and tile counts; None if infeasible."""
    ah, aw = cfg.ah, cfg.aw
    vn = choice.vn
    if vn > ah or vn < 1:
        return None
    ms, ks, ns, _ = programlib._oriented(gemm, choice)
    snapped = programlib.snap_tiling(gemm, choice, cfg)
    if snapped is None:
        return None
    m_t, k_t, n_t = snapped
    if choice.concurrent > aw:
        return None
    # capacity feasibility (bytes; elem_bytes == 1)
    if m_t * k_t > cfg.str_bytes:
        return None
    if k_t * n_t > cfg.sta_bytes:
        return None
    if m_t * n_t * cfg.acc_bytes > cfg.ob_bytes:
        return None

    n_m = math.ceil(ms / m_t)
    n_n = math.ceil(ns / n_t)
    n_k = math.ceil(ks / k_t)
    kg_tiles = math.ceil(k_t / vn)
    nb_tiles = math.ceil(n_t / vn)
    invocations = (math.ceil(kg_tiles / max(choice.n_kg, 1))
                   * math.ceil(nb_tiles / max(choice.n_nb, 1)))
    t_steps = math.ceil(m_t / choice.dup)
    stream_cycles = t_steps * vn
    sta_load = vn * vn
    drain = vn + cfg.birrd_stages + 2
    cycles_per_invocation = max(stream_cycles, sta_load) + drain
    return Tiling(ms=ms, ks=ks, ns=ns, m_t=m_t, k_t=k_t, n_t=n_t,
                  n_m=n_m, n_n=n_n, n_k=n_k, t_steps=t_steps,
                  invocations_per_tile=invocations,
                  cycles_per_invocation=cycles_per_invocation)


# ---------------------------------------------------------------------------
# Candidate enumeration (with Tab. VII pruning heuristics)
# ---------------------------------------------------------------------------

def _pow2_tiles(lo: int, hi: int) -> list[int]:
    out = []
    v = lo
    while v < hi:
        out.append(v)
        v *= 2
    out.append(hi)
    return sorted(set(out))


def _vn_candidates(k: int, ah: int) -> list[int]:
    """Balanced VN sizes: AH plus sizes that avoid zero-pad waste.

    For K <= AH the exact K is best; for K > AH the balanced size
    ceil(K / ceil(K / AH)) removes the ragged last VN (e.g. K=40, AH=16
    gives vn=14 over 3 tiles, or vn=10 over 4 exact tiles).
    """
    cands = {min(ah, k)}
    if k > ah:
        base_tiles = math.ceil(k / ah)
        for tiles in (base_tiles, base_tiles + 1):
            cands.add(math.ceil(k / tiles))
    return sorted(c for c in cands if 1 <= c <= ah)


def _divisors_pow2ish(n: int) -> list[int]:
    """Divisors of n (exact column coverage is required by Eq. 1)."""
    return [d for d in range(1, n + 1) if n % d == 0]


#: Structural memo for candidate enumeration.  The candidate set depends
#: only on the GEMM extents and the config (never on ``name``/``count``),
#: and the batched-decode M-bucket ladder re-enumerates the same shapes
#: once per bucket -- so equal problems share one materialised tuple.
_ENUM_CACHE: dict[tuple, tuple[MappingChoice, ...]] = {}
_ENUM_CACHE_MAX = 256


def enumerate_choices(gemm: Gemm, cfg: FeatherConfig,
                      max_candidates: int = 512) -> Iterable[MappingChoice]:
    key = (gemm.m, gemm.k, gemm.n, cfg, max_candidates)
    hit = _ENUM_CACHE.get(key)
    if hit is None:
        hit = tuple(_enumerate_choices(gemm, cfg, max_candidates))
        if len(_ENUM_CACHE) >= _ENUM_CACHE_MAX:
            _ENUM_CACHE.pop(next(iter(_ENUM_CACHE)))
        _ENUM_CACHE[key] = hit
    return hit


def _enumerate_choices(gemm: Gemm, cfg: FeatherConfig,
                       max_candidates: int = 512) -> Iterable[MappingChoice]:
    ah, aw = cfg.ah, cfg.aw
    for df in (isa.Dataflow.WOS, isa.Dataflow.IOS):
        ms, ks, ns = ((gemm.n, gemm.k, gemm.m) if df == isa.Dataflow.IOS
                      else (gemm.m, gemm.k, gemm.n))
        # Heuristic from §III-C: IO-S when M > N, WO-S otherwise; we still
        # search both but the pruning keeps the promising one cheap.
        for vn in _vn_candidates(ks, ah):
            # tiling: prefer the largest tiles that fit (fewer reloads)
            k_opts = _pow2_tiles(min(vn, ks), min(ks, cfg.sta_bytes))
            k_opts = [k for k in k_opts[-3:]]
            for k_t in k_opts:
                max_nt = max(1, cfg.sta_bytes // max(k_t, 1))
                n_opts = _pow2_tiles(min(vn, ns), min(ns, max_nt))
                for n_t in n_opts[-3:]:
                    max_mt = max(1, min(cfg.str_bytes // max(k_t, 1),
                                        cfg.ob_bytes // (max(n_t, 1)
                                                         * cfg.acc_bytes),
                                        cfg.vn_slots_per_col))
                    m_opts = _pow2_tiles(1, min(ms, max_mt))
                    for m_t in m_opts[-3:]:
                        kg = math.ceil(min(k_t, ks) / vn)
                        nb = math.ceil(min(n_t, ns) / vn)
                        # Group-formation knobs.  Eq. 1's index arithmetic
                        # forces exact column coverage: G_r = AW/n_kg,
                        # G_c = n_nb and the duplication factor is
                        # structurally d = G_r / G_c, so (n_kg, n_nb) must
                        # divide the column space exactly and d is derived.
                        for n_kg in _divisors_pow2ish(aw):
                            if n_kg > 2 * kg:
                                continue  # >half the columns masked: skip
                            g_r = aw // n_kg
                            for n_nb in _divisors_pow2ish(g_r):
                                if n_nb > 2 * nb:
                                    continue
                                dup = g_r // n_nb
                                yield MappingChoice(
                                    df=df, vn=vn, m_t=m_t, k_t=k_t,
                                    n_t=n_t, n_kg=n_kg, n_nb=n_nb,
                                    dup=dup)


# ---------------------------------------------------------------------------
# Layout feasibility (step 6)
# ---------------------------------------------------------------------------

def _layouts_for(gemm: Gemm, choice: MappingChoice, dims: Tiling,
                 cfg: FeatherConfig) -> tuple[layoutlib.VNLayout,
                                              layoutlib.VNLayout,
                                              layoutlib.VNLayout] | None:
    """Derive (stationary, streaming, output) layouts realising the mapping
    without bank conflicts.

    FEATHER+'s all-to-all distribution makes the *stationary* side conflict-
    free by construction (any resident VN can reach any column, §III-B), so
    the binding constraints are:

      streaming: the single-bank buffer serves one row (AW elements) per
        cycle; at stream step t, element e, every column reads element e of
        I_VN(m[t,a_w], j[a_w]) -- all of those must live in one buffer row
        (multicast handles duplicates).  Satisfied by placing I_VNs with the
        reduction rank innermost across columns (order with nr_L0 outermost,
        red_L1 innermost) when n_kg*dup <= AW ... we *verify* by direct
        address simulation below instead of trusting the construction.

      output: the AW OB banks absorb one psum per bank per cycle; BIRRD can
        permute, so legality is "<= AW distinct banks per drain cycle",
        guaranteed when the O_VN layout's level-0 free factor >= concurrent
        n-block width.  Also verified directly.
    """
    vn = choice.vn
    kg = math.ceil(min(dims.k_t, gemm.k) / vn)
    m_eff = dims.m_eff
    n_eff = dims.n_eff

    # candidate orders, most-promising first
    stream_orders = [0b100, 0b010, 0b000, 0b001, 0b011, 0b101]
    for o_i in stream_orders:
        lay_i = layoutlib.layout_for(kg, m_eff, vn, cfg.aw, order=o_i,
                                     nr_l0=min(cfg.aw, m_eff))
        if _stream_feasible(lay_i, choice, dims, cfg):
            break
    else:
        return None
    lay_w = layoutlib.layout_for(kg, n_eff, vn, cfg.aw, order=choice.order_w)
    lay_o = layoutlib.layout_for(math.ceil(n_eff / vn), m_eff, vn, cfg.aw,
                                 order=choice.order_o)
    if lay_w.rows_needed > cfg.d_sta or lay_i.rows_needed > cfg.d_str:
        return None
    return lay_w, lay_i, lay_o


def _stream_feasible(lay_i: layoutlib.VNLayout, choice: MappingChoice,
                     dims: Tiling, cfg: FeatherConfig,
                     probe_steps: int = 4) -> bool:
    """Single-bank streaming legality by direct address simulation."""
    aw = cfg.aw
    g_r = max(1, (aw // max(choice.n_kg, 1)))
    g_c = max(1, choice.n_nb)
    a_w = np.arange(aw)
    j = a_w // g_r
    for t in range(min(probe_steps, dims.t_steps)):
        m = choice.dup * t + (a_w % g_r) // g_c
        valid = (m < dims.m_eff) & (j < lay_i.red_l1)
        if not valid.any():
            continue
        rows, _ = lay_i.address(np.where(valid, j, 0), np.where(valid, m, 0))
        rows = rows[valid]
        # all concurrent reads within one row -> single-bank OK (the vn
        # elements advance row-by-row in lockstep for every column)
        if np.unique(rows).size > 1:
            return False
    return True


# ---------------------------------------------------------------------------
# Top-level search
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Plan:
    gemm: Gemm
    cfg: FeatherConfig
    choice: MappingChoice
    program: programlib.Program
    layouts: tuple       # (W, I, O) VNLayouts
    perf_minisa: perf.PerfResult
    perf_micro: perf.PerfResult

    @property
    def speedup(self) -> float:
        return self.perf_micro.cycles / max(self.perf_minisa.cycles, 1e-9)

    def execute(self, tensors: dict, backend="interpreter", mesh=None,
                shard_axis: str | None = None, **backend_kwargs) -> dict:
        """Run the winning Program on an execution backend.

        ``backend`` is a registry name ('interpreter' drives the FEATHER+
        functional machine tile by tile; 'pallas' compiles the Program's
        tiling to one ``pl.pallas_call``) or a ``backends.Backend``
        instance for stateful multi-layer runs.  Returns the named output
        tensors ({self.program.out_name: ...}).

        ``mesh`` (a ``dist.ArrayMesh`` with ``n_arrays > 1``) executes
        the Program sharded across the mesh's arrays instead
        (``program.shard_program``; ``shard_axis`` overrides the axis
        policy).
        """
        from repro import backends as backendlib
        be = backendlib.get_backend(backend, self.cfg, **backend_kwargs)
        if mesh is not None and mesh.n_arrays > 1:
            sharded = programlib.shard_program(self.program, mesh,
                                               axis=shard_axis)
            return be.run_sharded(sharded, tensors)
        return be.run_program(self.program, tensors)

    def summary(self) -> dict:
        p = self.program
        minisa_bytes = p.minisa_bytes()
        micro_bytes = p.micro_storage_bytes()
        return {
            "workload": self.gemm.name or f"{self.gemm.m}x{self.gemm.k}x{self.gemm.n}",
            "array": f"{self.cfg.ah}x{self.cfg.aw}",
            "df": self.choice.df.name,
            "vn": self.choice.vn,
            "tile": (p.n_m, p.n_n, p.n_k),
            "cycles_minisa": self.perf_minisa.cycles,
            "cycles_micro": self.perf_micro.cycles,
            "speedup": self.speedup,
            "util_minisa": self.perf_minisa.utilization,
            "stall_micro": self.perf_micro.stall_ifetch_frac,
            "stall_minisa": self.perf_minisa.stall_ifetch_frac,
            "instr_bytes_minisa": minisa_bytes,
            "instr_bytes_micro": micro_bytes,
            "instr_reduction": micro_bytes / max(minisa_bytes, 1e-9),
            "data_bytes": self.gemm.data_bytes,
        }


def _prescore(gemm: Gemm, dims: Tiling, cfg: FeatherConfig) -> float:
    """Closed-form lower-bound latency for candidate *ranking* only (the
    discrete-event pass over real Program tiles runs on the shortlist)."""
    compute = dims.n_tiles * dims.invocations_per_tile \
        * dims.cycles_per_invocation
    i_bytes = dims.ms * dims.ks * cfg.elem_bytes
    w_bytes = dims.ks * dims.ns * cfg.elem_bytes
    loads = (i_bytes * (1 if i_bytes <= cfg.str_bytes else dims.n_n)
             + w_bytes * (1 if dims.ks * dims.n_t <= cfg.sta_bytes
                          else dims.n_m))
    store = dims.ms * dims.ns * cfg.elem_bytes
    instr = dims.n_tiles * dims.invocations_per_tile * (
        cfg.bits_execute_mapping() + cfg.bits_execute_streaming()
        * math.ceil(dims.t_steps / max(cfg.vn_slots_per_col, 1))) / 8.0
    return max(compute, loads / cfg.in_bw, store / cfg.out_bw,
               instr / cfg.instr_bw)


def _prescore_batch(gemm: Gemm, cfg: FeatherConfig,
                    choices: list[MappingChoice]
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Vectorised twin of ``tiling()`` feasibility + ``_prescore()`` over
    ALL enumerated candidates at once (one numpy pass instead of a
    per-candidate Python loop).  Returns ``(scores, feasible)``; the
    formulas replicate the scalar pair exactly, so the shortlist ranking
    is bit-identical to the loop it replaces (asserted in tests)."""
    ah, aw = cfg.ah, cfg.aw
    wos = np.fromiter((c.df == isa.Dataflow.WOS for c in choices),
                      dtype=bool, count=len(choices))
    as_i64 = lambda attr: np.fromiter(  # noqa: E731
        (getattr(c, attr) for c in choices), dtype=np.int64,
        count=len(choices))
    vn = as_i64("vn")
    m_t, k_t, n_t = as_i64("m_t"), as_i64("k_t"), as_i64("n_t")
    n_kg, n_nb, dup = as_i64("n_kg"), as_i64("n_nb"), as_i64("dup")

    ms = np.where(wos, gemm.m, gemm.n)
    ks = np.full_like(vn, gemm.k)
    ns = np.where(wos, gemm.n, gemm.m)

    feas = (vn >= 1) & (vn <= ah)
    vn_s = np.maximum(vn, 1)                  # div-safe; masked by feas
    # snap_tiling: clip to the problem, snap k_t to a VN multiple
    m_t = np.minimum(m_t, ms)
    k_t = np.minimum(k_t, ks)
    n_t = np.minimum(n_t, ns)
    feas &= (m_t >= 1) & (k_t >= 1) & (n_t >= 1)
    k_t = np.where(k_t < ks, np.maximum(vn_s, (k_t // vn_s) * vn_s), k_t)
    # capacity + shape feasibility (tiling())
    feas &= n_kg * n_nb * dup <= aw
    feas &= m_t * k_t <= cfg.str_bytes
    feas &= k_t * n_t <= cfg.sta_bytes
    feas &= m_t * n_t * cfg.acc_bytes <= cfg.ob_bytes

    m_ts = np.maximum(m_t, 1)
    k_ts = np.maximum(k_t, 1)
    n_ts = np.maximum(n_t, 1)
    n_m = -(-ms // m_ts)
    n_n = -(-ns // n_ts)
    n_k = -(-ks // k_ts)
    n_tiles = n_m * n_n * n_k
    kg_tiles = -(-k_t // vn_s)
    nb_tiles = -(-n_t // vn_s)
    invocations = ((-(-kg_tiles // np.maximum(n_kg, 1)))
                   * (-(-nb_tiles // np.maximum(n_nb, 1))))
    t_steps = -(-m_t // np.maximum(dup, 1))
    cycles_per_inv = (np.maximum(t_steps * vn, vn * vn)
                      + vn + cfg.birrd_stages + 2)

    compute = (n_tiles * invocations * cycles_per_inv).astype(np.float64)
    elem = cfg.elem_bytes
    i_bytes = ms * ks * elem
    w_bytes = ks * ns * elem
    loads = (i_bytes * np.where(i_bytes <= cfg.str_bytes, 1, n_n)
             + w_bytes * np.where(ks * n_t <= cfg.sta_bytes, 1, n_m))
    store = ms * ns * elem
    es_per_inv = -(-t_steps // max(cfg.vn_slots_per_col, 1))
    instr = n_tiles * invocations * (
        cfg.bits_execute_mapping()
        + cfg.bits_execute_streaming() * es_per_inv) / 8.0
    score = np.maximum.reduce([
        compute, loads / cfg.in_bw, store / cfg.out_bw,
        instr / cfg.instr_bw])
    return score, feas


def search(gemm: Gemm, cfg: FeatherConfig, top_k: int = 8,
           shortlist: int = 10,
           fixed_input_vn: int | None = None,
           fixed_input_order: int | None = None,
           vectorized: bool = True) -> Plan:
    """Mapping-first, layout-second co-search returning the best Plan.

    ``fixed_input_vn`` / ``fixed_input_order`` implement the paper's
    *layout-constrained* mode (artifact item 6, §V step 7's inter-layer
    compatibility): when layer i's output layout is already committed,
    layer i+1 may only consider mappings whose input VN size matches and
    whose input layout order equals the committed one.

    ``vectorized`` prescores ALL enumerated candidates in one numpy batch
    (``_prescore_batch``) and materialises ``Tiling`` objects only for
    the shortlist; ``False`` keeps the per-candidate Python loop (same
    ranking -- retained as the reference and for the before/after
    benchmark in ``benchmarks/run.py``).
    """
    pool: list[MappingChoice] = []
    seen = set()
    for choice in enumerate_choices(gemm, cfg):
        if fixed_input_vn is not None and choice.vn != fixed_input_vn:
            continue
        if fixed_input_order is not None:
            choice = dataclasses.replace(choice,
                                         order_i=fixed_input_order)
        key = dataclasses.astuple(choice)
        if key in seen:
            continue
        seen.add(key)
        pool.append(choice)

    candidates: list[tuple[float, MappingChoice, Tiling]] = []
    if vectorized and pool:
        scores, feas = _prescore_batch(gemm, cfg, pool)
        order = np.flatnonzero(feas)
        order = order[np.argsort(scores[order], kind="stable")]
        for i in order[:shortlist]:
            dims = tiling(gemm, pool[i], cfg)   # exact, shortlist-only
            if dims is not None:                # always true: same maths
                candidates.append((float(scores[i]), pool[i], dims))
    else:
        for choice in pool:
            dims = tiling(gemm, choice, cfg)
            if dims is None:
                continue
            candidates.append((_prescore(gemm, dims, cfg), choice, dims))
        candidates.sort(key=lambda x: x[0])
    if not candidates:
        raise ValueError(f"no feasible mapping for {gemm} on "
                         f"{cfg.ah}x{cfg.aw}")
    # shortlist: lower to real Programs and score the actual tile streams.
    # Lowering is O(tiles), so huge candidate programs draw down a shared
    # tile budget -- at least 4 candidates are always fully lowered.
    scored = []
    tile_budget = 60_000
    for _, choice, dims in candidates[:shortlist]:
        if len(scored) >= 4 and tile_budget <= 0:
            break
        tile_budget -= dims.n_tiles
        prog = programlib.lower(gemm, choice, cfg)
        res = perf.simulate(prog.tile_costs("minisa"), cfg)
        scored.append((res.cycles, choice, dims, prog, res))
    scored.sort(key=lambda x: x[0])
    # layout-second: walk the best mappings until one has a feasible layout
    chosen = None
    for cycles, choice, dims, prog, res in scored[:max(top_k, 1)]:
        layouts = _layouts_for(gemm, choice, dims, cfg)
        if layouts is not None:
            chosen = (choice, dims, prog, res, layouts)
            break
    if chosen is None:
        # fall back: best mapping with default layouts (always functional;
        # perf model unchanged -- conflicts would cost cycles on silicon)
        cycles, choice, dims, prog, res = scored[0]
        vn = choice.vn
        kg = math.ceil(min(choice.k_t, gemm.k) / vn)
        lay_w = layoutlib.layout_for(kg, dims.n_eff, vn, cfg.aw)
        lay_i = layoutlib.layout_for(kg, dims.m_eff, vn, cfg.aw)
        lay_o = layoutlib.layout_for(math.ceil(dims.n_eff / vn),
                                     dims.m_eff, vn, cfg.aw)
        chosen = (choice, dims, prog, res, (lay_w, lay_i, lay_o))
    choice, dims, prog, res_minisa, layouts = chosen
    res_micro = perf.simulate(prog.tile_costs("micro"), cfg)
    return Plan(gemm=gemm, cfg=cfg, choice=choice, program=prog,
                layouts=layouts, perf_minisa=res_minisa,
                perf_micro=res_micro)


# ---------------------------------------------------------------------------
# Joint segment search: Pareto frontier over the fused-launch geometry
# ---------------------------------------------------------------------------

# Fixed cost per streamed weight window: the DMA descriptor issue plus
# the double-buffer swap at every K-step boundary.  Transfer *bytes* are
# K-tile-invariant (each weight byte streams once per M pass), so
# without this term the cycle model could not see that 220 one-column
# windows cost more than 2 full-K windows and the frontier would
# collapse onto minimum-VMEM unit tiles.
STREAM_SETUP_CYCLES = 64


@dataclasses.dataclass(frozen=True)
class SegmentChoice:
    """One joint fused-launch geometry for a chained segment: the shared
    host-M tile (resident activation rows) plus every layer's host-K
    weight-streaming tile -- exactly the PR 7 streamed search space that
    per-GEMM search + post-hoc snapping explored only one point of."""
    bm: int
    layer_bks: tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class SegmentPoint:
    """A Pareto point of the joint search, priced on three axes: the
    MINISA HBM traffic the ONE fused launch ships, the analytic
    discrete-event cycles of the fused tile stream, and the streamed
    VMEM high-water (``program._streamed_footprint_bytes``)."""
    choice: SegmentChoice
    traffic_bytes: float
    cycles: float
    vmem_bytes: int

    @property
    def metrics(self) -> tuple[float, float, int]:
        return (self.traffic_bytes, self.cycles, self.vmem_bytes)


def _dominates(a: tuple, b: tuple) -> bool:
    """a Pareto-dominates b: no worse on every axis, better on one."""
    return (all(x <= y for x, y in zip(a, b))
            and any(x < y for x, y in zip(a, b)))


def pareto_frontier(points: list[SegmentPoint]) -> list[SegmentPoint]:
    """Non-dominated subset (first-seen wins among metric ties),
    cycles-ascending so ``points[:k]`` is the analytic top-k."""
    front: list[SegmentPoint] = []
    seen_metrics: set[tuple] = set()
    for p in points:
        if p.metrics in seen_metrics:
            continue
        if any(_dominates(q.metrics, p.metrics) for q in points):
            continue
        seen_metrics.add(p.metrics)
        front.append(p)
    return sorted(front, key=lambda p: (p.cycles, p.traffic_bytes,
                                        p.vmem_bytes))


@dataclasses.dataclass
class SegmentFrontier:
    """The joint search result: every surviving geometry, not a single
    winner -- the measured autotune pass (``runtime.autotune``) picks
    among these against real launch wall clock."""
    points: list[SegmentPoint]           # non-dominated, cycles-ascending
    n_enumerated: int                    # joint candidates generated
    n_feasible: int                      # ... that fit the VMEM budget
    vmem_budget: int
    operand_dtype: str

    def top(self, k: int) -> list[SegmentPoint]:
        return self.points[:max(1, k)]

    def summary(self) -> dict:
        return {"n_points": len(self.points),
                "n_enumerated": self.n_enumerated,
                "n_feasible": self.n_feasible,
                "vmem_budget": self.vmem_budget,
                "operand_dtype": self.operand_dtype,
                "best_cycles": self.points[0].cycles
                if self.points else None}


def _restated_tiles(segment, base_costs) -> list:
    """The fused tile stream for one candidate geometry: the
    geometry-independent per-layer costs (interior loads/stores elided)
    plus each layer's weight bytes restated to the candidate's streamed
    K-tile schedule -- ``FusedSegment.layer_tile_costs`` factored so the
    expensive Program walk happens once per segment, not per point."""
    cfg = segment.cfg
    tiles = []
    for layer, costs in enumerate(base_costs):
        kp = segment.padded_ks[layer]
        g = segment.programs[layer].gemm
        shipped = float(cfg.elem_bytes * segment.m_steps * kp * g.n)
        per_tile = shipped / max(len(costs), 1)
        tiles.extend(dataclasses.replace(t, load_bytes=t.load_bytes
                                         + per_tile)
                     for t in costs)
    return tiles


def _bk_vectors(programs, adapts, vmem_budget, operand_dtype) -> list:
    """Candidate per-layer K-tile vectors: halving pressure levels from
    full-K streaming down to unit tiles, plus each layer's own snapped
    ``k_t`` and the greedy capped vector (so the post-hoc-snap geometry
    is always IN the joint space and can never be lost to it)."""
    ks = [p.gemm.k for p in programs]
    vecs: list[tuple[int, ...]] = []
    for j in range(0, 9):
        vec = tuple(max(1, -(-k // (1 << j))) for k in ks)
        if vec not in vecs:
            vecs.append(vec)
        if all(v == 1 for v in vec):
            break
    snapped = []
    for p in programs:
        st = programlib.snap_tiling(p.gemm, p.choice, p.cfg)
        snapped.append(max(1, min(st[1], p.gemm.k)) if st else 1)
    if tuple(snapped) not in vecs:
        vecs.append(tuple(snapped))
    greedy = programlib.fuse_segment(
        list(programs), vmem_budget=vmem_budget, adapts=adapts,
        operand_dtype=operand_dtype)
    if greedy is not None and greedy.layer_bks not in vecs:
        vecs.append(greedy.layer_bks)
    return vecs


def search_segment(programs, *,
                   vmem_budget: int = programlib.FUSED_VMEM_BUDGET,
                   adapts: tuple[bool, ...] | None = None,
                   operand_dtype: str = "float32",
                   max_tiles: int = 4096) -> SegmentFrontier | None:
    """Map a whole chained segment at once (ROADMAP item 3).

    Enumerates joint candidates over (shared ``bm``) x (per-layer
    ``bk_l``) priced by ``program._streamed_footprint_bytes`` -- the
    dtype-aware streamed VMEM budget -- and keeps the Pareto frontier
    over {MINISA traffic bytes, analytic cycles, VMEM high-water}
    instead of a single winner.  Returns None when the segment is not
    fusion-legal (the per-layer fallback path applies).

    The per-layer Programs stay the lowering source of truth: a
    ``SegmentChoice`` only re-geometries the fused launch, so every
    frontier point shares the Programs' instruction accounting and
    numerics (same accumulation shapes, different K-tile walk).
    """
    programs = list(programs)
    if adapts is None:
        adapts = (False,) * len(programs)
    template = programlib.fuse_segment(
        programs, vmem_budget=vmem_budget, adapts=adapts,
        operand_dtype=operand_dtype)
    if template is None:
        return None
    cfg = template.cfg
    n_layers = template.n_layers
    base_costs = [
        programs[layer].tile_costs(
            "minisa", max_tiles,
            elide_input_loads=layer > 0,
            elide_weight_loads=True,
            on_chip_store=layer < n_layers - 1)
        for layer in range(n_layers)]

    m = programs[0].gemm.m
    if any(adapts):
        # the in-kernel slab permutation needs every row resident
        bm_opts = [max(p.gemm.m for p in programs)]
    else:
        bm_opts = sorted({m, template.bm,
                          *_pow2_tiles(1, m)}, reverse=True)[:8]
    bk_vecs = _bk_vectors(programs, adapts, vmem_budget, operand_dtype)

    points: list[SegmentPoint] = []
    n_enumerated = 0
    for bm in bm_opts:
        for bks in bk_vecs:
            n_enumerated += 1
            seg = dataclasses.replace(template, bm=bm, layer_bks=bks)
            vmem = seg.vmem_highwater_bytes()
            if vmem > vmem_budget:
                continue
            k_steps = seg.m_steps * sum(
                -(-p.gemm.k // max(1, bk))
                for p, bk in zip(programs, bks))
            cycles = (perf.simulate(_restated_tiles(seg, base_costs),
                                    cfg).cycles
                      + STREAM_SETUP_CYCLES * k_steps)
            points.append(SegmentPoint(
                choice=SegmentChoice(bm=bm, layer_bks=bks),
                traffic_bytes=seg.kernel_hbm_bytes(),
                cycles=cycles, vmem_bytes=vmem))
    if not points:
        return None
    return SegmentFrontier(points=pareto_frontier(points),
                           n_enumerated=n_enumerated,
                           n_feasible=len(points),
                           vmem_budget=vmem_budget,
                           operand_dtype=operand_dtype)
