"""Micro-instruction (baseline) control-traffic model (paper §III-D, Tab. I).

The baseline programs FEATHER+ the way FEATHER is programmed: explicit
fine-grained per-cycle configuration of every switch and address generator.
The paper gives asymptotics -- BIRRD control grows O(AW log AW), buffer
addressing O(D x AW) -- but not the RTL word format, so we model the stream
field-by-field and split it into two traffic classes:

STORAGE volume (Fig. 12 bar chart -- what must exist as a program image):
  every cycle's full configuration word:

    word = per-PE micro-ops + BIRRD switches + distribution crossbars
           + per-bank OB addresses + streaming addresses

FETCH volume (what crosses the 9 B/cycle off-chip instruction interface,
which is what causes Tab. I's stalls):
  * switch programs and bank addresses are constant (or counter-generated)
    *within* one NEST invocation, so the instruction buffer replays them;
    they are re-fetched once per invocation (the mapping changes);
  * per-PE enable/select micro-ops are data-position dependent and never
    repeat: a unique stream of ``micro_pe_bits`` * AH * AW bits/cycle.

Calibration: ``micro_pe_bits`` is the single global constant.  With the
default 0.7 bits/PE/cycle the model reproduces Tab. I as:

    paper:  4x4 0%   8x8 0%   4x64 75.3%  16x16 65.2%  8x128 90.4%  16x256 96.9%
    model:  0%       0%       ~60%        ~62%         ~90%         ~97%

(no per-workload fitting; see benchmarks/stall_table.py).  The small-array
zero-stall boundary (<=64 PEs, Fig. 10) falls out exactly: 64 PEs * 0.7 bits
= 5.6 B/cycle < 9 B/cycle interface.
"""

from __future__ import annotations

import dataclasses

from repro.configs.feather import FeatherConfig, _clog2


@dataclasses.dataclass(frozen=True)
class MicroModel:
    cfg: FeatherConfig

    # -- per-cycle field widths (bits) --------------------------------------
    @property
    def birrd_bits_per_cycle(self) -> int:
        """2 bits per 2x2 switch (pass/swap/add-l/add-r), all stages."""
        return self.cfg.birrd_stages * self.cfg.birrd_switches * 2

    @property
    def xbar_bits_per_cycle(self) -> int:
        """All-to-all distribution crossbars (streaming + stationary):
        a source-select per NEST column."""
        return 2 * self.cfg.aw * _clog2(self.cfg.aw)

    @property
    def ob_addr_bits_per_cycle(self) -> int:
        """Per-bank OB address generation: AW banks x ceil(log2 D_ob)."""
        return self.cfg.aw * _clog2(max(self.cfg.d_ob, 2))

    @property
    def stream_addr_bits_per_cycle(self) -> int:
        """Per-bank streaming addresses (FEATHER's multi-bank interface)."""
        return self.cfg.aw * _clog2(max(self.cfg.d_str, 2))

    @property
    def pe_bits_per_cycle(self) -> float:
        """Unique per-PE control micro-ops (calibrated, see module doc)."""
        return self.cfg.micro_pe_bits * self.cfg.ah * self.cfg.aw

    # -- traffic classes -----------------------------------------------------
    @property
    def storage_bits_per_cycle(self) -> float:
        """Full per-cycle configuration word (program-image size)."""
        return (self.pe_bits_per_cycle + self.birrd_bits_per_cycle
                + self.xbar_bits_per_cycle + self.ob_addr_bits_per_cycle
                + self.stream_addr_bits_per_cycle)

    @property
    def unique_bits_per_cycle(self) -> float:
        """Never-repeating off-chip stream (fetch-side)."""
        return self.pe_bits_per_cycle

    @property
    def program_bits_per_invocation(self) -> float:
        """Re-fetched whenever the NEST mapping changes: switch programs +
        address-counter bases."""
        return (self.birrd_bits_per_cycle + self.xbar_bits_per_cycle
                + self.ob_addr_bits_per_cycle + self.stream_addr_bits_per_cycle)

    # -- per-workload volumes -------------------------------------------------
    def storage_bytes(self, compute_cycles: float) -> float:
        """Total micro-instruction bytes of the program image (Fig. 12)."""
        return self.storage_bits_per_cycle * compute_cycles / 8.0

    def fetch_bytes(self, compute_cycles: float, invocations: int) -> float:
        """Bytes crossing the off-chip instruction interface."""
        return (self.unique_bits_per_cycle * compute_cycles
                + self.program_bits_per_invocation * max(invocations, 1)) / 8.0
