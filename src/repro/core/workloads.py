"""Benchmark GEMM suite (paper Tab. IV): FHE BConv, FHE NTT, ZKP NTT,
GPT-oss.

Instantiating Tab. IV exactly yields 41 + 6 + 6 + 5 = 58 GEMMs (the prose
says "50"; the discrepancy is in the paper's own table -- we keep the full
table and report geomeans over it, see DESIGN.md §5).

BConv: the paper gives ranges K in [28, 60], N in [72, 160] with 41 shapes;
the concrete 41 (K, N) pairs are not listed, so we lay a deterministic
lattice over the ranges (documented here, fixed seed-free).
"""

from __future__ import annotations

from repro.core.mapper import Gemm


def _bconv_shapes() -> list[Gemm]:
    """41 deterministic (K, N) pairs spanning K in [28,60], N in [72,160].

    OpenFHE bootstrapping BConv kernels have K = #RNS limbs and N = #towers
    x digits; we use a uniform lattice: K stepped by 4 (9 values including
    irregular non-multiples of 4 via +2 offsets), N stepped by 8.
    """
    ks = [28, 30, 34, 38, 40, 44, 48, 52, 56, 60]
    ns = [72, 80, 88, 96, 112, 128, 144, 160]
    pairs = []
    # 41 pairs: diagonal-ish coverage of the lattice
    i = 0
    for kidx, k in enumerate(ks):
        for nidx, n in enumerate(ns):
            if (kidx + nidx) % 2 == 0:
                pairs.append((k, n))
                i += 1
    pairs = pairs[:41]
    while len(pairs) < 41:
        pairs.append((ks[len(pairs) % len(ks)], ns[len(pairs) % len(ns)]))
    return [Gemm(m=65536, k=k, n=n, name=f"fhe-bconv-{k}x{n}")
            for k, n in pairs]


def _fhe_ntt_shapes() -> list[Gemm]:
    """J = K = N in {1024, 2048, 4096}, M in {64, 128, 256}, M <= K/16."""
    out = []
    for k in (1024, 2048, 4096):
        for m in (64, 128, 256):
            if m <= k // 16:
                out.append(Gemm(m=m, k=k, n=k, name=f"fhe-ntt-{m}x{k}"))
    return out


def _zkp_ntt_shapes() -> list[Gemm]:
    """J = K = N in {8192, 16384, 32768}, M in {K/32, K/16}."""
    out = []
    for k in (8192, 16384, 32768):
        for m in (k // 32, k // 16):
            out.append(Gemm(m=m, k=k, n=k, name=f"zkp-ntt-{m}x{k}"))
    return out


def _gpt_oss_shapes() -> list[Gemm]:
    """GPT-oss 20B decode-batch GEMMs: M = 2048,
    (J=K, N) in {(64, 2048), (2880, 4096/5120/201088), (4096, 2880)}."""
    shapes = [(64, 2048), (2880, 4096), (2880, 5120), (2880, 201088),
              (4096, 2880)]
    return [Gemm(m=2048, k=k, n=n, name=f"gpt-oss-{k}x{n}")
            for k, n in shapes]


def suite() -> list[Gemm]:
    return (_bconv_shapes() + _fhe_ntt_shapes() + _zkp_ntt_shapes()
            + _gpt_oss_shapes())


def by_domain() -> dict[str, list[Gemm]]:
    return {
        "fhe-bconv": _bconv_shapes(),
        "fhe-ntt": _fhe_ntt_shapes(),
        "zkp-ntt": _zkp_ntt_shapes(),
        "gpt-oss": _gpt_oss_shapes(),
    }


def ci_conv():
    """The conv workload of the CI suite (paper Fig. 1: conv -> MatMul
    via im2col): a 3x3 conv whose im2col GEMM is 196 x 72 x 16."""
    from repro.core.conv import Conv2D
    return Conv2D(n=1, h=14, w=14, c_in=8, kh=3, kw=3, c_out=16,
                  name="conv-3x3s1-8to16-ci")


def ci_suite() -> list[Gemm]:
    """The Tab. IV sweep at functionally-executable extents, plus one
    conv (as its im2col GEMM) so the conv path rides the same spine.

    Same four families and the same relative geometry (tall-skinny BConv,
    square NTT, wide decode GEMMs), with the huge ranks scaled down so the
    execution backends can run *every* mapping the mapper emits against
    the einsum oracle on CPU in CI (max rank 256, max ~8M MACs/GEMM).
    The family scale factors are chosen so the downscaled families do not
    land on each other (fhe-ntt keeps m >= 32, zkp-ntt m <= 16); the one
    shape Tab. IV's own filler duplicates gets a deterministic m bump, so
    all 58 shapes are pairwise distinct and each contributes its own
    mapping-search problem.
    """
    out = [Gemm(m=96, k=g.k, n=g.n, name=g.name + "-ci")
           for g in _bconv_shapes()]
    out += [Gemm(m=g.m // 2, k=g.k // 16, n=g.n // 16, name=g.name + "-ci")
            for g in _fhe_ntt_shapes()]
    out += [Gemm(m=max(g.m // 128, 2), k=g.k // 256, n=g.n // 256,
                 name=g.name + "-ci")
            for g in _zkp_ntt_shapes()]
    out += [Gemm(m=64, k=max(g.k // 32, 8), n=min(max(g.n // 32, 8), 192),
                 name=g.name + "-ci")
            for g in _gpt_oss_shapes()]
    out.append(ci_conv().to_gemm())
    seen: set[tuple[int, int, int]] = set()
    uniq: list[Gemm] = []
    for g in out:
        m = g.m
        while (m, g.k, g.n) in seen:
            m += 8
        seen.add((m, g.k, g.n))
        uniq.append(Gemm(m=m, k=g.k, n=g.n, name=g.name))
    return uniq


def small_suite() -> list[Gemm]:
    """Reduced shapes (same families) for CI-speed tests."""
    return [
        Gemm(m=256, k=40, n=88, name="fhe-bconv-small"),
        Gemm(m=64, k=1024, n=1024, name="fhe-ntt-small"),
        Gemm(m=256, k=8192, n=8192, name="zkp-ntt-small"),
        Gemm(m=128, k=64, n=2048, name="gpt-oss-small"),
    ]
