"""MINISA core: the paper's contribution as a composable library.

Public surface:

  configs.feather.FeatherConfig / feather_config / SWEEP
  core.isa          -- the 8 MINISA instructions + bitwidths
  core.layout       -- Set*VNLayout semantics and address generation
  core.vn           -- Virtual Neuron views of operands
  core.machine      -- MINISA instruction semantics (FEATHER+ state in JAX)
  core.microinst    -- micro-instruction baseline traffic model
  core.perf         -- 5-engine analytical performance model
  core.mapper       -- mapping/layout co-search (paper §V)
  core.program      -- tiled Program IR (the single lowered artifact)
  core.workloads    -- Tab. IV GEMM suite
  core.planner      -- LM model graph -> per-layer MINISA plans

Execution backends (interpreter / Pallas) live in ``repro.backends``;
the model runtime (ProgramCache / ModelExecutable / Scheduler) lives in
``repro.runtime``.
"""

from repro.core.mapper import Gemm, MappingChoice, Plan, search  # noqa: F401
from repro.core.program import Program, Tile, lower  # noqa: F401
from repro.core.machine import (FeatherMachine, TraceOp, run_program,  # noqa: F401
                                run_trace)
