"""Virtual Neuron (VN) abstraction (paper §IV-A/B).

A VN is the minimal hardware dot-product atom: ``vn_size`` (<= AH)
consecutive elements along the *reduction* rank of an operand.

For a GEMM  O[M, N] = I[M, K] @ W[K, N]:

  I_VN(m, j): j in [0, ceil(K / vn)),  I[m, j*vn:(j+1)*vn]      (reduce K)
  W_VN(r, c): r in [0, ceil(K / vn)),  W[r*vn:(r+1)*vn, c]      (reduce K)
  O_VN(p, q): grouped along Q=N (which is the next layer's reduction rank J)

Out-of-range elements are zero-padded (paper: "implicitly zero-padded").
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


def num_vns(reduction_extent: int, vn_size: int) -> int:
    return math.ceil(reduction_extent / vn_size)


@dataclasses.dataclass(frozen=True)
class VNShape:
    """Logical 2-D VN array: rows = reduction-tile index, cols = free rank."""
    rows: int  # ceil(reduction / vn_size)
    cols: int  # free-rank extent
    vn_size: int

    @property
    def count(self) -> int:
        return self.rows * self.cols


def weight_vn_shape(k: int, n: int, vn_size: int) -> VNShape:
    return VNShape(rows=num_vns(k, vn_size), cols=n, vn_size=vn_size)


def input_vn_shape(m: int, k: int, vn_size: int) -> VNShape:
    # I_VN is indexed (m, j): free rank M, reduction tiles along K=J.
    return VNShape(rows=num_vns(k, vn_size), cols=m, vn_size=vn_size)


def output_vn_shape(m: int, n: int, vn_size: int) -> VNShape:
    # O_VN grouped along Q=N (next layer's reduction rank).
    return VNShape(rows=num_vns(n, vn_size), cols=m, vn_size=vn_size)


# ---------------------------------------------------------------------------
# Dense VN views (numpy; the JAX machine builds these on device)
# ---------------------------------------------------------------------------

def to_weight_vns(w: np.ndarray, vn_size: int) -> np.ndarray:
    """W[K, N] -> W_VN[rows, N, vn_size] with zero padding along K."""
    k, n = w.shape
    rows = num_vns(k, vn_size)
    pad = rows * vn_size - k
    wp = np.pad(w, ((0, pad), (0, 0)))
    return np.transpose(wp.reshape(rows, vn_size, n), (0, 2, 1))


def to_input_vns(i: np.ndarray, vn_size: int) -> np.ndarray:
    """I[M, K] -> I_VN[rows, M, vn_size] (row index = reduction tile j)."""
    m, k = i.shape
    rows = num_vns(k, vn_size)
    pad = rows * vn_size - k
    ip = np.pad(i, ((0, 0), (0, pad)))
    return np.transpose(ip.reshape(m, rows, vn_size), (1, 0, 2))


def from_output_vns(o_vn: np.ndarray, m: int, n: int) -> np.ndarray:
    """O_VN[rows, M, vn_size] -> O[M, N] (inverse of output grouping)."""
    rows, m_, vn = o_vn.shape
    assert m_ == m
    o = np.transpose(o_vn, (1, 0, 2)).reshape(m, rows * vn)
    return o[:, :n]


def to_output_vns(o: np.ndarray, vn_size: int) -> np.ndarray:
    """O[M, N] -> O_VN[rows, M, vn_size] grouped along N."""
    m, n = o.shape
    rows = num_vns(n, vn_size)
    pad = rows * vn_size - n
    op = np.pad(o, ((0, 0), (0, pad)))
    return np.transpose(op.reshape(m, rows, vn_size), (1, 0, 2))
