"""Set*VNLayout semantics (paper §IV-F, Tab. III, Fig. 5/6).

A layout places a logical 2-rank tensor into a physical ``D x AW`` buffer:

  1. split each rank into two levels:  K = K_L1 * K_L0,  N = N_L1 * N_L0,
     with the innermost *reduction* factor pinned to the VN size
     (K_L0 = vn_size), leaving three free ranks {K_L1, N_L0, N_L1};
  2. order those three ranks with one of 3! = 6 permutations (3-bit code);
  3. flatten VNs to a 1-D index L in that order and fold row-major into the
     D x AW buffer: a VN occupies ``vn_size`` consecutive rows at one column:

        slot  = L // AW,  col = L % AW
        element e of the VN lives at (slot * vn_size + e, col).

The identity of the three free ranks differs per operand (Tab. III) but the
permutation structure is shared; we canonicalise the rank tuple as

    (red_L1, nr_L0, nr_L1)

i.e. (K_L1, N_L0, N_L1) for W_VN, (J_L1, M_L0, M_L1) for I_VN and
(Q_L1, P_L0, P_L1) for O_VN.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import isa

# Tab. III: 3-bit code -> permutation (outermost..innermost) over the
# canonical rank tuple indices (0 = red_L1, 1 = nr_L0, 2 = nr_L1).
ORDER_TABLE: dict[int, tuple[int, int, int]] = {
    0b000: (0, 1, 2),
    0b001: (0, 2, 1),
    0b010: (1, 0, 2),
    0b011: (1, 2, 0),
    0b100: (2, 0, 1),
    0b101: (2, 1, 0),
}


@dataclasses.dataclass(frozen=True)
class VNLayout:
    """A concrete, bound layout for one operand in one buffer."""

    order: int          # Tab. III permutation id
    nr_l0: int          # level-0 factor of the non-reduction rank (<= AW)
    nr_l1: int          # level-1 factor of the non-reduction rank
    red_l1: int         # level-1 factor of the reduction rank (# VN rows)
    vn_size: int
    aw: int             # physical buffer width

    def __post_init__(self):
        if self.order not in ORDER_TABLE:
            raise ValueError(f"reserved order code {self.order}")
        for f in (self.nr_l0, self.nr_l1, self.red_l1, self.vn_size):
            if f < 1:
                raise ValueError("partition factors must be >= 1")

    # -- logical -> flattened VN index -------------------------------------
    @property
    def nr_extent(self) -> int:
        return self.nr_l0 * self.nr_l1

    @property
    def num_vns(self) -> int:
        return self.red_l1 * self.nr_extent

    @property
    def rows_needed(self) -> int:
        """Buffer rows consumed."""
        return math.ceil(self.num_vns / self.aw) * self.vn_size

    def flatten(self, r, c):
        """VN (r = reduction-tile index, c = non-reduction index) -> L.

        Accepts scalars or numpy arrays.  c is split as
        c = nr_l1_idx * nr_l0 + nr_l0_idx  (paper §IV-F.3).
        """
        rv = (r, np.mod(c, self.nr_l0), c // self.nr_l0)   # (red_L1, nr_L0, nr_L1)
        extents = (self.red_l1, self.nr_l0, self.nr_l1)
        p0, p1, p2 = ORDER_TABLE[self.order]
        return (rv[p0] * extents[p1] * extents[p2]
                + rv[p1] * extents[p2]
                + rv[p2])

    def unflatten(self, l):
        """Inverse of flatten: L -> (r, c)."""
        extents = (self.red_l1, self.nr_l0, self.nr_l1)
        p0, p1, p2 = ORDER_TABLE[self.order]
        v0 = l // (extents[p1] * extents[p2])
        rem = np.mod(l, extents[p1] * extents[p2])
        v1 = rem // extents[p2]
        v2 = np.mod(rem, extents[p2])
        rv = [None, None, None]
        rv[p0], rv[p1], rv[p2] = v0, v1, v2
        r = rv[0]
        c = rv[2] * self.nr_l0 + rv[1]
        return r, c

    # -- flattened VN index -> physical address -----------------------------
    def address(self, r, c):
        """VN (r, c) -> (first_row, col) in the D x AW buffer."""
        l = self.flatten(r, c)
        return (l // self.aw) * self.vn_size, np.mod(l, self.aw)

    # -- instruction form ----------------------------------------------------
    def to_instruction(self, operand: str) -> isa.SetLayoutBase:
        cls = {"W": isa.SetWVNLayout, "I": isa.SetIVNLayout,
               "O": isa.SetOVNLayout}[operand]
        return cls(order=self.order, nr_l0=self.nr_l0, nr_l1=self.nr_l1,
                   red_l1=self.red_l1)


def layout_for(operand_rows: int, operand_cols: int, vn_size: int, aw: int,
               order: int = 0, nr_l0: int | None = None) -> VNLayout:
    """Construct a layout covering a VN array of (rows=red tiles, cols=free).

    ``nr_l0`` defaults to min(cols, aw) (paper caps level-0 non-reduction
    factors at AW since larger values are performance-equivalent).
    """
    if nr_l0 is None:
        nr_l0 = min(operand_cols, aw)
    nr_l0 = max(1, min(nr_l0, aw))
    nr_l1 = math.ceil(operand_cols / nr_l0)
    return VNLayout(order=order, nr_l0=nr_l0, nr_l1=nr_l1,
                    red_l1=operand_rows, vn_size=vn_size, aw=aw)


# ---------------------------------------------------------------------------
# Buffer images (host-side reference placement used by the machine + tests)
# ---------------------------------------------------------------------------

def place(vns: np.ndarray, layout: VNLayout, depth: int) -> np.ndarray:
    """Materialise a buffer image from a VN array [rows, cols, vn_size].

    Returns a float/int array of shape (depth, aw); unused space is zero.
    Raises if the layout does not fit.
    """
    rows, cols, vn = vns.shape
    if vn != layout.vn_size:
        raise ValueError("vn_size mismatch")
    if rows > layout.red_l1 or cols > layout.nr_extent:
        raise ValueError("VN array exceeds layout extents")
    if layout.rows_needed > depth:
        raise ValueError(
            f"layout needs {layout.rows_needed} rows > buffer depth {depth}")
    buf = np.zeros((depth, layout.aw), dtype=vns.dtype)
    r_idx, c_idx = np.meshgrid(np.arange(rows), np.arange(cols), indexing="ij")
    first_row, col = layout.address(r_idx, c_idx)
    for e in range(vn):
        buf[first_row + e, col] = vns[r_idx, c_idx, e]
    return buf


def gather(buf: np.ndarray, layout: VNLayout, r, c) -> np.ndarray:
    """Read VN(r, c) back from a buffer image -> [..., vn_size].

    Out-of-extent (r, c) return zeros (paper: implicit zero padding).
    """
    r = np.asarray(r)
    c = np.asarray(c)
    valid = (r >= 0) & (r < layout.red_l1) & (c >= 0) & (c < layout.nr_extent)
    rs = np.where(valid, r, 0)
    cs = np.where(valid, c, 0)
    first_row, col = layout.address(rs, cs)
    out = np.stack([buf[first_row + e, col] for e in range(layout.vn_size)],
                   axis=-1)
    return np.where(valid[..., None], out, 0)
