"""Tiled Program IR: the single lowered artifact shared by simulation,
byte accounting and functional execution.

A :class:`Program` is an ordered sequence of :class:`Tile`\\ s.  Each tile
carries its MINISA instructions (Load* / ExecuteMapping / ExecuteStreaming /
Activation / Write TraceOps with simulator side-band metadata), knows its
operand-residency mode, and exposes a :class:`repro.core.perf.TileCost`.
One lowering produces everything downstream:

    Gemm + MappingChoice --lower()--> Program
        --> backends.InterpreterBackend  (functional execution, tile by tile)
        --> backends.PallasBackend       (compiled: tiling -> pallas_call)
        --> perf.simulate(tile_costs())  (5-engine analytical model)
        --> minisa_bytes()               (byte accounting == trace_bits of
                                          the flattened instruction stream)

so what we *count* is by construction what we *execute* -- there is no
separate closed-form instruction/byte model.

Scale-out: :func:`shard_program` partitions a Program across a
``dist.ArrayMesh`` of FEATHER+ arrays (M/N output splits, or K with a
reduction epilogue) into a :class:`ShardedProgram` whose per-array
sub-Programs keep all of the above exact per array.

Fusion: :func:`fuse_segment` turns a ``chain()``-ed segment into a
:class:`FusedSegment` -- the launch geometry for ONE compiled kernel
covering the whole chain, with every interior activation resident
on-chip (the kernel-level analog of the §IV-G commit) and the traffic
accounting elided to match.

Tiling & residency
------------------
The loop nest is n-outer, m-mid, k-inner in the mapper's search
orientation (IO-S transposes the GEMM).  Each operand is lowered in one of
three residency modes, decided against the real buffer capacities:

  full    the whole operand fits: one Load up front, VN indices are global
  panel   (stationary only) one k-panel per n-tile fits: incremental Loads
          per k-tile, reused across the m loop; VN rows global, cols local
  tiled   per-tile Loads every visit; VN indices tile-local

Execute instructions address whatever the Loads put in the buffer, so the
index bases differ per mode; the ExecuteStreaming TraceOp meta carries the
tile's global offsets/bounds for the simulator (j_off / m_off / c_off /
r_hi / c_hi / m_hi), which is side-band only -- hardware derives the same
from the Load base registers.

Inner-loop compression: the EM/ES block of a tile is stored as a compact
:class:`ExecBlock` descriptor (instruction *counts* and bitwidths are
exact; the instruction objects themselves materialise lazily via
``trace_ops()``), so lowering a multi-million-invocation GEMM stays O(tiles)
while the flattened stream remains well-defined and byte-identical.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Iterator

from repro.configs.feather import FeatherConfig
from repro.core import isa, layout as layoutlib, perf
from repro.core.microinst import MicroModel


@dataclasses.dataclass
class TraceOp:
    """An instruction plus simulation side-band metadata.

    The ISA encodes only what hardware needs (Fig. 3/5); the simulator
    additionally needs to know *which* host tensor a Load refers to, the
    bound VNLayout object and where a tile sits in the global problem.
    ``meta`` keys used:

      Load:            tensor (str), operand ('I'|'W'), layout (VNLayout),
                       slice ((r0, r1, c0, c1) host coords | None = whole),
                       vn_row0 / col0 (placement offset in the layout's VN
                       array), reset (bool), extents ((red, free) validity
                       region)
      Set*VNLayout:    layout (VNLayout)
      SetOVNLayout:    m_extent, n_extent (full accumulator shape)
      ExecuteStreaming: j_off, m_off, c_off, r_hi, c_hi, m_hi (tile bounds)
      Write:           tensor (str), transpose (bool), slice ((m0, m1, n0,
                       n1) in search orientation), final (bool), commit_to
                       (None | 'streaming' | 'stationary'), layout (commit
                       re-bind layout)
      Activation:      fn (callable) applied to the drained output slice,
                       name (act registry key -- lets the machine apply a
                       device-side twin without leaving the accelerator)
    """
    inst: isa.Instruction
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# Execute block (compressed EM/ES inner loop of one tile)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExecBlock:
    """The (ExecuteMapping, ExecuteStreaming*) lattice of one tile.

    Shared by every tile of the same extent class; instruction counts and
    per-instruction bitwidths are exact, materialisation is lazy.
    """
    kg_ext: int          # reduction groups covered by this tile
    nb_ext: int          # n-blocks covered by this tile
    m_ext: int           # streamed free-rank extent
    vn: int
    n_kg: int
    n_nb: int
    g_r: int
    g_c: int
    s_r: int
    s_c: int
    t_max: int           # ES T-field bound (paper §IV-G sub-tiling)
    df: isa.Dataflow

    @property
    def dup(self) -> int:
        return max(1, self.g_r // self.g_c)

    @property
    def t_steps(self) -> int:
        return math.ceil(self.m_ext / self.dup)

    @property
    def n_invocations(self) -> int:
        return (math.ceil(self.kg_ext / max(self.n_kg, 1))
                * math.ceil(self.nb_ext / max(self.n_nb, 1)))

    @property
    def es_per_invocation(self) -> int:
        return math.ceil(self.t_steps / max(self.t_max, 1))

    @property
    def n_es(self) -> int:
        return self.n_invocations * self.es_per_invocation

    def bits(self, cfg: FeatherConfig) -> int:
        return (self.n_invocations * cfg.bits_execute_mapping()
                + self.n_es * cfg.bits_execute_streaming())

    def compute_cycles(self, cfg: FeatherConfig) -> float:
        """Per-invocation: stream T VNs x vn cycles each; the stationary
        (re)load of vn VNs x vn elements is double-buffered and exposed
        only when longer than the streaming phase; plus drain."""
        stream = self.t_steps * self.vn
        sta_load = self.vn * self.vn
        drain = self.vn + cfg.birrd_stages + 2
        return self.n_invocations * (max(stream, sta_load) + drain)

    def trace_ops(self, sta_row_base: int, sta_col_base: int,
                  str_m_base: int, es_meta: dict) -> Iterator[TraceOp]:
        """Materialise the EM/ES stream with this tile's index bases."""
        dup = self.dup
        m_span = dup * max(self.t_max, 1)
        for kg0 in range(0, self.kg_ext, self.n_kg):
            em = isa.ExecuteMapping(
                r0=sta_row_base + kg0, c0=sta_col_base,
                g_r=self.g_r, g_c=self.g_c, s_r=self.s_r, s_c=self.s_c)
            for nb0 in range(0, self.nb_ext, self.n_nb):
                if nb0:
                    em = dataclasses.replace(
                        em, c0=sta_col_base + nb0 * self.vn)
                yield TraceOp(em, {})
                for mc in range(0, self.m_ext, m_span):
                    t = min(self.t_max,
                            math.ceil((self.m_ext - mc) / dup))
                    yield TraceOp(
                        isa.ExecuteStreaming(
                            m0=str_m_base + mc, s_m=dup, t=t,
                            vn_size=self.vn, df=self.df),
                        es_meta)


# ---------------------------------------------------------------------------
# Tile
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Tile:
    """One schedulable unit: its loads, its execute block, its drains."""
    im: int
    i_n: int
    ik: int
    m0: int                      # element offsets (search orientation)
    n0: int
    k0: int
    m_ext: int
    n_ext: int
    k_ext: int
    loads: tuple[TraceOp, ...]
    exec_block: ExecBlock
    drains: tuple[TraceOp, ...]  # [Activation,] Write at the last k tile
    sta_row_base: int
    sta_col_base: int
    str_row_base: int
    str_m_base: int
    last_k: bool

    @property
    def macs(self) -> int:
        return self.m_ext * self.k_ext * self.n_ext

    def es_meta(self) -> dict:
        return {
            "j_off": self.str_row_base - self.sta_row_base,
            "m_off": self.m0 - self.str_m_base,
            "c_off": self.n0 - self.sta_col_base,
            "r_hi": self.sta_row_base + self.exec_block.kg_ext,
            "c_hi": self.sta_col_base + self.n_ext,
            "m_hi": self.str_m_base + self.m_ext,
        }

    def trace_ops(self) -> Iterator[TraceOp]:
        yield from self.loads
        yield from self.exec_block.trace_ops(
            self.sta_row_base, self.sta_col_base, self.str_m_base,
            self.es_meta())
        yield from self.drains

    def bits(self, cfg: FeatherConfig) -> int:
        fixed = sum(op.inst.bitwidth(cfg)
                    for op in self.loads + self.drains)
        return fixed + self.exec_block.bits(cfg)


# ---------------------------------------------------------------------------
# Program
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Program:
    """Lowered tiled program for one GEMM layer."""
    gemm: Any                     # mapper.Gemm (kept duck-typed: m/k/n)
    choice: Any                   # mapper.MappingChoice
    cfg: FeatherConfig
    prologue: tuple[TraceOp, ...]   # SetIVNLayout? SetWVNLayout SetOVNLayout
    tiles: list[Tile]
    n_m: int
    n_n: int
    n_k: int
    residency: dict[str, str]     # {'stationary': mode, 'streaming': mode}
    input_role: str               # 'streaming' (WO-S) | 'stationary' (IO-S)
    out_name: str = "O"
    activation: Callable | None = None
    act_name: str = "none"
    input_elided: bool = False
    #: per-Program memo of trace-derived aggregates (tile-cost streams,
    #: instruction bits): ``perf.simulate`` and the MINISA byte accounting
    #: consume the same stream several times per Program (minisa vs micro
    #: control, mapper scoring, runtime perf_stats), so regenerating it
    #: each call is pure waste.  Keyed by the derivation arguments; never
    #: part of equality/pickling semantics.
    _memo: dict = dataclasses.field(default_factory=dict, repr=False,
                                    compare=False)

    def __getstate__(self):
        # the memo is derivable state: keep pickles (ProgramCache disk
        # persistence) lean and deterministic
        state = self.__dict__.copy()
        state["_memo"] = {}
        return state

    # -- structure -----------------------------------------------------------
    @property
    def n_tiles(self) -> int:
        return len(self.tiles)

    @property
    def total_invocations(self) -> int:
        return sum(t.exec_block.n_invocations for t in self.tiles)

    @property
    def macs(self) -> int:
        return sum(t.macs for t in self.tiles)

    def trace_ops(self) -> Iterator[TraceOp]:
        yield from self.prologue
        for tile in self.tiles:
            yield from tile.trace_ops()

    def instructions(self) -> Iterator[isa.Instruction]:
        for op in self.trace_ops():
            yield op.inst

    # -- byte accounting (exact: equals trace_bits of the flat stream) -------
    def minisa_bits(self) -> int:
        hit = self._memo.get("minisa_bits")
        if hit is not None:
            return hit
        cfg = self.cfg
        bits = sum(op.inst.bitwidth(cfg) for op in self.prologue)
        block_bits: dict[int, int] = {}
        for tile in self.tiles:
            key = id(tile.exec_block)
            if key not in block_bits:
                block_bits[key] = tile.exec_block.bits(cfg)
            bits += block_bits[key] + _fixed_bits(tile, cfg)
        self._memo["minisa_bits"] = bits
        return bits

    def minisa_bytes(self) -> float:
        return self.minisa_bits() / 8.0

    def summary(self) -> dict:
        return isa.trace_summary(self.instructions(), self.cfg)

    # -- timing --------------------------------------------------------------
    @property
    def compute_cycles(self) -> float:
        cycles: dict[int, float] = {}
        total = 0.0
        for tile in self.tiles:
            key = id(tile.exec_block)
            if key not in cycles:
                cycles[key] = tile.exec_block.compute_cycles(self.cfg)
            total += cycles[key]
        return total

    # -- micro-instruction baseline (counterfactual control scheme) ----------
    def micro_storage_bytes(self) -> float:
        return MicroModel(self.cfg).storage_bytes(self.compute_cycles)

    def micro_fetch_bytes(self) -> float:
        return MicroModel(self.cfg).fetch_bytes(
            self.compute_cycles, self.total_invocations)

    # -- perf-model tile stream (THE tile stream, not a re-derivation) -------
    def tile_costs(self, control: str = "minisa",
                   max_tiles: int = 4096, *,
                   elide_input_loads: bool = False,
                   elide_weight_loads: bool = False,
                   on_chip_store: bool = False) -> list[perf.TileCost]:
        """control in {'minisa', 'micro'} selects the fetch stream.

        A Write whose meta marks an on-chip commit (``commit_to``, paper
        §IV-G) never crosses HBM: it is costed as OB->operand-buffer
        commit cycles (out2stream) instead of store bytes, so the data
        traffic the model charges is the traffic the chain actually
        ships.  ``elide_input_loads`` / ``on_chip_store`` extend the same
        accounting to fused-segment execution, where *every* interior
        activation stays in VMEM: input-operand Loads (the consumer side
        of the chain) and all output Writes (the producer side) are kept
        on-chip.  ``elide_weight_loads`` drops the weight-operand Loads
        instead -- the streamed fused launch replaces the Program's
        residency-derived weight traffic with its own K-tile schedule
        (``FusedSegment.layer_tile_costs`` folds those bytes back in).

        Streams longer than ``max_tiles`` are run-length merged (k
        consecutive tiles -> one cost with summed fields); the engine
        recurrence is linear over uniform runs, so merging preserves the
        makespan to within one tile's skew.

        Results are memoised per (control, max_tiles, flags) -- see
        ``_memo``.
        """
        memo_key = ("tile_costs", control, max_tiles, elide_input_loads,
                    elide_weight_loads, on_chip_store)
        hit = self._memo.get(memo_key)
        if hit is not None:
            return hit
        cfg = self.cfg
        micro = MicroModel(cfg) if control == "micro" else None
        elem = cfg.elem_bytes
        prologue_bits = sum(op.inst.bitwidth(cfg) for op in self.prologue)
        block_cache: dict[int, tuple[int, float, int]] = {}
        out: list[perf.TileCost] = []
        for i, tile in enumerate(self.tiles):
            key = id(tile.exec_block)
            if key not in block_cache:
                blk = tile.exec_block
                block_cache[key] = (blk.bits(cfg), blk.compute_cycles(cfg),
                                    blk.n_invocations)
            blk_bits, blk_cycles, blk_inv = block_cache[key]
            fixed_bits = _fixed_bits(tile, cfg)
            if control == "micro":
                fetch = micro.fetch_bytes(blk_cycles, blk_inv)
            else:
                fetch = (blk_bits + fixed_bits
                         + (prologue_bits if i == 0 else 0)) / 8.0
            load_bytes = sum(
                op.inst.length for op in tile.loads
                if not ((elide_input_loads
                         and op.meta.get("operand") == "I")
                        or (elide_weight_loads
                            and op.meta.get("operand") == "W"))) * elem
            store = 0
            commit_elems = 0
            for op in tile.drains:
                if not isinstance(op.inst, isa.Write):
                    continue
                if on_chip_store or op.meta.get("commit_to") is not None:
                    commit_elems += op.inst.length
                else:
                    store += op.inst.length
            o2s = (tile.m_ext * tile.n_ext) / cfg.aw if tile.last_k else 0.0
            o2s += commit_elems / cfg.aw
            out.append(perf.TileCost(
                fetch_bytes=fetch, load_bytes=load_bytes,
                compute_cycles=blk_cycles, out2stream_cycles=o2s,
                store_bytes=float(store * elem), macs=float(tile.macs)))
        if len(out) <= max_tiles:
            self._memo[memo_key] = out
            return out
        merged: list[perf.TileCost] = []
        base, rem = divmod(len(out), max_tiles)
        idx = 0
        for gi in range(max_tiles):
            k = base + (1 if gi < rem else 0)
            run = out[idx:idx + k]
            idx += k
            merged.append(perf.TileCost(
                fetch_bytes=sum(t.fetch_bytes for t in run),
                load_bytes=sum(t.load_bytes for t in run),
                compute_cycles=sum(t.compute_cycles for t in run),
                out2stream_cycles=sum(t.out2stream_cycles for t in run),
                store_bytes=sum(t.store_bytes for t in run),
                macs=sum(t.macs for t in run)))
        self._memo[memo_key] = merged
        return merged


def _fixed_bits(tile: Tile, cfg: FeatherConfig) -> int:
    """Bits of a tile's non-execute instructions (class-constant widths)."""
    bits = len(tile.loads) * isa.class_bitwidth(isa.Load, cfg)
    for op in tile.drains:
        bits += isa.class_bitwidth(type(op.inst), cfg)
    return bits


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

FULL, PANEL, TILED = "full", "panel", "tiled"

#: Activations that normalise over a full output row and therefore cannot
#: be applied to a partial-row (n-tiled) drain.
ROW_WISE_ACTIVATIONS = frozenset({"softmax", "rmsnorm", "layernorm"})


def _oriented(gemm, choice) -> tuple[int, int, int, bool]:
    wos = choice.df == isa.Dataflow.WOS
    ms, ks, ns = ((gemm.m, gemm.k, gemm.n) if wos
                  else (gemm.n, gemm.k, gemm.m))
    return ms, ks, ns, wos


def snap_tiling(gemm, choice, cfg) -> tuple[int, int, int] | None:
    """Clip tile extents to the problem and snap k_t to a VN multiple
    (global VN-row indexing of resident operands needs aligned k tiles).
    Returns (m_t, k_t, n_t) or None if degenerate."""
    ms, ks, ns, _ = _oriented(gemm, choice)
    vn = choice.vn
    if vn < 1 or vn > cfg.ah:
        return None
    m_t = min(choice.m_t, ms)
    k_t = min(choice.k_t, ks)
    n_t = min(choice.n_t, ns)
    if min(m_t, k_t, n_t) < 1:
        return None
    if k_t < ks:
        k_t = max(vn, (k_t // vn) * vn)
    return m_t, k_t, n_t


def lower(gemm, choice, cfg: FeatherConfig, *,
          activation: Callable | None = None, act_name: str = "none",
          out_name: str = "O", commit_to: str | None = None,
          commit_layout=None, elide_input: bool = False) -> Program:
    """Lower a (Gemm, MappingChoice) to a tiled Program.

    ``elide_input`` drops the input operand's SetIVNLayout + Load(s)
    (paper §IV-G chained layers: the producer's committing Write already
    placed the data); only legal when the input operand is fully resident
    -- callers should check ``input_elidable`` first.
    """
    ms, ks, ns, wos = _oriented(gemm, choice)
    vn = choice.vn
    aw, elem = cfg.aw, cfg.elem_bytes
    snapped = snap_tiling(gemm, choice, cfg)
    if snapped is None:
        raise ValueError(f"infeasible mapping choice {choice} for {gemm}")
    m_t, k_t, n_t = snapped
    n_m = math.ceil(ms / m_t)
    n_n = math.ceil(ns / n_t)
    n_k = math.ceil(ks / k_t)
    if activation is not None and act_name in ROW_WISE_ACTIVATIONS \
            and n_n > 1:
        # drains apply the activation per output tile; a row-wise function
        # over a partial row would be silently wrong
        raise ValueError(
            f"row-wise activation {act_name!r} needs full output rows per "
            f"tile (n_n == 1), got n_n={n_n} for {gemm}")
    kg_total = math.ceil(ks / vn)

    # residency (real buffer-capacity bounds)
    str_mode = FULL if ms * ks * elem <= cfg.str_bytes else TILED
    if ks * ns * elem <= cfg.sta_bytes:
        sta_mode = FULL
    elif ks * n_t * elem <= cfg.sta_bytes:
        sta_mode = PANEL
    else:
        sta_mode = TILED

    sta_name, str_name = ("W", "I") if wos else ("I", "W")
    input_role = "streaming" if wos else "stationary"
    df = isa.Dataflow.WOS if wos else isa.Dataflow.IOS

    # full-region layouts (prologue Set*VNLayout payloads; Loads re-bind
    # region/tile layouts as data arrives)
    lay_sta = layoutlib.layout_for(kg_total, ns, vn, aw, order=choice.order_w)
    lay_str = layoutlib.layout_for(kg_total, ms, vn, aw, order=choice.order_i)
    lay_out = layoutlib.layout_for(math.ceil(ns / vn), ms, vn, aw,
                                   order=choice.order_o)

    def _lay_op(operand_tensor: str, lay) -> TraceOp:
        return TraceOp(lay.to_instruction(operand_tensor), {"layout": lay})

    prologue: list[TraceOp] = []
    if not elide_input:
        prologue.append(_lay_op("I", lay_str if wos else lay_sta))
    prologue.append(_lay_op("W", lay_sta if wos else lay_str))
    prologue.append(TraceOp(
        isa.SetOVNLayout(order=choice.order_o, nr_l0=min(ms, aw),
                         nr_l1=math.ceil(ms / min(ms, aw)),
                         red_l1=math.ceil(ns / vn)),
        {"layout": lay_out, "m_extent": ms, "n_extent": ns}))

    # host-coordinate slices: the stationary tensor has (red, free) =
    # (k, n-search); the streaming one (free, red) = (m-search, k) -- which
    # host axes those are depends on the dataflow.
    def sta_slice(k0, k1, f0, f1):
        return (k0, k1, f0, f1) if wos else (f0, f1, k0, k1)

    def str_slice(f0, f1, k0, k1):
        return (f0, f1, k0, k1) if wos else (k0, k1, f0, f1)

    g_r = aw // max(choice.n_kg, 1)
    g_c = max(choice.n_nb, 1)
    s_r, s_c = (g_c, 1) if choice.strided else (1, vn)
    t_max = max(cfg.vn_slots_per_col, 1)

    load_bits_target = (isa.BufferTarget.STATIONARY,
                        isa.BufferTarget.STREAMING)
    blocks: dict[tuple, ExecBlock] = {}
    lay_cache: dict[tuple, layoutlib.VNLayout] = {}

    def _lay(rows: int, cols: int, order: int) -> layoutlib.VNLayout:
        key = (rows, cols, order)
        if key not in lay_cache:
            lay_cache[key] = layoutlib.layout_for(rows, cols, vn, aw,
                                                  order=order)
        return lay_cache[key]

    tiles: list[Tile] = []
    hbm_sta, hbm_str = 0, ks * ns  # nominal HBM base addresses

    for i_n in range(n_n):
        n0 = i_n * n_t
        n_ext = min(n_t, ns - n0)
        for im in range(n_m):
            m0 = im * m_t
            m_ext = min(m_t, ms - m0)
            for ik in range(n_k):
                k0 = ik * k_t
                k_ext = min(k_t, ks - k0)
                kg_ext = math.ceil(k_ext / vn)
                nb_ext = math.ceil(n_ext / vn)
                kg0 = k0 // vn
                first = i_n == 0 and im == 0 and ik == 0
                loads: list[TraceOp] = []

                # stationary loads (under IO-S the stationary operand IS
                # the layer input, so elision skips this load instead)
                if sta_mode == FULL:
                    if first and not (elide_input and sta_name == "I"):
                        loads.append(TraceOp(
                            isa.Load(hbm_addr=hbm_sta, length=ks * ns,
                                     target=load_bits_target[0]),
                            {"tensor": sta_name, "operand": sta_name,
                             "layout": lay_sta, "slice": None,
                             "vn_row0": 0, "col0": 0, "reset": True,
                             "extents": (kg_total, ns)}))
                    sta_row_base, sta_col_base = kg0, n0
                elif sta_mode == PANEL:
                    if im == 0:
                        panel_lay = _lay(kg_total, n_ext, choice.order_w)
                        loads.append(TraceOp(
                            isa.Load(hbm_addr=hbm_sta + k0 * ns + n0,
                                     length=k_ext * n_ext,
                                     target=load_bits_target[0]),
                            {"tensor": sta_name, "operand": sta_name,
                             "layout": panel_lay,
                             "slice": sta_slice(k0, k0 + k_ext,
                                                n0, n0 + n_ext),
                             "vn_row0": kg0, "col0": 0, "reset": ik == 0,
                             "extents": (kg_total, n_ext)}))
                    sta_row_base, sta_col_base = kg0, 0
                else:
                    tile_lay = _lay(kg_ext, n_ext, choice.order_w)
                    loads.append(TraceOp(
                        isa.Load(hbm_addr=hbm_sta + k0 * ns + n0,
                                 length=k_ext * n_ext,
                                 target=load_bits_target[0]),
                        {"tensor": sta_name, "operand": sta_name,
                         "layout": tile_lay,
                         "slice": sta_slice(k0, k0 + k_ext, n0, n0 + n_ext),
                         "vn_row0": 0, "col0": 0, "reset": True,
                         "extents": (kg_ext, n_ext)}))
                    sta_row_base, sta_col_base = 0, 0

                # streaming loads
                if str_mode == FULL:
                    if first and not (elide_input and str_name == "I"):
                        loads.append(TraceOp(
                            isa.Load(hbm_addr=hbm_str, length=ms * ks,
                                     target=load_bits_target[1]),
                            {"tensor": str_name, "operand": str_name,
                             "layout": lay_str, "slice": None,
                             "vn_row0": 0, "col0": 0, "reset": True,
                             "extents": (kg_total, ms)}))
                    str_row_base, str_m_base = kg0, m0
                else:
                    tile_lay = _lay(kg_ext, m_ext, choice.order_i)
                    loads.append(TraceOp(
                        isa.Load(hbm_addr=hbm_str + m0 * ks + k0,
                                 length=m_ext * k_ext,
                                 target=load_bits_target[1]),
                        {"tensor": str_name, "operand": str_name,
                         "layout": tile_lay,
                         "slice": str_slice(m0, m0 + m_ext, k0, k0 + k_ext),
                         "vn_row0": 0, "col0": 0, "reset": True,
                         "extents": (kg_ext, m_ext)}))
                    str_row_base, str_m_base = 0, 0

                bkey = (kg_ext, nb_ext, m_ext)
                if bkey not in blocks:
                    blocks[bkey] = ExecBlock(
                        kg_ext=kg_ext, nb_ext=nb_ext, m_ext=m_ext, vn=vn,
                        n_kg=choice.n_kg, n_nb=choice.n_nb, g_r=g_r,
                        g_c=g_c, s_r=s_r, s_c=s_c, t_max=t_max, df=df)

                last_k = ik == n_k - 1
                drains: list[TraceOp] = []
                if last_k:
                    if activation is not None:
                        drains.append(TraceOp(
                            isa.Activation(
                                function=isa.ACTIVATION_FUNCS.get(
                                    act_name, 0),
                                length=m_ext * n_ext,
                                target=isa.BufferTarget.STREAMING),
                            {"fn": activation, "name": act_name}))
                    final = (i_n == n_n - 1 and im == n_m - 1)
                    wmeta: dict[str, Any] = {
                        "tensor": out_name, "transpose": not wos,
                        "slice": (m0, m0 + m_ext, n0, n0 + n_ext),
                        "final": final}
                    if final and commit_to is not None:
                        wmeta["commit_to"] = commit_to
                        wmeta["layout"] = commit_layout
                    drains.append(TraceOp(
                        isa.Write(hbm_addr=0, length=m_ext * n_ext,
                                  target=isa.BufferTarget.STREAMING),
                        wmeta))

                tiles.append(Tile(
                    im=im, i_n=i_n, ik=ik, m0=m0, n0=n0, k0=k0,
                    m_ext=m_ext, n_ext=n_ext, k_ext=k_ext,
                    loads=tuple(loads), exec_block=blocks[bkey],
                    drains=tuple(drains),
                    sta_row_base=sta_row_base, sta_col_base=sta_col_base,
                    str_row_base=str_row_base, str_m_base=str_m_base,
                    last_k=last_k))

    return Program(
        gemm=gemm, choice=choice, cfg=cfg, prologue=tuple(prologue),
        tiles=tiles, n_m=n_m, n_n=n_n, n_k=n_k,
        residency={"stationary": sta_mode, "streaming": str_mode},
        input_role=input_role, out_name=out_name,
        activation=activation, act_name=act_name,
        input_elided=elide_input)


# ---------------------------------------------------------------------------
# Program-to-Program transforms (paper §IV-G chained-layer elision)
# ---------------------------------------------------------------------------

def input_elidable(program: Program) -> bool:
    """A consumer may skip its input Load/SetIVNLayout only when the input
    operand is fully resident (one Load covers it -- exactly what the
    producer's on-chip commit replaces)."""
    return program.residency[program.input_role] == FULL


def elide_input(program: Program) -> Program:
    """Chained-consumer transform: re-lower without the input operand's
    SetIVNLayout + Load.  Returns ``program`` unchanged when not legal."""
    if program.input_elided or not input_elidable(program):
        return program
    return lower(program.gemm, program.choice, program.cfg,
                 activation=program.activation, act_name=program.act_name,
                 out_name=program.out_name, elide_input=True)


def with_commit(program: Program, commit_to: str, commit_layout) -> Program:
    """Chained-producer transform: the final Write commits the output
    on-chip into the consumer's operand buffer instead of going off-chip."""
    return lower(program.gemm, program.choice, program.cfg,
                 activation=program.activation, act_name=program.act_name,
                 out_name=program.out_name, commit_to=commit_to,
                 commit_layout=commit_layout,
                 elide_input=program.input_elided)


def chain(programs: list[Program], lower_fn: Callable = None
          ) -> list[Program]:
    """Wire a layer chain: producer i commits on-chip and consumer i+1
    elides its input Load + SetIVNLayout, whenever the VN sizes match and
    the consumer's input is fully resident; incompatible neighbours fall
    back to an off-chip round trip (no elision).

    Un-elided consumers have their input Loads retargeted to the producer's
    named output (the machine resolves tensor names against its committed
    outputs), so the fallback also executes correctly.  Input Programs are
    never mutated; rewired layers are fresh objects.

    ``lower_fn`` (signature of :func:`lower`) lets callers inject a
    memoising lowering -- the runtime's ProgramCache passes its own so a
    rebuilt chain reuses Program objects (and their compiled artifacts)."""
    if lower_fn is None:
        lower_fn = lower
    out: list[Program] = []
    for i, prog in enumerate(programs):
        nxt = programs[i + 1] if i + 1 < len(programs) else None
        elide = False
        retarget: str | None = None
        if i > 0:
            prev = programs[i - 1]
            if prev.choice.vn == prog.choice.vn and input_elidable(prog):
                elide = True
            else:
                retarget = prev.out_name
        commit_to = commit_lay = None
        if nxt is not None and nxt.choice.vn == prog.choice.vn \
                and input_elidable(nxt):
            vn = prog.choice.vn
            commit_lay = layoutlib.layout_for(
                math.ceil(prog.gemm.n / vn), prog.gemm.m, vn, prog.cfg.aw,
                order=prog.choice.order_o)
            commit_to = ("streaming"
                         if nxt.choice.df == isa.Dataflow.WOS
                         else "stationary")
        cur = prog
        if elide or commit_to is not None:
            # single re-lower carrying both roles; retargeting (below) must
            # come last so a re-lower cannot undo it
            cur = lower_fn(prog.gemm, prog.choice, prog.cfg,
                           activation=prog.activation,
                           act_name=prog.act_name, out_name=prog.out_name,
                           commit_to=commit_to, commit_layout=commit_lay,
                           elide_input=elide)
        if retarget is not None:
            cur = _retarget_input(cur, retarget)
        out.append(cur)
    return out


# ---------------------------------------------------------------------------
# M-polymorphic buckets (cross-request batched decode)
# ---------------------------------------------------------------------------

#: Padded host-M bucket ladder for cross-request batching: the serving
#: scheduler stacks B requests' decode rows along M and executes the
#: stack at the smallest bucket >= B, so every (segment, bucket) pair
#: compiles exactly once regardless of how the batch composition drifts
#: as requests admit and retire.
M_BUCKET_LADDER: tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64, 128)


def m_bucket(rows: int,
             ladder: tuple[int, ...] = M_BUCKET_LADDER) -> int:
    """Smallest ladder bucket >= ``rows`` (doubling past the ladder end,
    so an oversized batch still gets a power-of-two pad)."""
    if rows < 1:
        raise ValueError(f"need at least one row, got {rows}")
    for b in ladder:
        if b >= rows:
            return b
    b = ladder[-1]
    while b < rows:
        b *= 2
    return b


def bucketed_gemm(gemm, bucket: int):
    """The same GEMM with ``bucket`` stacked request blocks along host-M.

    K/N (and therefore the weight operand and its residency) are
    untouched; callers re-lower with the *original* MappingChoice, whose
    K tiling ``snap_tiling`` preserves, so every stacked row sees the
    same reduction order as the per-request Program -- the batched path
    stays on the sequential path's numeric trajectory."""
    if bucket < 1:
        raise ValueError(f"bucket must be >= 1, got {bucket}")
    name = f"{gemm.name}@mx{bucket}" if gemm.name else gemm.name
    return dataclasses.replace(gemm, m=bucket * gemm.m, name=name)


# ---------------------------------------------------------------------------
# Fused segments (chained-layer elision compiled to ONE kernel launch)
# ---------------------------------------------------------------------------

#: Elementwise activations the fused kernel applies at a layer's final-K
#: store.  Mirrors ``kernels.nest_gemm.ACT_FNS`` (asserted in tests); kept
#: as names here so the core IR stays JAX-free.
FUSED_ELEMENTWISE_ACTS = frozenset({"relu", "gelu", "silu"})

#: The GEMM stream carries no gate operand, so the runtime's ACTIVATIONS
#: registry maps the gated activations to their ungated halves; the fused
#: kernel follows the identical convention.
FUSED_ACT_ALIASES = {"swiglu": "silu", "geglu": "gelu"}

#: Bytes per element of the dtypes the fused kernel streams.  The budget
#: below is in BYTES, so bf16/int8 segments genuinely fit twice/four
#: times the fp32 working set instead of being sized as if fp32.
DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2, "int8": 1}

#: Default VMEM working-set budget for one fused segment, in BYTES
#: (double-buffered operand windows + the fp32 activation/accumulator
#: scratch).  16 MB == one TPU core's VMEM; segments over budget fall
#: back to per-layer launches rather than silently thrash.
FUSED_VMEM_BUDGET = 16 << 20

#: HBM->VMEM weight-pipeline depth: 2 == double buffering (the grid
#: pipeline fetches K-tile j+1 while K-tile j is in compute).
FUSED_STREAM_DEPTH = 2


def _streamed_footprint_bytes(bm: int, bk0: int, layer_dims, bks, *,
                              operand_dtype: str = "float32",
                              depth: int = FUSED_STREAM_DEPTH) -> int:
    """VMEM high-water of the streamed fused launch, in bytes.

    Operand windows (the segment input's (bm, bk0) block, each weight's
    (bk_l, n_l) K-tile, the (bm, n_out) output block) are held ``depth``
    deep by the pipeline; the resident activation slab and the
    accumulator are fp32 VMEM scratch sized by the widest interior layer.
    """
    db = DTYPE_BYTES[operand_dtype]
    kps = [-(-k // bk) * bk for (k, _), bk in zip(layer_dims, bks)]
    k_slab = max(kps[1:], default=1)
    n_max = max(n for _, n in layer_dims)
    n_out = layer_dims[-1][1]
    windows = (depth * bm * bk0
               + sum(depth * bk * n for bk, (_, n) in zip(bks, layer_dims))
               + depth * bm * n_out)
    scratch = 4 * bm * (k_slab + n_max)
    return db * windows + scratch


def fusion_illegal_reason(programs: list["Program"], *,
                          vmem_budget: int = FUSED_VMEM_BUDGET,
                          adapts: tuple[bool, ...] | None = None,
                          operand_dtype: str = "float32") -> str | None:
    """Why this chain cannot execute as one fused kernel (None == legal).

    Legal segments are ``wired`` chains: layer i's host output [m, n_i]
    is exactly layer i+1's host input [m, k_{i+1}] -- unless the caller
    marks the boundary in ``adapts`` (``adapts[i]`` True means layer i's
    input is the deterministic flatten/cycle/reshape ``adapt`` glue of
    layer i-1's output, which the kernel applies as an in-VMEM index
    permutation on the resident slab; that requires the whole activation
    resident, enforced geometrically by ``fuse_segment``).  Activations
    must be applicable inside the kernel: elementwise
    (``FUSED_ELEMENTWISE_ACTS``) anywhere; row-wise ones only when the
    layer's accumulator holds full host rows (WO-S -- the same condition
    under which the lowering admits them in-Program).  Sharded segments
    fall back: on-chip residency is per-array state and does not cross
    the mesh boundary (``fuse_sharded_segment`` fuses *within* each
    array instead).

    ``operand_dtype`` sizes the budget check in bytes: the minimal
    streamed footprint (one activation row, unit K tiles -- or the full
    resident activation when ``adapts`` forces it) must fit
    ``vmem_budget``.
    """
    if len(programs) < 2:
        return "segment has fewer than 2 layers"
    if operand_dtype not in DTYPE_BYTES:
        return f"operand dtype {operand_dtype!r} has no byte width"
    if adapts is None:
        adapts = (False,) * len(programs)
    if len(adapts) != len(programs):
        return (f"adapts length {len(adapts)} != segment length "
                f"{len(programs)}")
    if adapts[0]:
        return "layer 0 cannot adapt from a producer outside the segment"
    for i, prog in enumerate(programs):
        if isinstance(prog, (ShardedProgram, ShardedFusedSegment)):
            return f"layer {i} is mesh-sharded"
        if i > 0 and not adapts[i]:
            prev = programs[i - 1].gemm
            g = prog.gemm
            if (prev.m, prev.n) != (g.m, g.k):
                return (f"layer {i - 1} output {(prev.m, prev.n)} != "
                        f"layer {i} input {(g.m, g.k)}")
        act = FUSED_ACT_ALIASES.get(prog.act_name, prog.act_name)
        if prog.activation is not None and act == "none":
            return (f"layer {i} carries an anonymous activation callable "
                    f"the kernel cannot reproduce by name")
        if act != "none" and act not in FUSED_ELEMENTWISE_ACTS:
            if act not in ROW_WISE_ACTIVATIONS:
                return f"layer {i} activation {act!r} is not fusable"
            if prog.choice.df != isa.Dataflow.WOS:
                return (f"layer {i} row-wise activation {act!r} needs the "
                        f"host-row accumulator orientation (WO-S)")
    # necessary condition: even the minimal streamed geometry (unit K
    # tiles; one resident row, or every row when an adapt permutation
    # needs the whole activation resident) must fit the byte budget.
    # fuse_segment() additionally fits the real bm / bk schedule.
    dims = [(p.gemm.k, p.gemm.n) for p in programs]
    min_rows = max(p.gemm.m for p in programs) if any(adapts) else 1
    need = _streamed_footprint_bytes(min_rows, 1, dims,
                                     (1,) * len(programs),
                                     operand_dtype=operand_dtype)
    if need > vmem_budget:
        return (f"minimal streamed working set {need} bytes "
                f"({operand_dtype}) exceeds the fused VMEM budget "
                f"{vmem_budget}")
    return None


def fusable(programs: list["Program"], *,
            vmem_budget: int = FUSED_VMEM_BUDGET,
            adapts: tuple[bool, ...] | None = None,
            operand_dtype: str = "float32") -> bool:
    return fusion_illegal_reason(programs, vmem_budget=vmem_budget,
                                 adapts=adapts,
                                 operand_dtype=operand_dtype) is None


@dataclasses.dataclass
class FusedSegment:
    """A chained segment compiled as ONE kernel launch (paper §IV-G at
    kernel granularity).

    The per-layer Programs stay the source of truth for instruction
    accounting and the fallback path; the segment adds the *fused launch
    geometry*: every layer's tiling snapped to one common host-M tile
    (``bm`` rows of the chained activation stay resident in VMEM scratch
    across all layers) and a per-layer host-K tile (``layer_bks``) that
    streams each layer's weight HBM->VMEM in ``buffer_depth``-deep
    (double-buffered) K-tiles against the resident activation -- so the
    VMEM footprint is bounded by the largest layer's windows, not the
    sum of all weights.

    ``adapts[l]`` True marks layer l's input as the flatten/cycle/reshape
    ``adapt`` glue of layer l-1's output, executed inside the kernel as a
    static index permutation on the resident slab (whole activation
    resident: ``m_steps == 1`` whenever any adapt is present), which is
    what lets attention (qk/pv) and MLP fuse into ONE launch per
    transformer block.

    Data-traffic accounting (:meth:`tile_costs`) keeps every interior
    boundary on-chip -- interior Writes are costed as OB-commit cycles
    and interior input Loads vanish, while weight Loads are restated to
    the streamed K-tile schedule (re-fetched once per M step) -- so
    ``perf.simulate`` over the fused stream charges exactly the HBM
    bytes the fused kernel ships.
    """
    programs: list[Program]
    bm: int                       # common host-M tile (resident rows)
    layer_bks: tuple[int, ...]    # per-layer host-K weight-streaming tile
    acts: tuple[str | None, ...]  # per-layer in-kernel activation name
    adapts: tuple[bool, ...] = None       # in-kernel adapt boundaries
    buffer_depth: int = FUSED_STREAM_DEPTH    # K-tile pipeline depth
    vmem_budget: int = FUSED_VMEM_BUDGET      # bytes the geometry fit
    operand_dtype: str = "float32"            # streamed operand dtype

    def __post_init__(self):
        if self.adapts is None:
            self.adapts = (False,) * len(self.programs)

    @property
    def n_layers(self) -> int:
        return len(self.programs)

    @property
    def cfg(self) -> FeatherConfig:
        return self.programs[0].cfg

    @property
    def out_name(self) -> str:
        return self.programs[-1].out_name

    @property
    def m(self) -> int:
        return self.programs[0].gemm.m

    @property
    def k_in(self) -> int:
        return self.programs[0].gemm.k

    @property
    def widths(self) -> tuple[int, ...]:
        """Per-layer output widths (interior ones live in VMEM scratch)."""
        return tuple(p.gemm.n for p in self.programs)

    @property
    def macs(self) -> int:
        return sum(p.macs for p in self.programs)

    # -- streamed launch geometry --------------------------------------------
    @property
    def m_steps(self) -> int:
        """Host-M grid steps of the launch.  The weight K-tile stream
        restarts per M step (each step re-streams every layer's weight),
        and any in-kernel adapt permutation requires exactly one."""
        return -(-self.m // self.bm)

    @property
    def padded_ks(self) -> tuple[int, ...]:
        """Per-layer K extents padded to the K-tile schedule (the zero
        pad rows are inert: padded weight rows are zero)."""
        return tuple(-(-p.gemm.k // bk) * bk
                     for p, bk in zip(self.programs, self.layer_bks))

    def vmem_highwater_bytes(self) -> int:
        """Peak VMEM bytes the streamed launch holds: double-buffered
        operand windows (input block, one K-tile per weight, output
        block) plus the fp32 slab/accumulator scratch -- bounded by the
        largest layer's windows, NOT the sum of all weights."""
        dims = [(p.gemm.k, p.gemm.n) for p in self.programs]
        return _streamed_footprint_bytes(
            self.bm, min(self.layer_bks[0], self.programs[0].gemm.k),
            dims, self.layer_bks, operand_dtype=self.operand_dtype,
            depth=self.buffer_depth)

    def resident_vmem_bytes(self) -> int:
        """What the same segment would hold with every weight fully
        VMEM-resident (the pre-streaming discipline): the sum over
        layers, the footprint streaming replaces."""
        db = DTYPE_BYTES[self.operand_dtype]
        weights = sum(p.gemm.k * p.gemm.n for p in self.programs)
        slabs = self.bm * (self.k_in + sum(self.widths))
        return db * weights + 4 * slabs

    def max_layer_working_set_bytes(self) -> int:
        """The largest single layer's working set (its full weight plus
        its bm-row input/output slabs) -- the bound the streamed
        footprint is held to."""
        db = DTYPE_BYTES[self.operand_dtype]
        return max(db * (g.k * g.n) + 4 * self.bm * (g.k + g.n)
                   for g in (p.gemm for p in self.programs))

    # -- instruction accounting (the chained stream is unchanged) ------------
    def minisa_bits(self) -> int:
        return sum(p.minisa_bits() for p in self.programs)

    def minisa_bytes(self) -> float:
        return self.minisa_bits() / 8.0

    # -- data-traffic accounting ---------------------------------------------
    def layer_tile_costs(self, layer: int, control: str = "minisa",
                         max_tiles: int = 4096) -> list:
        """Layer ``layer``'s tile stream under streamed fused execution:
        interior stores stay on-chip, non-first layers read their input
        from the resident activation (no HBM Load), and the Program's
        residency-derived weight Loads are restated to the bytes the
        streamed kernel actually ships -- the padded weight fetched once
        per M step of the launch, spread evenly over the layer's tiles."""
        costs = self.programs[layer].tile_costs(
            control, max_tiles,
            elide_input_loads=layer > 0,
            elide_weight_loads=True,
            on_chip_store=layer < self.n_layers - 1)
        g = self.programs[layer].gemm
        kp = self.padded_ks[layer]
        shipped = float(self.cfg.elem_bytes * self.m_steps * kp * g.n)
        per_tile = shipped / max(len(costs), 1)
        return [dataclasses.replace(t, load_bytes=t.load_bytes + per_tile)
                for t in costs]

    def tile_costs(self, control: str = "minisa",
                   max_tiles: int = 4096) -> list:
        out = []
        for layer in range(self.n_layers):
            out.extend(self.layer_tile_costs(layer, control, max_tiles))
        return out

    def hbm_bytes(self) -> float:
        """Off-chip data bytes of the fused *machine-model* tile stream
        (loads + stores after interior elision)."""
        return sum(t.load_bytes + t.store_bytes for t in self.tile_costs())

    # -- kernel-launch traffic (what the compiled backend actually ships) ----
    def kernel_hbm_bytes(self) -> float:
        """Bytes the ONE fused launch moves across HBM: the segment
        input, every layer's weight K-tile stream (the padded weight,
        re-fetched once per M step -- the streaming discipline trades
        weight re-streams for bounded VMEM), the final output -- nothing
        else."""
        elem = self.cfg.elem_bytes
        m = self.m
        return elem * (m * self.k_in
                       + self.m_steps * sum(
                           kp * p.gemm.n
                           for kp, p in zip(self.padded_ks, self.programs))
                       + m * self.programs[-1].gemm.n)

    def per_layer_kernel_hbm_bytes(self) -> float:
        """Bytes L separate per-layer launches move: each launch reads
        its input from HBM and writes its output back, so every interior
        activation round-trips."""
        elem = self.cfg.elem_bytes
        m = self.m
        return elem * sum(m * p.gemm.k + p.gemm.k * p.gemm.n
                          + m * p.gemm.n for p in self.programs)

    def elided_hbm_bytes(self) -> float:
        """Intermediate traffic fusion keeps on-chip: one Write + one
        (re-)Load of every interior activation."""
        return self.per_layer_kernel_hbm_bytes() - self.kernel_hbm_bytes()

    def describe(self) -> dict:
        return {
            "n_layers": self.n_layers,
            "m": self.m,
            "widths": (self.k_in,) + self.widths,
            "bm": self.bm,
            "layer_bks": self.layer_bks,
            "acts": self.acts,
            "adapts": self.adapts,
            "m_steps": self.m_steps,
            "buffer_depth": self.buffer_depth,
            "operand_dtype": self.operand_dtype,
            "vmem_highwater_bytes": self.vmem_highwater_bytes(),
            "vmem_resident_bytes": self.resident_vmem_bytes(),
            "max_layer_working_set_bytes":
                self.max_layer_working_set_bytes(),
            "hbm_bytes_fused": self.kernel_hbm_bytes(),
            "hbm_bytes_per_layer": self.per_layer_kernel_hbm_bytes(),
            "hbm_bytes_elided": self.elided_hbm_bytes(),
        }


def fuse_segment(programs: list["Program"], *,
                 vmem_budget: int = FUSED_VMEM_BUDGET,
                 adapts: tuple[bool, ...] | None = None,
                 operand_dtype: str = "float32",
                 bm: int | None = None,
                 layer_bks: tuple[int, ...] | None = None
                 ) -> FusedSegment | None:
    """Build the streamed fused launch geometry for a chained segment,
    or None when the segment must fall back to per-layer execution.

    With ``bm``/``layer_bks`` given, the geometry comes from a *joint
    choice* (``mapper.SegmentChoice`` -- the fusion-aware segment
    search, or a measured autotune winner) instead of the per-layer
    snapping heuristic below: the requested tiles are clamped to the
    problem, the adapt residency rule is still enforced, and the
    candidate is rejected (None) if its streamed footprint exceeds
    ``vmem_budget``.

    Otherwise (the greedy-then-snap default): each layer's host-K tile
    (snapped from its own mapping, then capped so the double-buffered
    K-tile windows of ALL layers together stay under the largest single
    weight) becomes its HBM->VMEM streaming granularity.  The host-M
    tile covers the whole activation in one grid step whenever the
    streamed footprint allows (no weight re-streams) -- and MUST when
    ``adapts`` marks an in-kernel permutation boundary (the
    flatten/cycle/reshape glue needs every row resident); otherwise bm
    falls back to the tightest snapped tile and halves until the
    footprint fits ``vmem_budget`` (bytes, sized for ``operand_dtype``).
    """
    if fusion_illegal_reason(programs, vmem_budget=vmem_budget,
                             adapts=adapts,
                             operand_dtype=operand_dtype) is not None:
        return None
    n_layers = len(programs)
    if adapts is None:
        adapts = (False,) * n_layers
    m = programs[0].gemm.m
    m_max = max(p.gemm.m for p in programs)

    if bm is not None or layer_bks is not None:
        # joint-choice geometry: clamp, enforce residency, fit-or-reject
        if layer_bks is None or len(layer_bks) != n_layers:
            return None
        bks = [max(1, min(int(bk), p.gemm.k))
               for bk, p in zip(layer_bks, programs)]
        rows = m_max if any(adapts) else max(1, min(int(bm or m), m))
        dims = [(p.gemm.k, p.gemm.n) for p in programs]
        if _streamed_footprint_bytes(
                rows, bks[0], dims, bks,
                operand_dtype=operand_dtype) > vmem_budget:
            return None
        acts = tuple(
            None if p.act_name == "none"
            else FUSED_ACT_ALIASES.get(p.act_name, p.act_name)
            for p in programs)
        return FusedSegment(
            programs=list(programs), bm=rows, layer_bks=tuple(bks),
            acts=acts, adapts=tuple(adapts),
            buffer_depth=FUSED_STREAM_DEPTH, vmem_budget=vmem_budget,
            operand_dtype=operand_dtype)

    bm_snap = m_max
    bks = []
    for prog in programs:
        snapped = snap_tiling(prog.gemm, prog.choice, prog.cfg)
        if snapped is None:       # lower() would have raised already
            return None
        m_t, k_t, n_t = snapped
        wos = prog.choice.df == isa.Dataflow.WOS
        bm_snap = min(bm_snap, m_t if wos else n_t)
        bks.append(max(1, min(k_t, prog.gemm.k)))
    # cap the K tiles so the depth-deep K-tile windows of all layers sum
    # to no more than the largest single weight: the streamed footprint
    # is bounded by the biggest layer, not the per-layer sum
    depth = FUSED_STREAM_DEPTH
    w_max = max(p.gemm.k * p.gemm.n for p in programs)
    bks = [max(1, min(bk, max(1, w_max // (depth * n_layers * p.gemm.n))))
           for bk, p in zip(bks, programs)]
    dims = [(p.gemm.k, p.gemm.n) for p in programs]

    def fits(rows: int) -> bool:
        return _streamed_footprint_bytes(
            rows, bks[0], dims, bks,
            operand_dtype=operand_dtype) <= vmem_budget

    if any(adapts):
        bm = m_max            # the permutation needs the whole activation
        if not fits(bm):
            return None
    elif fits(m):
        bm = m                # whole M resident: weights stream exactly once
    else:
        bm = max(1, min(bm_snap, m))
        while bm > 1 and not fits(bm):
            bm //= 2
        if not fits(bm):
            return None       # not even one streamed row fits
    acts = tuple(
        None if p.act_name == "none"
        else FUSED_ACT_ALIASES.get(p.act_name, p.act_name)
        for p in programs)
    return FusedSegment(
        programs=list(programs), bm=max(1, bm),
        layer_bks=tuple(bks), acts=acts, adapts=tuple(adapts),
        buffer_depth=depth, vmem_budget=vmem_budget,
        operand_dtype=operand_dtype)


# ---------------------------------------------------------------------------
# Multi-array sharding (Program -> ShardedProgram over an ArrayMesh)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Shard:
    """One array's slice of a sharded GEMM, with its own lowered Program.

    Slice bounds are host-orientation element ranges of the *unsharded*
    problem: the shard computes ``O[m0:m1, n0:n1]`` (a partial sum over
    ``k0:k1`` when the split axis is K) from ``I[m0:m1, k0:k1]`` and
    ``W[k0:k1, n0:n1]``.
    """
    array: int                   # logical array index on the mesh
    program: Program
    m0: int
    m1: int
    n0: int
    n1: int
    k0: int
    k1: int

    def slice_tensors(self, tensors: dict | None) -> dict:
        """This shard's view of the host operand dict ('I' / 'W')."""
        out = dict(tensors) if tensors else {}
        if "I" in out:
            out["I"] = out["I"][self.m0:self.m1, self.k0:self.k1]
        if "W" in out:
            out["W"] = out["W"][self.k0:self.k1, self.n0:self.n1]
        return out


@dataclasses.dataclass
class ShardedProgram:
    """A Program split across the arrays of an ``dist.ArrayMesh``.

    The tile space is partitioned along one host GEMM rank: M or N
    shards compute disjoint output slices with the other operand
    replicated; a K split computes per-array partial sums that a
    reduction epilogue combines (``reduce``).  Activations that are not
    shard-local (any activation under a K split; row-wise ones under an
    N split, which breaks output rows) are hoisted out of the per-shard
    Programs into ``epilogue_act``, applied to the assembled output.

    Per-array accounting is exact: each shard's Program carries its own
    MINISA instruction stream, so ``per_array_minisa_bytes`` /
    ``tile_costs`` feed ``perf.simulate`` per array and sum to (within
    tiling overhead) the unsharded totals.
    """
    base: Program                # the unsharded lowering (reference/meta)
    mesh: Any                    # dist.ArrayMesh
    axis: str                    # 'm' | 'n' | 'k' (host orientation)
    shards: tuple[Shard, ...]
    epilogue_act: Callable | None = None
    epilogue_act_name: str = "none"

    @property
    def cfg(self) -> FeatherConfig:
        return self.base.cfg

    @property
    def out_name(self) -> str:
        return self.base.out_name

    @property
    def reduce(self) -> bool:
        return self.axis == "k"

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def n_arrays(self) -> int:
        return self.mesh.n_arrays

    @property
    def uniform(self) -> bool:
        """All shards cover equal extents (shard_map-able without host
        raggedness)."""
        spans = {(s.m1 - s.m0, s.n1 - s.n0, s.k1 - s.k0)
                 for s in self.shards}
        return len(spans) == 1

    def per_array_minisa_bytes(self) -> list[float]:
        """Instruction bytes per logical array (idle arrays report 0)."""
        out = [0.0] * self.n_arrays
        for s in self.shards:
            out[s.array] += s.program.minisa_bytes()
        return out

    def minisa_bytes(self) -> float:
        return sum(self.per_array_minisa_bytes())

    def per_array_tile_costs(self, control: str = "minisa",
                             max_tiles: int = 4096) -> list[list]:
        """One ``perf.TileCost`` stream per logical array."""
        out: list[list] = [[] for _ in range(self.n_arrays)]
        for s in self.shards:
            out[s.array].extend(s.program.tile_costs(control, max_tiles))
        return out

    @property
    def macs(self) -> int:
        return sum(s.program.macs for s in self.shards)

    def summary(self) -> dict:
        bytes_per = self.per_array_minisa_bytes()
        return {
            "axis": self.axis, "n_arrays": self.n_arrays,
            "n_shards": self.n_shards, "reduce": self.reduce,
            "minisa_bytes": sum(bytes_per),
            "minisa_bytes_per_array": bytes_per,
            "byte_imbalance": perf.load_imbalance(bytes_per),
        }


def _shard_ranges(dim: int, n: int) -> list[tuple[int, int]]:
    """Ceil-div contiguous split of [0, dim) into <= n non-empty ranges."""
    chunk = -(-dim // n)
    out = []
    for i in range(n):
        lo = i * chunk
        hi = min(lo + chunk, dim)
        if lo >= hi:
            break
        out.append((lo, hi))
    return out


def shard_program(program: Program, mesh, axis: str | None = None,
                  lower_fn: Callable = None) -> ShardedProgram:
    """Partition a lowered Program across ``mesh``'s arrays.

    ``axis`` forces the split rank; by default the ``dist.sharding``
    GEMM-rank policy picks it (N-first tensor parallelism, then M, then
    K-with-reduction).  Each shard re-lowers the same MappingChoice on
    its sub-extents -- ``snap_tiling`` clips, so every feasible choice
    stays feasible -- through ``lower_fn`` (defaults to :func:`lower`;
    the runtime passes its memoising ``ProgramCache.lower``).

    Chained Programs (elided input / on-chip commit) cannot be sharded:
    their operand flow is per-array machine state, and the mesh boundary
    is exactly where that state does not reach.
    """
    if lower_fn is None:
        lower_fn = lower
    if program.input_elided:
        raise ValueError("cannot shard a chained Program with an elided "
                         "input; shard the un-chained lowering instead")
    if any(op.meta.get("commit_to") is not None
           for tile in program.tiles for op in tile.drains):
        raise ValueError("cannot shard a Program whose final Write commits "
                         "on-chip; shard the un-chained lowering instead")
    g = program.gemm
    if axis is None:
        from repro.dist import sharding as shardinglib
        wos = program.choice.df == isa.Dataflow.WOS
        tiles = {"m": program.n_m if wos else program.n_n,
                 "n": program.n_n if wos else program.n_m,
                 "k": program.n_k}
        axis = shardinglib.gemm_shard_axis(g.m, g.k, g.n, mesh.n_arrays,
                                           tiles=tiles)
    if axis not in ("m", "n", "k"):
        raise ValueError(f"shard axis must be 'm'|'n'|'k', got {axis!r}")

    if mesh.n_arrays == 1:
        return ShardedProgram(
            base=program, mesh=mesh, axis=axis,
            shards=(Shard(array=0, program=program, m0=0, m1=g.m,
                          n0=0, n1=g.n, k0=0, k1=g.k),))

    # Activations that are not shard-local move to the epilogue: any
    # activation under a K split (partial sums are pre-activation), and
    # row-wise ones whenever a shard would hold partial accumulator rows
    # (rows are host-N under WO-S, so only a WO-S M split keeps them
    # intact per shard).
    wos = program.choice.df == isa.Dataflow.WOS
    hoist = program.activation is not None and (
        axis == "k"
        or (program.act_name in ROW_WISE_ACTIVATIONS
            and not (wos and axis == "m")))
    act = None if hoist else program.activation
    act_name = "none" if hoist else program.act_name

    dim = {"m": g.m, "n": g.n, "k": g.k}[axis]
    shards = []
    for i, (lo, hi) in enumerate(_shard_ranges(dim, mesh.n_arrays)):
        m0, m1 = (lo, hi) if axis == "m" else (0, g.m)
        n0, n1 = (lo, hi) if axis == "n" else (0, g.n)
        k0, k1 = (lo, hi) if axis == "k" else (0, g.k)
        sub = dataclasses.replace(
            g, m=m1 - m0, k=k1 - k0, n=n1 - n0,
            name=f"{g.name or 'gemm'}@{axis}{i}")
        shards.append(Shard(
            array=i,
            program=lower_fn(sub, program.choice, program.cfg,
                             activation=act, act_name=act_name,
                             out_name=program.out_name),
            m0=m0, m1=m1, n0=n0, n1=n1, k0=k0, k1=k1))
    return ShardedProgram(
        base=program, mesh=mesh, axis=axis, shards=tuple(shards),
        epilogue_act=program.activation if hoist else None,
        epilogue_act_name=program.act_name if hoist else "none")


@dataclasses.dataclass
class ShardedFusedSegment:
    """A chained segment fused WITHIN each array of an M-sharded stream.

    When every step of a wired run is sharded along host-M with aligned
    row ranges, each array owns a contiguous row slice of the *whole*
    chain: no interior activation ever crosses the mesh boundary, so the
    per-array sub-chains fuse into one streamed launch each.  The
    segment then costs ``n_arrays`` launches instead of
    ``n_arrays * n_layers`` -- the mesh only forbids fusing *across*
    arrays, never within one.
    """
    steps: list[ShardedProgram]                 # per-layer sharded lowerings
    mesh: Any                                   # dist.ArrayMesh
    array_segments: tuple[FusedSegment, ...]    # one fused chain per array
    row_ranges: tuple[tuple[int, int], ...]     # host rows [m0, m1) per array

    @property
    def cfg(self) -> FeatherConfig:
        return self.steps[0].cfg

    @property
    def out_name(self) -> str:
        return self.steps[-1].out_name

    @property
    def n_layers(self) -> int:
        return len(self.steps)

    @property
    def n_arrays(self) -> int:
        return len(self.array_segments)

    @property
    def m(self) -> int:
        return self.steps[0].base.gemm.m

    @property
    def n_out(self) -> int:
        return self.steps[-1].base.gemm.n

    @property
    def acts(self) -> tuple:
        return self.array_segments[0].acts

    def vmem_highwater_bytes(self) -> int:
        """Worst per-array streamed footprint (arrays run concurrently)."""
        return max(seg.vmem_highwater_bytes()
                   for seg in self.array_segments)

    def layer_tile_costs(self, layer: int, control: str = "minisa",
                         max_tiles: int = 4096) -> list:
        """Layer ``layer``'s tile stream across every array's fused
        sub-chain (per-array streams concatenated; arrays run in
        parallel, but the byte totals are what accounting sums)."""
        out = []
        for seg in self.array_segments:
            out.extend(seg.layer_tile_costs(layer, control, max_tiles))
        return out

    def describe(self) -> dict:
        return {
            "n_layers": self.n_layers, "n_arrays": self.n_arrays,
            "m": self.m, "row_ranges": list(self.row_ranges),
            "vmem_highwater_bytes": self.vmem_highwater_bytes(),
            "per_array": [seg.describe() for seg in self.array_segments],
        }


def fuse_sharded_segment(steps: list[ShardedProgram], *,
                         vmem_budget: int = FUSED_VMEM_BUDGET,
                         operand_dtype: str = "float32"
                         ) -> ShardedFusedSegment | None:
    """Fuse a run of M-sharded steps within each array, or None.

    Legal only when every step is split along host-M on the same mesh
    with identical row ranges (so array ``a``'s shard chain is a closed
    sub-problem) and each array's per-shard Program chain is itself
    fusable.  Adapt boundaries never qualify: the flatten/cycle
    permutation mixes rows globally, which is exactly the cross-array
    dataflow the mesh forbids.
    """
    if len(steps) < 2:
        return None
    if not all(isinstance(s, ShardedProgram) for s in steps):
        return None
    mesh = steps[0].mesh
    if any(s.mesh is not mesh or s.axis != "m" for s in steps):
        return None
    if any(s.epilogue_act is not None for s in steps):
        return None
    ranges = tuple((sh.m0, sh.m1) for sh in steps[0].shards)
    for s in steps[1:]:
        if tuple((sh.m0, sh.m1) for sh in s.shards) != ranges:
            return None
    array_segments = []
    for a in range(len(ranges)):
        chain = [s.shards[a].program for s in steps]
        seg = fuse_segment(chain, vmem_budget=vmem_budget,
                           operand_dtype=operand_dtype)
        if seg is None:
            return None
        array_segments.append(seg)
    return ShardedFusedSegment(
        steps=list(steps), mesh=mesh,
        array_segments=tuple(array_segments), row_ranges=ranges)


def _retarget_input(program: Program, source_name: str) -> Program:
    """Copy of ``program`` whose input Loads read ``source_name`` (the
    producer's committed output) instead of the host 'I' tensor.  The
    input Program -- possibly shared or memoized -- is left untouched."""
    new_tiles = []
    for tile in program.tiles:
        loads = tuple(
            TraceOp(op.inst, {**op.meta, "tensor": source_name})
            if op.meta.get("tensor") == "I" else op
            for op in tile.loads)
        if any(a is not b for a, b in zip(loads, tile.loads)):
            tile = dataclasses.replace(tile, loads=loads)
        new_tiles.append(tile)
    # fresh memo: the rewired copy must not share trace-derived caches
    # with (or leak them into) the source Program
    return dataclasses.replace(program, tiles=new_tiles, _memo={})
