"""DEPRECATED flat-trace compatibility layer over the tiled Program IR.

.. deprecated::
    The Program (``core/program.py``) is the single lowered artifact and
    the execution backends (``repro.backends``) are the supported way to
    run it; a flat instruction stream is just ``Program.trace_ops()``.
    All in-repo consumers have been ported; these wrappers remain only
    for external callers of the historical ``build_trace`` /
    ``build_chain_trace`` entry points and now emit
    ``DeprecationWarning``.  Use instead:

        plan.program.trace_ops()                      # flat stream
        program.chain([...])                          # §IV-G chaining
        plan.execute(tensors, backend=...)            # execution
"""

from __future__ import annotations

import warnings
from typing import Callable

from repro.core import program as programlib
from repro.core.machine import TraceOp  # noqa: F401 (re-export)
from repro.core.mapper import Plan


def _deprecated(name: str, replacement: str) -> None:
    warnings.warn(
        f"repro.core.trace.{name} is deprecated; use {replacement}",
        DeprecationWarning, stacklevel=3)


def build_trace(plan: Plan, activation: Callable | None = None,
                act_name: str = "none") -> list[TraceOp]:
    """Flattened instruction stream of the plan's Program (re-lowered when
    an activation is requested, since activations live in the tile drains).

    Deprecated: iterate ``plan.program.trace_ops()`` (lowering with
    ``program.lower(..., activation=...)`` when needed) instead."""
    _deprecated("build_trace", "Program.trace_ops()")
    prog = plan.program
    if activation is not None:
        prog = programlib.lower(plan.gemm, plan.choice, plan.cfg,
                                activation=activation, act_name=act_name)
    return list(prog.trace_ops())


def build_chain_trace(plans: list[Plan],
                      activations: list[Callable | None] | None = None,
                      act_names: list[str] | None = None
                      ) -> list[list[TraceOp]]:
    """Per-layer flat traces for a chain (paper §IV-G): layer i's Write
    commits the output on-chip into layer i+1's input buffer, and layer
    i+1 elides its SetIVNLayout + input Load.

    Deprecated: use ``program.chain`` on lowered Programs and execute
    them on a stateful backend instead."""
    _deprecated("build_chain_trace", "program.chain() + backends")
    progs = []
    for i, plan in enumerate(plans):
        act = activations[i] if activations else None
        name = act_names[i] if act_names else "none"
        progs.append(programlib.lower(
            plan.gemm, plan.choice, plan.cfg, activation=act,
            act_name=name, out_name=f"O{i}"))
    return [list(p.trace_ops()) for p in programlib.chain(progs)]
