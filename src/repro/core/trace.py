"""MINISA trace generation (paper §IV-G execution model, §V step 7).

Lowers a mapper Plan into the canonical per-layer trace

    Load* -> SetIVNLayout -> SetWVNLayout -> SetOVNLayout
          -> { ExecuteMapping -> ExecuteStreaming* }^rounds
          -> [Activation] -> Write

with the machine-executable TraceOp side-band (layouts, tensor names).

The functional builder keeps whole operands resident (tests use workloads
that fit on-chip); tiling is expressed through (r0, c0, m0) offsets, which
is semantically identical to re-loading tiles when capacity allows -- the
instruction *count* accounting for capacity-bound tilings lives in
``mapper.Schedule``.

For consecutive layers the paper elides SetOVNLayout(i) == SetIVNLayout(i+1);
``build_chain_trace`` implements that: layer i's outputs are committed to the
streaming buffer and layer i+1 skips its input Load and SetIVNLayout.
"""

from __future__ import annotations

import math
from typing import Callable

import numpy as np

from repro.configs.feather import FeatherConfig
from repro.core import isa, layout as layoutlib
from repro.core.machine import TraceOp
from repro.core.mapper import Gemm, MappingChoice, Plan


def build_trace(plan: Plan, activation: Callable | None = None,
                act_name: str = "none") -> list[TraceOp]:
    gemm, cfg, ch = plan.gemm, plan.cfg, plan.choice
    return _build_layer(gemm, ch, cfg, activation, act_name)


def _build_layer(gemm: Gemm, ch: MappingChoice, cfg: FeatherConfig,
                 activation: Callable | None = None,
                 act_name: str = "none",
                 out_name: str = "O",
                 commit_to: str | None = None,
                 skip_input_load: bool = False) -> list[TraceOp]:
    ah, aw = cfg.ah, cfg.aw
    vn = ch.vn
    wos = ch.df == isa.Dataflow.WOS
    # search orientation: stationary free rank = ns, streaming free = ms
    ms, ks, ns = (gemm.m, gemm.k, gemm.n) if wos else (gemm.n, gemm.k, gemm.m)
    kg_total = math.ceil(ks / vn)
    nb_total = math.ceil(ns / vn)

    lay_sta = layoutlib.layout_for(kg_total, ns, vn, aw, order=ch.order_w)
    lay_str = layoutlib.layout_for(kg_total, ms, vn, aw, order=ch.order_i)
    lay_out = layoutlib.layout_for(nb_total, ms, vn, aw, order=ch.order_o)

    sta_operand, str_operand = ("W", "I") if wos else ("I", "W")
    # operand-kind for VN grouping inside the machine: the stationary tensor
    # is always VN-ified along K as a [K, free] matrix ('W'-style) and the
    # streaming one as [free, K] ('I'-style) -- under IO-S the roles swap,
    # so the machine receives transposed tensors via the meta 'tensor' key.
    ops: list[TraceOp] = []

    def _lay_inst(operand: str, lay: layoutlib.VNLayout):
        return lay.to_instruction(operand)

    if not skip_input_load:
        # chained layers reuse the previous SetOVNLayout as SetIVNLayout
        ops.append(TraceOp(_lay_inst("I", lay_str if wos else lay_sta),
                           {"layout": lay_str if wos else lay_sta}))
    ops.append(TraceOp(_lay_inst("W", lay_sta if wos else lay_str),
                       {"layout": lay_sta if wos else lay_str}))
    ops.append(TraceOp(
        isa.SetOVNLayout(order=ch.order_o, nr_l0=min(ms, aw),
                         nr_l1=math.ceil(ms / min(ms, aw)),
                         red_l1=nb_total),
        {"layout": lay_out, "m_extent": ms, "n_extent": ns, "commit": None}))

    # Loads: a chained layer's *input* operand is already on-chip (placed
    # by the previous layer's committing Write), so only the weight-side
    # operand is loaded.  Under WO-S the input is the streaming operand;
    # under IO-S it is the stationary one.
    load_sta = not (skip_input_load and not wos)
    load_str = not (skip_input_load and wos)
    if load_sta:
        ops.append(TraceOp(
            isa.Load(hbm_addr=0, length=ks * ns,
                     target=isa.BufferTarget.STATIONARY),
            {"tensor": sta_operand, "operand": sta_operand,
             "layout": lay_sta}))
    if load_str:
        ops.append(TraceOp(
            isa.Load(hbm_addr=ks * ns, length=ms * ks,
                     target=isa.BufferTarget.STREAMING),
            {"tensor": str_operand, "operand": str_operand,
             "layout": lay_str}))

    # Execute rounds over the (kg, nb) group lattice + m chunks.
    g_r = aw // ch.n_kg
    g_c = ch.n_nb
    dup = g_r // g_c
    s_r, s_c = (g_c, 1) if ch.strided else (1, vn)
    t_max = max(cfg.vn_slots_per_col, 1)
    for kg0 in range(0, kg_total, ch.n_kg):
        for nb0 in range(0, nb_total, ch.n_nb):
            em = isa.ExecuteMapping(r0=kg0, c0=nb0 * vn, g_r=g_r, g_c=g_c,
                                    s_r=s_r, s_c=s_c)
            ops.append(TraceOp(em, {}))
            m_span = dup * t_max
            for m0 in range(0, ms, m_span):
                t = min(t_max, math.ceil((ms - m0) / dup))
                ops.append(TraceOp(
                    isa.ExecuteStreaming(
                        m0=m0, s_m=dup, t=t, vn_size=vn,
                        df=isa.Dataflow.WOS if wos else isa.Dataflow.IOS),
                    {}))

    if activation is not None:
        ops.append(TraceOp(
            isa.Activation(function=isa.ACTIVATION_FUNCS.get(act_name, 0),
                           length=ms * ns,
                           target=isa.BufferTarget.STREAMING),
            {"fn": activation}))
    write_meta = {"tensor": out_name, "transpose": not wos}
    if commit_to is not None:
        # next layer consumes the output on-chip: its input layout is this
        # layer's output-VN layout re-bound as an I_VN layout.  The commit
        # happens in GEMM orientation O[M, N] (post-transpose), so the next
        # input has free rank M and reduction rank N regardless of df.
        next_kg = math.ceil(gemm.n / vn)
        write_meta["commit_to"] = commit_to
        write_meta["layout"] = layoutlib.layout_for(next_kg, gemm.m, vn, aw,
                                                    order=ch.order_o)
    ops.append(TraceOp(
        isa.Write(hbm_addr=0, length=ms * ns,
                  target=isa.BufferTarget.STREAMING), write_meta))
    return ops


def build_chain_trace(plans: list[Plan],
                      activations: list[Callable | None] | None = None
                      ) -> list[list[TraceOp]]:
    """Per-layer traces for a chain (paper §IV-G): layer i's Write commits
    the output on-chip into layer i+1's input buffer, and layer i+1 elides
    its SetIVNLayout + input Load.

    On-chip chaining requires matching VN sizes across the boundary (the
    committed O_VNs *are* the next layer's I_VNs); incompatible neighbours
    fall back to an off-chip round trip (no elision).
    """
    traces = []
    for i, plan in enumerate(plans):
        act = activations[i] if activations else None
        nxt = plans[i + 1] if i + 1 < len(plans) else None
        commit_to = None
        if nxt is not None and nxt.choice.vn == plan.choice.vn:
            commit_to = ("streaming"
                         if nxt.choice.df == isa.Dataflow.WOS
                         else "stationary")
        prev = plans[i - 1] if i > 0 else None
        skip = (prev is not None and prev.choice.vn == plan.choice.vn)
        traces.append(_build_layer(
            plan.gemm, plan.choice, plan.cfg, activation=act,
            out_name=f"O{i}", commit_to=commit_to, skip_input_load=skip))
    return traces
