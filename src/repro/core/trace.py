"""Flat-trace compatibility layer over the tiled Program IR.

The untiled per-layer trace builder this module used to contain is gone:
``core/program.py`` is the single lowering (paper §IV-G execution model,
§V step 7), and what used to be a separate functional trace is now just
the flattened TraceOp stream of a Program.  These wrappers keep the
historical ``build_trace`` / ``build_chain_trace`` entry points for
examples and tests that want a plain list of ops.
"""

from __future__ import annotations

from typing import Callable

from repro.core import program as programlib
from repro.core.machine import TraceOp  # noqa: F401 (re-export)
from repro.core.mapper import Plan


def build_trace(plan: Plan, activation: Callable | None = None,
                act_name: str = "none") -> list[TraceOp]:
    """Flattened instruction stream of the plan's Program (re-lowered when
    an activation is requested, since activations live in the tile drains)."""
    prog = plan.program
    if activation is not None:
        prog = programlib.lower(plan.gemm, plan.choice, plan.cfg,
                                activation=activation, act_name=act_name)
    return list(prog.trace_ops())


def build_chain_trace(plans: list[Plan],
                      activations: list[Callable | None] | None = None,
                      act_names: list[str] | None = None
                      ) -> list[list[TraceOp]]:
    """Per-layer flat traces for a chain (paper §IV-G): layer i's Write
    commits the output on-chip into layer i+1's input buffer, and layer
    i+1 elides its SetIVNLayout + input Load.

    On-chip chaining requires matching VN sizes across the boundary (the
    committed O_VNs *are* the next layer's I_VNs); incompatible neighbours
    fall back to an off-chip round trip (no elision).
    """
    progs = []
    for i, plan in enumerate(plans):
        act = activations[i] if activations else None
        name = act_names[i] if act_names else "none"
        progs.append(programlib.lower(
            plan.gemm, plan.choice, plan.cfg, activation=act,
            act_name=name, out_name=f"O{i}"))
    return [list(p.trace_ops()) for p in programlib.chain(progs)]
