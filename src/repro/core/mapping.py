"""ExecuteMapping / ExecuteStreaming index semantics (paper §IV-D/E, Eq. 1).

Pure index math shared by the host-side mapper and the JAX machine.

WO-S convention (IO-S is the transposed problem):

  stationary VN on PE(a_h, a_w):   r = r0 + a_w // G_r
                                   c = c0 + s_r*a_h + s_c*(a_w % G_c)
  streamed VN into column a_w at step t:
                                   j = r0 + a_w // G_r           (== r)
                                   m = m0 + s_m*t + (a_w % G_r) // G_c

Each PE computes dot(streamed VN(m, j), stationary VN(r, c)) and the result
accumulates into O[m, c]; reduction over r happens across (ExecuteMapping,
ExecuteStreaming) pairs and/or across PEs mapped to the same (m, c) —
functionally a scatter-add, architecturally BIRRD + the output buffer.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.isa import Dataflow, ExecuteMapping, ExecuteStreaming


@dataclasses.dataclass(frozen=True)
class TileIndices:
    """Dense index lattices describing one (E.Mapping, E.Streaming) pair."""
    r: np.ndarray      # [AW]        stationary VN row per PE column
    c: np.ndarray      # [AH, AW]    stationary VN col per PE
    m: np.ndarray      # [T, AW]     streamed VN row per column per step
    t_steps: int


def tile_indices(em: ExecuteMapping, es: ExecuteStreaming,
                 ah: int, aw: int) -> TileIndices:
    a_w = np.arange(aw)
    a_h = np.arange(ah)
    r = em.r0 + a_w // em.g_r                                  # [AW]
    c = em.c0 + em.s_r * a_h[:, None] + em.s_c * (a_w % em.g_c)[None, :]
    t = np.arange(es.t)
    m = es.m0 + es.s_m * t[:, None] + ((a_w % em.g_r) // em.g_c)[None, :]
    return TileIndices(r=r, c=c, m=m, t_steps=es.t)


def tile_macs(em: ExecuteMapping, es: ExecuteStreaming, ah: int, aw: int,
              wvn_rows: int, wvn_cols: int, ivn_cols: int) -> int:
    """Useful MACs of one tile (zero-padded lanes excluded)."""
    idx = tile_indices(em, es, ah, aw)
    valid_w = ((idx.r[None, :] >= 0) & (idx.r[None, :] < wvn_rows)
               & (idx.c >= 0) & (idx.c < wvn_cols))            # [AH, AW]
    valid_m = (idx.m >= 0) & (idx.m < ivn_cols)                # [T, AW]
    pe_active = valid_w[None, :, :] & valid_m[:, None, :]      # [T, AH, AW]
    return int(pe_active.sum()) * es.vn_size


def tile_unique_outputs(em: ExecuteMapping, es: ExecuteStreaming,
                        ah: int, aw: int) -> int:
    idx = tile_indices(em, es, ah, aw)
    pairs = set()
    for ti in range(idx.t_steps):
        for w in range(aw):
            for h in range(ah):
                pairs.add((int(idx.m[ti, w]), int(idx.c[h, w])))
    return len(pairs)
