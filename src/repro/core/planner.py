"""Model-graph -> MINISA planner (the paper's ACT-ecosystem integration,
§V-A, adapted to this framework's model zoo).

The paper plugs the FEATHER+ mapper into ACT's graph-level analysis: ACT
finds layout-flexible regions, the mapper does layout-constrained search per
layer, and consecutive layers elide SetOVNLayout(i)/SetIVNLayout(i+1).

Here the "graph" is the per-layer GEMM stream of one of our assigned
architectures (see configs/<arch>.py:gemm_workloads).  The planner:

  1. runs the mapper per distinct GEMM shape (shapes repeat across layers,
     so plans are memoised -- the framework-level analogue of layout
     regions),
  2. applies the inter-layer elision as a Program-to-Program transform:
     a chained layer's Program drops its SetIVNLayout + input Load
     (``program.elide_input``), and the byte delta is measured on the
     transformed instruction stream rather than discounted by formula,
  3. aggregates instruction traffic, stall fractions, speedup, utilization
     per architecture x shape cell -- all byte counts taken from the
     lowered Programs' actual tile streams.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

from repro.configs.feather import FeatherConfig
from repro.core import mapper as mapperlib
from repro.core import perf as perflib
from repro.core import program as programlib
from repro.core.mapper import Gemm


def as_gemm(op_shape) -> Gemm:
    """Normalise a workload shape to the GEMM the spine maps: ``Gemm``
    passes through, anything with ``to_gemm`` (``core.conv.Conv2D``)
    lowers via im2col (paper Fig. 1's "conv. -> MatMul")."""
    if hasattr(op_shape, "to_gemm"):
        return op_shape.to_gemm()
    return op_shape


@dataclasses.dataclass(frozen=True)
class GemmOp:
    """One GEMM (or im2col-able Conv2D) in the model graph."""
    gemm: Any               # mapper.Gemm | core.conv.Conv2D
    layer: str = ""
    chained: bool = False   # consumes the previous op's output on-chip
    activation: str = "none"
    dynamic: bool = False   # both operands arrive at runtime (attention
                            # score/value GEMMs): the "weight" is request
                            # state, not part of the cached weight set


@dataclasses.dataclass
class ArchPlan:
    arch: str
    shape: str
    cfg: FeatherConfig
    ops: list[GemmOp]
    plans: dict[tuple, mapperlib.Plan]

    # aggregates
    total_macs: float = 0.0
    cycles_minisa: float = 0.0
    cycles_micro: float = 0.0
    minisa_bytes: float = 0.0
    micro_bytes: float = 0.0
    data_bytes: float = 0.0
    elided_bytes: float = 0.0

    # multi-array serving (mesh-aware planning)
    n_arrays: int = 1
    per_array_bytes: list = dataclasses.field(default_factory=list)
    per_array_cycles: list = dataclasses.field(default_factory=list)

    @property
    def load_imbalance(self) -> float:
        return perflib.load_imbalance(self.per_array_cycles)

    @property
    def speedup(self) -> float:
        return self.cycles_micro / max(self.cycles_minisa, 1e-9)

    @property
    def instr_reduction(self) -> float:
        return self.micro_bytes / max(self.minisa_bytes, 1e-9)

    @property
    def utilization(self) -> float:
        peak = self.cfg.peak_macs_per_cycle
        return self.total_macs / max(peak * self.cycles_minisa, 1e-9)

    @property
    def instr_to_data_minisa(self) -> float:
        return self.minisa_bytes / max(self.data_bytes, 1e-9)

    @property
    def instr_to_data_micro(self) -> float:
        return self.micro_bytes / max(self.data_bytes, 1e-9)

    def summary(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape,
            "array": f"{self.cfg.ah}x{self.cfg.aw}",
            "n_gemms": sum(getattr(op.gemm, "count", 1) for op in self.ops),
            "n_unique": len(self.plans),
            "macs": self.total_macs,
            "cycles_minisa": self.cycles_minisa,
            "cycles_micro": self.cycles_micro,
            "speedup": self.speedup,
            "utilization": self.utilization,
            "instr_bytes_minisa": self.minisa_bytes,
            "instr_bytes_micro": self.micro_bytes,
            "instr_reduction": self.instr_reduction,
            "instr_to_data_minisa": self.instr_to_data_minisa,
            "instr_to_data_micro": self.instr_to_data_micro,
            "elided_bytes": self.elided_bytes,
            "n_arrays": self.n_arrays,
            "load_imbalance": self.load_imbalance,
        }


def cross_check(arch_plan: ArchPlan,
                backends: Sequence[str] = ("interpreter", "pallas"),
                max_macs: float = 2e8, seed: int = 0) -> dict[tuple, dict]:
    """Execute the planned Programs on the selected backends against the
    einsum oracle (the correctness spine behind the analytic numbers).

    Every unique GEMM plan whose functional execution fits ``max_macs`` is
    run; huge layers (decode GEMMs reach billions of MACs) are skipped --
    their *mappings* are identical shape classes to the checked ones.
    Returns {(m, k, n): {backend: max_abs_err}} and raises on divergence.
    """
    import numpy as np

    from repro import backends as backendlib

    rng = np.random.default_rng(seed)
    out: dict[tuple, dict] = {}
    for key, plan in arch_plan.plans.items():
        g = plan.gemm
        if g.macs > max_macs:
            continue
        tensors = {
            "I": rng.standard_normal((g.m, g.k)).astype(np.float32),
            "W": rng.standard_normal((g.k, g.n)).astype(np.float32),
        }
        out[key] = backendlib.cross_check(plan.program, tensors,
                                          backends=tuple(backends))
    return out


def plan_model(arch: str, shape: str, ops: Sequence[GemmOp],
               cfg: FeatherConfig, cache=None, mesh=None) -> ArchPlan:
    """Plan a cell's GEMM stream.

    Mapper searches are memoised through a
    :class:`repro.runtime.cache.ProgramCache` (the process default unless
    ``cache`` is given), so the planner, the benchmarks and the runtime
    executables share one search/lowering memoisation; ``ArchPlan.plans``
    remains this cell's view of the distinct shapes it used.

    ``mesh`` (a ``dist.ArrayMesh``) plans the cell for multi-array
    serving: every Program is sharded across the mesh, per-GEMM cycles
    are the slowest array's (arrays run in parallel), instruction bytes
    sum over arrays, and the per-array aggregates / load imbalance land
    in the ArchPlan.  Inter-layer elision is per-array machine state and
    does not cross the mesh boundary, so chained ops stop eliding."""
    from repro.runtime.cache import default_cache
    cache = cache if cache is not None else default_cache()
    plans: dict[tuple, mapperlib.Plan] = {}
    elided_cache: dict[tuple, float] = {}
    mesh_cache: dict[tuple, tuple] = {}
    n_arrays = mesh.n_arrays if mesh is not None else 1
    out = ArchPlan(arch=arch, shape=shape, cfg=cfg, ops=list(ops),
                   plans=plans, n_arrays=n_arrays,
                   per_array_bytes=[0.0] * n_arrays,
                   per_array_cycles=[0.0] * n_arrays)
    for op in ops:
        g = as_gemm(op.gemm)
        key = (g.m, g.k, g.n)
        if key not in plans:
            plans[key] = cache.plan(g, cfg)
        plan = plans[key]
        prog = plan.program
        count = getattr(g, "count", 1)
        out.total_macs += g.macs * count
        if n_arrays > 1:
            if key not in mesh_cache:
                sharded = cache.sharded(prog, mesh)
                mesh_cache[key] = (
                    sharded,
                    perflib.simulate_sharded(sharded, cfg, "minisa"),
                    perflib.simulate_sharded(sharded, cfg, "micro"))
            sharded, mesh_minisa, mesh_micro = mesh_cache[key]
            out.cycles_minisa += mesh_minisa.cycles * count
            out.cycles_micro += mesh_micro.cycles * count
            bytes_per = sharded.per_array_minisa_bytes()
            for i, (b, r) in enumerate(zip(bytes_per,
                                           mesh_minisa.per_array)):
                out.per_array_bytes[i] += b * count
                out.per_array_cycles[i] += r.cycles * count
            out.minisa_bytes += sum(bytes_per) * count
        else:
            out.cycles_minisa += plan.perf_minisa.cycles * count
            out.cycles_micro += plan.perf_micro.cycles * count
            minisa_b = prog.minisa_bytes()
            if op.chained:
                if key not in elided_cache:
                    chained_prog = programlib.elide_input(prog)
                    elided_cache[key] = chained_prog.minisa_bytes()
                chained_b = elided_cache[key]
                out.elided_bytes += max(0.0, minisa_b - chained_b) * count
                minisa_b = chained_b
            out.minisa_bytes += minisa_b * count
        out.micro_bytes += prog.micro_storage_bytes() * count
        out.data_bytes += g.data_bytes * count
    return out
