"""Model-graph -> MINISA planner (the paper's ACT-ecosystem integration,
§V-A, adapted to this framework's model zoo).

The paper plugs the FEATHER+ mapper into ACT's graph-level analysis: ACT
finds layout-flexible regions, the mapper does layout-constrained search per
layer, and consecutive layers elide SetOVNLayout(i)/SetIVNLayout(i+1).

Here the "graph" is the per-layer GEMM stream of one of our assigned
architectures (see configs/<arch>.py:gemm_workloads).  The planner:

  1. runs the mapper per distinct GEMM shape (shapes repeat across layers,
     so plans are memoised -- the framework-level analogue of layout
     regions),
  2. applies the inter-layer elision discount to the MINISA byte count
     (chained layers skip one Set*VNLayout + the intermediate Load/Write
     pair when the producer's output layout already matches),
  3. aggregates instruction traffic, stall fractions, speedup, utilization
     per architecture x shape cell.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.configs.feather import FeatherConfig
from repro.core import mapper as mapperlib
from repro.core.mapper import Gemm


@dataclasses.dataclass(frozen=True)
class GemmOp:
    """One GEMM in the model graph."""
    gemm: Gemm
    layer: str = ""
    chained: bool = False   # consumes the previous op's output on-chip
    activation: str = "none"


@dataclasses.dataclass
class ArchPlan:
    arch: str
    shape: str
    cfg: FeatherConfig
    ops: list[GemmOp]
    plans: dict[tuple, mapperlib.Plan]

    # aggregates
    total_macs: float = 0.0
    cycles_minisa: float = 0.0
    cycles_micro: float = 0.0
    minisa_bytes: float = 0.0
    micro_bytes: float = 0.0
    data_bytes: float = 0.0
    elided_bytes: float = 0.0

    @property
    def speedup(self) -> float:
        return self.cycles_micro / max(self.cycles_minisa, 1e-9)

    @property
    def instr_reduction(self) -> float:
        return self.micro_bytes / max(self.minisa_bytes, 1e-9)

    @property
    def utilization(self) -> float:
        peak = self.cfg.peak_macs_per_cycle
        return self.total_macs / max(peak * self.cycles_minisa, 1e-9)

    @property
    def instr_to_data_minisa(self) -> float:
        return self.minisa_bytes / max(self.data_bytes, 1e-9)

    @property
    def instr_to_data_micro(self) -> float:
        return self.micro_bytes / max(self.data_bytes, 1e-9)

    def summary(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape,
            "array": f"{self.cfg.ah}x{self.cfg.aw}",
            "n_gemms": sum(op.gemm.count for op in self.ops),
            "n_unique": len(self.plans),
            "macs": self.total_macs,
            "cycles_minisa": self.cycles_minisa,
            "cycles_micro": self.cycles_micro,
            "speedup": self.speedup,
            "utilization": self.utilization,
            "instr_bytes_minisa": self.minisa_bytes,
            "instr_bytes_micro": self.micro_bytes,
            "instr_reduction": self.instr_reduction,
            "instr_to_data_minisa": self.instr_to_data_minisa,
            "instr_to_data_micro": self.instr_to_data_micro,
            "elided_bytes": self.elided_bytes,
        }


def plan_model(arch: str, shape: str, ops: Sequence[GemmOp],
               cfg: FeatherConfig) -> ArchPlan:
    plans: dict[tuple, mapperlib.Plan] = {}
    out = ArchPlan(arch=arch, shape=shape, cfg=cfg, ops=list(ops),
                   plans=plans)
    lay_bits = cfg.bits_set_layout()
    load_bits = cfg.bits_load_store()
    for op in ops:
        g = op.gemm
        key = (g.m, g.k, g.n)
        if key not in plans:
            plans[key] = mapperlib.search(g, cfg)
        plan = plans[key]
        sched = plan.schedule
        count = g.count
        out.total_macs += g.macs * count
        out.cycles_minisa += plan.perf_minisa.cycles * count
        out.cycles_micro += plan.perf_micro.cycles * count
        minisa_b = sched.minisa_storage_bytes()
        if op.chained:
            # SetIVNLayout elision + skipped intermediate Load/Write pair
            elide_bits = lay_bits + 2 * load_bits
            minisa_b = max(0.0, minisa_b - elide_bits / 8.0)
            out.elided_bytes += elide_bits / 8.0 * count
        out.minisa_bytes += minisa_b * count
        out.micro_bytes += sched.micro_storage_bytes() * count
        out.data_bytes += g.data_bytes * count
    return out
