"""Convolution -> GEMM lowering via im2col (paper Fig. 1).

The paper's workload model: "Convolution is converted to MatMul via
im2col"; the artifact's search handles "GEMM/conv." uniformly.  This
module provides the shape-level lowering used by the mapper/planner and a
functional im2col for end-to-end validation through the FEATHER+ machine.

Conv2D: input [N, H, W, C_in], kernel [KH, KW, C_in, C_out], stride s,
'SAME'/'VALID' padding  ->  GEMM  [N*OH*OW, KH*KW*C_in] x
[KH*KW*C_in, C_out].
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.mapper import Gemm


@dataclasses.dataclass(frozen=True)
class Conv2D:
    n: int
    h: int
    w: int
    c_in: int
    kh: int
    kw: int
    c_out: int
    stride: int = 1
    padding: str = "SAME"
    name: str = ""

    @property
    def out_hw(self) -> tuple[int, int]:
        if self.padding == "SAME":
            oh = math.ceil(self.h / self.stride)
            ow = math.ceil(self.w / self.stride)
        else:
            oh = (self.h - self.kh) // self.stride + 1
            ow = (self.w - self.kw) // self.stride + 1
        return oh, ow

    def to_gemm(self) -> Gemm:
        oh, ow = self.out_hw
        return Gemm(m=self.n * oh * ow, k=self.kh * self.kw * self.c_in,
                    n=self.c_out,
                    name=self.name or
                    f"conv{self.kh}x{self.kw}s{self.stride}-"
                    f"{self.c_in}->{self.c_out}")


def _pad_amount(size: int, k: int, s: int) -> tuple[int, int]:
    out = math.ceil(size / s)
    total = max((out - 1) * s + k - size, 0)
    return total // 2, total - total // 2


def im2col(x: np.ndarray, conv: Conv2D) -> np.ndarray:
    """x: [N, H, W, C_in] -> patches [N*OH*OW, KH*KW*C_in]."""
    n, h, w, c = x.shape
    assert (n, h, w, c) == (conv.n, conv.h, conv.w, conv.c_in)
    if conv.padding == "SAME":
        ph = _pad_amount(h, conv.kh, conv.stride)
        pw = _pad_amount(w, conv.kw, conv.stride)
        x = np.pad(x, ((0, 0), ph, pw, (0, 0)))
    oh, ow = conv.out_hw
    cols = np.empty((n, oh, ow, conv.kh, conv.kw, c), x.dtype)
    for i in range(conv.kh):
        for j in range(conv.kw):
            cols[:, :, :, i, j, :] = x[
                :, i:i + oh * conv.stride:conv.stride,
                j:j + ow * conv.stride:conv.stride, :]
    return cols.reshape(n * oh * ow, conv.kh * conv.kw * c)


def conv2d_ref(x: np.ndarray, kern: np.ndarray, conv: Conv2D) -> np.ndarray:
    """Reference conv via the lowered GEMM; kern: [KH, KW, C_in, C_out]."""
    patches = im2col(x, conv)
    wmat = kern.reshape(-1, conv.c_out)
    oh, ow = conv.out_hw
    return (patches @ wmat).reshape(conv.n, oh, ow, conv.c_out)
