"""Fused chained-GEMM megakernel: one ``pl.pallas_call`` for a whole
MINISA chained segment (paper §IV-G at kernel granularity).

The per-layer NEST kernel (``nest_gemm.py``) launches once per GEMM, so
every chained activation round-trips through HBM between launches even
though the Program IR commits it on-chip.  This kernel is the compiled
twin of that commit: the grid walks host-M blocks, and within one grid
step a ``bm``-row slab of the activation flows through *all* layers of
the segment without leaving VMEM --

  layer l:  acc = sum_k  h[:, k:k+bk_l] @ W_l[k:k+bk_l, :]
            (the layer's weight streamed in host-K tiles against the
             resident activation slab, fp32 accumulate)
            acc = act_l(acc)      at the final-K store -- the Activation
                                  drain, fused exactly where the
                                  interpreter applies it
            h   = scratch_l <- acc   interior commit: the chained
                                     activation lives in VMEM scratch,
                                     never in HBM

Only the segment input (one HBM read) and the last layer's output (one
HBM write) cross the chip boundary; ``core/program.FusedSegment``'s
traffic accounting charges exactly that.

Row-wise activations (softmax / rmsnorm / layernorm) are legal here even
though the per-layer kernel must defer them to the host: each layer's
accumulator block spans the layer's FULL output width (weights are VMEM-
resident per grid step), so a block holds complete host rows.  Their
numerics mirror ``runtime.executable.ACTIVATIONS`` (same eps, same
max-subtraction).

On CPU the kernel runs in Pallas interpret mode; on TPU the identical
call site lowers to Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.nest_gemm import ACT_FNS


def _softmax(x):
    z = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def _rmsnorm(x):
    return x / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _layernorm(x):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) * (x - mu), axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-6)


#: Activations applicable inside the fused kernel: the elementwise set
#: shared with the per-layer kernel, plus the row-wise ones (legal here
#: because a fused block holds full output rows).
FUSED_ACT_FNS = {
    **ACT_FNS,
    "softmax": _softmax,
    "rmsnorm": _rmsnorm,
    "layernorm": _layernorm,
}


def _fused_kernel(x_ref, *refs, dims, bks, acts):
    """One bm-row slab through every layer of the segment."""
    n_layers = len(dims)
    w_refs = refs[:n_layers]
    o_ref = refs[n_layers]
    h_refs = refs[n_layers + 1:]          # interior VMEM commits
    h = x_ref[...].astype(jnp.float32)
    for layer, (k_l, n_l) in enumerate(dims):
        acc = jnp.zeros((h.shape[0], n_l), jnp.float32)
        bk = bks[layer]
        for k0 in range(0, k_l, bk):      # stream the weight's K tiles
            k1 = min(k0 + bk, k_l)
            acc += jnp.dot(h[:, k0:k1], w_refs[layer][k0:k1, :],
                           preferred_element_type=jnp.float32)
        if acts[layer] is not None:       # Activation drain, fused
            acc = FUSED_ACT_FNS[acts[layer]](acc)
        if layer < n_layers - 1:
            h_refs[layer][...] = acc      # on-chip commit (stays in VMEM)
            h = h_refs[layer][...]
        else:
            o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bks", "acts", "interpret",
                                    "out_dtype"))
def fused_chain(x: jax.Array, *ws: jax.Array, bm: int,
                bks: tuple[int, ...], acts: tuple[str | None, ...],
                interpret: bool = False, out_dtype=None) -> jax.Array:
    """O = act_{L-1}(... act_0(X @ W_0) ... @ W_{L-1}); M % bm == 0
    (``kernels.ops.fused_chain`` pads).

    One kernel launch for the whole chain: grid (M/bm,), each weight
    VMEM-resident per grid step, interior activations in VMEM scratch.
    """
    m, k0 = x.shape
    assert ws, "fused_chain needs at least one weight"
    assert m % bm == 0, f"M={m} not divisible by bm={bm}"
    dims = tuple(w.shape for w in ws)
    k_prev = k0
    for k_l, n_l in dims:
        assert k_l == k_prev, f"chain shape mismatch: {k_prev} -> {k_l}"
        k_prev = n_l
    assert len(bks) == len(ws) and len(acts) == len(ws)
    assert all(a is None or a in FUSED_ACT_FNS for a in acts), acts
    n_out = dims[-1][1]
    out_dtype = out_dtype or x.dtype

    in_specs = [pl.BlockSpec((bm, k0), lambda i: (i, 0))]
    in_specs += [pl.BlockSpec(dim, lambda i: (0, 0)) for dim in dims]
    scratch = [pltpu.VMEM((bm, n_l), jnp.float32)
               for _, n_l in dims[:-1]]
    return pl.pallas_call(
        functools.partial(_fused_kernel, dims=dims, bks=tuple(bks),
                          acts=tuple(acts)),
        grid=(m // bm,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n_out), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(x, *ws)
