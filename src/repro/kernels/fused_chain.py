"""Fused chained-GEMM megakernel with double-buffered weight streaming:
one ``pl.pallas_call`` for a whole MINISA chained segment (paper §IV-G
at kernel granularity), VMEM bounded by the largest layer.

The PR-5 kernel kept every layer's FULL weight VMEM-resident per grid
step, so the VMEM budget capped segment length at the *sum* of the
weights and ``adapt`` (head-split) boundaries broke fusion.  This kernel
streams instead: the grid is ``(M/bm, sum_l K_l/bk_l)`` — the second
axis walks every layer's host-K tiles back to back, and Pallas's grid
pipeline double-buffers each weight's ``(bk_l, n_l)`` window because its
BlockSpec index advances between consecutive steps (and pins once the
layer is done, eliding refetch).  Per grid step::

  layer l, K-tile j:   acc[:, :n_l] += h[:, j*bk : (j+1)*bk] @ W_l_tile
  at j == kt_l - 1:    acc = act_l(acc)          (Activation drain)
                       slab <- acc | adapt(acc)  (interior commit — the
                                                  chained activation and
                                                  the head-split/merge
                                                  permutation both live
                                                  in VMEM scratch)

Only the segment input (one HBM read), the weight K-tiles (each shipped
once per M block) and the last layer's output (one HBM write) cross the
chip boundary; ``core/program.FusedSegment`` charges exactly that.

``adapt`` boundaries (the runtime's flatten/cycle/reshape shape glue
between chained layers) lower to an all-static index permutation on the
resident slab: the true ``(m_l, n_l)`` region of the accumulator is
raveled row-major, cycled to ``m' * k'`` elements and reshaped — bit-
identical to ``runtime.executable.adapt`` because it IS the same
indexing, just performed in VMEM.  This requires the whole activation
resident (one M block), which ``fuse_segment`` enforces.

Row-wise activations (softmax / rmsnorm / layernorm) stay legal: the
accumulator block spans the layer's FULL true output width, so it holds
complete host rows.  Their numerics mirror
``runtime.executable.ACTIVATIONS`` (same eps, same max-subtraction).

On CPU the kernel runs in Pallas interpret mode; on TPU the identical
call site lowers to Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.nest_gemm import ACT_FNS
from repro.obs.trace import trace


def _softmax(x):
    z = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def _rmsnorm(x):
    return x / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _layernorm(x):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) * (x - mu), axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-6)


#: Activations applicable inside the fused kernel: the elementwise set
#: shared with the per-layer kernel, plus the row-wise ones (legal here
#: because a fused block holds full output rows).
FUSED_ACT_FNS = {
    **ACT_FNS,
    "softmax": _softmax,
    "rmsnorm": _rmsnorm,
    "layernorm": _layernorm,
}


def _adapt_slab(acc, m_l, n_l, m_next, k_next):
    """The runtime ``adapt`` shape glue as a static index permutation:
    ravel the true region row-major, cycle to m'*k' elements, reshape."""
    flat = acc[:m_l, :].reshape(-1)
    need = m_next * k_next
    size = m_l * n_l
    if need > size:
        flat = jnp.tile(flat, -(-need // size))
    return flat[:need].reshape(m_next, k_next)


def _fused_kernel(x_ref, *refs, dims, bks, kts, offs, acts, adapts,
                  bm, k_slab):
    """One (m-block, K-tile) grid step: exactly one layer's tile fires."""
    n_layers = len(dims)
    w_refs = refs[:n_layers]
    o_ref = refs[n_layers]
    slab_ref = refs[n_layers + 1]     # resident interior activation
    acc_ref = refs[n_layers + 2]      # fp32 accumulator, n_max wide
    s = pl.program_id(1)

    for layer in range(n_layers):
        m_l, k_l, n_l = dims[layer]
        off, kt, bk = offs[layer], kts[layer], bks[layer]
        j = s - off                   # this layer's local K-tile index

        @pl.when((s >= off) & (s < off + kt))
        def _layer_step(layer=layer, m_l=m_l, k_l=k_l, n_l=n_l,
                        kt=kt, bk=bk, j=j):
            if layer == 0:
                # the input block window IS this K tile (streamed too)
                h = x_ref[...].astype(jnp.float32)
            else:
                h = slab_ref[:, pl.ds(j * bk, bk)]
            partial = jnp.dot(h, w_refs[layer][...].astype(jnp.float32),
                              preferred_element_type=jnp.float32)

            @pl.when(j == 0)
            def _init():
                acc_ref[:, :n_l] = jnp.zeros((bm, n_l), jnp.float32)

            acc_ref[:, :n_l] += partial

            @pl.when(j == kt - 1)     # final K tile: drain the layer
            def _drain():
                acc = acc_ref[:, :n_l]
                if acts[layer] is not None:
                    acc = FUSED_ACT_FNS[acts[layer]](acc)
                if layer == n_layers - 1:
                    o_ref[...] = acc.astype(o_ref.dtype)
                    return
                if adapts[layer + 1]:
                    m_next, k_next = dims[layer + 1][:2]
                    nxt = _adapt_slab(acc, m_l, n_l, m_next, k_next)
                else:
                    m_next, k_next = bm, n_l
                    nxt = acc
                # full overwrite, zero-padded: stale slab columns from
                # the previous (wider) layer can never leak, and the
                # zero K-pad matches the zero-padded weight rows
                slab_ref[...] = jnp.pad(
                    nxt, ((0, bm - m_next), (0, k_slab - k_next)))


def _pad_axis(x, axis, target):
    pad = target - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def fused_chain(x: jax.Array, *ws: jax.Array, bm: int,
                bks: tuple[int, ...], acts: tuple[str | None, ...],
                adapts: tuple[bool, ...] | None = None,
                dims: tuple[tuple[int, int, int], ...] | None = None,
                interpret: bool = False, out_dtype=None) -> jax.Array:
    """Traced entry point for the jitted megakernel: when the ``obs``
    tracer is enabled, the launch is timed to ``block_until_ready`` (the
    device-sync wall clock of the ONE ``pallas_call``); disabled, this
    is one attribute check on top of the jit dispatch."""
    if not trace.enabled:
        return _fused_chain_jit(x, *ws, bm=bm, bks=bks, acts=acts,
                                adapts=adapts, dims=dims,
                                interpret=interpret, out_dtype=out_dtype)
    with trace.span("kernel.fused_chain", n_layers=len(ws), bm=bm,
                    bks=tuple(bks), grid_k=sum(
                        -(-d[1] // max(1, min(bk, d[1])))
                        for d, bk in zip(dims, bks)) if dims else None):
        return jax.block_until_ready(
            _fused_chain_jit(x, *ws, bm=bm, bks=bks, acts=acts,
                             adapts=adapts, dims=dims,
                             interpret=interpret, out_dtype=out_dtype))


@functools.partial(jax.jit,
                   static_argnames=("bm", "bks", "acts", "adapts", "dims",
                                    "interpret", "out_dtype"))
def _fused_chain_jit(x: jax.Array, *ws: jax.Array, bm: int,
                     bks: tuple[int, ...], acts: tuple[str | None, ...],
                     adapts: tuple[bool, ...] | None = None,
                     dims: tuple[tuple[int, int, int], ...] | None = None,
                     interpret: bool = False, out_dtype=None) -> jax.Array:
    """O = act_{L-1}(... act_0(X @ W_0) ... @ W_{L-1}) in ONE launch,
    each weight streamed HBM->VMEM in double-buffered (bk_l, n_l) tiles.

    ``dims`` carries each layer's TRUE (m, k, n); operands are zero-
    padded here to the K-tile grid (zero pad rows make stale slab
    columns inert).  ``adapts[l]`` marks the runtime shape-glue boundary
    before layer ``l``, lowered to the in-kernel slab permutation —
    which needs the whole activation in one M block (bm >= every m_l).
    """
    assert ws, "fused_chain needs at least one weight"
    n_layers = len(ws)
    if adapts is None:
        adapts = (False,) * n_layers
    if dims is None:
        m = x.shape[0]
        dims = tuple((m, w.shape[0], w.shape[1]) for w in ws)
    assert len(bks) == len(acts) == len(adapts) == len(dims) == n_layers
    assert not adapts[0], "layer 0 reads the host input, not the slab"
    for l in range(1, n_layers):
        if not adapts[l]:
            assert dims[l][1] == dims[l - 1][2], \
                f"chain shape mismatch at layer {l}: " \
                f"{dims[l - 1][2]} -> {dims[l][1]}"
    assert all(a is None or a in FUSED_ACT_FNS for a in acts), acts
    out_dtype = out_dtype or x.dtype

    bks = tuple(max(1, min(bk, d[1])) for bk, d in zip(bks, dims))
    kts = tuple(-(-d[1] // bk) for d, bk in zip(dims, bks))
    padded_ks = tuple(kt * bk for kt, bk in zip(kts, bks))
    offs = tuple(sum(kts[:l]) for l in range(n_layers))
    total = sum(kts)
    m0, m_out = dims[0][0], dims[-1][0]
    n_out = dims[-1][2]
    if any(adapts):
        # the slab permutation needs every row of every layer resident
        bm = max(bm, max(d[0] for d in dims))
        n_m = 1
    else:
        bm = max(1, min(bm, m0))
        n_m = -(-m0 // bm)
    k_slab = max([pk for pk in padded_ks[1:]] or [1])

    x = _pad_axis(_pad_axis(x, 0, n_m * bm), 1, padded_ks[0])
    ws = tuple(_pad_axis(w, 0, pk) for w, pk in zip(ws, padded_ks))

    in_specs = [pl.BlockSpec(
        (bm, bks[0]),
        lambda i, s, kt0=kts[0]: (i, jnp.minimum(s, kt0 - 1)))]
    in_specs += [
        pl.BlockSpec(
            (bk, w.shape[1]),
            lambda i, s, off=off, kt=kt: (jnp.clip(s - off, 0, kt - 1), 0))
        for w, bk, off, kt in zip(ws, bks, offs, kts)]
    out = pl.pallas_call(
        functools.partial(
            _fused_kernel, dims=tuple(dims), bks=bks, kts=kts, offs=offs,
            acts=tuple(acts), adapts=tuple(adapts), bm=bm, k_slab=k_slab),
        grid=(n_m, total),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, n_out), lambda i, s: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_m * bm, n_out), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, k_slab), jnp.float32),
                        pltpu.VMEM((bm, max(d[2] for d in dims)),
                                   jnp.float32)],
        interpret=interpret,
    )(x, *ws)
    return out[:m_out]
