"""Selective-scan (Mamba-1 inner recurrence) as a Pallas TPU kernel.

    h_t = da_t * h_{t-1} + dbx_t          (elementwise in [d_blk, n])
    y_t = sum_n c_t[n] * h_t[:, n]

Grid: (batch, channel blocks, L chunks); the L-chunk dimension is innermost/
sequential, the carried state h lives in a VMEM scratch that persists across
chunk steps (TPU grid iteration is sequential per core).  Inside a chunk the
recurrence is a fori_loop over time in registers/VMEM -- the HBM<->VMEM
traffic is one read of (da, dbx, c) and one write of y per element, i.e. the
kernel is memory-bound by design, matching the SSM roofline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(da_ref, dbx_ref, c_ref, h0_ref, y_ref, hout_ref, h_ref, *,
                 chunk: int, n_chunks: int):
    li = pl.program_id(2)

    @pl.when(li == 0)
    def _init():
        h_ref[...] = h0_ref[0]

    def step(t, _):
        h = h_ref[...]
        h = da_ref[0, t] * h + dbx_ref[0, t]       # [d_blk, n]
        h_ref[...] = h
        y_ref[0, t] = jnp.sum(h * c_ref[0, t][None, :],
                              axis=-1).astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, chunk, step, ())

    @pl.when(li == n_chunks - 1)
    def _store():
        hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d_blk", "chunk", "interpret"))
def mamba_scan(da: jax.Array, dbx: jax.Array, c: jax.Array, h0: jax.Array,
               *, d_blk: int = 256, chunk: int = 64,
               interpret: bool = False):
    """da, dbx: [B, L, D, N]; c: [B, L, N]; h0: [B, D, N].

    Returns (y [B, L, D], h_last [B, D, N]).
    """
    b, l, d, n = da.shape
    d_blk = min(d_blk, d)
    chunk = min(chunk, l)
    assert d % d_blk == 0 and l % chunk == 0, (d, d_blk, l, chunk)
    n_chunks = l // chunk
    kernel = functools.partial(_scan_kernel, chunk=chunk, n_chunks=n_chunks)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(b, d // d_blk, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, d_blk, n), lambda bi, di, li: (bi, li, di, 0)),
            pl.BlockSpec((1, chunk, d_blk, n), lambda bi, di, li: (bi, li, di, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, di, li: (bi, li, 0)),
            pl.BlockSpec((1, d_blk, n), lambda bi, di, li: (bi, di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d_blk), lambda bi, di, li: (bi, li, di)),
            pl.BlockSpec((1, d_blk, n), lambda bi, di, li: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, d), da.dtype),
            jax.ShapeDtypeStruct((b, d, n), h0.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((d_blk, n), jnp.float32)],
        interpret=interpret,
    )(da, dbx, c, h0)
    return y, h_last
