"""NEST GEMM: the paper's compute atom as a Pallas TPU kernel.

TPU adaptation of FEATHER+'s NEST (DESIGN.md §2): the AH-element PE dot
product becomes the K-block of an MXU-tiled matmul; a NEST column's
"VN group" (vn stationary VNs x T streamed VNs) becomes one (bm x bk) x
(bk x bn) VMEM tile-pair; BIRRD's reorder-in-reduction becomes the output
BlockSpec index map, which lets the caller pick the *output layout*
(row-major or block-transposed) at reduction time for free -- the paper's
(dataflow, layout) co-switching insight expressed in Mosaic terms.

Grid: (M/bm, N/bn, K/bk); K is innermost (sequential on TPU) and the output
block is revisited across it, accumulating in a VMEM fp32 scratch.

``repro.backends.pallas_backend`` compiles lowered Programs onto this
kernel: the Program's snapped tiling becomes (bm, bk, bn), an IO-S
(transposed-accumulator) SetOVNLayout becomes ``out_block_t``, and an
elementwise Activation drain becomes ``act`` (fused at the final K step,
exactly where the interpreter applies it to the drained tile).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: Elementwise activations fusable at the output-store step (the MINISA
#: Activation instruction's elementwise subset; row-wise functions such as
#: softmax/norms need full rows and are applied by the caller instead).
ACT_FNS = {
    "relu": lambda x: jnp.maximum(x, 0.0),
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def _nest_gemm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int,
                      out_block_t: bool, act: str | None):
    """One (bm, bn) output tile; accumulates over the K grid dimension."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU: low-precision inputs, fp32 accumulate
    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _store():
        acc = acc_ref[...]
        if act is not None:
            acc = ACT_FNS[act](acc)
        if out_block_t:
            acc = acc.T
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "out_dtype", "out_block_t",
                                             "act"))
def nest_gemm(x: jax.Array, w: jax.Array, *, bm: int = 128, bn: int = 128,
              bk: int = 128, interpret: bool = False, out_dtype=None,
              out_block_t: bool = False,
              act: str | None = None) -> jax.Array:
    """O = X[M, K] @ W[K, N]; shapes must divide by the blocks (ops.py pads).

    out_block_t=True stores output *tiles* to transposed tile coordinates
    (O_t[j, i] blocks) -- the BIRRD-style free output re-layout: the next
    consumer can read a column-major-of-blocks layout with zero extra
    passes.  O then has shape (N//bn * bn rows of blocks ...) == (N, M) with
    per-block transposition applied.

    ``act`` fuses an elementwise activation (a key of :data:`ACT_FNS`) into
    the final-K store, before the optional block transpose.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"{(m, k, n)} not divisible by blocks {(bm, bk, bn)}"
    assert act is None or act in ACT_FNS, act
    n_k = k // bk
    out_dtype = out_dtype or x.dtype

    if out_block_t:
        out_spec = pl.BlockSpec((bn, bm), lambda i, j, kk: (j, i))
        out_shape = jax.ShapeDtypeStruct((n, m), out_dtype)
    else:
        out_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
        out_shape = jax.ShapeDtypeStruct((m, n), out_dtype)

    return pl.pallas_call(
        functools.partial(_nest_gemm_kernel, n_k=n_k,
                          out_block_t=out_block_t, act=act),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
