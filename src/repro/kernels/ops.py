"""jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run with interpret=True (the kernel body
executes in Python, validating semantics); on TPU the same call sites lower
to Mosaic.  ``interpret=None`` auto-detects.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import fused_chain as _fc
from repro.kernels import mamba_scan as _ms
from repro.kernels import nest_gemm as _ng


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.devices()[0].platform != "tpu"


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def nest_gemm(x, w, *, bm=128, bn=128, bk=128, interpret=None,
              out_dtype=None, out_block_t=False, act=None):
    """Ragged-shape-safe NEST GEMM (zero-pads to block multiples, the
    paper's implicit zero-padding semantics).  ``act`` fuses an
    elementwise activation from :data:`nest_gemm.ACT_FNS` into the store."""
    interpret = _auto_interpret(interpret)
    m, k = x.shape
    n = w.shape[1]
    bm_, bn_, bk_ = (min(bm, _rnd(m)), min(bn, _rnd(n)), min(bk, _rnd(k)))
    x, _ = _pad_to(x, 0, bm_)
    x, _ = _pad_to(x, 1, bk_)
    w, _ = _pad_to(w, 0, bk_)
    w, _ = _pad_to(w, 1, bn_)
    o = _ng.nest_gemm(x, w, bm=bm_, bn=bn_, bk=bk_, interpret=interpret,
                      out_dtype=out_dtype, out_block_t=out_block_t, act=act)
    if out_block_t:
        return o[:n, :m]
    return o[:m, :n]


def _rnd(x):
    """Largest power of two <= x (min 8) for block sizing on small shapes."""
    p = 8
    while p * 2 <= x:
        p *= 2
    return p


def fused_chain(x, ws, *, bm=128, bks=None, acts=None, adapts=None,
                dims=None, interpret=None, out_dtype=None):
    """Ragged-shape-safe fused chained GEMM: ONE kernel launch for
    ``act_{L-1}(... act_0(x @ ws[0]) ...) @ ws[-1]`` with every layer's
    weight streamed HBM->VMEM in double-buffered K tiles and every
    interior activation resident in VMEM (the kernel zero-pads M and K
    to the tile grid, the paper's implicit zero-padding semantics).

    ``bks`` sets each layer's weight-streaming granularity; ``acts``
    names per-layer activations from :data:`fused_chain.FUSED_ACT_FNS`
    (None entries skip); ``adapts``/``dims`` carry the runtime's shape-
    glue boundaries and true per-layer (m, k, n) so a whole transformer
    block (attention + MLP, spanning head-split reshapes) runs as one
    launch.
    """
    interpret = _auto_interpret(interpret)
    n_layers = len(ws)
    if bks is None:
        bks = (128,) * n_layers
    if acts is None:
        acts = (None,) * n_layers
    bks_ = tuple(max(1, min(bk, w.shape[0])) for bk, w in zip(bks, ws))
    return _fc.fused_chain(
        x, *ws, bm=bm, bks=bks_, acts=tuple(acts),
        adapts=None if adapts is None else tuple(adapts),
        dims=None if dims is None else tuple(tuple(d) for d in dims),
        interpret=interpret, out_dtype=out_dtype)


def flash_attention(q, k, v, *, causal=True, bq=128, bkv=128,
                    interpret=None):
    """q, k, v: [B, S, H, D] -> [B, S, H, D]."""
    interpret = _auto_interpret(interpret)
    b, s, h, d = q.shape
    sk = k.shape[1]
    bq_, bkv_ = min(bq, _rnd(s)), min(bkv, _rnd(sk))
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    qf, pq = _pad_to(qf, 1, bq_)
    kf, _ = _pad_to(kf, 1, bkv_)
    vf, _ = _pad_to(vf, 1, bkv_)
    # padded KV columns must not contribute: they are causally masked for
    # causal=True; for full attention, mask via large-negative k rows
    o = _fa.flash_attention(qf, kf, vf, causal=causal, bq=bq_, bkv=bkv_,
                            interpret=interpret, kv_len=sk)
    o = o[:, :s].reshape(b, h, s, d).transpose(0, 2, 1, 3)
    return o


def flash_decode(q, k, v, lengths=None, *, bkv=128, interpret=None,
                 scale=1.0):
    """Ragged-shape-safe batched decode attention: one launch advances
    every request in the batch, each masked to its own KV length.

    q: [B, sq, d], k, v: [B, skv, d], lengths: [B] or [B, 1] int true
    lengths (defaults to the full skv for every request).
    """
    interpret = _auto_interpret(interpret)
    b, sq, d = q.shape
    sk = k.shape[1]
    if lengths is None:
        lengths = jnp.full((b, 1), sk, dtype=jnp.int32)
    else:
        lengths = jnp.asarray(lengths, dtype=jnp.int32).reshape(b, 1)
    bkv_ = min(bkv, _rnd(sk))
    q, _ = _pad_to(q, 1, 8)
    k, _ = _pad_to(k, 1, bkv_)
    v, _ = _pad_to(v, 1, bkv_)
    o = _fa.flash_decode(q, k, v, lengths, bkv=bkv_, interpret=interpret,
                         scale=scale)
    return o[:, :sq]


def flash_decode_proj(q, k, v, wo, lengths=None, *, m_out, k_out,
                      bkv=128, interpret=None, scale=1.0):
    """Block-fused batched decode attention: one launch computes
    softmax(q k^T) v AND the adapt-cycled output projection ``wo`` for
    every request in the batch.

    q: [B, sq, d], k, v: [B, skv, d], wo: [k_out, n_out] shared across
    requests, lengths: [B] or [B, 1] int true KV lengths.  Each
    request's [sq, d] context is raveled row-major, cycled to
    m_out * k_out elements and refolded to [m_out, k_out] in VMEM (the
    runtime ``adapt`` head-merge) before the projection.  Returns
    [B, m_out, n_out].
    """
    interpret = _auto_interpret(interpret)
    b, sq, d = q.shape
    sk = k.shape[1]
    if lengths is None:
        lengths = jnp.full((b, 1), sk, dtype=jnp.int32)
    else:
        lengths = jnp.asarray(lengths, dtype=jnp.int32).reshape(b, 1)
    bkv_ = min(bkv, _rnd(sk))
    q, _ = _pad_to(q, 1, 8)
    k, _ = _pad_to(k, 1, bkv_)
    v, _ = _pad_to(v, 1, bkv_)
    return _fa.flash_decode_proj(q, k, v, lengths, jnp.asarray(wo),
                                 true_sq=sq, m_out=m_out, k_out=k_out,
                                 bkv=bkv_, interpret=interpret,
                                 scale=scale)


def mamba_scan(da, dbx, c, h0, *, d_blk=256, chunk=64, interpret=None):
    interpret = _auto_interpret(interpret)
    b, l, d, n = da.shape
    d_blk = min(d_blk, _rnd(d))
    chunk = min(chunk, _rnd(l))
    assert d % d_blk == 0 and l % chunk == 0, (
        "mamba_scan requires power-of-two-friendly shapes; "
        f"got d={d}, l={l}")
    return _ms.mamba_scan(da, dbx, c, h0, d_blk=d_blk, chunk=chunk,
                          interpret=interpret)
