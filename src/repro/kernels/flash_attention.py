"""Flash attention (causal/full) as a Pallas TPU kernel.

Grid: (batch*heads, Q blocks, KV blocks); KV is the innermost sequential
dimension.  Running (max, sum, acc) live in VMEM scratch and the output
block is finalised on the last KV step -- the classic online-softmax
recurrence, with causal block skipping via pl.when.

``flash_decode`` is the serving twin: one launch advances a whole batch
of decode requests, each row attending over its *own* gathered K/V pages
masked to its own true length (grid (requests, KV blocks); per-request
length rides along as a [B, 1] int32 operand).  Unlike the prefill
kernel it applies no ``d**-0.5`` scaling by default -- the MINISA GEMM
stream's score GEMM carries none, and the batched path must stay on the
sequential path's numeric trajectory.

``flash_decode_proj`` is the block-fused variant: at the last KV step
the finalised context is adapt-cycled (ravel -> tile -> slice ->
reshape, the runtime's head-merge permutation done statically in VMEM)
and multiplied by the resident output projection, so attention + Wo for
the whole decode batch is ONE launch instead of two.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_kv: int, bq: int, bkv: int, causal: bool, scale: float,
                  kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0]                       # [bq, d]
        k = k_ref[0]                       # [bkv, d]
        v = v_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        if kv_len < n_kv * bkv:
            # zero-padded KV tail (ops.py raggedness) must not contribute --
            # guard on the padded extent, not kv_len % bkv: a block-aligned
            # kv_len shorter than the padded buffer must still be masked
            s = jnp.where(kpos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        # exp(s - m_new) == 1, not 0, when an entire row is masked so far
        # (s == m_new == NEG_INF); zero those explicitly or padding leaks
        p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new))
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip fully-masked blocks: kv block strictly after the q block
        pl.when(ki * bkv <= qi * bq + bq - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == n_kv - 1)
    def _store():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bkv",
                                             "interpret", "kv_len"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 128, bkv: int = 128,
                    interpret: bool = False,
                    kv_len: int | None = None) -> jax.Array:
    """q, k, v: [BH, S, d] (heads folded into batch); returns [BH, S, d]."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % bq == 0 and sk % bkv == 0, (sq, sk, bq, bkv)
    n_kv = sk // bkv
    scale = d ** -0.5
    kernel = functools.partial(
        _flash_kernel, n_kv=n_kv, bq=bq, bkv=bkv, causal=causal, scale=scale,
        kv_len=kv_len if kv_len is not None else sk)
    return pl.pallas_call(
        kernel,
        grid=(bh, sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, n_kv: int, sq: int, bkv: int, scale: float):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                           # [sq, d]
    k = k_ref[0]                           # [bkv, d]
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (sq, bkv), 1)
    # this request's true KV length -- everything past it (other requests'
    # retired pages, zero padding) is masked out of the softmax
    s = jnp.where(kpos < len_ref[0, 0], s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new))
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _store():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _decode_proj_kernel(q_ref, k_ref, v_ref, len_ref, wo_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, n_kv: int, sq: int,
                        true_sq: int, d: int, bkv: int, scale: float,
                        m_out: int, k_out: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                           # [sq, d]
    k = k_ref[0]                           # [bkv, d]
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (sq, bkv), 1)
    s = jnp.where(kpos < len_ref[0, 0], s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new))
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _project():
        ctx = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)   # [sq, d]
        # runtime adapt on the TRUE context rows: ravel row-major, cycle
        # to m_out*k_out elements, refold -- the head-merge permutation
        # the per-layer path does on the host between pv and wo
        flat = ctx[:true_sq, :].reshape(-1)
        need, size = m_out * k_out, true_sq * d
        if need > size:
            flat = jnp.tile(flat, -(-need // size))
        h = flat[:need].reshape(m_out, k_out)
        o_ref[0] = jnp.dot(h, wo_ref[...],
                           preferred_element_type=jnp.float32
                           ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "true_sq", "m_out", "k_out", "bkv", "interpret", "scale"))
def flash_decode_proj(q: jax.Array, k: jax.Array, v: jax.Array,
                      lengths: jax.Array, wo: jax.Array, *, true_sq: int,
                      m_out: int, k_out: int, bkv: int = 128,
                      interpret: bool = False,
                      scale: float = 1.0) -> jax.Array:
    """Block-fused batched decode attention: softmax(q k^T) v followed by
    the adapt-cycled output projection, one launch for the whole batch.

    q: [B, sq, d] (rows past ``true_sq`` are carrier padding and are
    dropped before the adapt), k, v: [B, skv, d], lengths: [B, 1] int32,
    wo: [k_out, n_out] shared across requests (its BlockSpec is pinned,
    so it streams HBM->VMEM once).  Returns [B, m_out, n_out].
    """
    b, sq, d = q.shape
    sk = k.shape[1]
    n_out = wo.shape[1]
    assert sk % bkv == 0, (sk, bkv)
    assert wo.shape[0] == k_out, (wo.shape, k_out)
    n_kv = sk // bkv
    kernel = functools.partial(
        _decode_proj_kernel, n_kv=n_kv, sq=sq, true_sq=true_sq, d=d,
        bkv=bkv, scale=scale, m_out=m_out, k_out=k_out)
    return pl.pallas_call(
        kernel,
        grid=(b, n_kv),
        in_specs=[
            pl.BlockSpec((1, sq, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bkv, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((k_out, n_out), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m_out, n_out), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m_out, n_out), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((sq, 1), jnp.float32),
            pltpu.VMEM((sq, 1), jnp.float32),
            pltpu.VMEM((sq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lengths, wo)


@functools.partial(jax.jit, static_argnames=("bkv", "interpret", "scale"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 lengths: jax.Array, *, bkv: int = 128,
                 interpret: bool = False, scale: float = 1.0) -> jax.Array:
    """Batched ragged decode attention: one launch for the whole batch.

    q: [B, sq, d] (one decode carrier per request), k, v: [B, skv, d]
    (per-request gathered KV pages), lengths: [B, 1] int32 true KV
    lengths.  Softmax for request b runs over k[b, :lengths[b]] only.
    No default ``d**-0.5``: score scaling is the GEMM stream's business.
    """
    b, sq, d = q.shape
    sk = k.shape[1]
    assert sk % bkv == 0, (sk, bkv)
    n_kv = sk // bkv
    kernel = functools.partial(_decode_kernel, n_kv=n_kv, sq=sq, bkv=bkv,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, n_kv),
        in_specs=[
            pl.BlockSpec((1, sq, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bkv, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, sq, d), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((sq, 1), jnp.float32),
            pltpu.VMEM((sq, 1), jnp.float32),
            pltpu.VMEM((sq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lengths)
