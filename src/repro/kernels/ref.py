"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def nest_gemm_ref(x: jax.Array, w: jax.Array, out_dtype=None,
                  out_block_t: bool = False, bm: int = 128,
                  bn: int = 128) -> jax.Array:
    out_dtype = out_dtype or x.dtype
    o = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if out_block_t:
        # per-block transpose at swapped block coordinates == global
        # transpose (the BIRRD-free-relayout case)
        o = o.T
    return o.astype(out_dtype)


def flash_attention_ref(q, k, v, causal: bool = True):
    bh, sq, d = q.shape
    sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if causal:
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def mamba_scan_ref(da, dbx, c, h0):
    """Sequential reference recurrence."""
    def step(h, xs):
        da_t, dbx_t, c_t = xs
        h = da_t * h + dbx_t                       # [B, D, N]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h_last, ys = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (jnp.moveaxis(da, 1, 0).astype(jnp.float32),
         jnp.moveaxis(dbx, 1, 0).astype(jnp.float32),
         jnp.moveaxis(c, 1, 0).astype(jnp.float32)))
    return jnp.moveaxis(ys, 0, 1).astype(da.dtype), h_last.astype(h0.dtype)
