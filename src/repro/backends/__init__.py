"""Pluggable execution backends for lowered MINISA Programs.

    from repro import backends

    be = backends.get_backend("pallas", cfg)
    out = be.run_program(plan.program, {"I": i, "W": w})["O"]

Every backend consumes the same tiled Program IR the mapper lowers once
(``core/program.py``), so the cross-backend equivalence check

    interpreter == pallas == einsum oracle

is the correctness spine tying the functional machine, the compiled
kernels and the analytical model to one artifact.
"""

from __future__ import annotations

from typing import TYPE_CHECKING

import numpy as np

from repro.backends.base import Backend
from repro.backends.interpreter import InterpreterBackend
from repro.backends.pallas_backend import (CompiledProgram, CompiledSegment,
                                           PallasBackend, compile_program,
                                           compile_segment)

if TYPE_CHECKING:  # pragma: no cover
    from repro.configs.feather import FeatherConfig
    from repro.core.program import Program

__all__ = [
    "Backend", "InterpreterBackend", "PallasBackend", "CompiledProgram",
    "CompiledSegment", "compile_program", "compile_segment", "BACKENDS",
    "get_backend", "run", "cross_check", "run_sharded",
]

BACKENDS: dict[str, type[Backend]] = {
    InterpreterBackend.name: InterpreterBackend,
    PallasBackend.name: PallasBackend,
}


def get_backend(backend: str | Backend, cfg: "FeatherConfig",
                **kwargs) -> Backend:
    """Resolve a backend name (or pass an instance through)."""
    if isinstance(backend, Backend):
        return backend
    try:
        cls = BACKENDS[backend]
    except KeyError:
        raise ValueError(f"unknown backend {backend!r}; "
                         f"available: {sorted(BACKENDS)}") from None
    return cls(cfg, **kwargs)


def run(program: "Program", tensors: dict[str, np.ndarray],
        backend: str | Backend = "interpreter",
        **backend_kwargs) -> dict[str, np.ndarray]:
    """One-shot execution of a single Program on a fresh backend."""
    be = get_backend(backend, program.cfg, **backend_kwargs)
    return be.run_program(program, tensors)


def run_sharded(program: "Program", tensors: dict[str, np.ndarray], mesh,
                backend: str | Backend = "interpreter", axis: str | None = None,
                **backend_kwargs) -> dict[str, np.ndarray]:
    """One-shot sharded execution: partition ``program`` over ``mesh``'s
    arrays (``core/program.shard_program``) and run on a fresh backend."""
    from repro.core import program as programlib
    sharded = programlib.shard_program(program, mesh, axis=axis)
    be = get_backend(backend, program.cfg, **backend_kwargs)
    return be.run_sharded(sharded, tensors)


def cross_check(program: "Program", tensors: dict[str, np.ndarray],
                backends: tuple[str, ...] = ("interpreter", "pallas"),
                rtol: float = 2e-4, atol: float = 2e-4,
                mesh=None, axis: str | None = None) -> dict[str, float]:
    """Run ``program`` on every named backend and compare each output to
    the einsum oracle (fp32-accumulate tolerance); returns the max abs
    error per backend and raises on mismatch.

    With ``mesh`` (a ``dist.ArrayMesh``), each backend executes the
    Program *sharded* across the mesh's arrays instead -- the oracle is
    unchanged, which is exactly the sharded-equivalence contract."""
    g = program.gemm
    i = np.asarray(tensors["I"], np.float32)
    w = np.asarray(tensors["W"], np.float32)
    oracle = i @ w
    if program.activation is not None:
        oracle = np.asarray(program.activation(oracle))
    errs: dict[str, float] = {}
    for name in backends:
        if mesh is not None:
            out = run_sharded(program, tensors, mesh, backend=name,
                              axis=axis)[program.out_name]
        else:
            out = run(program, tensors, backend=name)[program.out_name]
        np.testing.assert_allclose(out, oracle, rtol=rtol,
                                   atol=atol + rtol * g.k,
                                   err_msg=f"backend {name!r} diverged from "
                                           f"oracle on {g}")
        errs[name] = float(np.abs(out - oracle).max())
    return errs
