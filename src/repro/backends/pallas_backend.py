"""PallasBackend: compile a lowered Program to ``pl.pallas_call``.

The interpreter replays the MINISA instruction stream tile by tile; this
backend instead *compiles* the Program once and runs the whole tile lattice
as a single Pallas kernel launch per layer -- the paper's (mapping, layout)
co-switching decisions executed at hardware speed:

  Program tiling (M_t, K_t, N_t)  ->  kernel grid (n_m, n_n, n_k) and
                                      (bm, bk, bn) BlockSpecs, K innermost
                                      sequential (the OB revisit order)
  SetOVNLayout / IO-S dataflow    ->  the accumulator is transposed w.r.t.
                                      the host output, which lowers to the
                                      BIRRD-style ``out_block_t`` output
                                      index map (blocks stored transposed
                                      at swapped coordinates, i.e. the free
                                      output re-layout in the reduction)
  operand residency               ->  block shapes: a ``full``/``panel``
                                      resident operand keeps its Program
                                      tile extent; ``tiled`` operands are
                                      additionally clamped to
                                      ``max_block`` so one kernel block
                                      never exceeds a VMEM-sized working
                                      set (the §IV-G sub-tiling analogue)
  elementwise Activation drain    ->  fused into the final-K store
                                      (``kernels.nest_gemm.ACT_FNS``);
                                      row-wise activations are applied by
                                      the backend on the assembled output,
                                      in the accumulator orientation the
                                      interpreter uses
  same-shaped tile runs           ->  one ``pallas_call`` covers the whole
                                      lattice; ragged edge tiles become
                                      zero-padding (the paper's implicit
                                      zero-pad semantics), not extra
                                      launches

On CPU the kernel runs in Pallas interpret mode (semantics-exact); on TPU
the identical call sites lower to Mosaic.  Chained Programs resolve their
elided/retargeted inputs against the backend's previous outputs, mirroring
the machine's on-chip commit.

Fused segments: ``run_segment`` compiles a whole ``program.chain``-ed
segment (a :class:`~repro.core.program.FusedSegment`) to ONE
``pallas_call`` -- the chained activation stays resident in VMEM scratch
across layers, each layer's weight streams in host-K tiles against it,
and each layer's Activation drain fuses at its final-K store
(``kernels.fused_chain``).  One fused compile replaces one compile per
GEMM, and the intermediate HBM round trips the per-layer path pays
vanish structurally.
"""

from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Any

import jax
import numpy as np

from repro.backends.base import Backend
from repro.core import isa
from repro.core import program as programlib
from repro.kernels import nest_gemm as nglib
from repro.kernels import ops as kernel_ops
from repro.obs import metrics as obs_metrics
from repro.obs.trace import trace

#: every pallas_call site increments this (labelled by kernel), so the
#: scheduler's per-instance ``n_launches`` diffs and the process-wide
#: scrape agree on what actually launched
_LAUNCHES = obs_metrics.counter(
    "backend_launches_total", "pallas_call launches by kernel")

if TYPE_CHECKING:  # pragma: no cover
    from repro.configs.feather import FeatherConfig
    from repro.core.program import Program


@dataclasses.dataclass(frozen=True)
class CompiledProgram:
    """Compilation artifact: everything needed to launch the kernel."""
    wos: bool                    # WO-S (host-oriented) vs IO-S (transposed)
    bm: int                      # host-coordinate kernel block sizes
    bk: int
    bn: int
    grid: tuple[int, int, int]   # (m blocks, n blocks, k blocks), padded
    out_block_t: bool            # BIRRD-style transposed-block output map
    fused_act: str | None        # activation fused into the kernel
    host_act: Any                # activation applied post-assembly
    input_name: str | None       # Load tensor name of the input operand
    weight_name: str             # Load tensor name of the weight operand
    out_name: str
    commit: bool                 # final Write commits on-chip (chaining)
    residency: dict[str, str]

    @property
    def n_launches(self) -> int:
        """Kernel grid cells in the single launch (vs Program tiles)."""
        return self.grid[0] * self.grid[1] * self.grid[2]

    def describe(self) -> dict:
        return {
            "dataflow": "WOS" if self.wos else "IOS",
            "blocks": (self.bm, self.bk, self.bn),
            "grid": self.grid,
            "out_block_t": self.out_block_t,
            "fused_act": self.fused_act,
            "residency": dict(self.residency),
        }


@dataclasses.dataclass(frozen=True)
class CompiledSegment:
    """Fused-segment artifact: ONE kernel launch for a chained segment.

    Mirrors the :class:`~repro.core.program.FusedSegment` geometry with
    the backend's ``max_block`` clamp applied; ``dims`` are the per-layer
    TRUE host (M, K, N) extents the streamed launch binds, ``adapts``
    the in-kernel shape-glue boundaries.
    """
    bm: int                         # resident-activation rows per grid step
    layer_bks: tuple[int, ...]      # per-layer weight K-streaming tile
    acts: tuple[str | None, ...]    # per-layer in-kernel activation
    dims: tuple[tuple[int, int, int], ...]
    adapts: tuple[bool, ...]
    out_name: str

    @property
    def n_layers(self) -> int:
        return len(self.dims)

    def describe(self) -> dict:
        return {
            "n_layers": self.n_layers,
            "bm": self.bm,
            "layer_bks": self.layer_bks,
            "acts": self.acts,
            "dims": self.dims,
            "adapts": self.adapts,
        }


def compile_segment(segment, *, max_block: int = 2048) -> CompiledSegment:
    """Clamp the FusedSegment launch geometry to the backend's working-set
    bound.  One call == one fused compile (vs one per layer unfused).

    Adapt-crossing segments keep their bm unclamped: the in-kernel slab
    permutation needs every activation row resident in one M block.
    """
    from repro.kernels.fused_chain import FUSED_ACT_FNS
    for act in segment.acts:
        if act is not None and act not in FUSED_ACT_FNS:
            raise ValueError(f"activation {act!r} has no fused kernel")
    adapts = tuple(segment.adapts)
    bm = segment.bm if any(adapts) else max(1, min(segment.bm, max_block))
    return CompiledSegment(
        bm=bm,
        layer_bks=tuple(max(1, min(bk, max_block))
                        for bk in segment.layer_bks),
        acts=tuple(segment.acts),
        dims=tuple((p.gemm.m, p.gemm.k, p.gemm.n)
                   for p in segment.programs),
        adapts=adapts,
        out_name=segment.out_name)


def _load_names(program: "Program") -> tuple[str | None, str]:
    """Tensor names the Program's Loads bind to ('I' may be retargeted to a
    producer's committed output, or absent entirely when elided)."""
    input_name, weight_name = None, "W"
    for tile in program.tiles:
        for op in tile.loads:
            if op.meta.get("operand") == "I":
                input_name = op.meta["tensor"]
            elif op.meta.get("operand") == "W":
                weight_name = op.meta["tensor"]
    return input_name, weight_name


def compile_program(program: "Program", *,
                    max_block: int = 2048) -> CompiledProgram:
    """Derive the kernel launch geometry from the Program's tiling."""
    cfg = program.cfg
    snapped = programlib.snap_tiling(program.gemm, program.choice, cfg)
    if snapped is None:  # lower() would have raised already
        raise ValueError(f"infeasible program {program.choice}")
    m_t, k_t, n_t = snapped
    wos = program.choice.df == isa.Dataflow.WOS
    # search orientation -> host orientation: under IO-S the search m-rank
    # tiles host N and the search n-rank tiles host M
    if wos:
        bm_t, bk_t, bn_t = m_t, k_t, n_t
    else:
        bm_t, bk_t, bn_t = n_t, k_t, m_t

    def _block(tile_ext: int, dim: int, mode: str) -> int:
        b = min(tile_ext, dim)
        if mode == programlib.TILED:
            b = min(b, max_block)
        return max(1, min(b, max_block * 2))

    g = program.gemm
    sta_mode = program.residency["stationary"]
    str_mode = program.residency["streaming"]
    # host-M is streamed under WO-S, stationary under IO-S (and vice versa
    # for host-N); K follows the tighter of the two operands
    bm = _block(bm_t, g.m, str_mode if wos else sta_mode)
    bn = _block(bn_t, g.n, sta_mode if wos else str_mode)
    bk = _block(bk_t, g.k,
                programlib.TILED if (sta_mode == programlib.TILED
                                     or str_mode == programlib.TILED)
                else programlib.FULL)
    grid = (math.ceil(g.m / bm), math.ceil(g.n / bn), math.ceil(g.k / bk))

    fused = None
    host_act = None
    if program.activation is not None:
        if program.act_name in nglib.ACT_FNS:
            fused = program.act_name
        else:
            host_act = program.activation

    input_name, weight_name = _load_names(program)
    commit = any(op.meta.get("commit_to") is not None
                 for tile in program.tiles for op in tile.drains)
    return CompiledProgram(
        wos=wos, bm=bm, bk=bk, bn=bn, grid=grid,
        out_block_t=not wos, fused_act=fused, host_act=host_act,
        input_name=input_name, weight_name=weight_name,
        out_name=program.out_name, commit=commit,
        residency=dict(program.residency))


class PallasBackend(Backend):
    """Compiled execution: one Pallas kernel launch per Program."""

    name = "pallas"

    def __init__(self, cfg: "FeatherConfig", *, interpret: bool | None = None,
                 max_block: int = 2048, compile_cache=None):
        super().__init__(cfg)
        # interpret=None auto-detects: Python-interpret on CPU, Mosaic on TPU
        self.interpret = (interpret if interpret is not None
                          else jax.devices()[0].platform != "tpu")
        self.max_block = max_block
        self._committed: np.ndarray | None = None
        # id(program) alone would go stale once a Program is collected and
        # its id reused; keeping the Program alongside pins the id and lets
        # us verify the hit.  Bounded so a long-lived backend cannot leak.
        self._cache: dict[int, tuple["Program", CompiledProgram]] = {}
        self._fused_cache: dict[int, tuple[Any, CompiledSegment]] = {}
        self._cache_limit = 128
        # Optional shared artifact store (runtime.cache.ProgramCache):
        # keyed *structurally*, so fresh-but-equivalent Program objects
        # (a rebuilt executable, another backend instance) reuse compiled
        # artifacts instead of recompiling.  n_compiles counts the real
        # compile_program invocations this instance performed.
        self.compile_cache = compile_cache
        self.n_compiles = 0
        # kernel launches this instance performed (one pallas_call each);
        # the Scheduler diffs this to prove one-launch-per-segment ticks
        self.n_launches = 0

    def compile(self, program: "Program") -> CompiledProgram:
        key = id(program)
        hit = self._cache.get(key)
        if hit is not None and hit[0] is program:
            return hit[1]
        comp = None
        if self.compile_cache is not None:
            comp = self.compile_cache.lookup_compiled(program,
                                                      self.max_block)
        if comp is None:
            with trace.span("backend.compile", out=program.out_name):
                comp = compile_program(program, max_block=self.max_block)
            self.n_compiles += 1
            if self.compile_cache is not None:
                self.compile_cache.store_compiled(program, self.max_block,
                                                  comp)
        if len(self._cache) >= self._cache_limit:
            self._cache.pop(next(iter(self._cache)))
        self._cache[key] = (program, comp)
        return comp

    def compile_fused(self, segment) -> CompiledSegment:
        """Fused-tier compile: one artifact per segment (structural key
        via the shared ProgramCache when attached), so serving a
        multi-layer cell costs ONE compile where the per-layer path pays
        one per GEMM."""
        key = id(segment)
        hit = self._fused_cache.get(key)
        if hit is not None and hit[0] is segment:
            return hit[1]
        comp = None
        if self.compile_cache is not None:
            comp = self.compile_cache.lookup_fused(segment, self.max_block)
        if comp is None:
            with trace.span("backend.compile_fused",
                            n_layers=len(segment.programs)):
                comp = compile_segment(segment, max_block=self.max_block)
            self.n_compiles += 1
            if self.compile_cache is not None:
                self.compile_cache.store_fused(segment, self.max_block,
                                               comp)
        if len(self._fused_cache) >= self._cache_limit:
            self._fused_cache.pop(next(iter(self._fused_cache)))
        self._fused_cache[key] = (segment, comp)
        return comp

    def run_segment(self, segment, tensors=None):
        """ONE ``pallas_call`` for the whole chained segment: each
        layer's weight streams HBM->VMEM in double-buffered K tiles
        against the resident activation slab, with adapt (head-split)
        boundaries lowered to in-kernel slab permutations; only the
        segment input, the weight tiles and the final output cross HBM.

        A :class:`~repro.core.program.ShardedFusedSegment` dispatches to
        the per-array path (one fused launch per array).
        """
        if isinstance(segment, programlib.ShardedFusedSegment):
            return self._run_sharded_segment(segment, tensors)
        comp = self.compile_fused(segment)
        self.n_launches += 1
        _LAUNCHES.inc(1, kernel="fused_chain")
        tensors = tensors or {}
        x = self._resolve("I", tensors, False)
        ws = [jax.numpy.asarray(
                  self._resolve(f"W{layer}", tensors, False),
                  jax.numpy.float32)
              for layer in range(comp.n_layers)]
        with trace.span("launch", kernel="fused_chain",
                        n_layers=comp.n_layers, bm=comp.bm,
                        out=comp.out_name) as sp:
            out = np.asarray(kernel_ops.fused_chain(
                jax.numpy.asarray(x, jax.numpy.float32), ws,
                bm=comp.bm, bks=comp.layer_bks, acts=comp.acts,
                adapts=comp.adapts, dims=comp.dims,
                interpret=self.interpret,
                out_dtype=jax.numpy.float32))
            if sp:          # np.asarray already forced device sync
                sp.set(n_launches=self.n_launches,
                       vmem_highwater_bytes=getattr(
                           segment, "vmem_highwater_bytes",
                           lambda: None)())
        self.outputs[comp.out_name] = out
        return self.outputs

    def run_batched_attention(self, programs, q, kT, v, lengths=None):
        """ONE ``flash_decode`` launch for the whole decode batch: every
        request's score+context GEMM pair, each row masked to its own
        true KV length (SNIPPETS §2 flash-decode shape).  Replaces 2*B
        per-request launches with one."""
        import jax.numpy as jnp
        self.n_launches += 1
        _LAUNCHES.inc(1, kernel="flash_decode")
        k = jnp.asarray(kT, jnp.float32).transpose(0, 2, 1)
        with trace.span("launch", kernel="flash_decode",
                        batch=int(q.shape[0])) as sp:
            out = np.asarray(kernel_ops.flash_decode(
                jnp.asarray(q, jnp.float32), k,
                jnp.asarray(v, jnp.float32),
                lengths, interpret=self.interpret))
            if sp:
                sp.set(n_launches=self.n_launches)
        return out

    def run_batched_attention_proj(self, programs, q, kT, v, wo, *,
                                   m_out, k_out, lengths=None):
        """ONE ``flash_decode_proj`` launch: batched ragged attention
        with the output projection folded into the last KV step, the
        adapt head-merge done as a static in-VMEM permutation.  Replaces
        the attention launch plus B per-request Wo launches."""
        import jax.numpy as jnp
        self.n_launches += 1
        _LAUNCHES.inc(1, kernel="flash_decode_proj")
        k = jnp.asarray(kT, jnp.float32).transpose(0, 2, 1)
        with trace.span("launch", kernel="flash_decode_proj",
                        batch=int(q.shape[0])) as sp:
            out = np.asarray(kernel_ops.flash_decode_proj(
                jnp.asarray(q, jnp.float32), k,
                jnp.asarray(v, jnp.float32),
                jnp.asarray(wo, jnp.float32), lengths, m_out=m_out,
                k_out=k_out, interpret=self.interpret))
            if sp:
                sp.set(n_launches=self.n_launches)
        return out

    def _resolve(self, name: str | None, tensors, elided: bool):
        if name is None:
            if not elided or self._committed is None:
                raise KeyError("Program has no input Load and no committed "
                               "producer output to elide from")
            return self._committed
        src = tensors.get(name) if tensors else None
        if src is None:
            src = self.outputs.get(name)
        if src is None:
            raise KeyError(f"Load refers to unknown tensor {name!r}")
        return np.asarray(src)

    def _make_shard_backend(self) -> "PallasBackend":
        return PallasBackend(self.cfg, interpret=self.interpret,
                             max_block=self.max_block,
                             compile_cache=self.compile_cache)

    def run_sharded(self, sharded, tensors=None):
        """One ``shard_map``-wrapped kernel launch over the array mesh.

        When the logical arrays are backed by JAX devices
        (``ArrayMesh.jax_mesh()``), the whole mesh executes as a single
        ``shard_map`` around the same ``nest_gemm`` kernel the unsharded
        path compiles: the split rank is padded to an even per-array
        extent (the paper's implicit zero-padding -- zero rows/cols/k
        contribute nothing), operands get the axis-appropriate
        PartitionSpecs, and a K split closes with ``lax.psum`` over the
        array axis.  Without a device mesh, falls back to the base
        sequential per-shard path (identical numerics).
        """
        jmesh = sharded.mesh.jax_mesh()
        if jmesh is None or sharded.n_shards < 2:
            return super().run_sharded(sharded, tensors)
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        # every shard shares one mapping choice, so the first shard's
        # compiled geometry serves the whole mesh (ragged final shards
        # are zero-padded to the uniform per-array extent)
        comp = self.compile(sharded.shards[0].program)
        g = sharded.base.gemm
        x = self._resolve(comp.input_name or "I", tensors, False)
        w = self._resolve(comp.weight_name, tensors, False)
        x = jnp.asarray(x, jnp.float32)
        w = jnp.asarray(w, jnp.float32)
        n = sharded.mesh.n_arrays
        axis, ax_name = sharded.axis, sharded.mesh.axis_name
        dim = {"m": g.m, "n": g.n, "k": g.k}[axis]
        pad = -dim % (-(-dim // n) * n)

        if axis == "m":
            x = jnp.pad(x, ((0, pad), (0, 0)))
            in_specs = (P(ax_name, None), P(None, None))
            out_spec = P(ax_name, None)
        elif axis == "n":
            w = jnp.pad(w, ((0, 0), (0, pad)))
            in_specs = (P(None, None), P(None, ax_name))
            out_spec = P(None, ax_name)
        else:
            x = jnp.pad(x, ((0, 0), (0, pad)))
            w = jnp.pad(w, ((0, pad), (0, 0)))
            in_specs = (P(None, ax_name), P(ax_name, None))
            out_spec = P()

        def body(xs, ws):
            o = kernel_ops.nest_gemm(
                xs, ws, bm=comp.bm, bn=comp.bn, bk=comp.bk,
                interpret=self.interpret, out_dtype=jnp.float32,
                out_block_t=comp.out_block_t, act=comp.fused_act)
            if comp.out_block_t:
                o = o.T
            if axis == "k":
                o = jax.lax.psum(o, ax_name)
            return o

        # check_rep=False: jax has no replication rule for pallas_call
        self.n_launches += 1
        _LAUNCHES.inc(1, kernel="nest_gemm_shard_map")
        with trace.span("launch", kernel="nest_gemm_shard_map",
                        n_arrays=n, axis=axis, out=sharded.out_name) as sp:
            out = shard_map(body, mesh=jmesh, in_specs=in_specs,
                            out_specs=out_spec, check_rep=False)(x, w)
            out = np.ascontiguousarray(np.asarray(out)[:g.m, :g.n])
            if sp:
                sp.set(n_launches=self.n_launches)
        if comp.host_act is not None:
            # per-shard Programs only keep shard-local activations (see
            # shard_program), so host application on the assembled output
            # is exact
            out = np.asarray(comp.host_act(out))
        if sharded.epilogue_act is not None:
            out = np.asarray(sharded.epilogue_act(out))
        self.outputs[sharded.out_name] = out
        return self.outputs

    def run_program(self, program: "Program",
                    tensors: dict[str, np.ndarray] | None = None
                    ) -> dict[str, np.ndarray]:
        if isinstance(program, programlib.ShardedProgram):
            return self.run_sharded(program, tensors)
        comp = self.compile(program)
        self.n_launches += 1
        _LAUNCHES.inc(1, kernel="nest_gemm")
        x = self._resolve(comp.input_name, tensors, program.input_elided)
        w = self._resolve(comp.weight_name, tensors, False)
        with trace.span("launch", kernel="nest_gemm", grid=comp.grid,
                        out=comp.out_name) as sp:
            out = np.asarray(kernel_ops.nest_gemm(
                jax.numpy.asarray(x, jax.numpy.float32),
                jax.numpy.asarray(w, jax.numpy.float32),
                bm=comp.bm, bn=comp.bn, bk=comp.bk,
                interpret=self.interpret, out_dtype=jax.numpy.float32,
                out_block_t=comp.out_block_t, act=comp.fused_act))
            if sp:
                sp.set(n_launches=self.n_launches)
        if comp.out_block_t:
            # the kernel stored the IO-S (search-oriented) accumulator; the
            # final Write's host-facing view is its transpose
            if comp.host_act is not None:
                out = np.asarray(comp.host_act(out))
            out = np.ascontiguousarray(out.T)
        elif comp.host_act is not None:
            out = np.asarray(comp.host_act(out))
        self.outputs[comp.out_name] = out
        if comp.commit:
            self._committed = out
        return self.outputs

    def reset(self) -> None:
        super().reset()
        self._committed = None
        self._cache = {}
        self._fused_cache = {}
