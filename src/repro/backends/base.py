"""Execution-backend interface: how a lowered Program becomes numbers.

A :class:`Backend` consumes the tiled Program IR (``core/program.py``) and
produces the named output tensors.  Two implementations ship:

  interpreter  ``backends.interpreter.InterpreterBackend`` -- drives the
               FEATHER+ functional machine tile by tile (the semantics of
               every MINISA instruction, formerly the orchestration loop
               inside ``core/machine.py``)
  pallas       ``backends.pallas_backend.PallasBackend`` -- compiles the
               Program's tiling to one ``pl.pallas_call`` per layer
               (interpret-mode on CPU, Mosaic on TPU)

Backends are stateful across ``run_program`` calls within one instance:
chained Programs (paper §IV-G) resolve their elided/retargeted inputs
against the backend's committed outputs, exactly like the machine's
on-chip commit.  ``reset()`` clears that state.

Multi-array execution: ``run_program`` also accepts a
:class:`~repro.core.program.ShardedProgram` (dispatching to
:meth:`run_sharded`).  The base implementation keeps one sub-backend per
logical array -- each array is its own machine with its own buffers and
committed state -- runs every shard on its array's executor, and
assembles the host output (concatenation along the split rank, or an
explicit reduction for K-partitioned shards) before applying any hoisted
epilogue activation.  Subclasses may override ``run_sharded`` with a
genuinely parallel path (the Pallas backend shard_maps one kernel over a
JAX device mesh when available).
"""

from __future__ import annotations

import abc
from typing import TYPE_CHECKING

import numpy as np

from repro.core.program import ShardedProgram

if TYPE_CHECKING:  # pragma: no cover
    from repro.configs.feather import FeatherConfig
    from repro.core.program import Program


class Backend(abc.ABC):
    """Common interface over Program executors."""

    #: registry key; subclasses override
    name: str = "abstract"

    def __init__(self, cfg: "FeatherConfig"):
        self.cfg = cfg
        self.outputs: dict[str, np.ndarray] = {}
        #: kernel launches performed (only compiled backends bump this;
        #: the interpreter replays instructions, it does not launch)
        self.n_launches = 0
        # one executor per logical array, created on first sharded run
        self._shard_subs: dict[int, "Backend"] = {}

    @abc.abstractmethod
    def run_program(self, program: "Program",
                    tensors: dict[str, np.ndarray] | None = None
                    ) -> dict[str, np.ndarray]:
        """Execute one lowered Program; returns all named outputs so far.

        Chained layer sequences (``program.chain``) are executed with one
        ``run_program`` call per layer on the same backend instance,
        passing each layer's own tensors (the default lowering names every
        layer's weight Load 'W', so a single shared dict would silently
        reuse layer 0's weights).

        A :class:`ShardedProgram` argument dispatches to
        :meth:`run_sharded`."""

    # -- chained-segment execution -------------------------------------------
    def run_segment(self, segment, tensors: dict[str, np.ndarray] | None
                    = None) -> dict[str, np.ndarray]:
        """Execute a :class:`~repro.core.program.FusedSegment`.

        ``tensors`` carries the segment input as ``'I'`` and layer l's
        weight as ``'W{l}'``.  The base implementation replays the
        chained per-layer Programs on this backend (the chain semantics
        -- on-chip commit, elided/retargeted inputs -- come from the
        Programs themselves), applying the runtime ``adapt`` shape glue
        at the segment's interior adapt boundaries; subclasses with a
        genuinely fused path (the Pallas backend's one-launch
        megakernel, which lowers adapt to an in-kernel slab permutation)
        override it.  A :class:`~repro.core.program.ShardedFusedSegment`
        dispatches to the per-array path.
        """
        from repro.core.program import ShardedFusedSegment
        if isinstance(segment, ShardedFusedSegment):
            return self._run_sharded_segment(segment, tensors)
        from repro.runtime.executable import adapt
        tensors = tensors or {}
        adapts = getattr(segment, "adapts", None) \
            or (False,) * len(segment.programs)
        for layer, prog in enumerate(segment.programs):
            t = {"W": tensors[f"W{layer}"]}
            if layer == 0:
                if "I" in tensors:
                    t["I"] = tensors["I"]
            elif adapts[layer]:
                prev = self.outputs[segment.programs[layer - 1].out_name]
                g = prog.gemm
                t["I"] = adapt(np.asarray(prev), g.m, g.k)
            elif any(op.meta.get("operand") == "I"
                     and op.meta.get("tensor") == "I"
                     for tile in prog.tiles for op in tile.loads):
                # unchained sub-programs (per-array shard chains) still
                # load the host 'I': feed the previous layer's output
                t["I"] = np.asarray(
                    self.outputs[segment.programs[layer - 1].out_name])
            self.run_program(prog, t)
        return self.outputs

    def _run_sharded_segment(self, segment, tensors=None
                             ) -> dict[str, np.ndarray]:
        """Per-array fused execution of an M-sharded chained segment:
        each array runs its row slice of the WHOLE chain on its own
        sub-backend (fused on backends that support it), so the segment
        costs n_arrays launches instead of n_arrays * n_layers."""
        tensors = tensors or {}
        out = np.zeros((segment.m, segment.n_out), np.float32)
        for a, (fseg, (m0, m1)) in enumerate(
                zip(segment.array_segments, segment.row_ranges)):
            sub = self._shard_backend(a)
            before = sub.n_launches
            t = {k: v for k, v in tensors.items() if k != "I"}
            if "I" in tensors:
                t["I"] = np.asarray(tensors["I"])[m0:m1]
            res = sub.run_segment(fseg, t)
            out[m0:m1] = np.asarray(res[fseg.out_name])[:m1 - m0]
            self.n_launches += sub.n_launches - before
        self.outputs[segment.out_name] = out
        return self.outputs

    # -- batched decode attention --------------------------------------------
    def run_batched_attention(self, programs, q: np.ndarray,
                              kT: np.ndarray, v: np.ndarray,
                              lengths=None) -> np.ndarray:
        """Advance a whole decode batch through one attention segment.

        ``programs`` is the (score, value) Program pair of a dynamic
        attention segment; ``q`` is [B, m, d] stacked per-request
        carriers, ``kT`` [B, d, skv] / ``v`` [B, skv, d_o] the
        per-request gathered KV operands, ``lengths`` the per-request
        true KV lengths.  Returns the stacked [B, m, d_o] context.

        The base implementation replays the chained Program pair once
        per request -- the sequential oracle the batched kernel must
        match.  The Programs' in-stream softmax spans the full ``skv``
        width, so the base path only accepts full-width lengths; the
        Pallas override (``kernel_ops.flash_decode``) handles genuinely
        ragged batches.
        """
        qk, pv = programs
        skv = kT.shape[2]
        if lengths is not None:
            assert all(int(x) == skv for x in np.asarray(lengths).ravel()), \
                ("base run_batched_attention replays full-width Programs; "
                 f"ragged lengths {lengths} need the Pallas backend")
        outs = []
        for r in range(q.shape[0]):
            self.run_program(qk, {"I": q[r], "W": kT[r]})
            out = self.run_program(pv, {"W": v[r]})[pv.out_name]
            outs.append(np.asarray(out))
        return np.stack(outs)

    def run_batched_attention_proj(self, programs, q: np.ndarray,
                                   kT: np.ndarray, v: np.ndarray,
                                   wo: np.ndarray, *, m_out: int,
                                   k_out: int, lengths=None) -> np.ndarray:
        """Block-fused decode attention: the attention pair PLUS the
        adapt-cycled output projection ``wo`` for every request.

        The base implementation replays :meth:`run_batched_attention`
        and applies the runtime ``adapt`` + GEMM per request on the host
        -- the oracle for the Pallas override, which folds the
        projection into the decode kernel's last KV step (one launch for
        attention + Wo instead of two).  Returns [B, m_out, n_out].
        """
        from repro.runtime.executable import adapt
        ctx = self.run_batched_attention(programs, q, kT, v,
                                         lengths=lengths)
        wo = np.asarray(wo, np.float32)
        return np.stack([adapt(ctx[r], m_out, k_out) @ wo
                         for r in range(ctx.shape[0])])

    # -- multi-array execution ----------------------------------------------
    def _make_shard_backend(self) -> "Backend":
        """A fresh executor for one logical array (subclasses thread their
        construction kwargs through)."""
        return type(self)(self.cfg)

    def _shard_backend(self, array: int) -> "Backend":
        be = self._shard_subs.get(array)
        if be is None:
            be = self._make_shard_backend()
            self._shard_subs[array] = be
        return be

    def run_sharded(self, sharded: ShardedProgram,
                    tensors: dict[str, np.ndarray] | None = None
                    ) -> dict[str, np.ndarray]:
        """Execute every shard on its array's executor and assemble.

        M/N shards write disjoint output slices; K shards produce
        partial sums combined by an explicit reduction -- the functional
        twin of the mesh all-reduce.  The hoisted epilogue activation
        (see ``program.shard_program``) runs on the assembled output.
        """
        g = sharded.base.gemm
        acc = np.zeros((g.m, g.n), np.float32)
        for shard in sharded.shards:
            sub = self._shard_backend(shard.array)
            out = np.asarray(
                sub.run_program(shard.program,
                                shard.slice_tensors(tensors))
                [sharded.out_name])
            if sharded.reduce:
                acc += out
            else:
                acc[shard.m0:shard.m1, shard.n0:shard.n1] = out
        if sharded.epilogue_act is not None:
            acc = np.asarray(sharded.epilogue_act(acc))
        self.outputs[sharded.out_name] = acc
        return self.outputs

    def reset(self) -> None:
        self.outputs = {}
        for sub in self._shard_subs.values():
            sub.reset()

    def __repr__(self) -> str:  # pragma: no cover
        return (f"{type(self).__name__}(ah={self.cfg.ah}, "
                f"aw={self.cfg.aw})")
