"""Execution-backend interface: how a lowered Program becomes numbers.

A :class:`Backend` consumes the tiled Program IR (``core/program.py``) and
produces the named output tensors.  Two implementations ship:

  interpreter  ``backends.interpreter.InterpreterBackend`` -- drives the
               FEATHER+ functional machine tile by tile (the semantics of
               every MINISA instruction, formerly the orchestration loop
               inside ``core/machine.py``)
  pallas       ``backends.pallas_backend.PallasBackend`` -- compiles the
               Program's tiling to one ``pl.pallas_call`` per layer
               (interpret-mode on CPU, Mosaic on TPU)

Backends are stateful across ``run_program`` calls within one instance:
chained Programs (paper §IV-G) resolve their elided/retargeted inputs
against the backend's committed outputs, exactly like the machine's
on-chip commit.  ``reset()`` clears that state.
"""

from __future__ import annotations

import abc
from typing import TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from repro.configs.feather import FeatherConfig
    from repro.core.program import Program


class Backend(abc.ABC):
    """Common interface over Program executors."""

    #: registry key; subclasses override
    name: str = "abstract"

    def __init__(self, cfg: "FeatherConfig"):
        self.cfg = cfg
        self.outputs: dict[str, np.ndarray] = {}

    @abc.abstractmethod
    def run_program(self, program: "Program",
                    tensors: dict[str, np.ndarray] | None = None
                    ) -> dict[str, np.ndarray]:
        """Execute one lowered Program; returns all named outputs so far.

        Chained layer sequences (``program.chain``) are executed with one
        ``run_program`` call per layer on the same backend instance,
        passing each layer's own tensors (the default lowering names every
        layer's weight Load 'W', so a single shared dict would silently
        reuse layer 0's weights)."""

    def reset(self) -> None:
        self.outputs = {}

    def __repr__(self) -> str:  # pragma: no cover
        return (f"{type(self).__name__}(ah={self.cfg.ah}, "
                f"aw={self.cfg.aw})")
