"""InterpreterBackend: the FEATHER+ functional machine behind the Backend
interface.

This is the orchestration loop that used to live in
``core/machine.FeatherMachine.run``: walk a Program's TraceOp stream,
``step`` each instruction through the machine, ``flush`` the batched
Execute invocations at the end.  The machine itself (``core/machine.py``)
now only implements instruction semantics and architecture state.

The backend keeps one machine across ``run_program`` calls, so chained
Programs (paper §IV-G on-chip commit + input elision) execute exactly as
before: layer i's committing Write places data in the operand buffer and
layer i+1's elided input reads it from there.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Iterable

import numpy as np

from repro.backends.base import Backend
from repro.core.machine import FeatherMachine
from repro.obs.trace import trace

if TYPE_CHECKING:  # pragma: no cover
    from repro.configs.feather import FeatherConfig
    from repro.core.program import Program, TraceOp


class InterpreterBackend(Backend):
    """Tile-by-tile interpretation of the MINISA instruction stream."""

    name = "interpreter"

    def __init__(self, cfg: "FeatherConfig", max_depth: int | None = None):
        super().__init__(cfg)
        self.max_depth = max_depth
        self.machine = FeatherMachine(cfg, max_depth=max_depth)

    def _make_shard_backend(self) -> "InterpreterBackend":
        # one functional machine per logical array
        return InterpreterBackend(self.cfg, max_depth=self.max_depth)

    def run_trace(self, ops: Iterable["TraceOp"],
                  tensors: dict[str, np.ndarray] | None = None
                  ) -> dict[str, np.ndarray]:
        """Drive the machine over a flat TraceOp stream."""
        m = self.machine
        with trace.span("interpret.trace"):
            for op in ops:
                m.step(op, tensors)
            m.flush()
        self.outputs = m.outputs
        return m.outputs

    def run_program(self, program: "Program",
                    tensors: dict[str, np.ndarray] | None = None
                    ) -> dict[str, np.ndarray]:
        from repro.core.program import ShardedProgram
        if isinstance(program, ShardedProgram):
            return self.run_sharded(program, tensors)
        return self.run_trace(program.trace_ops(), tensors)

    def reset(self) -> None:
        super().reset()
        self.machine.reset()
