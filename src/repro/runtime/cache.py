"""ProgramCache: one memoisation for the whole compile pipeline.

The expensive path from a GEMM shape to something executable is

    mapper.search (candidate enumeration + shortlist lowering + layout)
      -> program.lower (the winning Program, possibly re-lowered for
         activation / chaining variants)
        -> backend compile (CompiledProgram launch geometry for Pallas)

Before this module every consumer memoised its own slice of that pipeline
(the planner's per-``plan_model`` ``plans`` dict, ``benchmarks.common``'s
``lru_cache`` sweep, the PallasBackend's per-instance ``id()`` cache).  The
:class:`ProgramCache` replaces those with one three-tier cache:

  plans      (m, k, n, FeatherConfig, search kwargs)      -> mapper.Plan
  lowered    (shape, MappingChoice, cfg, lowering kwargs) -> Program
  compiled   (structural program key, max_block)          -> CompiledProgram
  sharded    (structural program key, mesh shape, axis)   -> ShardedProgram
  fused      (per-layer compiled keys, segment geometry)  -> CompiledSegment
  frontier   (structural segment key)                     -> SegmentFrontier
  tuned      (structural segment key + tuning state)      -> TunedGeometry

``plan`` also accepts a ``core.conv.Conv2D`` (anything with ``to_gemm``):
the im2col GEMM shape is the search problem, so convs share the same
memoisation as the GEMM stream.

Keys are *structural*: two equal-by-value ``Gemm``/``FeatherConfig``
instances hit the same entry regardless of object identity, and the
compiled tier keys on what ``compile_program`` actually reads (shape,
choice, cfg, activation, operand tensor names, commit flag) so a rebuilt
chain of fresh Program objects still reuses its artifacts.  Hit/miss/byte
stats are tracked per tier, and the plan tier optionally persists to disk
(``save``/``load``) so a warmed cache survives process restarts.

``core/planner.plan_model``, ``benchmarks/common.sweep_plans``, the
runtime's :class:`~repro.runtime.executable.ModelExecutable` and the
``PallasBackend`` (via its ``compile_cache`` hook) all share the process
default returned by :func:`default_cache`.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
import tempfile
from typing import TYPE_CHECKING, Any, Callable

from repro.core import mapper as mapperlib
from repro.core import program as programlib
from repro.obs import metrics as obs_metrics
from repro.obs.trace import trace

if TYPE_CHECKING:  # pragma: no cover
    from repro.backends.pallas_backend import CompiledProgram
    from repro.configs.feather import FeatherConfig
    from repro.core.mapper import Gemm, Plan, SegmentFrontier
    from repro.core.program import Program

#: Disk-payload version: bumped whenever the pickled layout changes.
#: Version 2 added the per-tier ``schema`` dict and the persisted tuned
#: tier; version 3 moved every entry under ``tiers`` as an individually
#: pickled ``(blob, sha256)`` pair, so load verifies each entry's
#: content checksum and a corrupt entry quarantines (counting a miss)
#: instead of poisoning -- or crashing -- the next process.
_PERSIST_VERSION = 3

#: Per-tier entry schemas inside the payload; a tier whose schema
#: doesn't match is rejected wholesale (same guard, finer grain: a
#: future plan-layout change won't discard still-valid tuned winners).
_TIER_SCHEMAS = {"plans": 2, "tuned": 2}


def _entry_digest(blob: bytes) -> str:
    """Content checksum persisted next to each pickled entry."""
    return hashlib.sha256(blob).hexdigest()


@dataclasses.dataclass
class CacheStats:
    """Per-tier hit/miss accounting (misses == real pipeline work done)."""
    plan_hits: int = 0
    plan_misses: int = 0          # == mapper searches performed
    lowered_hits: int = 0
    lowered_misses: int = 0       # == program.lower calls performed
    compile_hits: int = 0
    compile_misses: int = 0       # == backend compile_program calls
    sharded_hits: int = 0
    sharded_misses: int = 0       # == shard_program partitionings
    fused_hits: int = 0
    fused_misses: int = 0         # == fused-segment compiles
    frontier_hits: int = 0
    frontier_misses: int = 0      # == joint segment searches performed
    tuned_hits: int = 0
    tuned_misses: int = 0         # == tuned-geometry lookups that missed
    disk_rejected: int = 0        # stale persisted payloads refused
    disk_corrupt: int = 0         # checksum-failed entries quarantined
    evictions: int = 0
    disk_evictions: int = 0       # plans trimmed from the persisted tier
    disk_bytes: int = 0           # size of the persisted file, last save
    loaded_from_disk: int = 0

    @property
    def searches(self) -> int:
        return self.plan_misses

    @property
    def compiles(self) -> int:
        return self.compile_misses

    @property
    def hits(self) -> int:
        return (self.plan_hits + self.lowered_hits + self.compile_hits
                + self.sharded_hits + self.fused_hits
                + self.frontier_hits + self.tuned_hits)

    @property
    def misses(self) -> int:
        return (self.plan_misses + self.lowered_misses
                + self.compile_misses + self.sharded_misses
                + self.fused_misses + self.frontier_misses)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def snapshot(self) -> "CacheStats":
        return dataclasses.replace(self)

    def delta(self, since: "CacheStats") -> dict[str, int]:
        return {f.name: getattr(self, f.name) - getattr(since, f.name)
                for f in dataclasses.fields(self)}

    def summary(self) -> dict:
        return {
            "hits": self.hits, "misses": self.misses,
            "hit_rate": self.hit_rate,
            "searches": self.searches, "lowerings": self.lowered_misses,
            "compiles": self.compiles, "shardings": self.sharded_misses,
            "fused_compiles": self.fused_misses,
            "fused_hits": self.fused_hits,
            "frontier_searches": self.frontier_misses,
            "frontier_hits": self.frontier_hits,
            "tuned_hits": self.tuned_hits,
            "tuned_misses": self.tuned_misses,
            "disk_rejected": self.disk_rejected,
            "disk_corrupt": self.disk_corrupt,
            "evictions": self.evictions,
            "disk_evictions": self.disk_evictions,
            "disk_bytes": self.disk_bytes,
            "loaded_from_disk": self.loaded_from_disk,
        }


def _act_token(activation: Callable | None, act_name: str) -> Any:
    """Hashable identity of an activation binding.

    Registry activations (``runtime.executable.ACTIVATIONS``) are
    module-level callables, so ``id`` is stable for the process lifetime;
    keying on the id (not just the name) keeps two same-named programs
    bound to *different* callables from colliding."""
    if activation is None:
        return None
    return (act_name, id(activation))


def compiled_key(program: "Program", max_block: int) -> tuple:
    """Structural key covering everything ``compile_program`` reads."""
    from repro.backends.pallas_backend import _load_names
    g = program.gemm
    input_name, weight_name = _load_names(program)
    commit = any(op.meta.get("commit_to") is not None
                 for tile in program.tiles for op in tile.drains)
    return (g.m, g.k, g.n, program.choice, program.cfg, program.out_name,
            _act_token(program.activation, program.act_name),
            input_name, weight_name, commit, program.input_elided,
            max_block)


def fused_key(segment, max_block: int) -> tuple:
    """Structural key of a fused segment: the per-layer compiled keys
    plus the full streamed launch geometry -- a rebuilt executable's
    fresh FusedSegment objects hit the same artifact, while a changed
    K-tile schedule, adapt layout, buffer depth or VMEM budget can never
    serve a stale compiled kernel."""
    return (tuple(compiled_key(p, max_block) for p in segment.programs),
            segment.bm, segment.layer_bks, segment.acts,
            tuple(segment.adapts), segment.buffer_depth,
            segment.vmem_budget, segment.operand_dtype, max_block)


def segment_key(programs, *, adapts=None,
                vmem_budget: int | None = None,
                operand_dtype: str = "float32",
                tuning: tuple = ()) -> tuple:
    """Structural key of a chained segment *before* any launch geometry
    exists: per-layer (shape, MappingChoice, activation name), the
    config, the adapt boundaries and the streamed budget -- what the
    joint search (frontier tier) and the measured winner (tuned tier)
    are both functions of.

    ``tuning`` carries the measurement state a tuned winner is only
    valid for (backend kind, interpret flag, max_block): an autotune
    result measured under Pallas interpret mode never serves a Mosaic
    process.  Unlike ``compiled_key`` this key holds no ``id()``-based
    activation token (activation *names* suffice -- geometry does not
    depend on the callable), so tuned entries pickle cleanly and stay
    valid across processes.
    """
    if adapts is None:
        adapts = (False,) * len(programs)
    if vmem_budget is None:
        vmem_budget = programlib.FUSED_VMEM_BUDGET
    layers = tuple((p.gemm.m, p.gemm.k, p.gemm.n, p.choice, p.act_name)
                   for p in programs)
    return (layers, programs[0].cfg, tuple(adapts), int(vmem_budget),
            operand_dtype, programlib.FUSED_STREAM_DEPTH, tuple(tuning))


class ProgramCache:
    """Memoises mapper search -> Program lowering -> backend compile.

    ``path`` enables on-disk persistence of the plan tier: an existing
    file is loaded at construction and :meth:`save` writes the current
    plans back (lowered/compiled tiers hold callables and are rebuilt,
    cheaply, from the cached plans).  ``max_plans`` bounds the plan tier
    with insertion-order eviction -- the variant and artifact tiers get
    proportional bounds -- so a long-lived process cannot grow
    unboundedly.
    """

    def __init__(self, path: str | os.PathLike | None = None,
                 max_plans: int = 128):
        self._plans: dict[tuple, "Plan"] = {}
        self._lowered: dict[tuple, "Program"] = {}
        self._compiled: dict[tuple, "CompiledProgram"] = {}
        self._sharded: dict[tuple, Any] = {}
        self._fused: dict[tuple, Any] = {}
        self._frontiers: dict[tuple, Any] = {}
        self._tuned: dict[tuple, Any] = {}
        # struct part of a tuned key -> its full key (latest stored
        # winner wins), so segment builds can consume tuned geometry
        # without knowing which tuning state produced it
        self._tuned_by_struct: dict[tuple, tuple] = {}
        self.stats = CacheStats()
        self.max_plans = max_plans
        # variant/artifact tiers are bounded too (several lowering
        # variants and compiled artifacts may hang off one plan)
        self.max_lowered = 8 * max_plans
        self.max_compiled = 16 * max_plans
        self.max_sharded = 8 * max_plans
        self.max_fused = 8 * max_plans
        self.max_frontiers = 4 * max_plans
        self.max_tuned = 4 * max_plans
        self.path = os.fspath(path) if path is not None else None
        if self.path and os.path.exists(self.path):
            self.load(self.path)

    def _evict_over(self, table: dict, bound: int) -> None:
        while len(table) >= bound:
            table.pop(next(iter(table)))
            self.stats.evictions += 1

    # -- tier 1: mapper search ------------------------------------------------
    @staticmethod
    def plan_key(gemm: "Gemm", cfg: "FeatherConfig",
                 **search_kwargs) -> tuple:
        """Shape + config + search-mode key.  ``name``/``count`` are
        display/aggregation metadata and deliberately excluded: equal
        shapes share one mapping-search problem."""
        return (gemm.m, gemm.k, gemm.n, cfg,
                tuple(sorted(search_kwargs.items())))

    def plan(self, gemm: "Gemm", cfg: "FeatherConfig",
             **search_kwargs) -> "Plan":
        if hasattr(gemm, "to_gemm"):       # Conv2D (or any im2col-able op)
            gemm = gemm.to_gemm()
        key = self.plan_key(gemm, cfg, **search_kwargs)
        hit = self._plans.get(key)
        if hit is not None:
            self.stats.plan_hits += 1
            # LRU touch
            self._plans[key] = self._plans.pop(key)
            return hit
        self.stats.plan_misses += 1
        with trace.span("cache.search", m=gemm.m, k=gemm.k, n=gemm.n):
            plan = mapperlib.search(gemm, cfg, **search_kwargs)
        self._evict_over(self._plans, self.max_plans)
        self._plans[key] = plan
        return plan

    # -- tier 2: lowering variants (activation / chaining rewires) ------------
    def lower(self, gemm, choice, cfg: "FeatherConfig", *,
              activation: Callable | None = None, act_name: str = "none",
              out_name: str = "O", commit_to: str | None = None,
              commit_layout=None, elide_input: bool = False) -> "Program":
        """Memoising drop-in for ``program.lower`` (``chain``'s
        ``lower_fn``): a rebuilt executable reuses Program objects, which
        in turn keeps the compiled tier and the backends' ``id`` caches
        warm."""
        key = (gemm.m, gemm.k, gemm.n, choice, cfg,
               _act_token(activation, act_name), act_name, out_name,
               commit_to, commit_layout, elide_input)
        hit = self._lowered.get(key)
        if hit is not None:
            self.stats.lowered_hits += 1
            self._lowered[key] = self._lowered.pop(key)   # LRU touch
            return hit
        self.stats.lowered_misses += 1
        with trace.span("cache.lower", m=gemm.m, k=gemm.k, n=gemm.n,
                        out=out_name):
            prog = programlib.lower(gemm, choice, cfg,
                                    activation=activation,
                                    act_name=act_name, out_name=out_name,
                                    commit_to=commit_to,
                                    commit_layout=commit_layout,
                                    elide_input=elide_input)
        self._evict_over(self._lowered, self.max_lowered)
        self._lowered[key] = prog
        return prog

    # -- tier 4: mesh partitionings (ShardedProgram per mesh shape) -----------
    def sharded(self, program: "Program", mesh, axis: str | None = None):
        """Memoising drop-in for ``program.shard_program``: the mesh
        shape joins the structural key, so the same Program served on
        2- and 4-array meshes holds two entries, and every shard's
        sub-Program lowering flows through :meth:`lower` (shared with
        the unsharded variants)."""
        g = program.gemm
        key = (g.m, g.k, g.n, program.choice, program.cfg,
               program.out_name,
               _act_token(program.activation, program.act_name),
               program.input_elided, mesh.shape, mesh.axis_name, axis)
        hit = self._sharded.get(key)
        if hit is not None:
            self.stats.sharded_hits += 1
            self._sharded[key] = self._sharded.pop(key)   # LRU touch
            return hit
        self.stats.sharded_misses += 1
        with trace.span("cache.shard", mesh=mesh.shape, axis=axis):
            sharded = programlib.shard_program(program, mesh, axis=axis,
                                               lower_fn=self.lower)
        self._evict_over(self._sharded, self.max_sharded)
        self._sharded[key] = sharded
        return sharded

    # -- tier 3: backend compile artifacts (PallasBackend hook) ---------------
    def lookup_compiled(self, program: "Program",
                        max_block: int) -> "CompiledProgram | None":
        key = compiled_key(program, max_block)
        comp = self._compiled.get(key)
        if comp is not None:
            self.stats.compile_hits += 1
            self._compiled[key] = self._compiled.pop(key)   # LRU touch
        return comp

    def store_compiled(self, program: "Program", max_block: int,
                       comp: "CompiledProgram") -> None:
        self.stats.compile_misses += 1
        self._evict_over(self._compiled, self.max_compiled)
        self._compiled[compiled_key(program, max_block)] = comp

    # -- tier 5: fused-segment artifacts (one compile per chained segment) ----
    def lookup_fused(self, segment, max_block: int):
        key = fused_key(segment, max_block)
        comp = self._fused.get(key)
        if comp is not None:
            self.stats.fused_hits += 1
            self._fused[key] = self._fused.pop(key)   # LRU touch
        return comp

    def store_fused(self, segment, max_block: int, comp) -> None:
        self.stats.fused_misses += 1
        self._evict_over(self._fused, self.max_fused)
        self._fused[fused_key(segment, max_block)] = comp

    # -- tier 6: joint-search frontiers (one per segment structure) -----------
    def frontier(self, programs, *, adapts=None,
                 vmem_budget: int | None = None,
                 operand_dtype: str = "float32"):
        """Memoising drop-in for ``mapper.search_segment``: the Pareto
        frontier of joint (bm, per-layer bk) geometries for a chained
        segment, keyed structurally so rebuilt executables and repeat
        autotune calls never re-run the joint search.  Returns None for
        fusion-illegal segments (not cached -- the legality check is
        cheap and the result can change with ``adapts``)."""
        key = segment_key(programs, adapts=adapts,
                          vmem_budget=vmem_budget,
                          operand_dtype=operand_dtype)
        hit = self._frontiers.get(key)
        if hit is not None:
            self.stats.frontier_hits += 1
            self._frontiers[key] = self._frontiers.pop(key)   # LRU touch
            return hit
        self.stats.frontier_misses += 1
        with trace.span("cache.frontier", n_layers=len(programs)):
            front = mapperlib.search_segment(
                list(programs), adapts=adapts,
                vmem_budget=(vmem_budget if vmem_budget is not None
                             else programlib.FUSED_VMEM_BUDGET),
                operand_dtype=operand_dtype)
        if front is not None:
            self._evict_over(self._frontiers, self.max_frontiers)
            self._frontiers[key] = front
        return front

    # -- tier 7: measured autotune winners (persisted across processes) -------
    def lookup_tuned(self, key: tuple):
        """Exact-match lookup: ``key`` comes from :func:`segment_key`
        *with* the tuning state the caller measures under."""
        tg = self._tuned.get(key)
        if tg is not None:
            self.stats.tuned_hits += 1
            self._tuned[key] = self._tuned.pop(key)   # LRU touch
        else:
            self.stats.tuned_misses += 1
        return tg

    def store_tuned(self, key: tuple, tuned) -> None:
        self._evict_over(self._tuned, self.max_tuned)
        self._tuned[key] = tuned
        self._tuned_by_struct[key[:-1]] = key

    def tuned_geometry(self, programs, *, adapts=None,
                       vmem_budget: int | None = None,
                       operand_dtype: str = "float32",
                       tuning: tuple | None = None):
        """The measured winner for a segment structure, or None.

        With ``tuning`` given the lookup is exact; without, the most
        recently stored winner for the structure is returned (segment
        *builds* consume tuned geometry without knowing which backend
        state tuned it -- the geometry is valid under any, only the
        measured wall clock was state-specific)."""
        if tuning is not None:
            return self.lookup_tuned(segment_key(
                programs, adapts=adapts, vmem_budget=vmem_budget,
                operand_dtype=operand_dtype, tuning=tuning))
        struct = segment_key(programs, adapts=adapts,
                             vmem_budget=vmem_budget,
                             operand_dtype=operand_dtype)[:-1]
        full = self._tuned_by_struct.get(struct)
        if full is None:
            self.stats.tuned_misses += 1
            return None
        return self.lookup_tuned(full)

    # -- stats / persistence --------------------------------------------------
    def __len__(self) -> int:
        return (len(self._plans) + len(self._lowered)
                + len(self._compiled) + len(self._sharded)
                + len(self._fused) + len(self._frontiers)
                + len(self._tuned))

    def size_bytes(self) -> int:
        """Pickled payload size of the plan tier (computed on demand --
        the byte figure for the ``bytes`` stat, not a live counter)."""
        total = 0
        for plan in self._plans.values():
            try:
                total += len(pickle.dumps(plan,
                                          protocol=pickle.HIGHEST_PROTOCOL))
            except Exception:  # pragma: no cover - unpicklable plan
                total += int(plan.program.minisa_bytes())
        return total

    def publish_metrics(self, registry=None) -> None:
        """Sync the per-tier hit/miss/eviction stats and the disk-tier
        figures (``disk_bytes``, ``disk_evictions``) into the metrics
        registry (default: the shared ``obs.metrics`` one) as labelled
        gauges -- the unified scrape surface over every ad-hoc stats
        dict."""
        reg = registry if registry is not None else obs_metrics.REGISTRY
        s = self.stats
        tiers = {"plan": (s.plan_hits, s.plan_misses, self._plans),
                 "lowered": (s.lowered_hits, s.lowered_misses,
                             self._lowered),
                 "compile": (s.compile_hits, s.compile_misses,
                             self._compiled),
                 "sharded": (s.sharded_hits, s.sharded_misses,
                             self._sharded),
                 "fused": (s.fused_hits, s.fused_misses, self._fused),
                 "frontier": (s.frontier_hits, s.frontier_misses,
                              self._frontiers),
                 "tuned": (s.tuned_hits, s.tuned_misses, self._tuned)}
        for tier, (hits, misses, table) in tiers.items():
            reg.gauge("cache_hits",
                      "ProgramCache hits per tier").set(hits, tier=tier)
            reg.gauge("cache_misses",
                      "ProgramCache misses (real pipeline work) per "
                      "tier").set(misses, tier=tier)
            reg.gauge("cache_entries",
                      "live ProgramCache entries per tier").set(
                          len(table), tier=tier)
        reg.gauge("cache_hit_rate").set(s.hit_rate)
        reg.gauge("cache_evictions").set(s.evictions)
        reg.gauge("cache_disk_evictions",
                  "plans trimmed from the persisted tier").set(
                      s.disk_evictions)
        reg.gauge("cache_disk_bytes",
                  "size of the persisted plan file, last save").set(
                      s.disk_bytes)
        reg.gauge("cache_disk_corrupt",
                  "checksum-failed disk entries quarantined").set(
                      s.disk_corrupt)
        reg.gauge("cache_loaded_from_disk").set(s.loaded_from_disk)

    def summary(self) -> dict:
        return {
            "entries": {"plans": len(self._plans),
                        "lowered": len(self._lowered),
                        "compiled": len(self._compiled),
                        "sharded": len(self._sharded),
                        "fused": len(self._fused),
                        "frontiers": len(self._frontiers),
                        "tuned": len(self._tuned)},
            "bytes": self.size_bytes(),
            **self.stats.summary(),
        }

    def save(self, path: str | os.PathLike | None = None) -> str:
        """Persist the plan tier and the measured tuned winners (both
        hold only value objects, so they pickle cleanly; variant/compiled
        tiers hold callables/jitted artifacts and are re-derived).

        Each entry is pickled on its own and stored as a
        ``(blob, sha256)`` pair under ``tiers`` so :meth:`load` can
        verify entries independently -- one flipped byte quarantines one
        entry, not the whole cache.  The write is atomic and durable:
        a unique temp file in the destination directory (concurrent
        saves never collide), fsync'ed, then ``os.replace``'d into
        place, so a crash mid-save can never leave a torn file at
        ``path``.

        The documented ``max_plans`` LRU bound holds on disk too: only
        the most-recently-used ``max_plans`` entries persist (dict order
        IS recency order -- hits re-insert), trimmed entries count as
        ``disk_evictions``, and the written file's size is stat'ed into
        ``disk_bytes``."""
        path = os.fspath(path or self.path)
        if not path:
            raise ValueError("no persistence path configured")
        items = list(self._plans.items())
        trimmed = max(0, len(items) - self.max_plans)
        self.stats.disk_evictions += trimmed
        tuned = list(self._tuned.items())[-self.max_tuned:]
        tiers = {}
        for tier, entries in (("plans", items[trimmed:]),
                              ("tuned", tuned)):
            packed = []
            for key, value in entries:
                blob = pickle.dumps((key, value),
                                    protocol=pickle.HIGHEST_PROTOCOL)
                packed.append((blob, _entry_digest(blob)))
            tiers[tier] = packed
        payload = {"version": _PERSIST_VERSION,
                   "schema": dict(_TIER_SCHEMAS),
                   "tiers": tiers}
        dirname = os.path.dirname(path) or "."
        fd, tmp = tempfile.mkstemp(dir=dirname,
                                   prefix=os.path.basename(path) + ".",
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump(payload, f, protocol=pickle.HIGHEST_PROTOCOL)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self.stats.disk_bytes = os.path.getsize(path)
        return path

    # -- corruption quarantine ------------------------------------------------
    def quarantine_dir(self, path: str) -> str:
        return path + ".quarantine"

    def _quarantine(self, path: str, name: str, data: bytes) -> None:
        """Move corrupt bytes aside (never raising -- quarantine is a
        best-effort forensic aid on the serving path)."""
        self.stats.disk_corrupt += 1
        try:
            qdir = self.quarantine_dir(path)
            os.makedirs(qdir, exist_ok=True)
            with open(os.path.join(qdir, name), "wb") as f:
                f.write(data)
        except OSError:  # pragma: no cover - quarantine dir unwritable
            pass

    def load(self, path: str | os.PathLike) -> int:
        """Merge a persisted payload.

        Two distinct failure modes, deliberately handled differently:

        * **stale layout** -- a well-formed payload whose version or
          per-tier schema doesn't match raises ``ValueError`` (and
          counts ``disk_rejected``): the caller configured an
          incompatible file and should know.
        * **corruption** -- an unreadable/truncated file, or an entry
          whose sha256 doesn't match its blob, never raises: the file
          or entry moves to the ``<path>.quarantine`` sidecar, counts
          ``disk_corrupt``, and the entry is simply a miss (re-derived
          by the next search) -- torn disks must not crash a serve.
        """
        path = os.fspath(path)
        with open(path, "rb") as f:
            raw = f.read()
        try:
            payload = pickle.loads(raw)
            if not isinstance(payload, dict) or "version" not in payload:
                raise pickle.UnpicklingError("malformed cache payload")
        except (EOFError, KeyError, IndexError, ImportError,
                AttributeError, TypeError, pickle.PickleError):
            # truncated/garbled file: quarantine, never crash a serve
            self._quarantine(path, "payload.bin", raw)
            return 0
        if payload.get("version") != _PERSIST_VERSION:
            self.stats.disk_rejected += 1
            raise ValueError(
                f"cache file version {payload.get('version')!r} != "
                f"{_PERSIST_VERSION}")
        schema = payload.get("schema", {})
        for tier, want in _TIER_SCHEMAS.items():
            if schema.get(tier, want) != want:
                self.stats.disk_rejected += 1
                raise ValueError(
                    f"cache tier {tier!r} schema {schema.get(tier)!r} "
                    f"!= {want}")
        loaded = 0
        tiers = payload.get("tiers", {})
        for tier in ("plans", "tuned"):
            for i, entry in enumerate(tiers.get(tier, [])):
                try:
                    blob, digest = entry
                    if _entry_digest(blob) != digest:
                        raise ValueError("checksum mismatch")
                    key, value = pickle.loads(blob)
                except Exception:
                    blob = entry[0] if (isinstance(entry, (tuple, list))
                                        and entry) else b""
                    self._quarantine(path, f"{tier}-{i}.bin", bytes(blob))
                    continue
                if tier == "plans":
                    if key not in self._plans:
                        self._evict_over(self._plans, self.max_plans)
                        loaded += 1
                    self._plans[key] = value
                else:
                    if key not in self._tuned:
                        loaded += 1
                    self.store_tuned(key, value)
        self.stats.loaded_from_disk += loaded
        return loaded


_DEFAULT: ProgramCache | None = None


def default_cache() -> ProgramCache:
    """Process-wide shared cache (planner, benchmarks and runtime all
    memoise through this unless handed an explicit instance)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = ProgramCache()
    return _DEFAULT


def reset_default_cache() -> None:
    global _DEFAULT
    _DEFAULT = None
