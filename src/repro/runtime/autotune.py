"""Measured autotuning of the fused-segment Pareto frontier.

The joint segment search (``mapper.search_segment``, memoised in the
ProgramCache frontier tier) prices geometries analytically; this pass
closes the loop against *measured* hardware the way the configurable-
stack papers do: compile the top-k frontier points through the existing
``PallasBackend`` and score them with the PR 8 ``obs`` telemetry spine
-- the per-launch spans already carry ``block_until_ready`` wall clock
and VMEM high-water, and ``obs.export.span_breakdown`` turns them into
kernel-vs-host fractions -- no parallel timing path.

The measured winner persists in the ProgramCache tuned tier under a key
carrying the tuning state (backend kind, interpret flag, max_block), so
serving processes sharing a persisted cache never re-tune structurally
identical segments: ``autotune_segment`` on a warm cache is one dict
lookup, and ``ModelExecutable`` segment builds consume the winner's
geometry directly.

Usage::

    from repro.runtime import autotune
    report = autotune.autotune_segment(chained_programs, backend,
                                       cache=cache, adapts=adapts)
    seg = fuse_segment(chained_programs, adapts=adapts,
                       bm=report.winner.bm,
                       layer_bks=report.winner.layer_bks)
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import perf
from repro.core import program as programlib
from repro.obs import export as obs_export
from repro.obs.trace import trace
from repro.runtime.cache import ProgramCache, default_cache, segment_key


@dataclasses.dataclass(frozen=True)
class TunedGeometry:
    """A measured frontier winner: the joint geometry plus the evidence.

    Value-only (ints/floats/tuples), so it pickles into the ProgramCache
    tuned tier and survives process restarts."""
    bm: int
    layer_bks: tuple[int, ...]
    measured_s: float            # median fused-launch wall clock
    kernel_frac: float           # launch share of the measured window
    analytic_cycles: float       # the frontier point's modelled cycles
    traffic_bytes: float         # ... and modelled MINISA HBM bytes
    vmem_bytes: int              # streamed VMEM high-water (measured key)
    n_points_measured: int       # frontier points actually compiled+run


@dataclasses.dataclass
class AutotuneReport:
    winner: TunedGeometry
    trials: list[dict]           # one row per measured frontier point
    cached: bool                 # True == served from the tuned tier

    def summary(self) -> dict:
        w = self.winner
        return {"bm": w.bm, "layer_bks": list(w.layer_bks),
                "measured_us": w.measured_s * 1e6,
                "kernel_frac": w.kernel_frac,
                "vmem_bytes": w.vmem_bytes,
                "n_points_measured": w.n_points_measured,
                "cached": self.cached}


def tuning_state(backend) -> tuple:
    """The measurement state a tuned winner is valid for."""
    return (getattr(backend, "name", "pallas"),
            bool(getattr(backend, "interpret", False)),
            int(getattr(backend, "max_block", 2048)))


def _segment_tensors(programs, seed: int = 0) -> dict:
    """Deterministic operand set for measurement runs."""
    rng = np.random.default_rng(seed)
    g0 = programs[0].gemm
    t = {"I": rng.standard_normal((g0.m, g0.k)).astype(np.float32)}
    for i, p in enumerate(programs):
        g = p.gemm
        t[f"W{i}"] = (rng.standard_normal((g.k, g.n)).astype(np.float32)
                      / np.sqrt(g.k))
    return t


def _measure_launches(backend, seg, tensors, iters: int) -> dict | None:
    """Run the fused segment ``iters`` times and read the result off the
    telemetry spine: the backend's ``launch`` spans are timed to
    ``block_until_ready`` (the np.asarray device sync) and carry the
    VMEM high-water; ``span_breakdown`` gives the kernel-vs-host split
    of the measured window."""
    backend.run_segment(seg, tensors)        # compile + jit warm-up
    was_enabled = trace.enabled
    events_before = len(trace.events())
    trace.enable()
    try:
        with trace.span("autotune.trial", bm=seg.bm,
                        layer_bks=tuple(seg.layer_bks)):
            for _ in range(iters):
                backend.run_segment(seg, tensors)
    finally:
        if not was_enabled:
            trace.disable()
    events = trace.events()[events_before:]
    launches = [ev for ev in events if ev.name == "launch"]
    if not launches:
        return None
    durs = sorted(ev.dur_s for ev in launches)
    breakdown = obs_export.span_breakdown("autotune.trial", {"launch"},
                                          events)
    return {"median_s": durs[len(durs) // 2],
            "total_s": sum(durs),
            "n_launches": len(launches),
            "kernel_frac": breakdown["child_frac"],
            "vmem_highwater_bytes": max(
                ev.attrs.get("vmem_highwater_bytes", 0)
                for ev in launches)}


def autotune_segment(programs, backend, *,
                     cache: ProgramCache | None = None,
                     adapts: tuple[bool, ...] | None = None,
                     vmem_budget: int | None = None,
                     operand_dtype: str = "float32",
                     top_k: int = 4, iters: int = 3,
                     seed: int = 0) -> AutotuneReport | None:
    """Measure the top-k frontier points of a chained segment and
    persist the winner.

    Returns None when the segment is not fusion-legal (nothing to
    tune).  On a warm cache (the tuned tier already holds a winner for
    this structure under this backend's tuning state) the report comes
    back ``cached=True`` with zero searches, compiles or launches.
    """
    cache = cache if cache is not None else default_cache()
    programs = list(programs)
    if adapts is None:
        adapts = (False,) * len(programs)
    state = tuning_state(backend)
    key = segment_key(programs, adapts=adapts, vmem_budget=vmem_budget,
                      operand_dtype=operand_dtype, tuning=state)
    hit = cache.lookup_tuned(key)
    if hit is not None:
        return AutotuneReport(winner=hit, trials=[], cached=True)

    front = cache.frontier(programs, adapts=adapts,
                           vmem_budget=vmem_budget,
                           operand_dtype=operand_dtype)
    if front is None or not front.points:
        return None
    budget = (vmem_budget if vmem_budget is not None
              else programlib.FUSED_VMEM_BUDGET)

    # the greedy-then-snap default always joins the measured pool (even
    # when analytic pruning dominated it off the frontier), so the
    # persisted winner can never lose to the untuned geometry under the
    # same measurement conditions -- the CI gate relies on this
    geometries: list[tuple[int, tuple[int, ...], object]] = [
        (p.choice.bm, p.choice.layer_bks, p) for p in front.top(top_k)]
    greedy = programlib.fuse_segment(
        programs, adapts=adapts, vmem_budget=budget,
        operand_dtype=operand_dtype)
    if greedy is not None and all(
            (greedy.bm, greedy.layer_bks) != (bm, bks)
            for bm, bks, _ in geometries):
        geometries.append((greedy.bm, greedy.layer_bks, None))

    tensors = _segment_tensors(programs, seed=seed)
    trials: list[dict] = []
    best = None
    for bm, bks, point in geometries:
        seg = programlib.fuse_segment(
            programs, adapts=adapts, operand_dtype=operand_dtype,
            vmem_budget=budget, bm=bm, layer_bks=bks)
        if seg is None:       # budget race: frontier said fit, refused
            continue
        measured = _measure_launches(backend, seg, tensors, iters)
        if measured is None:
            continue
        if point is not None:
            cycles, traffic = point.cycles, point.traffic_bytes
            vmem = point.vmem_bytes
        else:                 # greedy baseline: price it the same way
            cycles = perf.simulate(seg.tile_costs("minisa"),
                                   seg.cfg).cycles
            traffic = seg.kernel_hbm_bytes()
            vmem = seg.vmem_highwater_bytes()
        trial = {"bm": seg.bm, "layer_bks": list(seg.layer_bks),
                 "analytic_cycles": cycles, "traffic_bytes": traffic,
                 "vmem_bytes": vmem, **measured}
        trials.append(trial)
        if best is None or measured["median_s"] < best[0]["median_s"]:
            best = (measured, trial, seg)
    if best is None:
        return None
    measured, trial, seg = best
    winner = TunedGeometry(
        bm=seg.bm, layer_bks=tuple(seg.layer_bks),
        measured_s=measured["median_s"],
        kernel_frac=measured["kernel_frac"],
        analytic_cycles=trial["analytic_cycles"],
        traffic_bytes=trial["traffic_bytes"],
        vmem_bytes=trial["vmem_bytes"],
        n_points_measured=len(trials))
    cache.store_tuned(key, winner)
    if cache.path:
        cache.save()
    return AutotuneReport(winner=winner, trials=trials, cached=False)
