"""Continuous-batching request scheduler over compiled model executables.

The paper's end-to-end speedups (§V) come from amortising instruction
fetch across layers *and requests*; this scheduler is that serving loop.
One prefill and one decode :class:`~repro.runtime.executable.ModelExecutable`
-- compiled once through the shared ProgramCache -- serve every request:

  * **weight residency**: the static weight tensors are generated once
    per scheduler and shared by all requests (only *dynamic* operands --
    the attention K^T/V, FEATHER+'s runtime-layout case -- are
    per-request state);
  * **KV residency**: each request's dynamic tensors live in a paged
    :class:`KVPool` arena for the request's lifetime; every step's
    output is committed back into them (a deterministic bounded update
    standing in for the model's KV append), and the next step's fresh
    inputs derive from the previous output, so the decode loop is a real
    numeric recurrence.  Pages are evicted back to the pool when a
    request retires; admission stalls (never deadlocks) when the pool is
    exhausted;
  * **one backend instance** executes everything, so the Pallas compile
    cache and the machine's jitted invocation kernels stay warm across
    requests -- a second request performs zero mapper searches and zero
    backend compiles (the cache stats in the report prove it).

Scheduling is split prefill/decode continuous batching: every tick first
advances the WHOLE decode batch -- with ``batch_decode`` the batch
stacks along M and moves through the decode stream's M-polymorphic
segments in ONE backend launch per segment
(``ModelExecutable.run_batch``), flash-decode included -- then retires
finished requests mid-batch, and only then spends the per-tick
``token_budget`` on prefill work: continuing admitted requests' prompt
chunks and admitting new requests into free slots.  Long prompts are
chunked (``prompt_tokens`` per request), so one long prompt can never
stall the decode batch.

Per-request accounting reuses the exact tile streams ``perf.simulate``
consumes (via ``ModelExecutable.perf_stats``): MINISA vs micro-instruction
traffic bytes, modelled cycles and instruction-fetch stall fractions,
plus wall-clock latency and time-to-first-token.  With mesh-sharded
executables the report additionally carries per-array traffic/cycles and
the load-imbalance factor, and seeded runs are bit-reproducible across
backends *and batch compositions* (quantised recurrence feedback; see
``_stabilize``).
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import time

import numpy as np

from repro.core import perf
from repro.obs import metrics as obs_metrics
from repro.obs.trace import trace
from repro.runtime.executable import ModelExecutable

#: The serving recurrence feeds backend outputs back into request state
#: (KV commits, the next step's input carrier).  Quantising that feedback
#: to this many decimals makes a seeded run *bit*-reproducible across
#: backends -- and across batch compositions: fp32 kernel-order
#: differences between the interpreter, the Pallas kernels and the
#: M-stacked batched launches (~1e-6 at serving extents) vanish under
#: the quantum, so every path walks the identical state trajectory.
_STATE_DECIMALS = 3


def _stabilize(x: np.ndarray) -> np.ndarray:
    return np.round(np.asarray(x, np.float32), _STATE_DECIMALS)


def _pct(vals: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(vals, np.float64), q)) \
        if vals else 0.0


@dataclasses.dataclass
class Request:
    rid: int
    decode_steps: int
    seed: int = 0
    #: prompt length in tokens; prompts longer than one prefill pass are
    #: chunked (None == exactly one pass, the pre-chunking behaviour)
    prompt_tokens: int | None = None
    t_submit: float = 0.0


@dataclasses.dataclass
class RequestReport:
    rid: int
    prefill_tokens: int
    decode_tokens: int
    wall_s: float
    minisa_bytes: float
    micro_bytes: float
    cycles_minisa: float
    cycles_micro: float
    stall_minisa: float
    stall_micro: float
    #: sha1 over the request's final quantised KV state + carrier --
    #: equal across backends / re-runs / batch compositions for equal
    #: seeds (determinism regression surface)
    state_checksum: str = ""
    #: submit -> first decode token out (prefill queueing + chunking)
    ttft_s: float = 0.0

    @property
    def tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens

    @property
    def instr_reduction(self) -> float:
        return self.micro_bytes / max(self.minisa_bytes, 1e-9)

    def summary(self) -> dict:
        return {
            "rid": self.rid, "tokens": self.tokens,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "wall_s": self.wall_s,
            "ttft_s": self.ttft_s,
            "minisa_bytes": self.minisa_bytes,
            "micro_bytes": self.micro_bytes,
            "instr_reduction": self.instr_reduction,
            "stall_minisa": self.stall_minisa,
            "stall_micro": self.stall_micro,
            "state_checksum": self.state_checksum,
        }


@dataclasses.dataclass
class SchedulerReport:
    backend: str
    requests: list[RequestReport]
    wall_s: float
    ticks: int
    max_concurrent: int
    cache: dict
    # multi-array serving (all zeros / ones on a single array)
    n_arrays: int = 1
    per_array_minisa_bytes: list = dataclasses.field(default_factory=list)
    per_array_cycles: list = dataclasses.field(default_factory=list)
    # batched decode fast path (fused-segment kernels)
    decode_fused: bool = False
    decode_fused_segments: int = 0    # fused launches per decode step
    decode_segments: int = 0          # total decode segments per step
    decode_hbm_elided_bytes: float = 0.0   # modelled per decode step
    # cross-request batched decode (M-polymorphic segments)
    batch_decode: bool = False
    decode_wall_s: float = 0.0        # wall time inside decode ticks
    prefill_wall_s: float = 0.0       # wall time inside prefill/admission
    decode_steps_total: int = 0       # request-steps decoded
    decode_ticks: int = 0             # ticks that ran a decode phase
    decode_launches: int = 0          # backend kernel launches in decode
    kv: dict = dataclasses.field(default_factory=dict)   # KVPool stats

    @property
    def total_tokens(self) -> int:
        return sum(r.tokens for r in self.requests)

    @property
    def tokens_per_sec(self) -> float:
        return self.total_tokens / max(self.wall_s, 1e-9)

    @property
    def decode_tokens_per_sec(self) -> float:
        """Decode-phase throughput, separated from prefill/TTFT."""
        toks = sum(r.decode_tokens for r in self.requests)
        return toks / max(self.decode_wall_s, 1e-9)

    @property
    def launches_per_decode_tick(self) -> float:
        return self.decode_launches / max(self.decode_ticks, 1)

    @property
    def load_imbalance(self) -> float:
        return perf.load_imbalance(self.per_array_cycles)

    def summary(self) -> dict:
        walls = [r.wall_s for r in self.requests]
        ttfts = [r.ttft_s for r in self.requests]
        return {
            "backend": self.backend,
            "n_requests": len(self.requests),
            "total_tokens": self.total_tokens,
            "tokens_per_sec": self.tokens_per_sec,
            "decode_tokens_per_sec": self.decode_tokens_per_sec,
            "wall_s": self.wall_s,
            "decode_wall_s": self.decode_wall_s,
            "prefill_wall_s": self.prefill_wall_s,
            "ticks": self.ticks,
            "max_concurrent": self.max_concurrent,
            "batch_decode": self.batch_decode,
            "decode_ticks": self.decode_ticks,
            "decode_steps_total": self.decode_steps_total,
            "decode_launches": self.decode_launches,
            "launches_per_decode_tick": self.launches_per_decode_tick,
            "latency_p50_s": _pct(walls, 50),
            "latency_p95_s": _pct(walls, 95),
            "latency_p99_s": _pct(walls, 99),
            "ttft_p50_s": _pct(ttfts, 50),
            "ttft_p95_s": _pct(ttfts, 95),
            "ttft_p99_s": _pct(ttfts, 99),
            "n_arrays": self.n_arrays,
            "per_array_minisa_bytes": list(self.per_array_minisa_bytes),
            "per_array_cycles": list(self.per_array_cycles),
            "load_imbalance": self.load_imbalance,
            "decode_fused": self.decode_fused,
            "decode_fused_segments": self.decode_fused_segments,
            "decode_segments": self.decode_segments,
            "decode_hbm_elided_bytes": self.decode_hbm_elided_bytes,
            "kv": dict(self.kv),
            "cache_hit_rate": self.cache.get("hit_rate", 0.0),
            "cache_searches": self.cache.get("searches", 0),
            "cache_compiles": self.cache.get("compiles", 0),
            "minisa_bytes_per_request": float(np.mean(
                [r.minisa_bytes for r in self.requests])) if self.requests
            else 0.0,
            "micro_bytes_per_request": float(np.mean(
                [r.micro_bytes for r in self.requests])) if self.requests
            else 0.0,
            "stall_minisa": float(np.mean(
                [r.stall_minisa for r in self.requests])) if self.requests
            else 0.0,
            "stall_micro": float(np.mean(
                [r.stall_micro for r in self.requests])) if self.requests
            else 0.0,
        }

    def to_dict(self) -> dict:
        """The full serialisable report: the summary, every per-request
        report, the complete cache stats (disk tier included) and the
        KVPool stats -- the shape the benchmark JSON and the CI
        artifacts carry."""
        return {
            **self.summary(),
            "requests": [r.summary() for r in self.requests],
            "cache": dict(self.cache),
            "kv": dict(self.kv),
        }

    def timeline(self, events=None) -> list[dict]:
        """Join tracer span events to requests: one entry per request,
        carrying its ``("request", rid)`` swimlane (submit instant,
        prefill chunks, per-tick decode spans, first-token / retire
        markers) in time order.  ``events`` defaults to the shared
        tracer's buffer; empty swimlanes (tracing off) yield empty
        span lists."""
        if events is None:
            events = trace.events()
        by_rid: dict[int, list] = {r.rid: [] for r in self.requests}
        for ev in events:
            if ev.track[0] == "request" and ev.track[1] in by_rid:
                by_rid[ev.track[1]].append(ev)
        out = []
        for r in self.requests:
            evs = sorted(by_rid[r.rid], key=lambda e: (e.t0_s, e.seq))
            out.append({
                "rid": r.rid,
                "ttft_s": r.ttft_s,
                "wall_s": r.wall_s,
                "state_checksum": r.state_checksum,
                "spans": [{
                    "name": ev.name, "t0_s": ev.t0_s, "dur_s": ev.dur_s,
                    "instant": ev.instant, **ev.attrs} for ev in evs],
            })
        return out

    def publish_metrics(self, registry=None) -> None:
        """Push the serving totals into the metrics registry (default:
        the shared ``obs.metrics`` one): MINISA vs micro instruction
        bytes and token counters, the scalar summary as gauges, and the
        KVPool + cache stats -- one scrape surface over every ad-hoc
        stats dict."""
        reg = registry if registry is not None else obs_metrics.REGISTRY
        reg.counter("minisa_bytes_total",
                    "MINISA instruction bytes served").inc(
                        sum(r.minisa_bytes for r in self.requests),
                        backend=self.backend)
        reg.counter("micro_bytes_total",
                    "micro-instruction control bytes (baseline)").inc(
                        sum(r.micro_bytes for r in self.requests),
                        backend=self.backend)
        reg.counter("tokens_total", "tokens served").inc(
            self.total_tokens, backend=self.backend)
        reg.counter("requests_total", "requests retired").inc(
            len(self.requests), backend=self.backend)
        summary = self.summary()
        reg.set_many({k: v for k, v in summary.items()
                      if k not in ("kv",)}, prefix="sched_")
        reg.set_many(self.kv, prefix="kv_")


# ---------------------------------------------------------------------------
# Paged per-request KV state
# ---------------------------------------------------------------------------

def _kv_specs(executable: ModelExecutable) -> dict[str, tuple]:
    """name -> (shape, time_axis, time_extent, width) for every dynamic
    tensor.  The time-like axis is the *longer* one -- the same rule the
    commit recurrence has always used."""
    specs = {}
    for name, (shape, kind) in executable.tensor_specs().items():
        if kind != "dynamic":
            continue
        rows, cols = shape
        if cols > rows:
            specs[name] = (shape, 1, cols, rows)
        else:
            specs[name] = (shape, 0, rows, cols)
    return specs


class KVPool:
    """Fixed arena of KV pages shared by all in-flight requests.

    One page holds ``page_size`` time slots of EVERY dynamic tensor (one
    arena per tensor, indexed by the same page table), so a request's
    whole KV state allocates and evicts as one page list.  ``allocate``
    returns None when the pool is exhausted -- the scheduler turns that
    into an admission stall, never an OOM.
    """

    def __init__(self, specs: dict[str, tuple], page_size: int,
                 n_pages: int):
        self.specs = specs
        self.page_size = max(1, page_size)
        self.n_pages = max(1, n_pages)
        self.arenas = {
            name: np.zeros((self.n_pages * self.page_size, width),
                           np.float32)
            for name, (_, _, _, width) in specs.items()}
        self._free = list(range(self.n_pages - 1, -1, -1))
        self.allocated_pages = 0
        self.high_water_pages = 0
        self.evicted_pages = 0
        self.admit_stalls = 0

    @property
    def time_extent(self) -> int:
        """Slots one request needs: the longest dynamic time axis."""
        return max((t for _, _, t, _ in self.specs.values()), default=1)

    @property
    def pages_per_request(self) -> int:
        return -(-self.time_extent // self.page_size)

    def allocate(self) -> list[int] | None:
        need = self.pages_per_request
        if len(self._free) < need:
            return None
        pages = [self._free.pop() for _ in range(need)]
        self.allocated_pages += need
        self.high_water_pages = max(self.high_water_pages,
                                    self.allocated_pages)
        return pages

    def release(self, pages: list[int]) -> None:
        self._free.extend(pages)
        self.allocated_pages -= len(pages)
        self.evicted_pages += len(pages)

    def stats(self) -> dict:
        return {
            "n_pages": self.n_pages,
            "page_size": self.page_size,
            "pages_per_request": self.pages_per_request,
            "allocated_pages": self.allocated_pages,
            "high_water_pages": self.high_water_pages,
            "evicted_pages": self.evicted_pages,
            "admit_stalls": self.admit_stalls,
        }


class PagedKV:
    """One request's KV state, resident in pool pages.

    ``seed``/``commit``/``gather`` reproduce the flat-dict recurrence
    bit-exactly: ``gather`` reconstructs the original-shaped float32
    tensors, so state checksums are independent of the paging layout.
    """

    def __init__(self, pool: KVPool, pages: list[int]):
        self.pool = pool
        self.pages = pages

    def _slot(self, j: int) -> int:
        ps = self.pool.page_size
        return self.pages[j // ps] * ps + j % ps

    def seed(self, dynamics: dict[str, np.ndarray]) -> None:
        for name, (shape, tax, t_ext, _) in self.pool.specs.items():
            arr = np.asarray(dynamics[name], np.float32)
            arena = self.pool.arenas[name]
            for j in range(t_ext):
                arena[self._slot(j), :] = arr[j, :] if tax == 0 \
                    else arr[:, j]

    def commit(self, out: np.ndarray, pos: int) -> None:
        """Deterministic bounded KV append: fold the step output into
        one time slot of each dynamic operand (same fold as the
        pre-paging ``_commit_kv``, same quantisation)."""
        vec = _stabilize(np.tanh(np.asarray(out, np.float32).ravel()))
        if vec.size == 0:
            return
        for name, (_, _, t_ext, width) in self.pool.specs.items():
            arena = self.pool.arenas[name]
            arena[self._slot(pos % t_ext), :] = np.resize(vec, width)

    def gather(self) -> dict[str, np.ndarray]:
        out = {}
        for name, (shape, tax, t_ext, _) in self.pool.specs.items():
            arena = self.pool.arenas[name]
            rows = np.stack([arena[self._slot(j)] for j in range(t_ext)]) \
                if t_ext else np.zeros(shape, np.float32)
            out[name] = np.ascontiguousarray(rows if tax == 0 else rows.T)
        return out

    def release(self) -> None:
        if self.pages:
            self.pool.release(self.pages)
            self.pages = []


@dataclasses.dataclass
class _Active:
    req: Request
    kv: PagedKV
    carry: np.ndarray | None            # previous step's output
    t_start: float
    prefill_chunks: int = 1             # total prompt chunks
    chunks_done: int = 0
    decoded: int = 0
    t_first: float = 0.0                # first decode token wall time

    @property
    def prefill_done(self) -> bool:
        return self.chunks_done >= self.prefill_chunks

    @property
    def dynamics(self) -> dict[str, np.ndarray]:
        """Flat view of the paged KV state (compat / checksums)."""
        return self.kv.gather()


def _commit_kv(dynamics: dict[str, np.ndarray], out: np.ndarray,
               pos: int) -> None:
    """Flat-dict twin of :meth:`PagedKV.commit` (kept for direct use on
    unpaged dynamics dicts)."""
    vec = _stabilize(np.tanh(np.asarray(out, np.float32).ravel()))
    if vec.size == 0:
        return
    for arr in dynamics.values():
        if arr.shape[1] > arr.shape[0]:
            arr[:, pos % arr.shape[1]] = np.resize(vec, arr.shape[0])
        else:
            arr[pos % arr.shape[0], :] = np.resize(vec, arr.shape[1])


def _state_checksum(dynamics: dict[str, np.ndarray],
                    carry: np.ndarray) -> str:
    h = hashlib.sha1()
    for name in sorted(dynamics):
        h.update(name.encode())
        h.update(np.ascontiguousarray(dynamics[name]).tobytes())
    h.update(_stabilize(carry).tobytes())
    return h.hexdigest()


class Scheduler:
    """Split prefill/decode continuous-batching loop over executables.

    Seeding is fully explicit: every request's tensors derive from
    ``(self.seed, request seed)`` only -- never from admission order or
    leftover generator state -- and all recurrence feedback is quantised
    (``_stabilize``), so a run with the same submissions is
    bit-reproducible run-to-run, across backends *and across batch
    compositions* (``RequestReport.state_checksum`` is the regression
    surface).

    ``batch_decode`` (default: on for the Pallas backend on a
    single-array stream) advances the whole active batch through the
    decode stream's M-polymorphic segments with ONE backend launch per
    segment per tick; ``token_budget`` caps prefill tokens per tick so
    prompt work never starves the decode batch, and ``prompt_tokens``
    at submit chunks long prompts across ticks.

    When the executables carry an ``ArrayMesh``, every Program executes
    sharded (per-request; batching auto-disables) and the report adds
    per-array instruction traffic, modelled cycles and the
    load-imbalance factor -- the multi-array serving simulator view.
    """

    def __init__(self, prefill: ModelExecutable, decode: ModelExecutable,
                 *, backend: str = "interpreter", max_concurrent: int = 4,
                 weight_seed: int = 0, seed: int = 0,
                 use_fused: bool | None = None,
                 batch_decode: bool | None = None,
                 token_budget: int | None = None,
                 kv_page_size: int = 4, kv_pages: int | None = None):
        if prefill.cfg != decode.cfg:
            raise ValueError("prefill/decode executables must share one "
                             "FeatherConfig")
        if prefill.cache is not decode.cache:
            raise ValueError("prefill/decode executables must share one "
                             "ProgramCache")
        if prefill.n_arrays != decode.n_arrays:
            raise ValueError("prefill/decode executables must share one "
                             "ArrayMesh shape")
        self.prefill = prefill
        self.decode = decode
        self.backend_name = backend
        self.backend = prefill.make_backend(backend)
        self.max_concurrent = max_concurrent
        self.seed = seed
        # Fused-segment fast path: chained segments execute as ONE kernel
        # launch (prefill and decode).  Defaults on for the compiled
        # backend (where per-launch overhead dominates); the interpreter
        # keeps the per-Program path, whose machine state IS the chain
        # semantics.
        self.use_fused = (use_fused if use_fused is not None
                          else backend == "pallas")
        # Cross-request batched decode: stack every active request along
        # M and advance the batch with one launch per segment per tick.
        # Mesh-sharded streams schedule per-request (on-chip residency is
        # per-array state), so batching auto-disables there.
        if batch_decode is None:
            batch_decode = backend == "pallas" and decode.mesh is None
        elif batch_decode and decode.mesh is not None:
            raise ValueError("batch_decode requires a single-array "
                             "decode stream (got an ArrayMesh)")
        self.batch_decode = batch_decode
        #: prefill tokens one tick may spend (None == unbounded); decode
        #: always runs first, so prompts never stall the decode batch
        self.token_budget = token_budget
        # paged per-request KV state: sized so max_concurrent requests
        # fit by default; smaller pools admission-stall, never OOM
        specs = _kv_specs(decode)
        t_ext = max((t for _, _, t, _ in specs.values()), default=1)
        per_req = -(-t_ext // max(1, kv_page_size))
        if kv_pages is None:
            kv_pages = per_req * max_concurrent
        if kv_pages < per_req:
            raise ValueError(
                f"kv_pages={kv_pages} cannot hold even one request "
                f"({per_req} pages of {kv_page_size} slots needed)")
        self.kv_pool = KVPool(specs, kv_page_size, kv_pages)
        # weight residency: one static weight set serves every request
        self.prefill_weights = prefill.make_tensors(weight_seed,
                                                    kinds=("weight",))
        self.decode_weights = decode.make_tensors(weight_seed,
                                                  kinds=("weight",))
        self._pending: collections.deque[Request] = collections.deque()
        self._next_rid = 0

    def submit(self, decode_steps: int, seed: int | None = None,
               prompt_tokens: int | None = None) -> Request:
        """Queue a request.  The default per-request seed derives from
        the scheduler seed and the rid alone, so a submission sequence
        reproduces exactly regardless of wall-clock or interleaving.
        ``prompt_tokens`` longer than one prefill pass are chunked
        across ticks under the token budget."""
        if seed is None:
            seed = self.seed * 1_000_003 + self._next_rid
        req = Request(rid=self._next_rid, decode_steps=decode_steps,
                      seed=seed, prompt_tokens=prompt_tokens,
                      t_submit=time.perf_counter())
        self._next_rid += 1
        self._pending.append(req)
        trace.instant("submit", ("request", req.rid),
                      decode_steps=decode_steps,
                      prompt_tokens=prompt_tokens)
        return req

    # -- one request's phases -------------------------------------------------
    def _chunks_for(self, req: Request) -> int:
        chunk = max(1, self.prefill.tokens or 1)
        prompt = req.prompt_tokens if req.prompt_tokens else chunk
        return max(1, -(-prompt // chunk))

    def _admit(self, req: Request) -> _Active | None:
        """Allocate KV pages and run the first prompt chunk; None when
        the pool cannot hold another request (admission stall)."""
        pages = self.kv_pool.allocate()
        if pages is None:
            return None
        trace.instant("admit", ("request", req.rid), pages=len(pages))
        # request wall time runs from submission (queueing included)
        a = _Active(req=req, kv=PagedKV(self.kv_pool, pages), carry=None,
                    t_start=req.t_submit or time.perf_counter(),
                    prefill_chunks=self._chunks_for(req))
        self._prefill_chunk(a)
        return a

    def _prefill_chunk(self, a: _Active) -> None:
        """One prompt chunk through the prefill stream (fused fast path
        under ``use_fused``), committed into the request's KV at the
        chunk position.  Chunk 0 seeds the KV from the request seed;
        later chunks carry the stabilised output forward, so chunking is
        itself a deterministic recurrence."""
        c = a.chunks_done
        env = dict(self.prefill_weights)
        if c == 0:
            env.update(self.prefill.make_tensors(
                a.req.seed, kinds=("dynamic", "input")))
        else:
            env.update(self.prefill.make_tensors(
                a.req.seed + 7_919 * c, kinds=("dynamic",)))
            env.update(self.prefill.inputs_from(_stabilize(a.carry)))
        with trace.span("prefill_chunk", ("request", a.req.rid),
                        chunk=c, of=a.prefill_chunks):
            res = self.prefill.run(self.backend, tensors=env,
                                   fused=self.use_fused)
        if c == 0:
            a.kv.seed(self.decode.make_tensors(a.req.seed,
                                               kinds=("dynamic",)))
        a.carry = res.final
        a.kv.commit(res.final, c)   # prompt chunk feeds the KV
        a.chunks_done += 1

    def _decode_env(self, a: _Active) -> dict[str, np.ndarray]:
        env = dict(self.decode_weights)
        env.update(a.kv.gather())
        # quantised carrier: every path feeds identical step inputs
        env.update(self.decode.inputs_from(_stabilize(a.carry)))
        return env

    def _after_decode(self, a: _Active, final: np.ndarray) -> None:
        a.decoded += 1
        a.carry = final
        if a.t_first == 0.0:
            a.t_first = time.perf_counter()
            trace.instant("first_token", ("request", a.req.rid),
                          ttft_s=a.t_first - (a.req.t_submit
                                              or a.t_start))
        # decode commits continue the prompt chunks' positions
        a.kv.commit(final, a.prefill_chunks - 1 + a.decoded)

    def _decode_step(self, a: _Active) -> None:
        with trace.span("decode_step", ("request", a.req.rid),
                        step=a.decoded):
            res = self.decode.run(self.backend,
                                  tensors=self._decode_env(a),
                                  fused=self.use_fused)
        self._after_decode(a, res.final)

    def _decode_batch(self, batch: list[_Active]) -> None:
        """One tick of the whole decode batch: every request's row
        stacked along M, one backend launch per M-polymorphic segment
        (``ModelExecutable.run_batch``).  Under tracing, the collective
        launch window is recorded onto every participating request's
        swimlane (one measurement, several lanes)."""
        t0 = time.perf_counter() if trace.enabled else 0.0
        finals = self.decode.run_batch(
            self.backend, [self._decode_env(a) for a in batch],
            fused=self.use_fused)
        if trace.enabled:
            t1 = time.perf_counter()
            for a in batch:
                trace.record("decode_step", ("request", a.req.rid),
                             t0, t1, step=a.decoded, batched=True,
                             batch=len(batch))
        for a, final in zip(batch, finals):
            self._after_decode(a, final)

    def _report(self, a: _Active, pre: dict, dec: dict) -> RequestReport:
        n = a.decoded
        c = a.chunks_done
        return RequestReport(
            rid=a.req.rid,
            prefill_tokens=c * (self.prefill.tokens or 0),
            decode_tokens=n * (self.decode.tokens or 1),
            wall_s=time.perf_counter() - a.t_start,
            minisa_bytes=c * pre["minisa_bytes"] + n * dec["minisa_bytes"],
            micro_bytes=c * pre["micro_bytes"] + n * dec["micro_bytes"],
            cycles_minisa=(c * pre["cycles_minisa"]
                           + n * dec["cycles_minisa"]),
            cycles_micro=(c * pre["cycles_micro"]
                          + n * dec["cycles_micro"]),
            stall_minisa=(c * pre["stall_cycles_minisa"]
                          + n * dec["stall_cycles_minisa"])
            / max(c * pre["cycles_minisa"] + n * dec["cycles_minisa"],
                  1e-9),
            stall_micro=(c * pre["stall_cycles_micro"]
                         + n * dec["stall_cycles_micro"])
            / max(c * pre["cycles_micro"] + n * dec["cycles_micro"], 1e-9),
            state_checksum=_state_checksum(a.kv.gather(), a.carry),
            ttft_s=(a.t_first - a.req.t_submit
                    if a.t_first and a.req.t_submit else 0.0),
        )

    # -- the serving loop -----------------------------------------------------
    def run(self) -> SchedulerReport:
        """Serve every submitted request to completion.  The loop runs
        under a ``scheduler.run`` span; on return the report's totals
        (plus the cache's per-tier stats) are published into the shared
        metrics registry."""
        with trace.span("scheduler.run", backend=self.backend_name,
                        batch_decode=self.batch_decode,
                        max_concurrent=self.max_concurrent):
            report = self._run_loop()
        report.publish_metrics()
        self.prefill.cache.publish_metrics()
        return report

    def _run_loop(self) -> SchedulerReport:
        t0 = time.perf_counter()
        n_arrays = self.prefill.n_arrays
        per_bytes = [0.0] * n_arrays
        per_cycles = [0.0] * n_arrays
        active: list[_Active] = []
        done: list[RequestReport] = []
        ticks = 0
        decode_wall = prefill_wall = 0.0
        decode_ticks = decode_steps_total = decode_launches = 0
        chunk_tokens = max(1, self.prefill.tokens or 1)
        while self._pending or active:
            ticks += 1
            # 1) decode phase: the whole ready batch advances one step
            ready = [a for a in active
                     if a.prefill_done and a.decoded < a.req.decode_steps]
            if ready:
                td = time.perf_counter()
                l0 = getattr(self.backend, "n_launches", 0)
                with trace.span("decode_tick", tick=ticks,
                                n_ready=len(ready),
                                batched=self.batch_decode) as sp:
                    if self.batch_decode:
                        self._decode_batch(ready)
                    else:
                        for a in ready:
                            self._decode_step(a)
                    if sp:
                        sp.set(launches=getattr(self.backend,
                                                "n_launches", 0) - l0)
                decode_wall += time.perf_counter() - td
                decode_launches += (getattr(self.backend, "n_launches", 0)
                                    - l0)
                decode_ticks += 1
                decode_steps_total += len(ready)
            # 2) retire finished requests mid-batch, evicting their KV
            for a in list(active):
                if a.prefill_done and a.decoded >= a.req.decode_steps:
                    active.remove(a)
                    pre = self.prefill.perf_stats()
                    dec = self.decode.perf_stats()
                    rep = self._report(a, pre, dec)
                    done.append(rep)
                    trace.instant("retire", ("request", a.req.rid),
                                  decoded=a.decoded)
                    if trace.enabled:
                        # the request's whole lifetime as one backdrop
                        # span on its swimlane (arrival -> retire)
                        trace.record("request", ("request", a.req.rid),
                                     a.t_start, time.perf_counter(),
                                     rid=a.req.rid, decoded=a.decoded,
                                     checksum=rep.state_checksum)
                    a.kv.release()   # checksum gathered; evict the pages
                    c, n = a.chunks_done, a.decoded
                    for i in range(n_arrays):
                        per_bytes[i] += (
                            c * pre["per_array_minisa_bytes"][i]
                            + n * dec["per_array_minisa_bytes"][i])
                        per_cycles[i] += (
                            c * pre["per_array_cycles_minisa"][i]
                            + n * dec["per_array_cycles_minisa"][i])
            # 3) prefill phase under the per-tick token budget: continue
            #    admitted prompts first (oldest-first), then admit new
            #    requests into free slots.  When nothing decoded and
            #    nothing progressed, one chunk is forced so the loop
            #    always makes progress.
            tp = time.perf_counter()
            budget = (self.token_budget if self.token_budget is not None
                      else float("inf"))
            progressed = False
            with trace.span("prefill_phase", tick=ticks,
                            n_pending=len(self._pending)):
                for a in active:
                    while (not a.prefill_done
                           and (budget >= chunk_tokens
                                or (not ready and not progressed))):
                        self._prefill_chunk(a)
                        budget -= chunk_tokens
                        progressed = True
                while self._pending and len(active) < self.max_concurrent:
                    if budget < chunk_tokens and (ready or progressed):
                        break
                    a = self._admit(self._pending[0])
                    if a is None:   # KV pool exhausted: wait for retires
                        self.kv_pool.admit_stalls += 1
                        break
                    self._pending.popleft()
                    active.append(a)
                    budget -= chunk_tokens
                    progressed = True
            prefill_wall += time.perf_counter() - tp
        done.sort(key=lambda r: r.rid)
        fusion = self.decode.fusion_stats()
        return SchedulerReport(
            backend=self.backend_name, requests=done,
            wall_s=time.perf_counter() - t0, ticks=ticks,
            max_concurrent=self.max_concurrent,
            cache=self.prefill.cache.stats.summary(),
            n_arrays=n_arrays,
            per_array_minisa_bytes=per_bytes,
            per_array_cycles=per_cycles,
            decode_fused=self.use_fused,
            decode_fused_segments=fusion["n_fused_segments"],
            decode_segments=fusion["n_segments"],
            decode_hbm_elided_bytes=(fusion["hbm_bytes_elided"]
                                     if self.use_fused else 0.0),
            batch_decode=self.batch_decode,
            decode_wall_s=decode_wall,
            prefill_wall_s=prefill_wall,
            decode_steps_total=decode_steps_total,
            decode_ticks=decode_ticks,
            decode_launches=decode_launches,
            kv=self.kv_pool.stats())
