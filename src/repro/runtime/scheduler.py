"""Continuous-batching request scheduler over compiled model executables.

The paper's end-to-end speedups (§V) come from amortising instruction
fetch across layers *and requests*; this scheduler is that serving loop.
One prefill and one decode :class:`~repro.runtime.executable.ModelExecutable`
-- compiled once through the shared ProgramCache -- serve every request:

  * **weight residency**: the static weight tensors are generated once
    per scheduler and shared by all requests (only *dynamic* operands --
    the attention K^T/V, FEATHER+'s runtime-layout case -- are
    per-request state);
  * **KV residency**: each request carries its dynamic tensors across
    decode steps; every step's output is committed back into them (a
    deterministic bounded update standing in for the model's KV append),
    and the next step's fresh inputs derive from the previous output, so
    the decode loop is a real numeric recurrence;
  * **one backend instance** executes everything, so the Pallas compile
    cache and the machine's jitted invocation kernels stay warm across
    requests -- a second request performs zero mapper searches and zero
    backend compiles (the cache stats in the report prove it).

Scheduling is continuous batching: up to ``max_concurrent`` requests are
in flight; each tick admits waiting requests into free slots (paying one
prefill) and advances every active request by one decode step; finished
requests retire immediately, freeing their slot mid-batch.

Per-request accounting reuses the exact tile streams ``perf.simulate``
consumes (via ``ModelExecutable.perf_stats``): MINISA vs micro-instruction
traffic bytes, modelled cycles and instruction-fetch stall fractions.
With mesh-sharded executables the report additionally carries per-array
traffic/cycles and the load-imbalance factor, and seeded runs are
bit-reproducible across backends (quantised recurrence feedback; see
``_stabilize``).
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import time

import numpy as np

from repro.core import perf
from repro.runtime.executable import ModelExecutable

#: The serving recurrence feeds backend outputs back into request state
#: (KV commits, the next step's input carrier).  Quantising that feedback
#: to this many decimals makes a seeded run *bit*-reproducible across
#: backends: fp32 kernel-order differences between the interpreter and
#: the Pallas kernels (~1e-6 at serving extents) vanish under the
#: quantum, so both backends walk the identical state trajectory.
_STATE_DECIMALS = 3


def _stabilize(x: np.ndarray) -> np.ndarray:
    return np.round(np.asarray(x, np.float32), _STATE_DECIMALS)


@dataclasses.dataclass
class Request:
    rid: int
    decode_steps: int
    seed: int = 0


@dataclasses.dataclass
class RequestReport:
    rid: int
    prefill_tokens: int
    decode_tokens: int
    wall_s: float
    minisa_bytes: float
    micro_bytes: float
    cycles_minisa: float
    cycles_micro: float
    stall_minisa: float
    stall_micro: float
    #: sha1 over the request's final quantised KV state + carrier --
    #: equal across backends / re-runs for equal seeds (determinism
    #: regression surface)
    state_checksum: str = ""

    @property
    def tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens

    @property
    def instr_reduction(self) -> float:
        return self.micro_bytes / max(self.minisa_bytes, 1e-9)

    def summary(self) -> dict:
        return {
            "rid": self.rid, "tokens": self.tokens,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "wall_s": self.wall_s,
            "minisa_bytes": self.minisa_bytes,
            "micro_bytes": self.micro_bytes,
            "instr_reduction": self.instr_reduction,
            "stall_minisa": self.stall_minisa,
            "stall_micro": self.stall_micro,
            "state_checksum": self.state_checksum,
        }


@dataclasses.dataclass
class SchedulerReport:
    backend: str
    requests: list[RequestReport]
    wall_s: float
    ticks: int
    max_concurrent: int
    cache: dict
    # multi-array serving (all zeros / ones on a single array)
    n_arrays: int = 1
    per_array_minisa_bytes: list = dataclasses.field(default_factory=list)
    per_array_cycles: list = dataclasses.field(default_factory=list)
    # batched decode fast path (fused-segment kernels)
    decode_fused: bool = False
    decode_fused_segments: int = 0    # fused launches per decode step
    decode_segments: int = 0          # total decode segments per step
    decode_hbm_elided_bytes: float = 0.0   # modelled per decode step

    @property
    def total_tokens(self) -> int:
        return sum(r.tokens for r in self.requests)

    @property
    def tokens_per_sec(self) -> float:
        return self.total_tokens / max(self.wall_s, 1e-9)

    @property
    def load_imbalance(self) -> float:
        return perf.load_imbalance(self.per_array_cycles)

    def summary(self) -> dict:
        return {
            "backend": self.backend,
            "n_requests": len(self.requests),
            "total_tokens": self.total_tokens,
            "tokens_per_sec": self.tokens_per_sec,
            "wall_s": self.wall_s,
            "ticks": self.ticks,
            "max_concurrent": self.max_concurrent,
            "n_arrays": self.n_arrays,
            "per_array_minisa_bytes": list(self.per_array_minisa_bytes),
            "per_array_cycles": list(self.per_array_cycles),
            "load_imbalance": self.load_imbalance,
            "decode_fused": self.decode_fused,
            "decode_fused_segments": self.decode_fused_segments,
            "decode_segments": self.decode_segments,
            "decode_hbm_elided_bytes": self.decode_hbm_elided_bytes,
            "cache_hit_rate": self.cache.get("hit_rate", 0.0),
            "cache_searches": self.cache.get("searches", 0),
            "cache_compiles": self.cache.get("compiles", 0),
            "minisa_bytes_per_request": float(np.mean(
                [r.minisa_bytes for r in self.requests])) if self.requests
            else 0.0,
            "micro_bytes_per_request": float(np.mean(
                [r.micro_bytes for r in self.requests])) if self.requests
            else 0.0,
            "stall_minisa": float(np.mean(
                [r.stall_minisa for r in self.requests])) if self.requests
            else 0.0,
            "stall_micro": float(np.mean(
                [r.stall_micro for r in self.requests])) if self.requests
            else 0.0,
        }


@dataclasses.dataclass
class _Active:
    req: Request
    dynamics: dict[str, np.ndarray]     # per-request KV residency
    carry: np.ndarray                   # previous step's output
    t_start: float
    decoded: int = 0


def _commit_kv(dynamics: dict[str, np.ndarray], out: np.ndarray,
               pos: int) -> None:
    """Deterministic bounded KV append: fold the step output into one
    slot of each dynamic operand along its time-like (longer) axis.
    Quantised (see ``_stabilize``) so the committed state is identical
    across backends."""
    vec = _stabilize(np.tanh(np.asarray(out, np.float32).ravel()))
    if vec.size == 0:
        return
    for arr in dynamics.values():
        if arr.shape[1] > arr.shape[0]:
            arr[:, pos % arr.shape[1]] = np.resize(vec, arr.shape[0])
        else:
            arr[pos % arr.shape[0], :] = np.resize(vec, arr.shape[1])


def _state_checksum(dynamics: dict[str, np.ndarray],
                    carry: np.ndarray) -> str:
    h = hashlib.sha1()
    for name in sorted(dynamics):
        h.update(name.encode())
        h.update(np.ascontiguousarray(dynamics[name]).tobytes())
    h.update(_stabilize(carry).tobytes())
    return h.hexdigest()


class Scheduler:
    """Continuous-batching serving loop over prefill/decode executables.

    Seeding is fully explicit: every request's tensors derive from
    ``(self.seed, request seed)`` only -- never from admission order or
    leftover generator state -- and all recurrence feedback is quantised
    (``_stabilize``), so a run with the same submissions is
    bit-reproducible run-to-run *and* across backends
    (``RequestReport.state_checksum`` is the regression surface).

    When the executables carry an ``ArrayMesh``, every Program executes
    sharded and the report adds per-array instruction traffic, modelled
    cycles and the load-imbalance factor -- the multi-array serving
    simulator view.
    """

    def __init__(self, prefill: ModelExecutable, decode: ModelExecutable,
                 *, backend: str = "interpreter", max_concurrent: int = 4,
                 weight_seed: int = 0, seed: int = 0,
                 use_fused: bool | None = None):
        if prefill.cfg != decode.cfg:
            raise ValueError("prefill/decode executables must share one "
                             "FeatherConfig")
        if prefill.cache is not decode.cache:
            raise ValueError("prefill/decode executables must share one "
                             "ProgramCache")
        if prefill.n_arrays != decode.n_arrays:
            raise ValueError("prefill/decode executables must share one "
                             "ArrayMesh shape")
        self.prefill = prefill
        self.decode = decode
        self.backend_name = backend
        self.backend = prefill.make_backend(backend)
        self.max_concurrent = max_concurrent
        self.seed = seed
        # Batched decode fast path: every tick advances the whole batch of
        # active requests through the decode stream's *fused segments* --
        # one kernel launch per chained segment instead of one dispatch
        # per layer.  Defaults on for the compiled backend (where the
        # per-launch overhead is the decode loop's dominant cost); the
        # interpreter keeps the per-Program path, whose machine state IS
        # the chain semantics.
        self.use_fused = (use_fused if use_fused is not None
                          else backend == "pallas")
        # weight residency: one static weight set serves every request
        self.prefill_weights = prefill.make_tensors(weight_seed,
                                                    kinds=("weight",))
        self.decode_weights = decode.make_tensors(weight_seed,
                                                  kinds=("weight",))
        self._pending: collections.deque[Request] = collections.deque()
        self._next_rid = 0

    def submit(self, decode_steps: int, seed: int | None = None) -> Request:
        """Queue a request.  The default per-request seed derives from
        the scheduler seed and the rid alone, so a submission sequence
        reproduces exactly regardless of wall-clock or interleaving."""
        if seed is None:
            seed = self.seed * 1_000_003 + self._next_rid
        req = Request(rid=self._next_rid, decode_steps=decode_steps,
                      seed=seed)
        self._next_rid += 1
        self._pending.append(req)
        return req

    # -- one request's phases -------------------------------------------------
    def _admit(self, req: Request) -> _Active:
        t_start = time.perf_counter()   # request wall time includes prefill
        env = dict(self.prefill_weights)
        env.update(self.prefill.make_tensors(req.seed,
                                             kinds=("dynamic", "input")))
        res = self.prefill.run(self.backend, tensors=env)
        dynamics = self.decode.make_tensors(req.seed, kinds=("dynamic",))
        _commit_kv(dynamics, res.final, 0)   # prefill output seeds the KV
        return _Active(req=req, dynamics=dynamics, carry=res.final,
                       t_start=t_start)

    def _decode_step(self, a: _Active) -> None:
        env = dict(self.decode_weights)
        env.update(a.dynamics)
        # quantised carrier: both backends feed identical step inputs
        env.update(self.decode.inputs_from(_stabilize(a.carry)))
        res = self.decode.run(self.backend, tensors=env,
                              fused=self.use_fused)
        a.decoded += 1
        a.carry = res.final
        _commit_kv(a.dynamics, res.final, a.decoded)

    def _report(self, a: _Active, pre: dict, dec: dict) -> RequestReport:
        n = a.decoded
        return RequestReport(
            rid=a.req.rid,
            prefill_tokens=self.prefill.tokens or 0,
            decode_tokens=n * (self.decode.tokens or 1),
            wall_s=time.perf_counter() - a.t_start,
            minisa_bytes=pre["minisa_bytes"] + n * dec["minisa_bytes"],
            micro_bytes=pre["micro_bytes"] + n * dec["micro_bytes"],
            cycles_minisa=pre["cycles_minisa"] + n * dec["cycles_minisa"],
            cycles_micro=pre["cycles_micro"] + n * dec["cycles_micro"],
            stall_minisa=(pre["stall_cycles_minisa"]
                          + n * dec["stall_cycles_minisa"])
            / max(pre["cycles_minisa"] + n * dec["cycles_minisa"], 1e-9),
            stall_micro=(pre["stall_cycles_micro"]
                         + n * dec["stall_cycles_micro"])
            / max(pre["cycles_micro"] + n * dec["cycles_micro"], 1e-9),
            state_checksum=_state_checksum(a.dynamics, a.carry),
        )

    # -- the serving loop -----------------------------------------------------
    def run(self) -> SchedulerReport:
        t0 = time.perf_counter()
        n_arrays = self.prefill.n_arrays
        per_bytes = [0.0] * n_arrays
        per_cycles = [0.0] * n_arrays
        active: list[_Active] = []
        done: list[RequestReport] = []
        ticks = 0
        while self._pending or active:
            while self._pending and len(active) < self.max_concurrent:
                active.append(self._admit(self._pending.popleft()))
            for a in list(active):
                if a.decoded < a.req.decode_steps:
                    self._decode_step(a)
                if a.decoded >= a.req.decode_steps:
                    active.remove(a)
                    pre = self.prefill.perf_stats()
                    dec = self.decode.perf_stats()
                    done.append(self._report(a, pre, dec))
                    for i in range(n_arrays):
                        per_bytes[i] += (
                            pre["per_array_minisa_bytes"][i]
                            + a.decoded * dec["per_array_minisa_bytes"][i])
                        per_cycles[i] += (
                            pre["per_array_cycles_minisa"][i]
                            + a.decoded * dec["per_array_cycles_minisa"][i])
            ticks += 1
        done.sort(key=lambda r: r.rid)
        fusion = self.decode.fusion_stats()
        return SchedulerReport(
            backend=self.backend_name, requests=done,
            wall_s=time.perf_counter() - t0, ticks=ticks,
            max_concurrent=self.max_concurrent,
            cache=self.prefill.cache.stats.summary(),
            n_arrays=n_arrays,
            per_array_minisa_bytes=per_bytes,
            per_array_cycles=per_cycles,
            decode_fused=self.use_fused,
            decode_fused_segments=fusion["n_fused_segments"],
            decode_segments=fusion["n_segments"],
            decode_hbm_elided_bytes=(fusion["hbm_bytes_elided"]
                                     if self.use_fused else 0.0))
