"""Continuous-batching request scheduler over compiled model executables.

The paper's end-to-end speedups (§V) come from amortising instruction
fetch across layers *and requests*; this scheduler is that serving loop.
One prefill and one decode :class:`~repro.runtime.executable.ModelExecutable`
-- compiled once through the shared ProgramCache -- serve every request:

  * **weight residency**: the static weight tensors are generated once
    per scheduler and shared by all requests (only *dynamic* operands --
    the attention K^T/V, FEATHER+'s runtime-layout case -- are
    per-request state);
  * **KV residency**: each request's dynamic tensors live in a paged
    :class:`KVPool` arena for the request's lifetime; every step's
    output is committed back into them (a deterministic bounded update
    standing in for the model's KV append), and the next step's fresh
    inputs derive from the previous output, so the decode loop is a real
    numeric recurrence.  Pages are evicted back to the pool when a
    request retires; admission stalls (never deadlocks) when the pool is
    exhausted;
  * **one backend instance** executes everything, so the Pallas compile
    cache and the machine's jitted invocation kernels stay warm across
    requests -- a second request performs zero mapper searches and zero
    backend compiles (the cache stats in the report prove it).

Scheduling is split prefill/decode continuous batching: every tick first
advances the WHOLE decode batch -- with ``batch_decode`` the batch
stacks along M and moves through the decode stream's M-polymorphic
segments in ONE backend launch per segment
(``ModelExecutable.run_batch``), flash-decode included -- then retires
finished requests mid-batch, and only then spends the per-tick
``token_budget`` on prefill work: continuing admitted requests' prompt
chunks and admitting new requests into free slots.  Long prompts are
chunked (``prompt_tokens`` per request), so one long prompt can never
stall the decode batch.

Per-request accounting reuses the exact tile streams ``perf.simulate``
consumes (via ``ModelExecutable.perf_stats``): MINISA vs micro-instruction
traffic bytes, modelled cycles and instruction-fetch stall fractions,
plus wall-clock latency and time-to-first-token.  With mesh-sharded
executables the report additionally carries per-array traffic/cycles and
the load-imbalance factor, and seeded runs are bit-reproducible across
backends *and batch compositions* (quantised recurrence feedback; see
``_stabilize``).
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import time

import numpy as np

from repro.core import perf
from repro.faults.inject import (CircuitBreaker, FaultError, FaultInjector,
                                 PoisonedOutputError, check_finite)
from repro.faults.plan import FaultPlan
from repro.obs import metrics as obs_metrics
from repro.obs.trace import trace
from repro.runtime.executable import ModelExecutable

#: The serving recurrence feeds backend outputs back into request state
#: (KV commits, the next step's input carrier).  Quantising that feedback
#: to this many decimals makes a seeded run *bit*-reproducible across
#: backends -- and across batch compositions: fp32 kernel-order
#: differences between the interpreter, the Pallas kernels and the
#: M-stacked batched launches (~1e-6 at serving extents) vanish under
#: the quantum, so every path walks the identical state trajectory.
_STATE_DECIMALS = 3


def _stabilize(x: np.ndarray) -> np.ndarray:
    return np.round(np.asarray(x, np.float32), _STATE_DECIMALS)


def _pct(vals: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(vals, np.float64), q)) \
        if vals else 0.0


@dataclasses.dataclass
class Request:
    rid: int
    decode_steps: int
    seed: int = 0
    #: prompt length in tokens; prompts longer than one prefill pass are
    #: chunked (None == exactly one pass, the pre-chunking behaviour)
    prompt_tokens: int | None = None
    t_submit: float = 0.0
    #: wall-clock budget from submission; an overdue request retires as
    #: ``timed_out`` instead of wedging the tick loop (None == no limit)
    deadline_s: float | None = None


@dataclasses.dataclass
class RequestReport:
    rid: int
    prefill_tokens: int
    decode_tokens: int
    wall_s: float
    minisa_bytes: float
    micro_bytes: float
    cycles_minisa: float
    cycles_micro: float
    stall_minisa: float
    stall_micro: float
    #: sha1 over the request's final quantised KV state + carrier --
    #: equal across backends / re-runs / batch compositions for equal
    #: seeds (determinism regression surface)
    state_checksum: str = ""
    #: submit -> first decode token out (prefill queueing + chunking)
    ttft_s: float = 0.0
    #: terminal state: "ok" | "timed_out" (deadline hit) | "failed"
    #: (retry budget exhausted under persistent faults)
    status: str = "ok"
    #: fault-retried steps this request absorbed (0 on a clean run)
    retries: int = 0

    @property
    def tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens

    @property
    def instr_reduction(self) -> float:
        return self.micro_bytes / max(self.minisa_bytes, 1e-9)

    def summary(self) -> dict:
        return {
            "rid": self.rid, "tokens": self.tokens,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "wall_s": self.wall_s,
            "ttft_s": self.ttft_s,
            "minisa_bytes": self.minisa_bytes,
            "micro_bytes": self.micro_bytes,
            "instr_reduction": self.instr_reduction,
            "stall_minisa": self.stall_minisa,
            "stall_micro": self.stall_micro,
            "state_checksum": self.state_checksum,
            "status": self.status,
            "retries": self.retries,
        }


@dataclasses.dataclass
class SchedulerReport:
    backend: str
    requests: list[RequestReport]
    wall_s: float
    ticks: int
    max_concurrent: int
    cache: dict
    # multi-array serving (all zeros / ones on a single array)
    n_arrays: int = 1
    per_array_minisa_bytes: list = dataclasses.field(default_factory=list)
    per_array_cycles: list = dataclasses.field(default_factory=list)
    # batched decode fast path (fused-segment kernels)
    decode_fused: bool = False
    decode_fused_segments: int = 0    # fused launches per decode step
    decode_segments: int = 0          # total decode segments per step
    decode_hbm_elided_bytes: float = 0.0   # modelled per decode step
    # cross-request batched decode (M-polymorphic segments)
    batch_decode: bool = False
    decode_wall_s: float = 0.0        # wall time inside decode ticks
    prefill_wall_s: float = 0.0       # wall time inside prefill/admission
    decode_steps_total: int = 0       # request-steps decoded
    decode_ticks: int = 0             # ticks that ran a decode phase
    decode_launches: int = 0          # backend kernel launches in decode
    kv: dict = dataclasses.field(default_factory=dict)   # KVPool stats
    #: fault/recovery accounting ({} on a run with resilience off):
    #: injected/recovered/skipped per kind, unrecovered, retries,
    #: timed_out/failed request counts, breaker state, mesh degradations
    resilience: dict = dataclasses.field(default_factory=dict)

    @property
    def total_tokens(self) -> int:
        return sum(r.tokens for r in self.requests)

    @property
    def tokens_per_sec(self) -> float:
        return self.total_tokens / max(self.wall_s, 1e-9)

    @property
    def decode_tokens_per_sec(self) -> float:
        """Decode-phase throughput, separated from prefill/TTFT."""
        toks = sum(r.decode_tokens for r in self.requests)
        return toks / max(self.decode_wall_s, 1e-9)

    @property
    def launches_per_decode_tick(self) -> float:
        return self.decode_launches / max(self.decode_ticks, 1)

    @property
    def load_imbalance(self) -> float:
        return perf.load_imbalance(self.per_array_cycles)

    def summary(self) -> dict:
        walls = [r.wall_s for r in self.requests]
        ttfts = [r.ttft_s for r in self.requests]
        return {
            "backend": self.backend,
            "n_requests": len(self.requests),
            "total_tokens": self.total_tokens,
            "tokens_per_sec": self.tokens_per_sec,
            "decode_tokens_per_sec": self.decode_tokens_per_sec,
            "wall_s": self.wall_s,
            "decode_wall_s": self.decode_wall_s,
            "prefill_wall_s": self.prefill_wall_s,
            "ticks": self.ticks,
            "max_concurrent": self.max_concurrent,
            "batch_decode": self.batch_decode,
            "decode_ticks": self.decode_ticks,
            "decode_steps_total": self.decode_steps_total,
            "decode_launches": self.decode_launches,
            "launches_per_decode_tick": self.launches_per_decode_tick,
            "latency_p50_s": _pct(walls, 50),
            "latency_p95_s": _pct(walls, 95),
            "latency_p99_s": _pct(walls, 99),
            "ttft_p50_s": _pct(ttfts, 50),
            "ttft_p95_s": _pct(ttfts, 95),
            "ttft_p99_s": _pct(ttfts, 99),
            "n_arrays": self.n_arrays,
            "per_array_minisa_bytes": list(self.per_array_minisa_bytes),
            "per_array_cycles": list(self.per_array_cycles),
            "load_imbalance": self.load_imbalance,
            "decode_fused": self.decode_fused,
            "decode_fused_segments": self.decode_fused_segments,
            "decode_segments": self.decode_segments,
            "decode_hbm_elided_bytes": self.decode_hbm_elided_bytes,
            "kv": dict(self.kv),
            "resilience": dict(self.resilience),
            "requests_ok": sum(1 for r in self.requests
                               if r.status == "ok"),
            "requests_timed_out": sum(1 for r in self.requests
                                      if r.status == "timed_out"),
            "requests_failed": sum(1 for r in self.requests
                                   if r.status == "failed"),
            "retries_total": sum(r.retries for r in self.requests),
            "cache_hit_rate": self.cache.get("hit_rate", 0.0),
            "cache_searches": self.cache.get("searches", 0),
            "cache_compiles": self.cache.get("compiles", 0),
            "minisa_bytes_per_request": float(np.mean(
                [r.minisa_bytes for r in self.requests])) if self.requests
            else 0.0,
            "micro_bytes_per_request": float(np.mean(
                [r.micro_bytes for r in self.requests])) if self.requests
            else 0.0,
            "stall_minisa": float(np.mean(
                [r.stall_minisa for r in self.requests])) if self.requests
            else 0.0,
            "stall_micro": float(np.mean(
                [r.stall_micro for r in self.requests])) if self.requests
            else 0.0,
        }

    def to_dict(self) -> dict:
        """The full serialisable report: the summary, every per-request
        report, the complete cache stats (disk tier included) and the
        KVPool stats -- the shape the benchmark JSON and the CI
        artifacts carry."""
        return {
            **self.summary(),
            "requests": [r.summary() for r in self.requests],
            "cache": dict(self.cache),
            "kv": dict(self.kv),
        }

    def timeline(self, events=None) -> list[dict]:
        """Join tracer span events to requests: one entry per request,
        carrying its ``("request", rid)`` swimlane (submit instant,
        prefill chunks, per-tick decode spans, first-token / retire
        markers) in time order.  ``events`` defaults to the shared
        tracer's buffer; empty swimlanes (tracing off) yield empty
        span lists."""
        if events is None:
            events = trace.events()
        by_rid: dict[int, list] = {r.rid: [] for r in self.requests}
        for ev in events:
            if ev.track[0] == "request" and ev.track[1] in by_rid:
                by_rid[ev.track[1]].append(ev)
        out = []
        for r in self.requests:
            evs = sorted(by_rid[r.rid], key=lambda e: (e.t0_s, e.seq))
            out.append({
                "rid": r.rid,
                "ttft_s": r.ttft_s,
                "wall_s": r.wall_s,
                "state_checksum": r.state_checksum,
                "spans": [{
                    "name": ev.name, "t0_s": ev.t0_s, "dur_s": ev.dur_s,
                    "instant": ev.instant, **ev.attrs} for ev in evs],
            })
        return out

    def publish_metrics(self, registry=None) -> None:
        """Push the serving totals into the metrics registry (default:
        the shared ``obs.metrics`` one): MINISA vs micro instruction
        bytes and token counters, the scalar summary as gauges, and the
        KVPool + cache stats -- one scrape surface over every ad-hoc
        stats dict."""
        reg = registry if registry is not None else obs_metrics.REGISTRY
        reg.counter("minisa_bytes_total",
                    "MINISA instruction bytes served").inc(
                        sum(r.minisa_bytes for r in self.requests),
                        backend=self.backend)
        reg.counter("micro_bytes_total",
                    "micro-instruction control bytes (baseline)").inc(
                        sum(r.micro_bytes for r in self.requests),
                        backend=self.backend)
        reg.counter("tokens_total", "tokens served").inc(
            self.total_tokens, backend=self.backend)
        reg.counter("requests_total", "requests retired").inc(
            len(self.requests), backend=self.backend)
        timed_out = sum(1 for r in self.requests
                        if r.status == "timed_out")
        if timed_out:
            reg.counter("requests_timed_out_total",
                        "requests retired past their deadline").inc(
                            timed_out, backend=self.backend)
        retries = sum(r.retries for r in self.requests)
        if retries:
            reg.counter("retries_total",
                        "fault-retried request steps").inc(
                            retries, backend=self.backend)
        summary = self.summary()
        reg.set_many({k: v for k, v in summary.items()
                      if k not in ("kv", "resilience")}, prefix="sched_")
        reg.set_many(self.kv, prefix="kv_")


# ---------------------------------------------------------------------------
# Paged per-request KV state
# ---------------------------------------------------------------------------

def _kv_specs(executable: ModelExecutable) -> dict[str, tuple]:
    """name -> (shape, time_axis, time_extent, width) for every dynamic
    tensor.  The time-like axis is the *longer* one -- the same rule the
    commit recurrence has always used."""
    specs = {}
    for name, (shape, kind) in executable.tensor_specs().items():
        if kind != "dynamic":
            continue
        rows, cols = shape
        if cols > rows:
            specs[name] = (shape, 1, cols, rows)
        else:
            specs[name] = (shape, 0, rows, cols)
    return specs


class KVPool:
    """Fixed arena of KV pages shared by all in-flight requests.

    One page holds ``page_size`` time slots of EVERY dynamic tensor (one
    arena per tensor, indexed by the same page table), so a request's
    whole KV state allocates and evicts as one page list.  ``allocate``
    returns None when the pool is exhausted -- the scheduler turns that
    into an admission stall, never an OOM.
    """

    def __init__(self, specs: dict[str, tuple], page_size: int,
                 n_pages: int):
        self.specs = specs
        self.page_size = max(1, page_size)
        self.n_pages = max(1, n_pages)
        self.arenas = {
            name: np.zeros((self.n_pages * self.page_size, width),
                           np.float32)
            for name, (_, _, _, width) in specs.items()}
        self._free = list(range(self.n_pages - 1, -1, -1))
        self._allocated: set[int] = set()
        self.allocated_pages = 0
        self.high_water_pages = 0
        self.evicted_pages = 0
        self.admit_stalls = 0
        self.double_releases = 0
        self.reserved_pages = 0       # held out by a fault spike, now

    @property
    def time_extent(self) -> int:
        """Slots one request needs: the longest dynamic time axis."""
        return max((t for _, _, t, _ in self.specs.values()), default=1)

    @property
    def pages_per_request(self) -> int:
        return -(-self.time_extent // self.page_size)

    def allocate(self) -> list[int] | None:
        need = self.pages_per_request
        if len(self._free) < need:
            return None
        pages = [self._free.pop() for _ in range(need)]
        self._allocated.update(pages)
        self.allocated_pages += need
        self.high_water_pages = max(self.high_water_pages,
                                    self.allocated_pages)
        return pages

    def release(self, pages: list[int]) -> None:
        """Idempotent: only pages this pool currently has allocated go
        back to the free list.  A double release (or a stale page id)
        counts ``double_releases`` and is otherwise a no-op -- a page
        can never re-enter ``_free`` twice and be handed to two live
        requests."""
        live = [p for p in pages if p in self._allocated]
        self.double_releases += len(pages) - len(live)
        self._allocated.difference_update(live)
        self._free.extend(live)
        self.allocated_pages -= len(live)
        self.evicted_pages += len(live)

    def reserve(self, n: int = 0) -> list[int]:
        """Hold pages out of the free list (a fault-injected pressure
        spike): ``n <= 0`` grabs every free page.  Reserved pages are
        neither free nor allocated until :meth:`unreserve` returns
        them."""
        take = len(self._free) if n <= 0 else min(n, len(self._free))
        held = [self._free.pop() for _ in range(take)]
        self.reserved_pages += len(held)
        return held

    def unreserve(self, held: list[int]) -> None:
        self._free.extend(held)
        self.reserved_pages -= len(held)

    def stats(self) -> dict:
        return {
            "n_pages": self.n_pages,
            "page_size": self.page_size,
            "pages_per_request": self.pages_per_request,
            "allocated_pages": self.allocated_pages,
            "high_water_pages": self.high_water_pages,
            "evicted_pages": self.evicted_pages,
            "admit_stalls": self.admit_stalls,
            "double_releases": self.double_releases,
            "reserved_pages": self.reserved_pages,
        }


class PagedKV:
    """One request's KV state, resident in pool pages.

    ``seed``/``commit``/``gather`` reproduce the flat-dict recurrence
    bit-exactly: ``gather`` reconstructs the original-shaped float32
    tensors, so state checksums are independent of the paging layout.
    """

    def __init__(self, pool: KVPool, pages: list[int]):
        self.pool = pool
        self.pages = pages

    def _slot(self, j: int) -> int:
        ps = self.pool.page_size
        return self.pages[j // ps] * ps + j % ps

    def seed(self, dynamics: dict[str, np.ndarray]) -> None:
        for name, (shape, tax, t_ext, _) in self.pool.specs.items():
            arr = np.asarray(dynamics[name], np.float32)
            arena = self.pool.arenas[name]
            for j in range(t_ext):
                arena[self._slot(j), :] = arr[j, :] if tax == 0 \
                    else arr[:, j]

    def commit(self, out: np.ndarray, pos: int) -> None:
        """Deterministic bounded KV append: fold the step output into
        one time slot of each dynamic operand (same fold as the
        pre-paging ``_commit_kv``, same quantisation)."""
        vec = _stabilize(np.tanh(np.asarray(out, np.float32).ravel()))
        if vec.size == 0:
            return
        for name, (_, _, t_ext, width) in self.pool.specs.items():
            arena = self.pool.arenas[name]
            arena[self._slot(pos % t_ext), :] = np.resize(vec, width)

    def gather(self) -> dict[str, np.ndarray]:
        out = {}
        for name, (shape, tax, t_ext, _) in self.pool.specs.items():
            arena = self.pool.arenas[name]
            rows = np.stack([arena[self._slot(j)] for j in range(t_ext)]) \
                if t_ext else np.zeros(shape, np.float32)
            out[name] = np.ascontiguousarray(rows if tax == 0 else rows.T)
        return out

    def release(self) -> None:
        if self.pages:
            self.pool.release(self.pages)
            self.pages = []


@dataclasses.dataclass
class _Active:
    req: Request
    kv: PagedKV
    carry: np.ndarray | None            # previous step's output
    t_start: float
    prefill_chunks: int = 1             # total prompt chunks
    chunks_done: int = 0
    decoded: int = 0
    t_first: float = 0.0                # first decode token wall time
    # fault-tolerance state (all inert on a clean run)
    retries: int = 0                    # fault-retried steps, total
    consec_faults: int = 0              # consecutive, reset on success
    backoff_until: int = 0              # first tick allowed to run again
    pending_faults: list = dataclasses.field(default_factory=list)
    status: str = "ok"

    @property
    def prefill_done(self) -> bool:
        return self.chunks_done >= self.prefill_chunks

    @property
    def dynamics(self) -> dict[str, np.ndarray]:
        """Flat view of the paged KV state (compat / checksums)."""
        return self.kv.gather()


def _commit_kv(dynamics: dict[str, np.ndarray], out: np.ndarray,
               pos: int) -> None:
    """Flat-dict twin of :meth:`PagedKV.commit` (kept for direct use on
    unpaged dynamics dicts)."""
    vec = _stabilize(np.tanh(np.asarray(out, np.float32).ravel()))
    if vec.size == 0:
        return
    for arr in dynamics.values():
        if arr.shape[1] > arr.shape[0]:
            arr[:, pos % arr.shape[1]] = np.resize(vec, arr.shape[0])
        else:
            arr[pos % arr.shape[0], :] = np.resize(vec, arr.shape[1])


def _state_checksum(dynamics: dict[str, np.ndarray],
                    carry: np.ndarray) -> str:
    h = hashlib.sha1()
    for name in sorted(dynamics):
        h.update(name.encode())
        h.update(np.ascontiguousarray(dynamics[name]).tobytes())
    h.update(_stabilize(carry).tobytes())
    return h.hexdigest()


class Scheduler:
    """Split prefill/decode continuous-batching loop over executables.

    Seeding is fully explicit: every request's tensors derive from
    ``(self.seed, request seed)`` only -- never from admission order or
    leftover generator state -- and all recurrence feedback is quantised
    (``_stabilize``), so a run with the same submissions is
    bit-reproducible run-to-run, across backends *and across batch
    compositions* (``RequestReport.state_checksum`` is the regression
    surface).

    ``batch_decode`` (default: on for the Pallas backend on a
    single-array stream) advances the whole active batch through the
    decode stream's M-polymorphic segments with ONE backend launch per
    segment per tick; ``token_budget`` caps prefill tokens per tick so
    prompt work never starves the decode batch, and ``prompt_tokens``
    at submit chunks long prompts across ticks.

    When the executables carry an ``ArrayMesh``, every Program executes
    sharded (per-request; batching auto-disables) and the report adds
    per-array instruction traffic, modelled cycles and the
    load-imbalance factor -- the multi-array serving simulator view.
    """

    def __init__(self, prefill: ModelExecutable, decode: ModelExecutable,
                 *, backend: str = "interpreter", max_concurrent: int = 4,
                 weight_seed: int = 0, seed: int = 0,
                 use_fused: bool | None = None,
                 batch_decode: bool | None = None,
                 token_budget: int | None = None,
                 kv_page_size: int = 4, kv_pages: int | None = None,
                 faults: "FaultPlan | FaultInjector | None" = None,
                 finite_check: bool | None = None,
                 max_retries: int = 4,
                 backoff_base: int = 1, backoff_cap: int = 8,
                 breaker_threshold: int = 4, breaker_cooldown: int = 4):
        if prefill.cfg != decode.cfg:
            raise ValueError("prefill/decode executables must share one "
                             "FeatherConfig")
        if prefill.cache is not decode.cache:
            raise ValueError("prefill/decode executables must share one "
                             "ProgramCache")
        if prefill.n_arrays != decode.n_arrays:
            raise ValueError("prefill/decode executables must share one "
                             "ArrayMesh shape")
        self.prefill = prefill
        self.decode = decode
        self.backend_name = backend
        self.backend = prefill.make_backend(backend)
        self.max_concurrent = max_concurrent
        self.seed = seed
        # -- fault tolerance: entirely inert (no wrapper, no checks, no
        # extra branches on the hot path) unless a fault plan / injector
        # or an explicit finite_check opts in
        if isinstance(faults, FaultPlan):
            faults = FaultInjector(faults)
        self.injector: FaultInjector | None = faults
        self.finite_check = (finite_check if finite_check is not None
                             else faults is not None)
        self.resilient = self.injector is not None or self.finite_check
        self.max_retries = max(1, max_retries)
        self.backoff_base = max(1, backoff_base)
        self.backoff_cap = max(self.backoff_base, backoff_cap)
        self.breaker = (CircuitBreaker(breaker_threshold, breaker_cooldown)
                        if self.resilient else None)
        if self.injector is not None:
            self.backend = self.injector.wrap(self.backend)
        self._kv_spikes: list[tuple[int, list[int]]] = []
        self._mesh_degraded = 0
        # Fused-segment fast path: chained segments execute as ONE kernel
        # launch (prefill and decode).  Defaults on for the compiled
        # backend (where per-launch overhead dominates); the interpreter
        # keeps the per-Program path, whose machine state IS the chain
        # semantics.
        self.use_fused = (use_fused if use_fused is not None
                          else backend == "pallas")
        # Cross-request batched decode: stack every active request along
        # M and advance the batch with one launch per segment per tick.
        # Mesh-sharded streams schedule per-request (on-chip residency is
        # per-array state), so batching auto-disables there.
        if batch_decode is None:
            batch_decode = backend == "pallas" and decode.mesh is None
        elif batch_decode and decode.mesh is not None:
            raise ValueError("batch_decode requires a single-array "
                             "decode stream (got an ArrayMesh)")
        self.batch_decode = batch_decode
        #: prefill tokens one tick may spend (None == unbounded); decode
        #: always runs first, so prompts never stall the decode batch
        self.token_budget = token_budget
        # paged per-request KV state: sized so max_concurrent requests
        # fit by default; smaller pools admission-stall, never OOM
        specs = _kv_specs(decode)
        t_ext = max((t for _, _, t, _ in specs.values()), default=1)
        per_req = -(-t_ext // max(1, kv_page_size))
        if kv_pages is None:
            kv_pages = per_req * max_concurrent
        if kv_pages < per_req:
            raise ValueError(
                f"kv_pages={kv_pages} cannot hold even one request "
                f"({per_req} pages of {kv_page_size} slots needed)")
        self.kv_pool = KVPool(specs, kv_page_size, kv_pages)
        # weight residency: one static weight set serves every request
        self.prefill_weights = prefill.make_tensors(weight_seed,
                                                    kinds=("weight",))
        self.decode_weights = decode.make_tensors(weight_seed,
                                                  kinds=("weight",))
        self._pending: collections.deque[Request] = collections.deque()
        self._next_rid = 0
        # serving state shared between the loop, snapshot() and resumed
        # run() calls: in-flight work, retired reports (this process +
        # restored from a snapshot), and the monotone tick clock
        self._active: list[_Active] = []
        self._done: list[RequestReport] = []
        self._restored: list[RequestReport] = []
        self._ticks = 0

    def submit(self, decode_steps: int, seed: int | None = None,
               prompt_tokens: int | None = None,
               deadline_s: float | None = None) -> Request:
        """Queue a request.  The default per-request seed derives from
        the scheduler seed and the rid alone, so a submission sequence
        reproduces exactly regardless of wall-clock or interleaving.
        ``prompt_tokens`` longer than one prefill pass are chunked
        across ticks under the token budget; ``deadline_s`` bounds the
        request's wall clock from submission (overdue -> ``timed_out``)."""
        if seed is None:
            seed = self.seed * 1_000_003 + self._next_rid
        req = Request(rid=self._next_rid, decode_steps=decode_steps,
                      seed=seed, prompt_tokens=prompt_tokens,
                      t_submit=time.perf_counter(), deadline_s=deadline_s)
        self._next_rid += 1
        self._pending.append(req)
        trace.instant("submit", ("request", req.rid),
                      decode_steps=decode_steps,
                      prompt_tokens=prompt_tokens)
        return req

    # -- one request's phases -------------------------------------------------
    def _chunks_for(self, req: Request) -> int:
        chunk = max(1, self.prefill.tokens or 1)
        prompt = req.prompt_tokens if req.prompt_tokens else chunk
        return max(1, -(-prompt // chunk))

    def _admit(self, req: Request) -> _Active | None:
        """Allocate KV pages and run the first prompt chunk; None when
        the pool cannot hold another request (admission stall).  A fault
        on the first chunk (resilient runs only) backs the request off
        in place -- it is admitted, pages held, chunk 0 retried on a
        later tick."""
        pages = self.kv_pool.allocate()
        if pages is None:
            return None
        trace.instant("admit", ("request", req.rid), pages=len(pages))
        # request wall time runs from submission (queueing included)
        a = _Active(req=req, kv=PagedKV(self.kv_pool, pages), carry=None,
                    t_start=req.t_submit or time.perf_counter(),
                    prefill_chunks=self._chunks_for(req))
        try:
            self._prefill_chunk(a)
        except FaultError as e:
            self._on_fault(a, e)
        return a

    def _prefill_chunk(self, a: _Active) -> None:
        """One prompt chunk through the prefill stream (fused fast path
        under ``use_fused``), committed into the request's KV at the
        chunk position.  Chunk 0 seeds the KV from the request seed;
        later chunks carry the stabilised output forward, so chunking is
        itself a deterministic recurrence."""
        c = a.chunks_done
        env = dict(self.prefill_weights)
        if c == 0:
            env.update(self.prefill.make_tensors(
                a.req.seed, kinds=("dynamic", "input")))
        else:
            env.update(self.prefill.make_tensors(
                a.req.seed + 7_919 * c, kinds=("dynamic",)))
            env.update(self.prefill.inputs_from(_stabilize(a.carry)))
        with trace.span("prefill_chunk", ("request", a.req.rid),
                        chunk=c, of=a.prefill_chunks):
            res = self.prefill.run(self.backend, tensors=env,
                                   fused=self.use_fused)
        if self.finite_check and not check_finite(res.final):
            # nothing committed yet: the chunk replays identically
            raise PoisonedOutputError(
                f"non-finite prefill output (rid {a.req.rid} chunk {c})")
        if c == 0:
            a.kv.seed(self.decode.make_tensors(a.req.seed,
                                               kinds=("dynamic",)))
        a.carry = res.final
        a.kv.commit(res.final, c)   # prompt chunk feeds the KV
        a.chunks_done += 1

    def _decode_env(self, a: _Active) -> dict[str, np.ndarray]:
        env = dict(self.decode_weights)
        env.update(a.kv.gather())
        # quantised carrier: every path feeds identical step inputs
        env.update(self.decode.inputs_from(_stabilize(a.carry)))
        return env

    def _after_decode(self, a: _Active, final: np.ndarray) -> None:
        if self.resilient:
            self._note_success(a)
        a.decoded += 1
        a.carry = final
        if a.t_first == 0.0:
            a.t_first = time.perf_counter()
            trace.instant("first_token", ("request", a.req.rid),
                          ttft_s=a.t_first - (a.req.t_submit
                                              or a.t_start))
        # decode commits continue the prompt chunks' positions
        a.kv.commit(final, a.prefill_chunks - 1 + a.decoded)

    def _decode_step(self, a: _Active) -> None:
        with trace.span("decode_step", ("request", a.req.rid),
                        step=a.decoded):
            res = self.decode.run(self.backend,
                                  tensors=self._decode_env(a),
                                  fused=self.use_fused)
        if self.finite_check and not check_finite(res.final):
            # carry/KV untouched: the retry replays from identical state
            raise PoisonedOutputError(
                f"non-finite decode output (rid {a.req.rid} "
                f"step {a.decoded})")
        self._after_decode(a, res.final)

    def _decode_batch(self, batch: list[_Active]) -> None:
        """One tick of the whole decode batch: every request's row
        stacked along M, one backend launch per M-polymorphic segment
        (``ModelExecutable.run_batch``).  Under tracing, the collective
        launch window is recorded onto every participating request's
        swimlane (one measurement, several lanes).  With the finite
        guard on, each request's row is checked before its commit:
        poisoned rows fault (and retry), clean rows commit -- one bad
        launch cannot wedge the whole batch."""
        t0 = time.perf_counter() if trace.enabled else 0.0
        finals = self.decode.run_batch(
            self.backend, [self._decode_env(a) for a in batch],
            fused=self.use_fused)
        if trace.enabled:
            t1 = time.perf_counter()
            for a in batch:
                trace.record("decode_step", ("request", a.req.rid),
                             t0, t1, step=a.decoded, batched=True,
                             batch=len(batch))
        for a, final in zip(batch, finals):
            if self.finite_check and not check_finite(final):
                self._on_fault(a, PoisonedOutputError(
                    f"non-finite batched decode output "
                    f"(rid {a.req.rid} step {a.decoded})"))
            else:
                self._after_decode(a, final)

    def _report(self, a: _Active, pre: dict, dec: dict) -> RequestReport:
        n = a.decoded
        c = a.chunks_done
        return RequestReport(
            rid=a.req.rid,
            prefill_tokens=c * (self.prefill.tokens or 0),
            decode_tokens=n * (self.decode.tokens or 1),
            wall_s=time.perf_counter() - a.t_start,
            minisa_bytes=c * pre["minisa_bytes"] + n * dec["minisa_bytes"],
            micro_bytes=c * pre["micro_bytes"] + n * dec["micro_bytes"],
            cycles_minisa=(c * pre["cycles_minisa"]
                           + n * dec["cycles_minisa"]),
            cycles_micro=(c * pre["cycles_micro"]
                          + n * dec["cycles_micro"]),
            stall_minisa=(c * pre["stall_cycles_minisa"]
                          + n * dec["stall_cycles_minisa"])
            / max(c * pre["cycles_minisa"] + n * dec["cycles_minisa"],
                  1e-9),
            stall_micro=(c * pre["stall_cycles_micro"]
                         + n * dec["stall_cycles_micro"])
            / max(c * pre["cycles_micro"] + n * dec["cycles_micro"], 1e-9),
            state_checksum=_state_checksum(a.kv.gather(), a.carry),
            ttft_s=(a.t_first - a.req.t_submit
                    if a.t_first and a.req.t_submit else 0.0),
            status=a.status,
            retries=a.retries,
        )

    # -- fault tolerance ------------------------------------------------------
    def _on_fault(self, a: _Active, err: FaultError) -> None:
        """One failed step: nothing was committed (carry and KV are
        untouched), so the retry replays from bit-identical state.
        Capped exponential backoff in ticks; the breaker counts the
        failure; past ``max_retries`` consecutive faults the request
        retires as ``failed`` instead of wedging the loop."""
        tick = self._ticks
        kind = ("launch_nan" if isinstance(err, PoisonedOutputError)
                else "launch_transient")
        a.retries += 1
        a.consec_faults += 1
        a.pending_faults.append(kind)
        delay = min(self.backoff_cap,
                    self.backoff_base * (1 << (a.consec_faults - 1)))
        a.backoff_until = tick + delay
        if self.breaker is not None:
            self.breaker.record_failure(tick)
        trace.instant("fault_retry", ("request", a.req.rid), kind=kind,
                      retry=a.retries, backoff_ticks=delay, tick=tick)
        if a.consec_faults > self.max_retries:
            a.status = "failed"

    def _note_success(self, a: _Active) -> None:
        """A step committed: the request's pending faults are recovered
        (counted against the injector's ledger), its backoff resets, and
        the breaker sees the success."""
        if a.pending_faults:
            if self.injector is not None:
                for kind in a.pending_faults:
                    self.injector.mark_recovered(kind, rid=a.req.rid)
            a.pending_faults.clear()
        a.consec_faults = 0
        a.backoff_until = 0
        if self.breaker is not None and (
                self.breaker.failures or self.breaker.state != "closed"):
            self.breaker.record_success()

    def _degrade_mesh(self, site: int) -> None:
        """Array ``site`` went unhealthy: both executables re-lower onto
        the surviving mesh in place (a cache-miss re-lower through
        ``shard_program`` -- plan/lowered tiers all hit).  In-flight
        requests keep their KV state; only the *lowering* changed, and
        quantised recurrence feedback keeps the state trajectory
        bit-identical to the undegraded run."""
        mesh = self.prefill.mesh.degraded(1)
        self.injector.mark_injected("array_down", site=site,
                                    n_arrays=mesh.n_arrays)
        with trace.span("mesh_failover", ("fault", "array_down"),
                        site=site, n_arrays=mesh.n_arrays):
            self.prefill.remesh(mesh)
            self.decode.remesh(mesh)
        self._mesh_degraded += 1
        self.injector.mark_recovered("array_down", n_arrays=mesh.n_arrays)

    def _apply_fault_event(self, ev) -> None:
        """Dispatch one due scheduler-level fault event."""
        tick = self._ticks
        if ev.kind == "array_down":
            if self.prefill.mesh is None:
                self.injector.mark_skipped("array_down")
            else:
                self._degrade_mesh(ev.site)
        elif ev.kind == "kv_exhaust":
            held = self.kv_pool.reserve(ev.pages)
            self._kv_spikes.append((tick + ev.duration, held))
            self.injector.mark_injected("kv_exhaust", pages=len(held),
                                        until=tick + ev.duration)
        elif ev.kind == "cache_corrupt":
            cache = self.prefill.cache
            if not cache.path:
                self.injector.mark_skipped("cache_corrupt")
                return
            cache.save()
            if not self.injector.corrupt_cache_file(cache.path):
                self.injector.mark_skipped("cache_corrupt")
                return
            self.injector.mark_injected("cache_corrupt")
            before = cache.stats.disk_corrupt
            cache.load(cache.path)   # quarantines, never raises
            if cache.stats.disk_corrupt > before:
                self.injector.mark_recovered(
                    "cache_corrupt",
                    quarantined=cache.stats.disk_corrupt - before)

    def _release_due_spikes(self, drain: bool = False) -> None:
        """Expired pressure spikes hand their pages back (``drain``
        releases everything -- the loop finished under pressure, so the
        pool is whole again by construction)."""
        tick = self._ticks
        for until, held in [s for s in self._kv_spikes
                            if drain or s[0] <= tick]:
            self.kv_pool.unreserve(held)
            self._kv_spikes.remove((until, held))
            self.injector.mark_recovered("kv_exhaust", pages=len(held))

    def _overdue(self, a: _Active) -> bool:
        d = a.req.deadline_s
        return (d is not None
                and time.perf_counter() - a.t_start > d)

    # -- snapshot / resume ----------------------------------------------------
    def snapshot(self) -> dict:
        """The deterministic request state a resumed process needs:
        every not-yet-finished request (pending queue + in-flight, which
        replay from their seeds) and every retired report.  Pair with
        ``dist.elastic.save_serving_snapshot`` for the atomic file."""
        pending = [dataclasses.asdict(a.req) for a in self._active]
        pending += [dataclasses.asdict(r) for r in self._pending]
        pending.sort(key=lambda r: r["rid"])
        return {"version": 1, "seed": self.seed,
                "next_rid": self._next_rid,
                "pending": pending,
                "done": [dataclasses.asdict(r)
                         for r in self._restored + self._done]}

    def restore(self, snap: dict) -> int:
        """Adopt a snapshot into a fresh scheduler: retired reports are
        kept verbatim, unfinished requests re-queue (same rid, same
        seed -- the replayed trajectory is bit-identical, so the resumed
        run's checksums match an uninterrupted one).  Returns the number
        of requests re-queued."""
        if snap.get("version") != 1:
            raise ValueError(f"unknown snapshot version "
                             f"{snap.get('version')!r}")
        if snap.get("seed") != self.seed:
            raise ValueError("snapshot seed mismatch: replayed requests "
                             "would not reproduce")
        self._next_rid = max(self._next_rid, int(snap["next_rid"]))
        now = time.perf_counter()
        for r in snap["pending"]:
            self._pending.append(dataclasses.replace(
                Request(**r), t_submit=now))
        self._restored = [RequestReport(**d) for d in snap["done"]]
        return len(snap["pending"])

    # -- the serving loop -----------------------------------------------------
    def run(self, max_ticks: int | None = None) -> SchedulerReport:
        """Serve every submitted request to completion.  The loop runs
        under a ``scheduler.run`` span; on return the report's totals
        (plus the cache's per-tier stats) are published into the shared
        metrics registry.

        ``max_ticks`` stops the loop early (chaos-kill simulation / an
        external drain signal): unfinished requests stay in the
        scheduler's state for :meth:`snapshot`, and the partial report
        covers only the retired ones."""
        with trace.span("scheduler.run", backend=self.backend_name,
                        batch_decode=self.batch_decode,
                        max_concurrent=self.max_concurrent):
            report = self._run_loop(max_ticks)
        report.publish_metrics()
        self.prefill.cache.publish_metrics()
        return report

    def _retire(self, a: _Active, per_bytes: list, per_cycles: list,
                done: list) -> None:
        """Retire one request (complete, timed out or failed): report,
        trace, evict its KV pages, fold its per-array accounting."""
        self._active.remove(a)
        pre = self.prefill.perf_stats()
        dec = self.decode.perf_stats()
        rep = self._report(a, pre, dec)
        done.append(rep)
        trace.instant("retire", ("request", a.req.rid),
                      decoded=a.decoded, status=a.status)
        if trace.enabled:
            # the request's whole lifetime as one backdrop
            # span on its swimlane (arrival -> retire)
            trace.record("request", ("request", a.req.rid),
                         a.t_start, time.perf_counter(),
                         rid=a.req.rid, decoded=a.decoded,
                         checksum=rep.state_checksum)
        a.kv.release()   # checksum gathered; evict the pages
        c, n = a.chunks_done, a.decoded
        # a degraded mesh shrinks the executables' per-array lists
        # mid-run; fold what both sides still account for
        n_fold = min(len(per_bytes), len(pre["per_array_minisa_bytes"]),
                     len(dec["per_array_minisa_bytes"]))
        for i in range(n_fold):
            per_bytes[i] += (
                c * pre["per_array_minisa_bytes"][i]
                + n * dec["per_array_minisa_bytes"][i])
            per_cycles[i] += (
                c * pre["per_array_cycles_minisa"][i]
                + n * dec["per_array_cycles_minisa"][i])

    def _resilience_summary(self, done: list) -> dict:
        if not self.resilient:
            return {}
        res = {
            "finite_check": self.finite_check,
            "max_retries": self.max_retries,
            "retries_total": sum(r.retries for r in done),
            "timed_out": sum(1 for r in done if r.status == "timed_out"),
            "failed": sum(1 for r in done if r.status == "failed"),
            "breaker": self.breaker.stats(),
            "mesh_degraded": self._mesh_degraded,
            "kv_spikes_live": len(self._kv_spikes),
        }
        if self.injector is not None:
            res.update(self.injector.summary())
        return res

    def _run_loop(self, max_ticks: int | None = None) -> SchedulerReport:
        t0 = time.perf_counter()
        n_arrays = self.prefill.n_arrays
        per_bytes = [0.0] * n_arrays
        per_cycles = [0.0] * n_arrays
        active = self._active
        done = self._done
        ran = 0
        decode_wall = prefill_wall = 0.0
        decode_ticks = decode_steps_total = decode_launches = 0
        chunk_tokens = max(1, self.prefill.tokens or 1)
        while (self._pending or active) and (max_ticks is None
                                             or ran < max_ticks):
            ran += 1
            self._ticks += 1
            ticks = self._ticks
            # 0) fault plan: due scheduler-level events apply first, and
            #    expired KV spikes hand their pages back
            if self.injector is not None:
                self._release_due_spikes()
                for ev in self.injector.begin_tick(ticks):
                    self._apply_fault_event(ev)
            # 1) decode phase: the whole ready batch advances one step
            ready = [a for a in active
                     if a.prefill_done and a.decoded < a.req.decode_steps]
            if self.resilient:
                gate = self.breaker.allow(ticks)
                ready = [a for a in ready
                         if gate and a.status == "ok"
                         and ticks >= a.backoff_until]
            if ready:
                td = time.perf_counter()
                l0 = getattr(self.backend, "n_launches", 0)
                with trace.span("decode_tick", tick=ticks,
                                n_ready=len(ready),
                                batched=self.batch_decode) as sp:
                    try:
                        if self.batch_decode:
                            self._decode_batch(ready)
                        else:
                            for a in ready:
                                try:
                                    self._decode_step(a)
                                except FaultError as e:
                                    self._on_fault(a, e)
                    except FaultError as e:
                        # batched transient: the whole batch missed its
                        # step (no state was committed anywhere)
                        for a in ready:
                            self._on_fault(a, e)
                    if sp:
                        sp.set(launches=getattr(self.backend,
                                                "n_launches", 0) - l0)
                decode_wall += time.perf_counter() - td
                decode_launches += (getattr(self.backend, "n_launches", 0)
                                    - l0)
                decode_ticks += 1
                decode_steps_total += len(ready)
            # 2) retire finished requests mid-batch, evicting their KV;
            #    overdue requests retire as timed_out, and requests past
            #    their retry budget as failed -- neither wedges the loop
            for a in list(active):
                finished = (a.prefill_done
                            and a.decoded >= a.req.decode_steps)
                if not finished:
                    if a.status == "ok" and self._overdue(a):
                        a.status = "timed_out"
                        trace.instant("timeout", ("request", a.req.rid),
                                      decoded=a.decoded)
                    if a.status == "ok":
                        continue
                self._retire(a, per_bytes, per_cycles, done)
            # 3) prefill phase under the per-tick token budget: continue
            #    admitted prompts first (oldest-first), then admit new
            #    requests into free slots.  When nothing decoded and
            #    nothing progressed, one chunk is forced so the loop
            #    always makes progress.
            tp = time.perf_counter()
            budget = (self.token_budget if self.token_budget is not None
                      else float("inf"))
            progressed = False
            gate = (not self.resilient) or self.breaker.allow(ticks)
            with trace.span("prefill_phase", tick=ticks,
                            n_pending=len(self._pending)):
                for a in active:
                    if self.resilient and (a.status != "ok"
                                           or ticks < a.backoff_until):
                        continue
                    while (gate and not a.prefill_done
                           and (budget >= chunk_tokens
                                or (not ready and not progressed))):
                        try:
                            self._prefill_chunk(a)
                        except FaultError as e:
                            self._on_fault(a, e)
                            break
                        budget -= chunk_tokens
                        progressed = True
                while (gate and self._pending
                       and len(active) < self.max_concurrent):
                    if budget < chunk_tokens and (ready or progressed):
                        break
                    a = self._admit(self._pending[0])
                    if a is None:   # KV pool exhausted: wait for retires
                        self.kv_pool.admit_stalls += 1
                        break
                    self._pending.popleft()
                    active.append(a)
                    budget -= chunk_tokens
                    progressed = True
            prefill_wall += time.perf_counter() - tp
        if self.injector is not None and not (self._pending or active):
            self._release_due_spikes(drain=True)
        done = sorted(self._restored + done, key=lambda r: r.rid)
        fusion = self.decode.fusion_stats()
        return SchedulerReport(
            backend=self.backend_name, requests=done,
            wall_s=time.perf_counter() - t0, ticks=self._ticks,
            max_concurrent=self.max_concurrent,
            cache=self.prefill.cache.stats.summary(),
            n_arrays=self.prefill.n_arrays,
            per_array_minisa_bytes=per_bytes,
            per_array_cycles=per_cycles,
            decode_fused=self.use_fused,
            decode_fused_segments=fusion["n_fused_segments"],
            decode_segments=fusion["n_segments"],
            decode_hbm_elided_bytes=(fusion["hbm_bytes_elided"]
                                     if self.use_fused else 0.0),
            batch_decode=self.batch_decode,
            decode_wall_s=decode_wall,
            prefill_wall_s=prefill_wall,
            decode_steps_total=decode_steps_total,
            decode_ticks=decode_ticks,
            decode_launches=decode_launches,
            kv=self.kv_pool.stats(),
            resilience=self._resilience_summary(done))
