"""MINISA model runtime: compiled-Program cache, whole-model executables
and a batched serving scheduler.

    configs -> model_gemms -> ProgramCache -> ModelExecutable
                                                  -> Scheduler -> Backend

  cache       ProgramCache -- one memoisation of mapper search ->
              Program lowering -> backend compile, shared by the planner,
              the benchmarks and the runtime (hit/miss/byte stats,
              optional on-disk persistence)
  executable  ModelExecutable -- an (arch x shape) cell's GEMM stream
              lowered once into chained Programs and executed end-to-end
              on any Backend against an einsum oracle of the same stream
  scheduler   Scheduler -- continuous-batching serving loop over
              prefill/decode executables with per-request MINISA vs
              micro-instruction traffic and stall reporting

Multi-array serving: build the executables with ``mesh=ArrayMesh(N)``
(``repro.dist``) and every Program executes sharded across N FEATHER+
arrays -- the cache keys carry the mesh shape, and the scheduler report
adds per-array traffic, cycles and load imbalance.
"""

from repro.runtime.autotune import (AutotuneReport,  # noqa: F401
                                    TunedGeometry, autotune_segment)
from repro.runtime.cache import (CacheStats, ProgramCache,  # noqa: F401
                                 default_cache, reset_default_cache,
                                 segment_key)
from repro.runtime.executable import (ACTIVATIONS, BatchPlan,  # noqa: F401
                                      BatchSegment, ModelExecutable,
                                      RunResult, Segment, Step, TINY_SHAPES,
                                      adapt)
from repro.runtime.scheduler import (KVPool, PagedKV, Request,  # noqa: F401
                                     RequestReport, Scheduler,
                                     SchedulerReport)

__all__ = [
    "AutotuneReport", "TunedGeometry", "autotune_segment", "segment_key",
    "CacheStats", "ProgramCache", "default_cache", "reset_default_cache",
    "ACTIVATIONS", "BatchPlan", "BatchSegment", "ModelExecutable",
    "RunResult", "Segment", "Step", "TINY_SHAPES", "adapt", "KVPool",
    "PagedKV", "Request", "RequestReport", "Scheduler", "SchedulerReport",
]
