"""ModelExecutable: a whole model's GEMM stream, lowered once, runnable.

``core/planner.py`` *plans* an (architecture x shape) cell analytically;
this module makes the same cell *run*: the cell's ``GemmOp`` stream
(``core/model_gemms.gemm_workloads``) is lowered once -- through the shared
:class:`~repro.runtime.cache.ProgramCache` -- into a chained sequence of
Programs, and executed end-to-end with real numerics on any ``Backend``,
cross-checked step by step against an einsum oracle of the identical
stream.

Stream semantics
----------------
Each ``GemmOp`` becomes one :class:`Step` (repeated layers execute one
representative instance; ``reps`` carries the multiplicity into the
traffic accounting, exactly like the planner's analytic aggregates).  A
step's input operand comes from one of three sources:

  wired   the op is ``chained`` and the producer's output shape equals
          the consumer's input shape: the pair joins one
          ``program.chain`` segment (paper §IV-G on-chip commit + input
          elision / named-output retarget) -- no host round trip.
  adapt   the op is ``chained`` but the shapes differ (the model's
          head-split/reshape between projections and attention): the
          producer's *numbers* still feed the consumer, through the
          deterministic host glue :func:`adapt` that the oracle replays.
  fresh   not chained: a seeded host tensor.

Weight operands are host tensors per step -- except ops flagged
``dynamic`` (the attention score/value GEMMs, FEATHER+'s headline
runtime-layout case), whose "weights" (K^T / V) are runtime tensors
supplied per request by the serving scheduler, not part of the cached
weight set.

Fused segments: consecutive ``wired`` steps form a :class:`Segment`;
when the whole chain is fusion-legal (``program.fuse_segment``) the
segment carries a ``FusedSegment`` and ``run(fused=True)`` executes it
as ONE backend kernel launch -- interior activations never leave the
chip, the serving scheduler's decode fast path runs on this, and
``fusion_stats`` reports the elided HBM traffic.

Activations run inside the Program (Activation drain, fused by the
Pallas backend where elementwise) whenever that is semantics-preserving:
elementwise always; row-wise (softmax/norms) only under WO-S with full
output rows per tile.  Anything else is applied host-side between
Programs, which also breaks the chain there (the oracle mirrors both
paths).

Multi-array serving: constructed with ``mesh=dist.ArrayMesh(N)``, every
step's Program is sharded across the mesh (``ProgramCache.sharded``) and
executed via the backends' sharded path.  On-chip chaining is per-array
machine state and does not cross the mesh boundary, so sharded streams
keep every layer's host round trip ('wired' steps feed the producer's
output back explicitly); ``perf_stats`` then reports per-array traffic,
cycles and load imbalance.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, ShapeConfig
from repro.core import isa, perf
from repro.core import program as programlib
from repro.core.planner import GemmOp, as_gemm
from repro.obs.trace import trace
from repro.runtime.cache import ProgramCache, default_cache


# ---------------------------------------------------------------------------
# Activation registry (numeric twins of the ISA's Activation functions)
# ---------------------------------------------------------------------------

def _jnp_act(fn):
    return lambda x: np.asarray(fn(jnp.asarray(x, jnp.float32)))


def _softmax(x):
    x = np.asarray(x, np.float32)
    z = x - x.max(axis=-1, keepdims=True)
    e = np.exp(z)
    return e / e.sum(axis=-1, keepdims=True)


def _rmsnorm(x):
    x = np.asarray(x, np.float32)
    return x / np.sqrt((x * x).mean(axis=-1, keepdims=True) + 1e-6)


def _layernorm(x):
    x = np.asarray(x, np.float32)
    mu = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mu) / np.sqrt(var + 1e-6)


#: act_name -> callable.  The elementwise entries match the Pallas
#: backend's fused ``kernels.nest_gemm.ACT_FNS`` numerics; the gated
#: activations (swiglu/geglu) are approximated by their ungated halves --
#: the GEMM stream carries no gate operand (DESIGN.md arch-applicability).
ACTIVATIONS: dict[str, Callable | None] = {
    "none": None,
    "relu": _jnp_act(lambda x: jnp.maximum(x, 0.0)),
    "gelu": _jnp_act(jax.nn.gelu),
    "silu": _jnp_act(jax.nn.silu),
    "swiglu": _jnp_act(jax.nn.silu),
    "geglu": _jnp_act(jax.nn.gelu),
    "softmax": _softmax,
    "rmsnorm": _rmsnorm,
    "layernorm": _layernorm,
}


def adapt(x: np.ndarray, m: int, k: int) -> np.ndarray:
    """Deterministic host glue between shape-incompatible chained layers
    (the reshape/head-split the GEMM-stream abstraction elides): flatten,
    cycle-extend, reshape to the consumer's [m, k]."""
    flat = np.asarray(x, np.float32).ravel()
    need = m * k
    if flat.size == 0:
        return np.zeros((m, k), np.float32)
    if flat.size < need:
        flat = np.tile(flat, -(-need // flat.size))
    return np.ascontiguousarray(flat[:need].reshape(m, k))


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Step:
    """One executable GEMM of the stream (one representative instance)."""
    index: int
    op: GemmOp
    program: programlib.Program     # executed (possibly chain-rewired)
    input_mode: str                 # 'wired' | 'adapt' | 'fresh'
    host_act: Callable | None       # applied host-side after the Program
    reps: int                       # multiplicity for traffic accounting
    sharded: programlib.ShardedProgram | None = None   # multi-array form

    @property
    def weight_name(self) -> str:
        return f"W{self.index}"

    @property
    def input_name(self) -> str:
        return f"I{self.index}"


@dataclasses.dataclass
class Segment:
    """A maximal chained run of steps, possibly spanning adapt breaks.

    ``fused`` carries the one-kernel-launch geometry when the whole
    segment is fusion-legal (``program.fuse_segment``): ``wired`` chains
    with kernel-applicable activations, joined across interior ``adapt``
    (head split/merge) boundaries -- the streamed megakernel lowers the
    shape glue to an in-kernel slab permutation, so a whole transformer
    block runs as one launch.  On a mesh, ``fused`` may instead be a
    :class:`~repro.core.program.ShardedFusedSegment` (fused WITHIN each
    array when the run is M-sharded with aligned rows -- the mesh only
    forbids fusing *across* arrays).  Everything else falls back to the
    per-Program path automatically.
    """
    indices: list[int]                            # step indices, in order
    fused: programlib.FusedSegment | None = None

    @property
    def n_steps(self) -> int:
        return len(self.indices)


@dataclasses.dataclass
class BatchSegment:
    """One segment of the M-polymorphic batched-decode plan.

    kind 'static':    weight-stationary segment re-lowered at the M
                      bucket (same MappingChoice, so the K accumulation
                      order matches the per-request path); every active
                      request's rows stack along M and advance in ONE
                      launch (``fused`` when fusion-legal, else the
                      bucketed chained per-layer Programs).
    kind 'attention': the dynamic score+context pair; per-request KV
                      rides in as stacked operands and the backend's
                      ``run_batched_attention`` advances the whole batch
                      in one launch (flash-decode on Pallas).
    kind 'perreq':    anything the bucketed lowering cannot express --
                      sequential per-request replay, bit-identical to
                      the unbatched path.
    """
    kind: str                          # 'static' | 'attention' | 'perreq'
    indices: list[int]                 # step indices of the segment
    programs: list                     # bucketed Programs / (score, ctx)
    fused: programlib.FusedSegment | None = None
    host_act: Callable | None = None   # last step's host-side activation
    m_rows: int = 1                    # per-request rows through the seg


@dataclasses.dataclass
class BatchPlan:
    """Batched-decode execution plan for one M bucket."""
    bucket: int
    segments: list[BatchSegment]

    @property
    def launches_per_tick(self) -> int | None:
        """Backend launches one tick costs, or None if a per-request
        fallback segment makes it batch-size-dependent."""
        total = 0
        for seg in self.segments:
            if seg.kind == "perreq":
                return None
            if seg.kind == "static" and seg.fused is None:
                total += len(seg.programs)
            else:
                total += 1
        return total


@dataclasses.dataclass
class RunResult:
    outputs: list[np.ndarray]       # per-step outputs (post host_act);
                                    # interior steps of a fused segment
                                    # stay on-chip and report None
    final: np.ndarray
    checked: bool = False
    fused_segments: int = 0         # segments executed as one kernel


#: Reduced shapes sized for functional end-to-end execution (the SHAPES
#: cells target analytic planning; running decode_32k numerically is not
#: the point of a CPU correctness spine).
TINY_SHAPES = {
    "prefill_tiny": ShapeConfig("prefill_tiny", seq_len=16, global_batch=2,
                                kind="prefill"),
    "decode_tiny": ShapeConfig("decode_tiny", seq_len=16, global_batch=1,
                               kind="decode"),
}


class ModelExecutable:
    """A cell's GEMM stream compiled (through the shared cache) into
    chained Programs, executable on any backend against the oracle."""

    def __init__(self, ops: list[GemmOp], cfg, *,
                 cache: ProgramCache | None = None, name: str = "model",
                 mesh=None):
        self.cfg = cfg
        self.cache = cache if cache is not None else default_cache()
        self.name = name
        # normalise Conv2D (or any to_gemm-able) ops: the whole stream
        # machinery (tensor specs, wiring, oracle) speaks GEMM shapes
        self.ops = [dataclasses.replace(op, gemm=as_gemm(op.gemm))
                    if hasattr(op.gemm, "to_gemm") else op
                    for op in ops]
        self.tokens: int | None = None   # set by for_cell
        # multi-array serving: a dist.ArrayMesh shards every step across
        # the arrays (None / 1 array == the single-array pipeline)
        self.mesh = mesh if mesh is not None and mesh.n_arrays > 1 else None
        self.segments: list[Segment] = []
        with trace.span("executable.build", model=name,
                        n_ops=len(self.ops)):
            self.steps = self._build()
        self._perf_cache: dict[int, tuple] = {}
        self._fusion_stats: dict | None = None
        self._batch_plans: dict[int, BatchPlan] = {}

    def remesh(self, mesh) -> None:
        """Rebuild the stream onto a different ArrayMesh in place --
        the degraded-mesh failover path.  Cache keys carry the mesh
        shape, so this is a cache-miss re-lower through ``shard_program``
        (plans and lowered Programs all hit), not new machinery; perf
        and batch-plan caches reset because per-array accounting
        changed.  ``mesh=None`` (or one array) falls back to the
        unsharded single-array pipeline."""
        self.mesh = mesh if mesh is not None and mesh.n_arrays > 1 else None
        self.segments = []
        with trace.span("executable.remesh", model=self.name,
                        n_arrays=self.n_arrays):
            self.steps = self._build()
        self._perf_cache = {}
        self._fusion_stats = None
        self._batch_plans = {}

    # -- construction --------------------------------------------------------
    @classmethod
    def for_cell(cls, arch: str, shape: str | ShapeConfig, cfg, *,
                 cache: ProgramCache | None = None,
                 reduce_model: bool = True, layers: int = 2,
                 d_model: int = 64, vocab: int = 256,
                 mesh=None) -> "ModelExecutable":
        """Build the executable for an (architecture x shape) cell.

        ``reduce_model`` shrinks the architecture family-preservingly
        (``configs.base.reduced``) so the stream executes functionally on
        CPU; ``shape`` accepts the planning SHAPES, the TINY_SHAPES
        serving cells, or an explicit ShapeConfig."""
        from repro.configs.base import reduced
        from repro.configs.registry import get_config
        from repro.core.model_gemms import gemm_workloads

        mcfg = get_config(arch)
        if reduce_model:
            mcfg = reduced(mcfg, layers=layers, d_model=d_model, vocab=vocab)
        if isinstance(shape, ShapeConfig):
            scfg = shape
        else:
            scfg = {**SHAPES, **TINY_SHAPES}[shape]
        ex = cls(gemm_workloads(mcfg, scfg), cfg, cache=cache,
                 name=f"{arch}/{scfg.name}", mesh=mesh)
        ex.tokens = (scfg.global_batch if scfg.kind == "decode"
                     else scfg.tokens)
        return ex

    def _build(self) -> list[Step]:
        cache = self.cache
        base: list[tuple[GemmOp, Any, programlib.Program,
                         Callable | None]] = []
        for i, op in enumerate(self.ops):
            plan = cache.plan(op.gemm, self.cfg)
            act_name = op.activation or "none"
            fn = ACTIVATIONS.get(act_name)
            in_program = fn is not None and (
                act_name not in programlib.ROW_WISE_ACTIVATIONS
                or (plan.choice.df == isa.Dataflow.WOS
                    and plan.program.n_n == 1))
            prog = cache.lower(
                plan.gemm, plan.choice, self.cfg,
                activation=fn if in_program else None,
                act_name=act_name if in_program else "none",
                out_name=f"O{i}")
            base.append((op, plan, prog,
                         None if in_program else fn))

        steps: list[Step] = []
        segment: list[tuple] = []
        modes: list[str] = []

        def flush():
            if not segment:
                return
            progs = [e[2] for e in segment]
            # on-chip commit / input elision is per-array machine state;
            # a mesh-sharded stream keeps every layer's host round trip
            # ('wired' steps feed the producer's output back as 'I')
            if len(progs) > 1 and self.mesh is None:
                # chain each maximal wired sub-run; interior adapt
                # boundaries keep their host-shaped input Program (the
                # fused kernel lowers the shape glue to an in-kernel
                # slab permutation; the per-step fallback adapts
                # host-side)
                chained: list = []
                start = 0
                for i in range(1, len(progs) + 1):
                    if i == len(progs) or modes[i] != "wired":
                        sub = progs[start:i]
                        chained.extend(
                            programlib.chain(sub, lower_fn=cache.lower)
                            if len(sub) > 1 else sub)
                        start = i
                progs = chained
            first = len(steps)
            shardeds = []
            for (op, _, _, host_act), prog, mode in zip(segment, progs,
                                                        modes):
                sharded = (self.cache.sharded(prog, self.mesh)
                           if self.mesh is not None else None)
                shardeds.append(sharded)
                steps.append(Step(index=len(steps), op=op, program=prog,
                                  input_mode=mode, host_act=host_act,
                                  reps=max(1, getattr(op.gemm, "count", 1)),
                                  sharded=sharded))
            fused = None
            if len(progs) > 1:
                if self.mesh is None:
                    # interior adapt boundaries fuse as in-kernel
                    # permutations; the FIRST step's adapt (if any) is
                    # applied host-side to the segment input
                    adapts = (False,) + tuple(
                        m == "adapt" for m in modes[1:])
                    fused = self._fuse_with_tuned(progs, adapts)
                elif all(s is not None for s in shardeds):
                    # fuse WITHIN each array: legal when the whole run
                    # is M-sharded with aligned rows (mesh segments
                    # contain only wired sub-runs by construction)
                    fused = programlib.fuse_sharded_segment(shardeds)
            self.segments.append(
                Segment(indices=list(range(first, len(steps))),
                        fused=fused))
            segment.clear()
            modes.clear()

        prev: tuple[GemmOp, Callable | None] | None = None
        for entry in base:
            op, _, _, host_act = entry
            g = op.gemm
            wired = (prev is not None and op.chained
                     and prev[1] is None       # host act breaks the chain
                     and (prev[0].gemm.m, prev[0].gemm.n) == (g.m, g.k))
            # a chained shape break (head split/merge) no longer flushes:
            # the segment continues across the adapt boundary and the
            # fused kernel swallows the reshape (single-array streams
            # only -- per-array residency stops at the mesh boundary)
            adaptable = (not wired and prev is not None and op.chained
                         and prev[1] is None and self.mesh is None)
            if not (wired or adaptable):
                flush()
            segment.append(entry)
            modes.append("wired" if wired
                         else "adapt" if (op.chained and prev is not None)
                         else "fresh")
            prev = (op, host_act)
        flush()
        return steps

    def _fuse_with_tuned(self, progs,
                         adapts: tuple[bool, ...] | None = None):
        """Fused launch geometry for a chained run, preferring a
        measured autotune winner (the ProgramCache tuned tier --
        ``runtime.autotune``) over the greedy-then-snap default: a
        serving process sharing a warmed cache consumes the tuned
        geometry at build time, with zero searches and zero re-tuning."""
        fused = programlib.fuse_segment(progs, adapts=adapts)
        if fused is None:
            return None
        tg = self.cache.tuned_geometry(progs, adapts=adapts)
        if tg is not None:
            tuned = programlib.fuse_segment(
                progs, adapts=adapts, bm=tg.bm, layer_bks=tg.layer_bks)
            if tuned is not None:
                return tuned
        return fused

    # -- tensor environment ---------------------------------------------------
    def tensor_specs(self) -> dict[str, tuple[tuple[int, int], str]]:
        """name -> (shape, kind); kind in {'weight', 'dynamic', 'input'}.
        ``dynamic`` marks runtime-supplied operands (attention K^T / V)."""
        specs: dict[str, tuple[tuple[int, int], str]] = {}
        for s in self.steps:
            g = s.op.gemm
            specs[s.weight_name] = ((g.k, g.n),
                                    "dynamic" if s.op.dynamic else "weight")
            if s.input_mode == "fresh":
                specs[s.input_name] = ((g.m, g.k), "input")
        return specs

    def make_tensors(self, seed: int = 0,
                     kinds: tuple[str, ...] = ("weight", "dynamic", "input")
                     ) -> dict[str, np.ndarray]:
        """Seeded host tensors; weights scaled 1/sqrt(k) so chained layer
        magnitudes stay O(1) across the stream."""
        rng = np.random.default_rng(seed)
        out: dict[str, np.ndarray] = {}
        for name, (shape, kind) in self.tensor_specs().items():
            arr = rng.standard_normal(shape).astype(np.float32)
            if kind != "input":
                arr /= np.sqrt(shape[0])
            if kind in kinds:
                out[name] = arr
        return out

    def inputs_from(self, x: np.ndarray) -> dict[str, np.ndarray]:
        """Fresh-input tensors derived from a carrier array (the serving
        scheduler feeds each decode step from the previous step's
        output)."""
        return {s.input_name: adapt(x, s.op.gemm.m, s.op.gemm.k)
                for s in self.steps if s.input_mode == "fresh"}

    # -- execution ------------------------------------------------------------
    def make_backend(self, backend):
        from repro import backends as backendlib
        kwargs = {}
        if backend == "pallas":
            kwargs["compile_cache"] = self.cache
        return backendlib.get_backend(backend, self.cfg, **kwargs)

    def run(self, backend="interpreter", *,
            tensors: dict[str, np.ndarray] | None = None, seed: int = 0,
            check: bool = False, rtol: float = 2e-3,
            atol: float = 2e-3, fused: bool = False) -> RunResult:
        """Execute the stream end-to-end.

        ``backend`` is a registry name or a live ``Backend`` instance (the
        scheduler reuses one across requests).  ``tensors`` supplies any
        subset of :meth:`tensor_specs`; missing entries are seeded.
        ``check=True`` asserts every step against the einsum-oracle replay
        of the identical stream.

        ``fused=True`` executes every fusion-legal segment as ONE backend
        kernel launch (``Backend.run_segment``): interior activations stay
        on-chip, so interior steps report ``None`` in ``outputs``; the
        oracle check still verifies every fused segment's final output
        against the step-by-step einsum replay.  Segments without a fused
        form (single steps, adapt boundaries, sharded streams, non-fusable
        activations) take the per-Program path unchanged."""
        be = backend if not isinstance(backend, str) \
            else self.make_backend(backend)
        env = dict(tensors) if tensors else {}
        for name, arr in self.make_tensors(seed).items():
            env.setdefault(name, arr)

        prev: np.ndarray | None = None
        ref_prev: np.ndarray | None = None
        outputs: list[np.ndarray | None] = [None] * len(self.steps)
        n_fused = 0

        def seg_input(first: Step, carrier, env):
            g = first.op.gemm
            if first.input_mode == "fresh":
                return env[first.input_name]
            if first.input_mode == "adapt":
                return adapt(carrier, g.m, g.k)
            return carrier

        for seg in self.segments:
            steps = [self.steps[i] for i in seg.indices]
            if fused and seg.fused is not None:
                first, last = steps[0], steps[-1]
                t = {"I": np.asarray(seg_input(first, prev, env),
                                     np.float32)}
                for j, s in enumerate(steps):
                    t[f"W{j}"] = env[s.weight_name]
                with trace.span("segment", kind="fused",
                                n_steps=len(steps),
                                first=steps[0].index):
                    out = np.asarray(
                        be.run_segment(seg.fused, t)[seg.fused.out_name])
                if last.host_act is not None:
                    out = np.asarray(last.host_act(out))
                if check:
                    ref = np.asarray(seg_input(first, ref_prev, env),
                                     np.float32)
                    for j, s in enumerate(steps):
                        if j > 0 and s.input_mode == "adapt":
                            ref = adapt(ref, s.op.gemm.m, s.op.gemm.k)
                        ref = ref.astype(np.float32) @ env[s.weight_name]
                        if s.program.activation is not None:
                            ref = np.asarray(s.program.activation(ref))
                        if s.host_act is not None:
                            ref = np.asarray(s.host_act(ref))
                    k_max = max(s.op.gemm.k for s in steps)
                    np.testing.assert_allclose(
                        out, ref, rtol=rtol, atol=atol + rtol * k_max,
                        err_msg=(f"fused segment at steps {seg.indices} "
                                 f"diverged from the stream oracle"))
                    ref_prev = ref
                outputs[last.index] = out
                prev = out
                n_fused += 1
                continue
            for s in steps:
                g = s.op.gemm
                w = env[s.weight_name]
                t: dict[str, np.ndarray] = {"W": w}
                if s.input_mode == "fresh":
                    t["I"] = env[s.input_name]
                elif s.input_mode == "adapt":
                    t["I"] = adapt(prev, g.m, g.k)
                elif s.input_mode == "wired" and s.sharded is not None:
                    # sharded streams do not chain on-chip: the producer's
                    # output crosses the host boundary explicitly
                    t["I"] = prev
                with trace.span("segment", kind="per_step", step=s.index):
                    out = np.asarray(
                        be.run_program(s.sharded if s.sharded is not None
                                       else s.program, t)
                        [s.program.out_name])
                if s.host_act is not None:
                    out = np.asarray(s.host_act(out))
                if check:
                    if s.input_mode == "fresh":
                        ref_x = env[s.input_name]
                    elif s.input_mode == "adapt":
                        ref_x = adapt(ref_prev, g.m, g.k)
                    else:
                        ref_x = ref_prev
                    ref = ref_x.astype(np.float32) @ w
                    if s.program.activation is not None:
                        ref = np.asarray(s.program.activation(ref))
                    if s.host_act is not None:
                        ref = np.asarray(s.host_act(ref))
                    np.testing.assert_allclose(
                        out, ref, rtol=rtol, atol=atol + rtol * g.k,
                        err_msg=(f"step {s.index} ({g.name or g}) diverged "
                                 f"from the stream oracle"))
                    ref_prev = ref
                outputs[s.index] = out
                prev = out
        return RunResult(outputs=outputs, final=prev, checked=check,
                         fused_segments=n_fused)

    # -- cross-request batched execution (M-polymorphic segments) -------------
    def batch_plan(self, n_requests: int) -> BatchPlan:
        """The M-polymorphic plan serving ``n_requests`` stacked rows.

        Bucketed to :func:`program.m_bucket` so thousands of batch
        compositions share a handful of compiled artifacts; plans are
        memoised per bucket and their Programs flow through the shared
        ProgramCache like every other lowering."""
        if self.mesh is not None:
            raise ValueError("batched decode requires a single-array "
                             "stream; mesh-sharded streams schedule "
                             "per-request")
        bucket = programlib.m_bucket(n_requests)
        plan = self._batch_plans.get(bucket)
        if plan is None:
            with trace.span("executable.batch_plan", bucket=bucket,
                            n_requests=n_requests):
                plan = self._build_batch_plan(bucket)
            self._batch_plans[bucket] = plan
        return plan

    def _build_batch_plan(self, bucket: int) -> BatchPlan:
        """Re-lower every static segment at m = bucket * m_rows.

        Each bucketed GEMM reuses the base step's *own* MappingChoice
        (``snap_tiling`` clips the M tile; K tiling is untouched), so the
        per-row accumulation order matches the per-request path -- the
        batched numbers stay on the sequential trajectory.  In-program
        activation legality survives the re-lowering: elementwise acts
        are M-independent and row-wise acts were only in-program under
        WO-S with full output rows, which bucketing preserves."""
        cache = self.cache
        segs: list[BatchSegment] = []
        runs: list[list[int]] = []
        for seg in self.segments:
            # Stacked-batch flattening cannot cross an interior adapt
            # boundary (the flatten/cycle glue would mix requests' rows)
            # or a dynamic<->static transition, so fused segments that
            # span them re-split here into batchable sub-runs -- the
            # pre-streaming segment granularity.
            run: list[int] = []
            for i in seg.indices:
                s = self.steps[i]
                if run and (s.input_mode == "adapt"
                            or s.op.dynamic != self.steps[run[-1]].op.dynamic):
                    runs.append(run)
                    run = []
                run.append(i)
            if run:
                runs.append(run)
        for idx in runs:
            steps = [self.steps[i] for i in idx]
            m_rows = steps[0].op.gemm.m
            if any(s.op.dynamic for s in steps):
                if (len(steps) == 2 and all(s.op.dynamic for s in steps)
                        and steps[0].program.act_name == "softmax"
                        and steps[0].host_act is None
                        and steps[1].input_mode == "wired"
                        and steps[1].program.act_name == "none"):
                    segs.append(BatchSegment(
                        kind="attention", indices=idx,
                        programs=[steps[0].program, steps[1].program],
                        host_act=steps[-1].host_act, m_rows=m_rows))
                else:
                    segs.append(BatchSegment(kind="perreq", indices=idx,
                                             programs=[]))
                continue
            try:
                progs = []
                for s in steps:
                    bg = programlib.bucketed_gemm(s.op.gemm, bucket)
                    progs.append(cache.lower(
                        bg, s.program.choice, self.cfg,
                        activation=s.program.activation,
                        act_name=s.program.act_name,
                        out_name=s.program.out_name))
                fused = None
                if len(progs) > 1:
                    progs = programlib.chain(progs, lower_fn=cache.lower)
                    fused = self._fuse_with_tuned(progs)
            except ValueError:
                segs.append(BatchSegment(kind="perreq", indices=idx,
                                         programs=[]))
                continue
            segs.append(BatchSegment(kind="static", indices=idx,
                                     programs=list(progs), fused=fused,
                                     host_act=steps[-1].host_act,
                                     m_rows=m_rows))
        return BatchPlan(bucket=bucket, segments=segs)

    def run_batch(self, backend, envs: list[dict[str, np.ndarray]], *,
                  lengths=None, fused: bool = True) -> list[np.ndarray]:
        """Advance EVERY request one step with one launch per segment.

        ``envs`` carries one tensor dict per request (static weights are
        identical across requests by construction; dynamic KV operands
        and fresh inputs are per-request).  ``lengths`` are the
        per-request true KV widths for the attention segment.  Returns
        the per-request final carriers, each bit-comparable (modulo the
        stabilised-recurrence regime) to a sequential :meth:`run`.
        """
        be = backend if not isinstance(backend, str) \
            else self.make_backend(backend)
        n = len(envs)
        plan = self.batch_plan(n)
        prevs: list[np.ndarray | None] = [None] * n
        for bseg in plan.segments:
            steps = [self.steps[i] for i in bseg.indices]
            first = steps[0]
            g = first.op.gemm
            if bseg.kind == "perreq":
                with trace.span("batch_segment", kind="perreq",
                                n_steps=len(steps), batch=n):
                    for r in range(n):
                        prevs[r] = self._run_steps_perreq(
                            be, steps, envs[r], prevs[r])
                continue
            xs = []
            for r in range(n):
                if first.input_mode == "fresh":
                    xs.append(np.asarray(envs[r][first.input_name],
                                         np.float32))
                elif first.input_mode == "adapt":
                    xs.append(adapt(prevs[r], g.m, g.k))
                else:          # 'wired' never starts a segment
                    xs.append(np.asarray(prevs[r], np.float32))
            if bseg.kind == "attention":
                kT = np.stack([np.asarray(envs[r][steps[0].weight_name],
                                          np.float32) for r in range(n)])
                v = np.stack([np.asarray(envs[r][steps[1].weight_name],
                                         np.float32) for r in range(n)])
                with trace.span("batch_segment", kind="attention",
                                batch=n):
                    out = be.run_batched_attention(
                        tuple(bseg.programs), np.stack(xs), kT, v, lengths)
                outs = [np.asarray(out[r]) for r in range(n)]
                if bseg.host_act is not None:
                    outs = [np.asarray(bseg.host_act(o)) for o in outs]
                prevs = outs
                continue
            # static: stack along M, zero-pad to the bucket, one launch
            m_rows = bseg.m_rows
            X = np.concatenate(xs, axis=0)
            if n < plan.bucket:
                X = np.concatenate(
                    [X, np.zeros(((plan.bucket - n) * m_rows, X.shape[1]),
                                 np.float32)], axis=0)
            if fused and bseg.fused is not None:
                t = {"I": X}
                for j, s in enumerate(steps):
                    t[f"W{j}"] = envs[0][s.weight_name]
                with trace.span("batch_segment", kind="static_fused",
                                n_steps=len(steps), batch=n,
                                bucket=plan.bucket):
                    out = np.asarray(
                        be.run_segment(bseg.fused, t)[bseg.fused.out_name])
            else:
                with trace.span("batch_segment", kind="static",
                                n_steps=len(steps), batch=n,
                                bucket=plan.bucket):
                    out = X
                    for j, (s, prog) in enumerate(zip(steps,
                                                      bseg.programs)):
                        t = {"W": envs[0][s.weight_name]}
                        if j == 0:
                            t["I"] = X
                        out = np.asarray(be.run_program(prog, t)
                                         [prog.out_name])
            out = out[:n * m_rows]
            if bseg.host_act is not None:
                out = np.asarray(bseg.host_act(out))
            prevs = [out[r * m_rows:(r + 1) * m_rows] for r in range(n)]
        return prevs

    def _run_steps_perreq(self, be, steps, env, prev):
        """Sequential replay of one segment for one request (the batched
        plan's fallback; numerics identical to :meth:`run`'s per-Program
        path)."""
        for s in steps:
            g = s.op.gemm
            t: dict[str, np.ndarray] = {"W": env[s.weight_name]}
            if s.input_mode == "fresh":
                t["I"] = env[s.input_name]
            elif s.input_mode == "adapt":
                t["I"] = adapt(prev, g.m, g.k)
            out = np.asarray(be.run_program(s.program, t)
                             [s.program.out_name])
            if s.host_act is not None:
                out = np.asarray(s.host_act(out))
            prev = out
        return prev

    # -- accounting (the same tile streams perf.simulate consumes) ------------
    @property
    def n_arrays(self) -> int:
        return self.mesh.n_arrays if self.mesh is not None else 1

    def perf_stats(self) -> dict[str, float]:
        """Aggregate MINISA vs micro traffic + stall fractions over the
        stream, ``reps``-weighted; simulated once per unique Program.

        On a mesh, per-GEMM cycles are the slowest array's (arrays run
        in parallel), instruction bytes sum over arrays, and the
        per-array breakdowns plus ``load_imbalance`` join the dict."""
        n_arrays = self.n_arrays
        tot = {"minisa_bytes": 0.0, "micro_bytes": 0.0,
               "cycles_minisa": 0.0, "cycles_micro": 0.0,
               "stall_cycles_minisa": 0.0, "stall_cycles_micro": 0.0,
               "macs": 0.0, "n_gemms": 0.0}
        per_bytes = [0.0] * n_arrays
        per_cycles = [0.0] * n_arrays
        for s in self.steps:
            key = id(s.sharded if s.sharded is not None else s.program)
            if key not in self._perf_cache:
                if s.sharded is not None:
                    pm = perf.simulate_sharded(s.sharded, self.cfg,
                                               "minisa")
                    pu = perf.simulate_sharded(s.sharded, self.cfg,
                                               "micro")
                    mb = s.sharded.minisa_bytes()
                    arr_b = s.sharded.per_array_minisa_bytes()
                    arr_c = [r.cycles for r in pm.per_array]
                else:
                    pm = perf.simulate(s.program.tile_costs("minisa"),
                                       self.cfg)
                    pu = perf.simulate(s.program.tile_costs("micro"),
                                       self.cfg)
                    mb = s.program.minisa_bytes()
                    arr_b = [mb]
                    arr_c = [pm.cycles]
                self._perf_cache[key] = (
                    pm, pu, mb, s.program.micro_storage_bytes(),
                    arr_b, arr_c)
            pm, pu, mb, ub, arr_b, arr_c = self._perf_cache[key]
            r = s.reps
            tot["minisa_bytes"] += mb * r
            tot["micro_bytes"] += ub * r
            tot["cycles_minisa"] += pm.cycles * r
            tot["cycles_micro"] += pu.cycles * r
            tot["stall_cycles_minisa"] += pm.stall_ifetch_frac * pm.cycles * r
            tot["stall_cycles_micro"] += pu.stall_ifetch_frac * pu.cycles * r
            tot["macs"] += s.op.gemm.macs * r
            tot["n_gemms"] += r
            for i in range(min(len(arr_b), n_arrays)):
                per_bytes[i] += arr_b[i] * r
                per_cycles[i] += arr_c[i] * r
        tot["stall_minisa"] = (tot["stall_cycles_minisa"]
                               / max(tot["cycles_minisa"], 1e-9))
        tot["stall_micro"] = (tot["stall_cycles_micro"]
                              / max(tot["cycles_micro"], 1e-9))
        tot["instr_reduction"] = (tot["micro_bytes"]
                                  / max(tot["minisa_bytes"], 1e-9))
        tot["n_arrays"] = n_arrays
        tot["per_array_minisa_bytes"] = per_bytes
        tot["per_array_cycles_minisa"] = per_cycles
        tot["load_imbalance"] = perf.load_imbalance(per_cycles)
        return tot

    def fusion_stats(self) -> dict:
        """Modelled traffic and cycles of the stream under per-layer vs
        fused execution, ``reps``-weighted.

        Two layers of accounting, matching the two execution realities:
        ``cycles_*`` come from the machine-model tile streams (interior
        elision applied for fused segments -- ``FusedSegment.tile_costs``),
        while ``hbm_bytes_*`` are *kernel-launch* traffic: per-layer
        launches round-trip every interior activation through HBM, the
        fused launch ships only the segment input, the weights and the
        final output.  ``hbm_bytes_elided`` is exactly the difference --
        what the fused kernels keep on-chip.

        Depends only on the (immutable) step/segment structure, so the
        result is computed once and cached."""
        if self._fusion_stats is not None:
            return dict(self._fusion_stats)
        out = {"n_segments": len(self.segments),
               "n_fused_segments": 0, "n_fused_steps": 0,
               "hbm_bytes_per_layer": 0.0, "hbm_bytes_fused": 0.0,
               "cycles_per_layer": 0.0, "cycles_fused": 0.0}
        elem = self.cfg.elem_bytes
        for seg in self.segments:
            steps = [self.steps[i] for i in seg.indices]
            if seg.fused is not None:
                out["n_fused_segments"] += 1
                out["n_fused_steps"] += len(steps)
            for pos, s in enumerate(steps):
                g = s.op.gemm
                plain = s.program.tile_costs("minisa")
                r = s.reps
                res = perf.simulate(plain, self.cfg)
                launch = elem * (g.m * g.k + g.k * g.n + g.m * g.n)
                out["hbm_bytes_per_layer"] += r * launch
                out["cycles_per_layer"] += r * res.cycles
                if seg.fused is not None:
                    fused_costs = seg.fused.layer_tile_costs(pos)
                    fres = perf.simulate(fused_costs, self.cfg)
                    if isinstance(seg.fused, programlib.FusedSegment):
                        # weights ship K-padded, once per M step of the
                        # streamed launch (kernel_hbm_bytes semantics)
                        fused_launch = elem * (seg.fused.m_steps
                                               * seg.fused.padded_ks[pos]
                                               * g.n)
                    else:
                        fused_launch = elem * (g.k * g.n)
                    if pos == 0:
                        fused_launch += elem * g.m * g.k    # segment input
                    if pos == len(steps) - 1:
                        fused_launch += elem * g.m * g.n    # final output
                    out["hbm_bytes_fused"] += r * fused_launch
                    out["cycles_fused"] += r * fres.cycles
                else:
                    out["hbm_bytes_fused"] += r * launch
                    out["cycles_fused"] += r * res.cycles
        out["hbm_bytes_elided"] = (out["hbm_bytes_per_layer"]
                                   - out["hbm_bytes_fused"])
        self._fusion_stats = out
        return dict(out)

    def describe(self) -> dict:
        return {
            "name": self.name,
            "n_steps": len(self.steps),
            "n_segments": len(self.segments),
            "n_fused_segments": sum(1 for s in self.segments
                                    if s.fused is not None),
            "n_gemms": int(sum(s.reps for s in self.steps)),
            "n_dynamic": sum(1 for s in self.steps if s.op.dynamic),
            "n_wired": sum(1 for s in self.steps
                           if s.input_mode == "wired"),
            "n_elided": sum(1 for s in self.steps
                            if s.program.input_elided),
            "n_arrays": self.n_arrays,
            "n_sharded": sum(1 for s in self.steps
                             if s.sharded is not None),
        }
