"""Decoder-only transformer LM assembly (dense / MoE / MLA variants), with
scanned layer stacks + remat (required to keep 80-layer dry-run HLO small
and activation memory bounded).

Layer caches are pytrees stacked along the layer axis and threaded through
the same lax.scan that runs the layers.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common, moe as moelib
from repro.models.common import Maker
from repro.models.mlp import mlp, mlp_params


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def _layer_params(mk: Maker, cfg, dense_ff: int | None = None) -> dict:
    p = {"ln_attn": common.rmsnorm_params(mk, cfg.d_model),
         "ln_mlp": common.rmsnorm_params(mk, cfg.d_model)}
    if cfg.mla:
        p["attn"] = attn.mla_params(mk, cfg)
    else:
        p["attn"] = attn.gqa_params(mk, cfg)
    if dense_ff is not None:
        p["mlp"] = mlp_params(mk, cfg.d_model, dense_ff, cfg.mlp_act)
    elif cfg.moe_enabled:
        p["moe"] = moelib.moe_params(
            mk, cfg.d_model, cfg.moe_d_ff, cfg.num_experts, cfg.mlp_act,
            num_shared=cfg.num_shared_experts, shared_d_ff=cfg.shared_d_ff)
    else:
        p["mlp"] = mlp_params(mk, cfg.d_model, cfg.d_ff, cfg.mlp_act)
    return p


def stacked_params(cfg, n: int, fn, mk: Maker):
    """Stack n copies of fn(mk) along a leading 'layers' axis."""
    if mk.mode == "axes":
        sub = fn(Maker(mode="axes"))
        return jax.tree.map(lambda a: ("layers",) + a, sub,
                            is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(mk._next_key(), n)

    def one(key):
        return fn(Maker(mode="init", key=key, dtype=mk.dtype))

    return jax.vmap(one)(keys)


def decoder_params(mk: Maker, cfg) -> dict:
    p = {"embed": common.embed_params(mk, cfg.vocab_size, cfg.d_model),
         "ln_f": common.rmsnorm_params(mk, cfg.d_model)}
    n_scan = cfg.num_layers - cfg.first_k_dense
    if cfg.first_k_dense:
        p["dense_layers"] = [
            _layer_params(mk, cfg, dense_ff=cfg.dense_d_ff)
            for _ in range(cfg.first_k_dense)]
    p["layers"] = stacked_params(
        cfg, n_scan, lambda m: _layer_params(m, cfg), mk)
    if not cfg.tie_embeddings:
        p["head"] = {"w": mk.param((cfg.d_model, cfg.vocab_size),
                                   ("embed", "vocab"), scale=0.02)}
    return p


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _apply_layer(p, cfg, x, positions, mode: str, cache, position_idx,
                 dense: bool = False):
    """mode: 'train' | 'prefill' | 'decode'."""
    from repro.dist.sharding import constrain_batch
    x = constrain_batch(x)
    h = common.rmsnorm(p["ln_attn"], x)
    if mode == "decode":
        if cfg.mla:
            a, new_cache = attn.mla_decode_attention(
                p["attn"], cfg, h, cache[0], cache[1], position_idx)
        else:
            a, new_cache = attn.gqa_decode_attention(
                p["attn"], cfg, h, cache[0], cache[1], position_idx)
    else:
        if cfg.mla:
            a, new_cache = attn.mla_self_attention(
                p["attn"], cfg, h, positions, causal=True)
        else:
            a, new_cache = attn.gqa_self_attention(
                p["attn"], cfg, h, positions, causal=True)
    x = x + a
    h = common.rmsnorm(p["ln_mlp"], x)
    aux = jnp.zeros((), jnp.float32)
    if dense or not cfg.moe_enabled:
        f = mlp(p["mlp"], h, cfg.mlp_act)
    else:
        f, aux = moelib.moe(
            p["moe"], h, num_experts=cfg.num_experts,
            top_k=cfg.experts_per_token, kind=cfg.mlp_act,
            capacity_factor=cfg.capacity_factor)
    x = x + f
    return x, new_cache, aux


def decoder_forward(params, cfg, tokens, mode: str = "train",
                    cache=None, position_idx=None, prefix_embeds=None,
                    remat: bool = True):
    """Returns (logits, new_cache, aux_loss).

    tokens: [B, S] (S == 1 for decode).
    cache: stacked per-layer cache pytree or None.
    position_idx: [B] decode positions.
    prefix_embeds: [B, P, d] multimodal prefix (vlm), prepended in
    train/prefill mode.
    """
    x = common.embed(params["embed"], tokens)
    if prefix_embeds is not None and mode != "decode":
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    if mode == "decode" and position_idx is not None:
        positions = position_idx[:, None]

    aux_total = jnp.zeros((), jnp.float32)
    n_dense = cfg.first_k_dense
    dense_caches = []
    if n_dense:
        for i, lp in enumerate(params["dense_layers"]):
            c = None if cache is None else jax.tree.map(
                lambda a: a[i], cache["dense"])
            x, nc, aux = _apply_layer(p=lp, cfg=cfg, x=x,
                                      positions=positions, mode=mode,
                                      cache=c, position_idx=position_idx,
                                      dense=True)
            dense_caches.append(nc)
            aux_total = aux_total + aux

    def body(carry, xs):
        x, aux_acc = carry
        lp, c = xs
        x, nc, aux = _apply_layer(p=lp, cfg=cfg, x=x, positions=positions,
                                  mode=mode, cache=c,
                                  position_idx=position_idx)
        return (x, aux_acc + aux), nc

    if remat and mode == "train":
        import os
        # §Perf iteration 8: 'dots' policy saves matmul outputs instead of
        # recomputing every projection in the backward scan
        if os.environ.get("REPRO_REMAT_DOTS"):
            body_fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies
                .dots_with_no_batch_dims_saveable)
        else:
            body_fn = jax.checkpoint(body)
    else:
        body_fn = body
    scan_cache = None if cache is None else cache["layers"]
    n_scan = cfg.num_layers - n_dense
    if scan_cache is None:
        # provide a dummy None-cache stream via a zero-length pytree
        (x, aux_total), new_scan_cache = jax.lax.scan(
            lambda carry, lp: body_fn(carry, (lp, None)),
            (x, aux_total), params["layers"])
    else:
        (x, aux_total), new_scan_cache = jax.lax.scan(
            body_fn, (x, aux_total), (params["layers"], scan_cache))

    x = common.rmsnorm(params["ln_f"], x)
    if cfg.tie_embeddings:
        logits = common.unembed(params["embed"], x)
    else:
        logits = x @ params["head"]["w"].astype(x.dtype)

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"layers": new_scan_cache}
        if n_dense:
            new_cache["dense"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *dense_caches) \
                if len(dense_caches) > 1 else jax.tree.map(
                    lambda a: a[None], dense_caches[0])
    return logits, new_cache, aux_total
