"""Mixture-of-Experts with sort-based grouped GEMM dispatch.

Design (DESIGN.md §6 + §Perf iteration 6):

  * sort-based dispatch (MegaBlocks-style) instead of the GShard one-hot
    dispatch tensor: O(topk * T * d) memory instead of O(T * E * C);
  * dispatch is ROW-LOCAL: routing/sort/capacity run per batch row, so
    every dispatch tensor keeps the leading batch dim and stays sharded
    over the data axes.  A global-token formulation makes the scatter
    target cross-shard and XLA lowers it to per-layer all-reduces of the
    full (E, C, d) buffer -- measured 7.7 TB/device on deepseek-v2
    prefill_32k before this restructure;
  * the (B, E, C, d) buffer is anchored to (batch->data, experts->model),
    so the expert GEMM is a local einsum under expert parallelism when E
    divides the model axis (deepseek 160), falling back to TP-inside-
    expert otherwise (granite 40).

Capacity: C = ceil(S * topk / E * capacity_factor) per row; overflow drops
(combine weight zero), underflow slots are zero -- standard capacity
semantics, applied per row.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import Maker, activation
from repro.models.mlp import GATED


def moe_params(mk: Maker, d_model: int, d_ff: int, num_experts: int,
               kind: str, num_shared: int = 0, shared_d_ff: int = 0) -> dict:
    e = num_experts
    p = {
        "router": mk.param((d_model, e), ("embed", "experts"), scale=0.02),
    }
    if kind in GATED:
        p["w_gate"] = mk.param((e, d_model, d_ff),
                               ("experts", "embed", "expert_ffn"))
    p["w_up"] = mk.param((e, d_model, d_ff),
                         ("experts", "embed", "expert_ffn"))
    p["w_down"] = mk.param((e, d_ff, d_model),
                           ("experts", "expert_ffn", "embed"))
    if num_shared:
        sf = shared_d_ff or d_ff * num_shared
        p["shared"] = {
            "w_gate": mk.param((d_model, sf), ("embed", "ffn")),
            "w_up": mk.param((d_model, sf), ("embed", "ffn")),
            "w_down": mk.param((sf, d_model), ("ffn", "embed")),
        }
    return p


def _expert_ffn(p, xs, kind: str):
    """xs: [B, E, C, d] -> [B, E, C, d] through each expert's FFN."""
    if kind in GATED:
        act = activation(GATED[kind])
        h = act(jnp.einsum("becd,edf->becf", xs, p["w_gate"].astype(xs.dtype)))
        h = h * jnp.einsum("becd,edf->becf", xs, p["w_up"].astype(xs.dtype))
    else:
        act = activation(kind)
        h = act(jnp.einsum("becd,edf->becf", xs, p["w_up"].astype(xs.dtype)))
    return jnp.einsum("becf,efd->becd", h, p["w_down"].astype(xs.dtype))


def _route_row(gate_idx, num_experts: int, cap: int):
    """Per-row routing bookkeeping.

    gate_idx: [S, k] expert ids.  Returns (slot [S*k], keep [S*k],
    token [S*k]) where slot indexes an (E * cap) buffer.
    """
    s, k = gate_idx.shape
    flat_expert = gate_idx.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(s), k)
    order = jnp.argsort(flat_expert)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    counts = jnp.bincount(flat_expert, length=num_experts)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(s * k) - starts[sorted_expert]
    keep = pos < cap
    slot = sorted_expert * cap + jnp.where(keep, pos, 0)
    return order, sorted_token, slot, keep


def moe(p, x, *, num_experts: int, top_k: int, kind: str,
        capacity_factor: float = 1.25, router_softmax: bool = True):
    """x: [B, S, d] -> ([B, S, d], aux_loss)."""
    from repro.dist.sharding import constrain_batch

    b, s, d = x.shape
    logits = (x.astype(jnp.float32)
              @ p["router"].astype(jnp.float32))                 # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)            # [B, S, k]
    if router_softmax:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = int(math.ceil(s * top_k / num_experts * capacity_factor))
    cap = max(cap, min(s * top_k, 8), 1)

    order, sorted_token, slot, keep = jax.vmap(
        lambda gi: _route_row(gi, num_experts, cap))(gate_idx)
    sorted_gate = jnp.take_along_axis(
        gate_vals.reshape(b, -1), order, axis=1)

    # dispatch: [B, E*cap, d], batch-sharded, experts EP-sharded
    vals = jnp.take_along_axis(
        x, sorted_token[..., None], axis=1)                      # [B, S*k, d]
    vals = jnp.where(keep[..., None], vals, 0)
    scatter_idx = jnp.where(keep, slot, num_experts * cap - 1)
    # vmapped scatter: keeps the batch dim a true HLO batch dimension so
    # GSPMD preserves data-sharding (an explicit [bidx, idx] scatter made
    # the indices span the global batch and XLA replicated the buffer --
    # §Perf iteration 6c)
    buf = jax.vmap(
        lambda idx_r, val_r: jnp.zeros(
            (num_experts * cap, d), x.dtype).at[idx_r].add(val_r)
    )(scatter_idx, vals)
    buf = buf.reshape(b, num_experts, cap, d)
    buf = constrain_batch(buf)

    out_buf = _expert_ffn(p, buf, kind)
    out_buf = constrain_batch(out_buf)
    out_buf = out_buf.reshape(b, num_experts * cap, d)

    # combine: gather back, weight by gate, scatter-add to tokens
    gathered = jnp.take_along_axis(out_buf, slot[..., None], axis=1)
    gathered = gathered * (sorted_gate * keep)[..., None].astype(x.dtype)
    out = jax.vmap(
        lambda tok_r, g_r: jnp.zeros((s, d), x.dtype).at[tok_r].add(g_r)
    )(sorted_token, gathered)

    if "shared" in p:
        act = activation(GATED.get(kind, "silu"))
        sh = p["shared"]
        xt = x.reshape(b * s, d)
        hs = act(xt @ sh["w_gate"].astype(x.dtype)) * (
            xt @ sh["w_up"].astype(x.dtype))
        out = out + (hs @ sh["w_down"].astype(x.dtype)).reshape(b, s, d)

    # load-balancing aux loss (Switch-style), computed globally
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((num_experts,), jnp.float32).at[
        gate_idx.reshape(-1)].add(1.0) / (b * s * top_k)
    aux = num_experts * jnp.sum(me * ce)
    return out, aux
