"""Feed-forward blocks: gated (SwiGLU/GeGLU) and plain MLPs."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.common import Maker, activation


GATED = {"swiglu": "silu", "geglu": "gelu"}


def mlp_params(mk: Maker, d_model: int, d_ff: int, kind: str) -> dict:
    if kind in GATED:
        return {
            "w_gate": mk.param((d_model, d_ff), ("embed", "ffn")),
            "w_up": mk.param((d_model, d_ff), ("embed", "ffn")),
            "w_down": mk.param((d_ff, d_model), ("ffn", "embed")),
        }
    return {
        "w_up": mk.param((d_model, d_ff), ("embed", "ffn")),
        "w_down": mk.param((d_ff, d_model), ("ffn", "embed")),
    }


def mlp(p, x, kind: str):
    if kind in GATED:
        act = activation(GATED[kind])
        h = act(x @ p["w_gate"].astype(x.dtype)) * (x @ p["w_up"].astype(x.dtype))
    else:
        act = activation(kind)
        h = act(x @ p["w_up"].astype(x.dtype))
    return h @ p["w_down"].astype(x.dtype)
