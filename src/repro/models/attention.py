"""Attention variants: GQA/MQA (optionally biased), blockwise (online-
softmax) attention for long prefill, sequence-sharded flash-decode, and
DeepSeek-style MLA with an absorbed latent-cache decode path.

Memory discipline (needed for the 32k prefill / 32k-500k decode dry-runs):

  * train/short prefill: plain masked attention (best compile time);
  * long prefill (> BLOCKWISE_THRESHOLD): lax.scan over KV chunks with a
    running (max, sum, acc) -- O(S * chunk) score memory;
  * decode: one-token query against the full cache.  The cache's sequence
    axis is sharded over the 'model' mesh axis (SP for inference); XLA
    inserts the partial-softmax all-reduces.  This is what makes e.g.
    qwen2-72b decode_32k fit (53 GB of KV per chip otherwise).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import Maker, dense, dense_params, rope

BLOCKWISE_THRESHOLD = 8192
BLOCKWISE_CHUNK = 1024
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def gqa_params(mk: Maker, cfg) -> dict:
    d = cfg.d_model
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": mk.param((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": mk.param((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": mk.param((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": mk.param((h, hd, d), ("heads", "head_dim", "embed")),
        **({"bq": mk.param((h, hd), ("heads", "head_dim"), init="zeros"),
            "bk": mk.param((kv, hd), ("kv_heads", "head_dim"), init="zeros"),
            "bv": mk.param((kv, hd), ("kv_heads", "head_dim"), init="zeros")}
           if getattr(cfg, "qkv_bias", False) else {}),
    }


def _project_qkv(p, cfg, x, positions, use_rope=True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k, num_heads):
    """[b, s, kv, d] -> [b, s, h, d] by group repetition."""
    b, s, kv, d = k.shape
    if kv == num_heads:
        return k
    rep = num_heads // kv
    return jnp.repeat(k, rep, axis=2)


# ---------------------------------------------------------------------------
# Dense masked attention (train / short prefill)
# ---------------------------------------------------------------------------

def _attend_full(q, k, v, causal: bool, q_offset: int = 0):
    """Grouped (GQA) attention without materialising repeated K/V
    (§Perf iteration 3: repeat_kv inflated decode/prefill KV traffic by
    H/KVH, e.g. 8x on qwen2)."""
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d)
    scale = d ** -0.5
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) * scale
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(sk)
        mask = kpos[None, :] <= qpos[:, None]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, h, v.shape[-1])


# ---------------------------------------------------------------------------
# Blockwise attention: scan over KV chunks with online softmax
# ---------------------------------------------------------------------------

def _attend_blockwise(q, k, v, causal: bool, chunk: int = BLOCKWISE_CHUNK):
    from repro.dist.sharding import constrain_batch
    # anchor KV to (batch->dp, seq, heads->model) before chunk-reshaping:
    # otherwise the scan's per-chunk dynamic-slice loses the head sharding
    # and gathers the full KV each iteration (§Perf iteration 7)
    q = constrain_batch(q, extra=("", "model"))
    k = constrain_batch(k, extra=("", "model"))
    v = constrain_batch(v, extra=("", "model"))
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    dv = v.shape[-1]            # MLA: value head_dim != qk head_dim
    n_chunks = max(1, sk // chunk)
    chunk = sk // n_chunks
    scale = d ** -0.5
    kc = k.reshape(b, n_chunks, chunk, kvh, d)
    vc = v.reshape(b, n_chunks, chunk, kvh, dv)
    qg = q.reshape(b, sq, kvh, g, d)
    qpos = jnp.arange(sq)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        k_i, v_i, idx = xs
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_i) * scale
        if causal:
            kpos = idx * chunk + jnp.arange(chunk)
            mask = kpos[None, :] <= qpos[:, None]
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        scores = scores.astype(jnp.float32)
        m_i = jnp.maximum(m_prev, scores.max(axis=-1))
        alpha = jnp.exp(m_prev - m_i)
        p = jnp.exp(scores - m_i[..., None])
        l_i = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(q.dtype), v_i).astype(jnp.float32)
        return (m_i, l_i, acc), None

    m0 = jnp.full((b, kvh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
         jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)          # [b,kvh,g,q,dv]
    out = jnp.moveaxis(out.astype(q.dtype), 3, 1)          # [b,q,kvh,g,dv]
    return out.reshape(b, sq, h, dv)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def attend(q, k, v, causal: bool, blockwise: bool | None = None):
    import os
    if os.environ.get("REPRO_REPEAT_KV"):   # §Perf before/after toggle
        k = _repeat_kv(k, q.shape[2])
        v = _repeat_kv(v, q.shape[2])
    if blockwise is None:
        blockwise = k.shape[1] >= BLOCKWISE_THRESHOLD
    if blockwise:
        return _attend_blockwise(q, k, v, causal)
    return _attend_full(q, k, v, causal)


def gqa_self_attention(p, cfg, x, positions, causal=True, use_rope=True):
    """Train / prefill path; returns (out, (k, v)) for cache seeding."""
    q, k, v = _project_qkv(p, cfg, x, positions, use_rope)
    out = attend(q, k, v, causal)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, (k, v)


def gqa_decode_attention(p, cfg, x, cache_k, cache_v, position,
                         cache_len=None, use_rope=True):
    """One-token decode against a [b, S, kv, d] cache.

    ``position``: [b] current index; the new K/V is written at it.
    """
    q, k_new, v_new = _project_qkv(
        p, cfg, x, position[:, None], use_rope)
    b, s_max = cache_k.shape[0], cache_k.shape[1]
    # write the new token as an elementwise one-hot blend.  Measured
    # alternatives (§Perf iteration 5): take_along_axis reads -> XLA
    # all-gathers the sharded cache (17.9 GB/step); batched scatter ->
    # +23% memory term (worse fusion); the blend fuses into one
    # read+write pass over the cache shard.
    onehot = jax.nn.one_hot(position, s_max, dtype=cache_k.dtype)
    oh = onehot[:, :, None, None]
    cache_k = cache_k * (1 - oh) + oh * k_new
    cache_v = cache_v * (1 - oh) + oh * v_new
    import os
    kvh = cfg.num_kv_heads
    if os.environ.get("REPRO_REPEAT_KV"):   # §Perf before/after toggle
        cache_k = _repeat_kv(cache_k, cfg.num_heads)
        cache_v = _repeat_kv(cache_v, cfg.num_heads)
        kvh = cfg.num_heads
    g = cfg.num_heads // kvh
    qg = q.reshape(q.shape[0], 1, kvh, g, cfg.head_dim)
    scale = cfg.head_dim ** -0.5
    from repro.dist.sharding import constrain_seq_scores
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, cache_k) * scale
    scores = constrain_seq_scores(scores)
    kpos = jnp.arange(s_max)
    mask = kpos[None, :] <= position[:, None]
    scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, cache_v)
    out = out.reshape(q.shape[0], 1, cfg.num_heads, cfg.head_dim)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, (cache_k, cache_v)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank KV with decoupled RoPE; absorbed decode
# ---------------------------------------------------------------------------

def mla_params(mk: Maker, cfg) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "wq_a": mk.param((d, qr), ("embed", "q_lora")),
        "q_norm": {"scale": mk.param((qr,), ("q_lora",), init="ones")},
        "wq_b": mk.param((qr, h, dn + dr), ("q_lora", "heads", "head_dim")),
        "wkv_a": mk.param((d, kvr + dr), ("embed", "kv_lora")),
        "kv_norm": {"scale": mk.param((kvr,), ("kv_lora",), init="ones")},
        "wk_b": mk.param((kvr, h, dn), ("kv_lora", "heads", "head_dim")),
        "wv_b": mk.param((kvr, h, dv), ("kv_lora", "heads", "head_dim")),
        "wo": mk.param((h, dv, d), ("heads", "head_dim", "embed")),
    }


def _mla_q(p, cfg, x, positions):
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    ql = common.rmsnorm(p["q_norm"], x @ p["wq_a"].astype(x.dtype))
    q = jnp.einsum("bsr,rhk->bshk", ql, p["wq_b"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, cfg, x, positions):
    kvr, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    kv = x @ p["wkv_a"].astype(x.dtype)
    c_kv = common.rmsnorm(p["kv_norm"], kv[..., :kvr])
    k_rope = rope(kv[..., kvr:][:, :, None, :], positions,
                  cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_self_attention(p, cfg, x, positions, causal=True):
    """Materialised MLA for train/prefill; returns latent cache pieces."""
    dn, dv = cfg.qk_nope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv, k_rope = _mla_latent(p, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"].astype(x.dtype))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"].astype(x.dtype))
    h = cfg.num_heads
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                k_rope.shape[:2] + (h, k_rope.shape[-1]))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    scale_fix = (dn + cfg.qk_rope_head_dim) ** -0.5 / (q.shape[-1] ** -0.5)
    out = attend(q * scale_fix, k, v, causal)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, (c_kv, k_rope)


def mla_decode_attention(p, cfg, x, cache_c, cache_rope, position):
    """Absorbed decode: attend in the 512(+64)-dim latent space.

    Beyond-paper optimisation (DESIGN.md §6): the per-token cache is
    kv_lora_rank + rope_dim instead of 2*h*head_dim (a ~14x byte cut for
    deepseek-v2), and the per-step FLOPs drop the full K/V expansion.
    """
    dn = cfg.qk_nope_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x, position[:, None])
    c_new, rope_new = _mla_latent(p, cfg, x, position[:, None])
    b, s_max = cache_c.shape[0], cache_c.shape[1]
    onehot = jax.nn.one_hot(position, s_max, dtype=cache_c.dtype)
    oh = onehot[:, :, None]
    cache_c = cache_c * (1 - oh) + oh * c_new
    cache_rope = cache_rope * (1 - oh) + oh * rope_new
    # absorb W_kb into q: q_lat[b,1,h,r] = q_nope . wk_b^T
    q_lat = jnp.einsum("bqhk,rhk->bqhr", q_nope, p["wk_b"].astype(x.dtype))
    scale = (dn + cfg.qk_rope_head_dim) ** -0.5
    from repro.dist.sharding import constrain_seq_scores
    scores = (jnp.einsum("bqhr,bsr->bhqs", q_lat, cache_c)
              + jnp.einsum("bqhk,bsk->bhqs", q_rope, cache_rope)) * scale
    scores = constrain_seq_scores(scores)
    kpos = jnp.arange(s_max)
    mask = kpos[None, :] <= position[:, None]
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", probs, cache_c)
    out = jnp.einsum("bqhr,rhk->bqhk", o_lat, p["wv_b"].astype(x.dtype))
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, (cache_c, cache_rope)
