"""Shared model-building blocks (pure JAX, no flax).

Parameters are nested dicts.  Every parameter is created through a ``Maker``
which runs in one of two modes:

  * ``init``: returns initialised jnp arrays (given a PRNG key stream);
  * ``axes``: returns the tuple of *logical axis names* for the same leaf.

Running the same model-definition code in both modes yields two pytrees with
identical structure -- values and logical axes -- from which
``dist/sharding.py`` derives NamedShardings.  This is the flax
``param_with_axes`` idea without the dependency.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


class Maker:
    """Dual-mode parameter factory."""

    def __init__(self, mode: str = "init", key: jax.Array | None = None,
                 dtype=jnp.float32):
        assert mode in ("init", "axes")
        self.mode = mode
        self.dtype = dtype
        self._key = key

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(self, shape: tuple[int, ...], axes: tuple[str, ...],
              init: str = "normal", scale: float | None = None):
        assert len(shape) == len(axes), (shape, axes)
        if self.mode == "axes":
            return axes
        key = self._next_key()
        if init == "zeros":
            return jnp.zeros(shape, self.dtype)
        if init == "ones":
            return jnp.ones(shape, self.dtype)
        if init == "normal":
            if scale is None:
                # fan-in scaling over the contracted (first) dim by default
                fan_in = shape[0] if len(shape) > 1 else shape[0]
                scale = 1.0 / np.sqrt(max(fan_in, 1))
            return (jax.random.normal(key, shape) * scale).astype(self.dtype)
        raise ValueError(init)


# ---------------------------------------------------------------------------
# Normalisation
# ---------------------------------------------------------------------------

def rmsnorm_params(mk: Maker, dim: int):
    return {"scale": mk.param((dim,), ("embed",), init="ones")}


def rmsnorm(p, x, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return y.astype(dtype) * p["scale"].astype(dtype)


def layernorm_params(mk: Maker, dim: int):
    return {"scale": mk.param((dim,), ("embed",), init="ones"),
            "bias": mk.param((dim,), ("embed",), init="zeros")}


def layernorm(p, x, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
         rotary_dim: int | None = None) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    head_dim = x.shape[-1]
    rd = rotary_dim or head_dim
    half = rd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, half]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x_rot, x_pass = x[..., :rd], x[..., rd:]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


def sinusoidal_at(positions: jax.Array, dim: int) -> jax.Array:
    """Sinusoidal embeddings for arbitrary integer positions (in-graph; no
    host-side giant constants).  positions: [...] -> [..., dim]."""
    half = dim // 2
    div = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                  / half)
    ang = positions[..., None].astype(jnp.float32) * div
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    return sinusoidal_at(jnp.arange(length), dim)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def activation(name: str):
    return {
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_params(mk: Maker, vocab: int, dim: int):
    return {"table": mk.param((vocab, dim), ("vocab", "embed"),
                              scale=1.0)}


def _table(p):
    # anchor to vocab-sharded / embed-replicated before contractions
    # (§Perf iteration 2, see dist.sharding.constrain_rows_model)
    from repro.dist.sharding import constrain_rows_model
    return constrain_rows_model(p["table"])


def embed(p, tokens):
    return jnp.take(_table(p), tokens, axis=0)


def unembed(p, x):
    return jnp.einsum("...d,vd->...v", x, _table(p))


def dense_params(mk: Maker, d_in: int, d_out: int,
                 axes: tuple[str, str], bias: bool = False,
                 bias_axis: str | None = None):
    p = {"w": mk.param((d_in, d_out), axes)}
    if bias:
        p["b"] = mk.param((d_out,), (bias_axis or axes[1],), init="zeros")
    return p


def dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y
