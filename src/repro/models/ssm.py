"""Selective state-space blocks: Mamba-1 (falcon-mamba-7b) and Mamba-2
(zamba2 hybrid).

Recurrence (per channel d, state n):

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t
    y_t = C_t . h_t + D * x_t

Implementation notes (TPU adaptation):
  * prefill/train uses jax.lax.scan over sequence *chunks*: within a chunk
    the recurrence is an associative scan (log-depth, MXU/VPU friendly);
    across chunks a carry h propagates.  Chunking bounds the O(L*d*n)
    element tensor to O(chunk*d*n) live memory -- required for the 32k/500k
    cells.
  * decode is the single-step recurrence on a carried state "cache".
  * the fused per-chunk kernel has a Pallas implementation in
    kernels/mamba_scan.py (validated interpret=True against ssm_ref).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import Maker

SCAN_CHUNK = 256


# ---------------------------------------------------------------------------
# Mamba-1 parameters
# ---------------------------------------------------------------------------

def mamba_params(mk: Maker, cfg) -> dict:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    n = cfg.ssm_state
    dt_rank = cfg.ssm_dt_rank
    return {
        "w_in": mk.param((d, 2 * di), ("embed", "ssm_inner")),
        "conv_w": mk.param((cfg.ssm_d_conv, di), ("conv", "ssm_inner"),
                           scale=0.5),
        "conv_b": mk.param((di,), ("ssm_inner",), init="zeros"),
        "w_x": mk.param((di, dt_rank + 2 * n), ("ssm_inner", "ssm_proj")),
        "w_dt": mk.param((dt_rank, di), ("ssm_proj", "ssm_inner")),
        "dt_bias": mk.param((di,), ("ssm_inner",), init="zeros"),
        "a_log": mk.param((di, n), ("ssm_inner", "ssm_state"), init="zeros"),
        "d_skip": mk.param((di,), ("ssm_inner",), init="ones"),
        "w_out": mk.param((di, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv; x: [b, l, di], w: [k, di].

    Returns (y, new_state) where state is the last k-1 inputs."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else state
    return y + b.astype(x.dtype), new_state


def _ssm_scan_fused(dt, a_log_or_a, b_t, xs, c_t, h0,
                    chunk: int = SCAN_CHUNK, mamba2: bool = False,
                    d_skip=None):
    """Chunked selective scan with the discretisation fused *inside* the
    chunk body (§Perf iteration 9).

    The unfused path materialises da/dbx of shape [B, L, ...states...] in
    fp32 before scanning (~34 GB/device for falcon-mamba train_4k); here
    each chunk's da/dbx exist only inside the scan body, bounding live
    memory to [B, chunk, ...] (the backward rematerialises per chunk via
    jax.checkpoint).

    mamba1: dt [B,L,D], a [D,N], b_t/c_t [B,L,N], xs [B,L,D]
            -> y [B,L,D], h_last [B,D,N]
    mamba2: dt [B,L,H], a [H],  b_t/c_t [B,L,H,N], xs [B,L,H,P]
            -> y [B,L,H,P], h_last [B,H,P,N]
    """
    bsz, l = dt.shape[0], dt.shape[1]
    n_chunks = max(1, l // chunk)
    if l % n_chunks:
        n_chunks = 1
    cl = l // n_chunks

    def reshape_c(x):
        return jnp.moveaxis(
            x.reshape((bsz, n_chunks, cl) + x.shape[2:]), 1, 0)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    def chunk_body(h, args):
        if mamba2:
            dt_i, b_i, c_i, x_i = args
            da = jnp.exp(dt_i * a_log_or_a)[..., None, None]  # [B,cl,H,1,1]
            dbx = (dt_i[..., None] * x_i.astype(jnp.float32))[..., None] \
                * b_i.astype(jnp.float32)[..., :, None, :]    # [B,cl,H,P,N]
        else:
            dt_i, b_i, c_i, x_i = args
            da = jnp.exp(dt_i.astype(jnp.float32)[..., None]
                         * a_log_or_a)                        # [B,cl,D,N]
            dbx = (dt_i * x_i).astype(jnp.float32)[..., None] \
                * b_i.astype(jnp.float32)[..., None, :]       # [B,cl,D,N]
        acc_a, acc_b = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        h_all = acc_a * h[:, None] + acc_b
        if mamba2:
            y = jnp.einsum("blhdn,blhn->blhd", h_all,
                           c_i.astype(jnp.float32))
        else:
            y = jnp.einsum("bldn,bln->bld", h_all,
                           c_i.astype(jnp.float32))
        return h_all[:, -1], y

    body = jax.checkpoint(chunk_body)
    h_last, ys = jax.lax.scan(
        body, h0.astype(jnp.float32),
        (reshape_c(dt), reshape_c(b_t), reshape_c(c_t), reshape_c(xs)))
    y = jnp.moveaxis(ys, 0, 1).reshape((bsz, l) + ys.shape[3:])
    return y, h_last


def _ssm_scan_chunked(a, bx, h0, chunk: int = SCAN_CHUNK):
    """h_t = a_t * h_{t-1} + bx_t over axis 1 (length L).

    a, bx: [B, L, ...]; h0: [B, ...].  Associative scan inside chunks,
    sequential carry across chunks.
    """
    bsz, l = a.shape[0], a.shape[1]
    n_chunks = max(1, l // chunk)
    if l % n_chunks:
        n_chunks = 1
    cl = l // n_chunks
    # a may be broadcast-shaped against bx (mamba2: scalar decay per head)
    rest_a, rest_b = a.shape[2:], bx.shape[2:]
    a_c = a.reshape((bsz, n_chunks, cl) + rest_a)
    bx_c = bx.reshape((bsz, n_chunks, cl) + rest_b)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    def chunk_body(h, xs):
        a_i, bx_i = xs                      # [B, cl, ...]
        acc_a, acc_b = jax.lax.associative_scan(combine, (a_i, bx_i), axis=1)
        h_all = acc_a * h[:, None] + acc_b  # [B, cl, ...]
        return h_all[:, -1], h_all

    h_last, h_seq = jax.lax.scan(
        chunk_body, h0,
        (jnp.moveaxis(a_c, 1, 0), jnp.moveaxis(bx_c, 1, 0)))
    h_seq = jnp.moveaxis(h_seq, 0, 1).reshape((bsz, l) + rest_b)
    return h_seq, h_last


def mamba_block(p, cfg, x, state=None):
    """x: [b, l, d] -> (y, new_state).

    state = {"conv": [b, k-1, di], "ssm": [b, di, n]} (decode carry).
    """
    di, n = cfg.ssm_d_inner, cfg.ssm_state
    dt_rank = cfg.ssm_dt_rank
    xz = x @ p["w_in"].astype(x.dtype)
    xs, z = xz[..., :di], xz[..., di:]
    conv_state = state["conv"] if state else None
    xs, new_conv = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_state)
    xs = jax.nn.silu(xs)
    proj = xs @ p["w_x"].astype(x.dtype)
    dt = jax.nn.softplus(
        proj[..., :dt_rank] @ p["w_dt"].astype(x.dtype)
        + p["dt_bias"].astype(x.dtype))                      # [b, l, di]
    b_t = proj[..., dt_rank:dt_rank + n]                     # [b, l, n]
    c_t = proj[..., dt_rank + n:]                            # [b, l, n]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))             # [di, n]
    h0 = (state["ssm"].astype(jnp.float32) if state
          else jnp.zeros((x.shape[0], di, n), jnp.float32))
    import os
    if os.environ.get("REPRO_SSM_UNFUSED"):   # §Perf iteration 9 toggle
        da = jnp.exp(dt.astype(jnp.float32)[..., None] * a)
        dbx = (dt * xs).astype(jnp.float32)[..., None] * \
            b_t.astype(jnp.float32)[..., None, :]
        h_seq, h_last = _ssm_scan_chunked(da, dbx, h0)
        y = jnp.einsum("bldn,bln->bld", h_seq, c_t.astype(jnp.float32))
    else:
        y, h_last = _ssm_scan_fused(dt, a, b_t, xs, c_t, h0)
    y = y.astype(x.dtype)
    y = y + xs * p["d_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["w_out"].astype(x.dtype)
    new_state = {"conv": new_conv, "ssm": h_last.astype(jnp.float32)}
    return out, new_state


# ---------------------------------------------------------------------------
# Mamba-2 (multi-head, scalar decay per head)
# ---------------------------------------------------------------------------

def mamba2_params(mk: Maker, cfg) -> dict:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    g = cfg.ssm_groups
    return {
        "w_in": mk.param((d, 2 * di + 2 * g * n + h),
                         ("embed", "ssm_inner")),
        "conv_w": mk.param((cfg.ssm_d_conv, di + 2 * g * n),
                           ("conv", "ssm_inner"), scale=0.5),
        "conv_b": mk.param((di + 2 * g * n,), ("ssm_inner",), init="zeros"),
        "a_log": mk.param((h,), ("ssm_heads",), init="zeros"),
        "dt_bias": mk.param((h,), ("ssm_heads",), init="zeros"),
        "d_skip": mk.param((h,), ("ssm_heads",), init="ones"),
        "norm": {"scale": mk.param((di,), ("ssm_inner",), init="ones")},
        "w_out": mk.param((di, d), ("ssm_inner", "embed")),
    }


def mamba2_block(p, cfg, x, state=None):
    di, n = cfg.ssm_d_inner, cfg.ssm_state
    h, g = cfg.ssm_heads, cfg.ssm_groups
    hd = di // h
    bsz, l, _ = x.shape
    zxbcdt = x @ p["w_in"].astype(x.dtype)
    z, xbc, dt_raw = (zxbcdt[..., :di],
                      zxbcdt[..., di:di + di + 2 * g * n],
                      zxbcdt[..., -h:])
    conv_state = state["conv"] if state else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di].reshape(bsz, l, h, hd)
    b_t = xbc[..., di:di + g * n].reshape(bsz, l, g, n)
    c_t = xbc[..., di + g * n:].reshape(bsz, l, g, n)
    rep = h // g
    b_t = jnp.repeat(b_t, rep, axis=2)                       # [b, l, h, n]
    c_t = jnp.repeat(c_t, rep, axis=2)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # [b, l, h]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))              # [h]
    h0 = (state["ssm"].astype(jnp.float32) if state
          else jnp.zeros((bsz, h, hd, n), jnp.float32))
    y, h_last = _ssm_scan_fused(dt, a, b_t, xs, c_t, h0, mamba2=True)
    y = y + xs.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[..., None]
    y = y.reshape(bsz, l, di).astype(x.dtype)
    y = common.rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = y @ p["w_out"].astype(x.dtype)
    return out, {"conv": new_conv, "ssm": h_last.astype(jnp.float32)}
