"""Whisper-style encoder-decoder.

The audio conv frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings [B, frontend_len, d_model] from input_specs().
Whisper uses LayerNorm (not RMSNorm) and GELU MLPs; positions are
sinusoidal (the released model's learned decoder positions are simplified
to sinusoidal -- noted in DESIGN.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attnlib
from repro.models import common
from repro.models.common import Maker
from repro.models.mlp import mlp, mlp_params
from repro.models.transformer import stacked_params


def _enc_layer_params(mk: Maker, cfg) -> dict:
    return {
        "ln_attn": common.layernorm_params(mk, cfg.d_model),
        "attn": attnlib.gqa_params(mk, cfg),
        "ln_mlp": common.layernorm_params(mk, cfg.d_model),
        "mlp": mlp_params(mk, cfg.d_model, cfg.d_ff, cfg.mlp_act),
    }


def _dec_layer_params(mk: Maker, cfg) -> dict:
    p = _enc_layer_params(mk, cfg)
    p["ln_cross"] = common.layernorm_params(mk, cfg.d_model)
    p["cross"] = attnlib.gqa_params(mk, cfg)
    return p


def encdec_params(mk: Maker, cfg) -> dict:
    return {
        "embed": common.embed_params(mk, cfg.vocab_size, cfg.d_model),
        "enc_layers": stacked_params(
            cfg, cfg.encoder_layers, lambda m: _enc_layer_params(m, cfg), mk),
        "enc_ln_f": common.layernorm_params(mk, cfg.d_model),
        "dec_layers": stacked_params(
            cfg, cfg.num_layers, lambda m: _dec_layer_params(m, cfg), mk),
        "dec_ln_f": common.layernorm_params(mk, cfg.d_model),
    }


def _cross_attend(p, cfg, x, enc_k, enc_v):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    out = attnlib.attend(q, enc_k, enc_v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def _enc_kv(p, cfg, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    return k, v


def encode(params, cfg, frames, remat=True):
    """frames: [B, T_enc, d_model] (frontend stub output)."""
    x = frames + common.sinusoidal_positions(
        frames.shape[1], cfg.d_model).astype(frames.dtype)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    from repro.dist.sharding import constrain_batch

    def body(x, lp):
        x = constrain_batch(x)
        h = common.layernorm(lp["ln_attn"], x)
        a, _ = attnlib.gqa_self_attention(lp["attn"], cfg, h, positions,
                                          causal=False, use_rope=False)
        x = x + a
        h = common.layernorm(lp["ln_mlp"], x)
        return x + mlp(lp["mlp"], h, cfg.mlp_act), None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return common.layernorm(params["enc_ln_f"], x)


def decode_stack(params, cfg, tokens, enc_out, mode="train", cache=None,
                 position_idx=None, remat=True):
    x = common.embed(params["embed"], tokens)
    b, s, _ = x.shape
    if mode == "decode" and position_idx is not None:
        pos_emb = common.sinusoidal_at(position_idx, cfg.d_model)[:, None]
        positions = position_idx[:, None]
    else:
        pos_emb = common.sinusoidal_positions(s, cfg.d_model)[None]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = x + pos_emb.astype(x.dtype)

    from repro.dist.sharding import constrain_batch

    def body(carry, xs):
        x = carry
        lp, c = xs
        x = constrain_batch(x)
        h = common.layernorm(lp["ln_attn"], x)
        if mode == "decode":
            a, new_kv = attnlib.gqa_decode_attention(
                lp["attn"], cfg, h, c["self"][0], c["self"][1],
                position_idx, use_rope=False)
        else:
            a, new_kv = attnlib.gqa_self_attention(
                lp["attn"], cfg, h, positions, causal=True, use_rope=False)
        x = x + a
        h = common.layernorm(lp["ln_cross"], x)
        if mode == "decode":
            enc_k, enc_v = c["cross"]
        else:
            enc_k, enc_v = _enc_kv(lp["cross"], cfg, enc_out)
        x = x + _cross_attend(lp["cross"], cfg, h, enc_k, enc_v)
        h = common.layernorm(lp["ln_mlp"], x)
        x = x + mlp(lp["mlp"], h, cfg.mlp_act)
        return x, {"self": new_kv, "cross": (enc_k, enc_v)}

    body_fn = jax.checkpoint(body) if (remat and mode == "train") else body
    if cache is None:
        x, new_cache = jax.lax.scan(
            lambda carry, lp: body_fn(carry, (lp, None)), x,
            params["dec_layers"])
    else:
        x, new_cache = jax.lax.scan(body_fn, x,
                                    (params["dec_layers"], cache["layers"]))
    x = common.layernorm(params["dec_ln_f"], x)
    logits = common.unembed(params["embed"], x)
    out_cache = ({"layers": new_cache} if mode in ("prefill", "decode")
                 else None)
    return logits, out_cache, jnp.zeros((), jnp.float32)
