"""Zamba2-style hybrid: scanned Mamba-2 blocks with a *shared* transformer
block applied every ``attn_every`` blocks (weight reuse across applications,
with per-application input norms).

Simplifications vs the released zamba2 (noted in DESIGN.md): the shared
block consumes the residual stream directly (no concat with the original
embedding) and per-application LoRA deltas are replaced by per-application
input norms.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attnlib
from repro.models import common, ssm
from repro.models.common import Maker
from repro.models.mlp import mlp, mlp_params
from repro.models.transformer import stacked_params


def _mamba_block_params(mk: Maker, cfg) -> dict:
    return {"ln": common.rmsnorm_params(mk, cfg.d_model),
            "mamba": ssm.mamba2_params(mk, cfg)}


def _shared_block_params(mk: Maker, cfg) -> dict:
    return {
        "ln_attn": common.rmsnorm_params(mk, cfg.d_model),
        "attn": attnlib.gqa_params(mk, cfg),
        "ln_mlp": common.rmsnorm_params(mk, cfg.d_model),
        "mlp": mlp_params(mk, cfg.d_model, cfg.d_ff, cfg.mlp_act),
    }


def _n_attn(cfg) -> int:
    return cfg.num_layers // cfg.attn_every


def hybrid_params(mk: Maker, cfg) -> dict:
    n_attn = _n_attn(cfg)
    return {
        "embed": common.embed_params(mk, cfg.vocab_size, cfg.d_model),
        "mamba_layers": stacked_params(
            cfg, cfg.num_layers, lambda m: _mamba_block_params(m, cfg), mk),
        "shared": _shared_block_params(mk, cfg),
        "app_norms": stacked_params(
            cfg, n_attn, lambda m: common.rmsnorm_params(m, cfg.d_model), mk),
        "ln_f": common.rmsnorm_params(mk, cfg.d_model),
    }


def _tree_slice(tree, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], tree)


def hybrid_forward(params, cfg, tokens, mode="train", cache=None,
                   position_idx=None, remat=True, prefix_embeds=None):
    x = common.embed(params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    if mode == "decode" and position_idx is not None:
        positions = position_idx[:, None]

    from repro.dist.sharding import constrain_batch

    def mamba_body(x, xs):
        lp, c = xs
        x = constrain_batch(x)
        h = common.rmsnorm(lp["ln"], x)
        y, nc = ssm.mamba2_block(lp["mamba"], cfg, h, state=c)
        return x + y, nc

    mamba_fn = (jax.checkpoint(mamba_body)
                if (remat and mode == "train") else mamba_body)

    def run_span(x, lo, hi, span_cache):
        lp = _tree_slice(params["mamba_layers"], lo, hi)
        if span_cache is None:
            return jax.lax.scan(
                lambda carry, p: mamba_fn(carry, (p, None)), x, lp)
        return jax.lax.scan(mamba_fn, x, (lp, span_cache))

    def shared_block(x, app_idx, kv):
        norm = jax.tree.map(lambda a: a[app_idx], params["app_norms"])
        sp = params["shared"]
        h = common.rmsnorm(norm, x)
        h = common.rmsnorm(sp["ln_attn"], h)
        if mode == "decode":
            a, new_kv = attnlib.gqa_decode_attention(
                sp["attn"], cfg, h, kv[0], kv[1], position_idx)
        else:
            a, new_kv = attnlib.gqa_self_attention(
                sp["attn"], cfg, h, positions, causal=True)
        x = x + a
        h = common.rmsnorm(sp["ln_mlp"], x)
        x = x + mlp(sp["mlp"], h, cfg.mlp_act)
        return x, new_kv

    n_attn = _n_attn(cfg)
    new_mamba_caches = []
    new_kv_caches = []
    pos = 0
    for app in range(n_attn):
        lo, hi = pos, pos + cfg.attn_every
        span_cache = (None if cache is None else
                      _tree_slice(cache["mamba"], lo, hi))
        x, nc = run_span(x, lo, hi, span_cache)
        new_mamba_caches.append(nc)
        kv = None if cache is None else jax.tree.map(
            lambda a: a[app], cache["kv"])
        x, new_kv = shared_block(x, app, kv)
        new_kv_caches.append(new_kv)
        pos = hi
    if pos < cfg.num_layers:
        span_cache = (None if cache is None else
                      _tree_slice(cache["mamba"], pos, cfg.num_layers))
        x, nc = run_span(x, pos, cfg.num_layers, span_cache)
        new_mamba_caches.append(nc)

    x = common.rmsnorm(params["ln_f"], x)
    logits = common.unembed(params["embed"], x)

    out_cache = None
    if mode in ("prefill", "decode"):
        mamba_cache = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *new_mamba_caches)
        kv_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_kv_caches)
        out_cache = {"mamba": mamba_cache, "kv": kv_cache}
    return logits, out_cache, jnp.zeros((), jnp.float32)
