"""Unified model facade: build_model(cfg) -> Model with init / loss /
prefill / decode_step, plus abstract cache/batch specs for the dry-run and
logical-axes pytrees for sharding.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, ssm_lm, transformer
from repro.models.common import Maker

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


def _dtype(cfg):
    return DTYPES[cfg.dtype]


@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # ---------------- parameters ----------------
    def init(self, key) -> Any:
        mk = Maker(mode="init", key=key, dtype=_dtype(self.cfg))
        return self._params(mk)

    def axes(self) -> Any:
        return self._params(Maker(mode="axes"))

    def _params(self, mk: Maker):
        f = self.cfg.family
        if f in ("dense", "moe", "vlm"):
            return transformer.decoder_params(mk, self.cfg)
        if f == "ssm":
            return ssm_lm.ssm_lm_params(mk, self.cfg)
        if f == "hybrid":
            return hybrid.hybrid_params(mk, self.cfg)
        if f == "encdec":
            return encdec.encdec_params(mk, self.cfg)
        raise ValueError(f)

    # ---------------- forward dispatch ----------------
    def _forward(self, params, tokens, mode, cache=None, position_idx=None,
                 prefix_embeds=None, frames=None, remat=True):
        f = self.cfg.family
        if f in ("dense", "moe", "vlm"):
            return transformer.decoder_forward(
                params, self.cfg, tokens, mode=mode, cache=cache,
                position_idx=position_idx, prefix_embeds=prefix_embeds,
                remat=remat)
        if f == "ssm":
            return ssm_lm.ssm_lm_forward(
                params, self.cfg, tokens, mode=mode, cache=cache,
                position_idx=position_idx, remat=remat)
        if f == "hybrid":
            return hybrid.hybrid_forward(
                params, self.cfg, tokens, mode=mode, cache=cache,
                position_idx=position_idx, remat=remat)
        if f == "encdec":
            if mode == "decode":
                return encdec.decode_stack(
                    params, self.cfg, tokens, None, mode=mode, cache=cache,
                    position_idx=position_idx)
            enc_out = encdec.encode(params, self.cfg, frames,
                                    remat=(mode == "train" and remat))
            return encdec.decode_stack(params, self.cfg, tokens, enc_out,
                                       mode=mode, remat=remat)
        raise ValueError(f)

    # ---------------- training ----------------
    def loss(self, params, batch, remat: bool = True):
        """Next-token cross-entropy; returns (loss, metrics)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        logits, _, aux = self._forward(
            params, tokens, "train",
            prefix_embeds=batch.get("patches"),
            frames=batch.get("frames"), remat=remat)
        # align: predict token[t+1] from position t
        prefix = 0
        if cfg.family == "vlm" and "patches" in batch:
            prefix = batch["patches"].shape[1]
            logits = logits[:, prefix:]
        logits = logits[:, :-1].astype(jnp.float32)
        targets = tokens[:, 1:]
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None],
                                   axis=-1)[..., 0]
        nll = (logz - gold).mean()
        loss = nll + 0.01 * aux
        return loss, {"nll": nll, "aux": aux,
                      "perplexity": jnp.exp(nll)}

    # ---------------- serving ----------------
    def prefill(self, params, tokens, prefix_embeds=None, frames=None):
        logits, cache, _ = self._forward(
            params, tokens, "prefill", prefix_embeds=prefix_embeds,
            frames=frames, remat=False)
        return logits[:, -1], cache

    def decode_step(self, params, tokens, cache, position_idx):
        logits, cache, _ = self._forward(
            params, tokens, "decode", cache=cache,
            position_idx=position_idx, remat=False)
        return logits[:, -1], cache

    # ---------------- abstract specs (dry-run) ----------------
    def batch_spec(self, shape: ShapeConfig) -> dict:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        dt = _dtype(cfg)
        spec = {}
        if shape.kind == "decode":
            spec["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
            spec["position"] = jax.ShapeDtypeStruct((b,), jnp.int32)
        else:
            text = s
            if cfg.family == "vlm":
                text = s - cfg.frontend_len
                spec["patches"] = jax.ShapeDtypeStruct(
                    (b, cfg.frontend_len, cfg.d_model), dt)
            if cfg.family == "encdec":
                spec["frames"] = jax.ShapeDtypeStruct(
                    (b, cfg.frontend_len, cfg.d_model), dt)
            spec["tokens"] = jax.ShapeDtypeStruct((b, text), jnp.int32)
        return spec

    def cache_spec(self, batch: int, max_len: int) -> Any:
        """Abstract decode cache (ShapeDtypeStruct pytree)."""
        cfg = self.cfg
        dt = _dtype(cfg)
        L = cfg.num_layers - cfg.first_k_dense
        sds = jax.ShapeDtypeStruct
        if cfg.family in ("dense", "moe", "vlm"):
            if cfg.mla:
                layer = (sds((L, batch, max_len, cfg.kv_lora_rank), dt),
                         sds((L, batch, max_len, cfg.qk_rope_head_dim), dt))
            else:
                kvshape = (L, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
                layer = (sds(kvshape, dt), sds(kvshape, dt))
            out = {"layers": layer}
            if cfg.first_k_dense:
                k = cfg.first_k_dense
                if cfg.mla:
                    out["dense"] = (
                        sds((k, batch, max_len, cfg.kv_lora_rank), dt),
                        sds((k, batch, max_len, cfg.qk_rope_head_dim), dt))
                else:
                    kd = (k, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
                    out["dense"] = (sds(kd, dt), sds(kd, dt))
            return out
        if cfg.family == "ssm":
            di = cfg.ssm_d_inner
            return {"layers": {
                "conv": sds((L, batch, cfg.ssm_d_conv - 1, di), dt),
                "ssm": sds((L, batch, di, cfg.ssm_state), jnp.float32)}}
        if cfg.family == "hybrid":
            di = cfg.ssm_d_inner
            gnn = 2 * cfg.ssm_groups * cfg.ssm_state
            hd = di // cfg.ssm_heads
            n_attn = cfg.num_layers // cfg.attn_every
            kvshape = (n_attn, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
            return {
                "mamba": {
                    "conv": sds((cfg.num_layers, batch, cfg.ssm_d_conv - 1,
                                 di + gnn), dt),
                    "ssm": sds((cfg.num_layers, batch, cfg.ssm_heads, hd,
                                cfg.ssm_state), jnp.float32)},
                "kv": (sds(kvshape, dt), sds(kvshape, dt))}
        if cfg.family == "encdec":
            kvshape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads,
                       cfg.head_dim)
            cross = (cfg.num_layers, batch, cfg.frontend_len,
                     cfg.num_kv_heads, cfg.head_dim)
            return {"layers": {"self": (sds(kvshape, dt), sds(kvshape, dt)),
                               "cross": (sds(cross, dt), sds(cross, dt))}}
        raise ValueError(cfg.family)

    def cache_axes(self) -> Any:
        """Logical axes mirroring cache_spec."""
        cfg = self.cfg
        kv_ax = ("layers", "batch", "kvseq", "kv_heads", "head_dim")
        if cfg.family in ("dense", "moe", "vlm"):
            if cfg.mla:
                layer = (("layers", "batch", "kvseq", "kv_lora"),
                         ("layers", "batch", "kvseq", "head_dim"))
            else:
                layer = (kv_ax, kv_ax)
            out = {"layers": layer}
            if cfg.first_k_dense:
                out["dense"] = layer
            return out
        if cfg.family == "ssm":
            return {"layers": {
                "conv": ("layers", "batch", "conv", "ssm_inner"),
                "ssm": ("layers", "batch", "ssm_inner", "ssm_state")}}
        if cfg.family == "hybrid":
            return {
                "mamba": {
                    "conv": ("layers", "batch", "conv", "ssm_inner"),
                    "ssm": ("layers", "batch", "ssm_heads", "head_dim",
                            "ssm_state")},
                "kv": (kv_ax, kv_ax)}
        if cfg.family == "encdec":
            return {"layers": {"self": (kv_ax, kv_ax),
                               "cross": (kv_ax, kv_ax)}}
        raise ValueError(cfg.family)

    # ---------------- parameter counting ----------------
    def param_count(self) -> int:
        shapes = jax.eval_shape(
            lambda k: self.init(k), jax.random.PRNGKey(0))
        return sum(int(math.prod(x.shape))
                   for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Active params per token (MoE discounts inactive experts)."""
        total = self.param_count()
        cfg = self.cfg
        if not cfg.moe_enabled:
            return total
        # expert params: 3 matrices per expert in gated MLPs
        gated = cfg.mlp_act in ("swiglu", "geglu")
        per_expert = (3 if gated else 2) * cfg.d_model * cfg.moe_d_ff
        n_scan = cfg.num_layers - cfg.first_k_dense
        inactive = (cfg.num_experts - cfg.experts_per_token)
        return total - n_scan * inactive * per_expert


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg)
