"""Attention-free SSM LM (falcon-mamba-7b): scanned Mamba-1 blocks."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common, ssm
from repro.models.common import Maker
from repro.models.transformer import stacked_params


def _block_params(mk: Maker, cfg) -> dict:
    return {"ln": common.rmsnorm_params(mk, cfg.d_model),
            "mamba": ssm.mamba_params(mk, cfg)}


def ssm_lm_params(mk: Maker, cfg) -> dict:
    return {
        "embed": common.embed_params(mk, cfg.vocab_size, cfg.d_model),
        "layers": stacked_params(cfg, cfg.num_layers,
                                 lambda m: _block_params(m, cfg), mk),
        "ln_f": common.rmsnorm_params(mk, cfg.d_model),
    }


def ssm_lm_forward(params, cfg, tokens, mode="train", cache=None,
                   position_idx=None, remat=True, prefix_embeds=None):
    x = common.embed(params["embed"], tokens)

    from repro.dist.sharding import constrain_batch

    def body(x, xs):
        lp, c = xs
        x = constrain_batch(x)
        h = common.rmsnorm(lp["ln"], x)
        y, nc = ssm.mamba_block(lp["mamba"], cfg, h, state=c)
        return x + y, nc

    body_fn = jax.checkpoint(body) if (remat and mode == "train") else body
    scan_cache = None if cache is None else cache["layers"]
    if scan_cache is None:
        x, new_cache = jax.lax.scan(
            lambda carry, lp: body_fn(carry, (lp, None)), x,
            params["layers"])
    else:
        x, new_cache = jax.lax.scan(body_fn, x,
                                    (params["layers"], scan_cache))
    x = common.rmsnorm(params["ln_f"], x)
    logits = common.unembed(params["embed"], x)  # falcon-mamba ties embeddings
    out_cache = {"layers": new_cache} if mode in ("prefill", "decode") else None
    return logits, out_cache, jnp.zeros((), jnp.float32)
