"""Counter/gauge registry: the repo's ad-hoc stats behind one namespace.

Before this module every layer kept its own dict: ``SchedulerReport``
summaries, ``CacheStats``, ``KVPool.stats()``, ``Backend.n_launches``,
``perf.PerfResult`` breakdowns.  The registry gives them one shared,
labelled home -- ``obs.metrics`` -- with a Prometheus-style text
exposition, so a serving run's MINISA/micro byte counters, fetch-stall
fractions, cache tier hits and KV pool high-water all scrape from one
snapshot.

Two instrument kinds (deliberately minimal -- this is a reproduction's
telemetry spine, not a client library):

  Counter   monotonically accumulating (``inc``); bytes, launches, hits
  Gauge     last-write-wins (``set``; ``high`` keeps the max); stall
            fractions, high-water marks, entry counts

Both are labelled: ``counter("cache_events_total").inc(1, tier="plan",
kind="hit")`` keeps one value per label set.  Metric updates are a dict
lookup plus an add under a lock -- sub-microsecond, so even the kernel
launch sites count unconditionally (a launch costs milliseconds); the
bulk ``publish_metrics`` bridges run at report granularity.

The module-level functions operate on :data:`REGISTRY`, the process
default that ``obs.metrics`` exposes.
"""

from __future__ import annotations

import threading


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _label_str(key: tuple) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._values: dict[tuple, float] = {}

    def value(self, **labels) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def items(self) -> list[tuple[tuple, float]]:
        with self._lock:
            return sorted(self._values.items())

    def clear(self) -> None:
        with self._lock:
            self._values = {}


class Counter(_Metric):
    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + value


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._values[_label_key(labels)] = float(value)

    def high(self, value: float, **labels) -> None:
        """High-water semantics: keep the maximum seen."""
        key = _label_key(labels)
        with self._lock:
            self._values[key] = max(self._values.get(key, float("-inf")),
                                    float(value))


class Registry:
    """Named metrics; registration is idempotent per (name, kind)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{m.kind}, not {cls.kind}")
            if help and not m.help:
                m.help = help
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def set_many(self, mapping: dict, *, prefix: str = "",
                 **labels) -> None:
        """Bulk-publish a stats dict as gauges (non-numeric values are
        skipped) -- the bridge from the existing ``.stats()`` /
        ``.summary()`` dicts into the registry."""
        for key, value in mapping.items():
            if isinstance(value, bool) or not isinstance(value,
                                                         (int, float)):
                continue
            self.gauge(prefix + key).set(float(value), **labels)

    def snapshot(self) -> dict[str, dict[str, float]]:
        """name -> {label string ('' for unlabelled) -> value}."""
        with self._lock:
            metrics = list(self._metrics.values())
        return {m.name: {_label_str(k): v for k, v in m.items()}
                for m in metrics}

    def render_prometheus(self) -> str:
        """Prometheus text exposition format, deterministic order."""
        with self._lock:
            metrics = sorted(self._metrics.values(),
                             key=lambda m: m.name)
        lines: list[str] = []
        for m in metrics:
            items = m.items()
            if not items:
                continue
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for key, value in items:
                lines.append(f"{m.name}{_label_str(key)} {value:g}")
        return "\n".join(lines) + "\n"

    def reset(self) -> None:
        """Zero every metric, KEEPING registrations: instrumented
        modules hold their Counter/Gauge handles at import time (e.g.
        the backend's launch counter), so dropping the objects would
        silently detach them from future snapshots."""
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            m.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._metrics)


#: The process-wide registry ``obs.metrics`` exposes.
REGISTRY = Registry()


def counter(name: str, help: str = "") -> Counter:
    return REGISTRY.counter(name, help)


def gauge(name: str, help: str = "") -> Gauge:
    return REGISTRY.gauge(name, help)


def set_many(mapping: dict, *, prefix: str = "", **labels) -> None:
    REGISTRY.set_many(mapping, prefix=prefix, **labels)


def snapshot() -> dict[str, dict[str, float]]:
    return REGISTRY.snapshot()


def render_prometheus() -> str:
    return REGISTRY.render_prometheus()


def reset() -> None:
    REGISTRY.reset()
