"""Structured span tracer: where inside a tick time actually goes.

The paper's headline numbers are *observability* claims (fetch-stall
fractions, traffic reductions, end-to-end speedups), and ROADMAP item 3
wants to autotune the mapper against *measured* wall clock -- both need
a way to see inside a serving tick.  This tracer is that substrate:

  * **zero-dep**: stdlib only (``time``, ``threading``, ``json`` via the
    exporter) -- importable from every layer without dragging anything
    in;
  * **off by default, near-zero overhead**: ``trace.span(...)`` performs
    one attribute check and returns a shared no-op singleton when
    disabled, so the instrumentation compiled into the scheduler, the
    backends and the kernel launch sites costs nanoseconds per call on
    the untraced hot path (``tests/test_obs.py`` bounds it against a
    decode tick);
  * **nestable + thread-safe**: spans keep a per-thread stack (depth and
    track inherit from the enclosing span) and finished events append
    under one lock with a global sequence number, so the event order is
    deterministic for a deterministic workload;
  * **tracks** give every span a swimlane identity: ``("host", <thread>)``
    by default, ``("request", rid)`` for per-request lifecycle spans --
    the Chrome/Perfetto exporter (``obs.export``) turns tracks into
    pid/tid lanes.

Timestamps are ``time.perf_counter`` seconds relative to the tracer's
origin; they never feed back into any computation, so a traced run's
numerics are bit-identical to an untraced one (asserted end-to-end via
the scheduler's ``state_checksum``).

Usage::

    from repro.obs import trace
    trace.enable()
    with trace.span("decode_tick", n_ready=4) as sp:
        ...
        sp.set(launches=7)
    trace.export_chrome("trace.json")     # via obs.export
"""

from __future__ import annotations

import dataclasses
import threading
import time


@dataclasses.dataclass(frozen=True)
class SpanEvent:
    """One finished span (or instant, when ``dur_s == 0`` and
    ``instant`` is True)."""
    name: str
    track: tuple                      # ("host", <thread name>) | ("request", rid) | ...
    t0_s: float                       # seconds since tracer origin
    dur_s: float
    depth: int                        # nesting depth at entry (0 == top)
    seq: int                          # global completion order
    attrs: dict
    instant: bool = False

    @property
    def t1_s(self) -> float:
        return self.t0_s + self.dur_s

    def key(self) -> tuple:
        """Timing-free identity -- the determinism-regression surface
        (two seeded runs must produce identical key sequences)."""
        return (self.name, self.track, self.depth)


class _NullSpan:
    """Shared disabled-mode span: every method is a no-op."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self

    def __bool__(self) -> bool:
        return False


NULL_SPAN = _NullSpan()


class _Span:
    """A live span; records a SpanEvent on ``__exit__``."""

    __slots__ = ("_tracer", "name", "track", "attrs", "_t0", "_depth")

    def __init__(self, tracer: "Tracer", name: str, track: tuple | None,
                 attrs: dict):
        self._tracer = tracer
        self.name = name
        self.track = track
        self.attrs = attrs

    def set(self, **attrs) -> "_Span":
        """Attach attributes discovered mid-span (wall clock, launch
        counts, VMEM high-water...)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "_Span":
        tracer = self._tracer
        stack = tracer._stack()
        if self.track is None:
            # inherit the enclosing span's lane; top-level spans land on
            # the host lane of their thread
            self.track = (stack[-1].track if stack
                          else ("host", threading.current_thread().name))
        self._depth = len(stack)
        stack.append(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        tracer = self._tracer
        stack = tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        tracer._record(SpanEvent(
            name=self.name, track=self.track,
            t0_s=self._t0 - tracer._origin, dur_s=t1 - self._t0,
            depth=self._depth, seq=0, attrs=self.attrs))
        return False


class Tracer:
    """Process tracer; the module-level :data:`trace` instance is the
    one every instrumented layer shares."""

    def __init__(self):
        self.enabled = False
        self._lock = threading.Lock()
        self._events: list[SpanEvent] = []
        self._seq = 0
        self._origin = time.perf_counter()
        self._tls = threading.local()

    # -- lifecycle ----------------------------------------------------------
    def enable(self) -> "Tracer":
        self.enabled = True
        return self

    def disable(self) -> "Tracer":
        self.enabled = False
        return self

    def clear(self) -> "Tracer":
        with self._lock:
            self._events = []
            self._seq = 0
            self._origin = time.perf_counter()
        return self

    def __enter__(self) -> "Tracer":          # `with trace:` == enable
        return self.enable()

    def __exit__(self, *exc) -> bool:
        self.disable()
        return False

    # -- recording ----------------------------------------------------------
    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _record(self, ev: SpanEvent) -> None:
        with self._lock:
            self._events.append(dataclasses.replace(ev, seq=self._seq))
            self._seq += 1

    def span(self, name: str, track: tuple | None = None, **attrs):
        """Context manager timing a region.  ``track`` pins the span to
        a swimlane (defaults to the enclosing span's lane)."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, track, attrs)

    def instant(self, name: str, track: tuple | None = None,
                **attrs) -> None:
        """A zero-duration marker (request submit / first token /
        retire)."""
        if not self.enabled:
            return
        stack = self._stack()
        if track is None:
            track = (stack[-1].track if stack
                     else ("host", threading.current_thread().name))
        self._record(SpanEvent(
            name=name, track=track,
            t0_s=time.perf_counter() - self._origin, dur_s=0.0,
            depth=len(stack), seq=0, attrs=attrs, instant=True))

    def record(self, name: str, track: tuple, t0: float, t1: float,
               depth: int = 0, **attrs) -> None:
        """Inject a span with explicit ``perf_counter`` endpoints -- used
        where one collective measurement covers several lanes (a batched
        decode launch recorded onto every participating request's
        swimlane)."""
        if not self.enabled:
            return
        self._record(SpanEvent(
            name=name, track=track, t0_s=t0 - self._origin,
            dur_s=max(0.0, t1 - t0), depth=depth, seq=0, attrs=attrs))

    # -- consumption --------------------------------------------------------
    def events(self) -> list[SpanEvent]:
        with self._lock:
            return list(self._events)

    def keys(self) -> list[tuple]:
        """Timing-free event identities in completion order."""
        return [ev.key() for ev in self.events()]

    def export_chrome(self, path: str) -> str:
        """Write the Chrome/Perfetto ``trace.json`` (see
        :func:`repro.obs.export.write_chrome_trace`)."""
        from repro.obs.export import write_chrome_trace
        return write_chrome_trace(path, self.events())


#: The process-wide tracer every instrumented layer shares.
trace = Tracer()
