"""Telemetry spine: structured tracing, a metrics registry, exporters.

One observability layer shared by the scheduler, the executables, the
ProgramCache, both backends and the kernel launch sites:

  trace     the process span tracer (``repro.obs.trace``) -- off by
            default, near-zero overhead when disabled, nestable and
            thread-safe; spans carry a *track* that becomes a
            Chrome/Perfetto swimlane (per-request lanes for serving)
  metrics   the process counter/gauge registry (``repro.obs.metrics``)
            -- MINISA vs micro instruction bytes, fetch-stall fractions,
            cache tier hits, KV pool high-water, kernel launches, all
            behind one labelled namespace with a Prometheus-style text
            snapshot
  export    ``chrome_trace``/``write_chrome_trace`` (Perfetto
            timelines), ``write_metrics_snapshot`` (Prometheus text),
            ``span_breakdown`` (fraction-of-tick-inside-kernels numbers
            for the mapper-autotuning work)

Quick start::

    from repro import obs
    obs.trace.enable()
    report = scheduler.run()              # spans + metrics accumulate
    obs.write_chrome_trace("trace.json")
    obs.write_metrics_snapshot("metrics.prom")
    report.timeline()                     # spans joined to requests

Tracing never feeds back into computation: a traced run's per-request
``state_checksum``s are bit-identical to an untraced run on every
backend (CI gates on this).
"""

from repro.obs import metrics  # noqa: F401
from repro.obs.export import (chrome_trace, span_breakdown,  # noqa: F401
                              write_chrome_trace,
                              write_metrics_snapshot)
from repro.obs.metrics import Registry  # noqa: F401
from repro.obs.trace import SpanEvent, Tracer, trace  # noqa: F401

__all__ = [
    "trace", "Tracer", "SpanEvent", "metrics", "Registry",
    "chrome_trace", "write_chrome_trace", "write_metrics_snapshot",
    "span_breakdown",
]
