"""Exporters: Chrome/Perfetto trace.json, Prometheus snapshot, breakdowns.

Chrome trace format (the subset Perfetto/chrome://tracing read):

  * one ``"X"`` (complete) event per finished span -- ``ts``/``dur`` in
    microseconds, ``args`` carrying the span attributes;
  * ``"i"`` (instant) events for zero-duration markers (request submit,
    first token, retire);
  * ``"M"`` metadata events naming the lanes: every distinct span track
    kind becomes a *process* row (``host``, ``request``, ...) and every
    distinct track id a named *thread* lane inside it -- so a serving
    run renders as per-request swimlanes (arrival -> TTFT -> per-tick
    decode spans) under the scheduler's host lane.

``span_breakdown`` post-processes events into "fraction of X inside Y"
numbers (e.g. the share of a decode tick spent inside kernel launches vs
host scheduling) -- the measurement substrate the fusion-aware mapper
autotuning (ROADMAP item 3) consumes.
"""

from __future__ import annotations

import json
from typing import Iterable, Sequence

from repro.obs.trace import SpanEvent, trace


def _lane_ids(events: Sequence[SpanEvent]) -> dict[tuple, tuple[int, int]]:
    """track -> (pid, tid): one pid per track kind, one tid per track."""
    kinds: dict[str, int] = {}
    lanes: dict[tuple, tuple[int, int]] = {}
    tids: dict[str, int] = {}
    for ev in events:
        kind = str(ev.track[0]) if ev.track else "host"
        if kind not in kinds:
            kinds[kind] = len(kinds) + 1
            tids[kind] = 0
        if ev.track not in lanes:
            tids[kind] += 1
            lanes[ev.track] = (kinds[kind], tids[kind])
    return lanes


def _json_safe(attrs: dict) -> dict:
    out = {}
    for k, v in attrs.items():
        if isinstance(v, (str, int, float, bool)) or v is None:
            out[k] = v
        elif isinstance(v, (list, tuple)):
            out[k] = [x if isinstance(x, (str, int, float, bool))
                      else str(x) for x in v]
        else:
            out[k] = str(v)
    return out


def chrome_trace(events: Sequence[SpanEvent] | None = None) -> dict:
    """Build the ``trace.json`` document for a list of span events
    (defaults to the shared tracer's)."""
    if events is None:
        events = trace.events()
    lanes = _lane_ids(events)
    out: list[dict] = []
    # lane naming metadata first: process = track kind, thread = track id
    named_pids: set[int] = set()
    for track, (pid, tid) in sorted(lanes.items(),
                                    key=lambda kv: kv[1]):
        if pid not in named_pids:
            named_pids.add(pid)
            out.append({"ph": "M", "name": "process_name", "pid": pid,
                        "tid": 0, "args": {"name": str(track[0])}})
        label = " ".join(str(p) for p in track)
        out.append({"ph": "M", "name": "thread_name", "pid": pid,
                    "tid": tid, "args": {"name": label}})
    for ev in events:
        pid, tid = lanes[ev.track]
        rec = {"name": ev.name, "cat": str(ev.track[0]),
               "pid": pid, "tid": tid,
               "ts": round(ev.t0_s * 1e6, 3),
               "args": _json_safe({**ev.attrs, "seq": ev.seq,
                                   "depth": ev.depth})}
        if ev.instant:
            rec.update(ph="i", s="t")       # thread-scoped instant
        else:
            rec.update(ph="X", dur=round(ev.dur_s * 1e6, 3))
        out.append(rec)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str,
                       events: Sequence[SpanEvent] | None = None) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(events), f, indent=1)
    return path


def write_metrics_snapshot(path: str, registry=None) -> str:
    """Write the Prometheus text exposition of a registry (defaults to
    the shared ``obs.metrics`` registry)."""
    from repro.obs import metrics as metricslib
    reg = registry if registry is not None else metricslib.REGISTRY
    with open(path, "w") as f:
        f.write(reg.render_prometheus())
    return path


def fault_events(events: Sequence[SpanEvent] | None = None) -> list[dict]:
    """Every event on the ``("fault", kind)`` swimlanes -- injections,
    recoveries, breaker transitions -- as serialisable dicts in time
    order: the chaos-run artifact CI uploads next to the full trace."""
    if events is None:
        events = trace.events()
    out = []
    for ev in sorted(events, key=lambda e: (e.t0_s, e.seq)):
        if ev.track and str(ev.track[0]) == "fault":
            out.append({"name": ev.name,
                        "kind": str(ev.track[1]) if len(ev.track) > 1
                        else "", "t_s": ev.t0_s,
                        **_json_safe(ev.attrs)})
    return out


def write_fault_events(path: str,
                       events: Sequence[SpanEvent] | None = None) -> str:
    with open(path, "w") as f:
        json.dump({"fault_events": fault_events(events)}, f, indent=1)
    return path


def span_breakdown(parent: str, children: Iterable[str],
                   events: Sequence[SpanEvent] | None = None) -> dict:
    """Time inside ``children`` spans as a fraction of ``parent`` spans.

    A child interval counts when it lies inside some parent interval
    (span nesting guarantees containment for genuinely nested work).
    Returns totals plus ``child_frac`` (kernel share) and ``host_frac``
    (the remainder: host scheduling, assembly, bookkeeping).

    A run with nothing to measure -- no parent spans, zero parent time,
    or no child (launch) spans inside them, e.g. an interpreter-only
    trace that never launched a kernel -- returns an *explicit empty
    breakdown*: ``empty=True`` with both fractions 0.0, never a divide
    by zero, a NaN, or a phantom ``host_frac == 1.0`` that would read
    as "the whole window was host time" when nothing was measured.
    """
    if events is None:
        events = trace.events()
    children = set(children)
    parents = [(ev.t0_s, ev.t1_s) for ev in events if ev.name == parent]
    parent_s = sum(t1 - t0 for t0, t1 in parents)
    child_s = 0.0
    n_children = 0
    for ev in events:
        if ev.name not in children:
            continue
        if any(t0 <= ev.t0_s and ev.t1_s <= t1 + 1e-9
               for t0, t1 in parents):
            child_s += ev.dur_s
            n_children += 1
    empty = not parents or parent_s <= 0.0 or n_children == 0
    frac = 0.0 if empty else child_s / parent_s
    return {"parent": parent, "n_parents": len(parents),
            "parent_s": parent_s, "child_s": child_s,
            "n_children": n_children, "empty": empty,
            "child_frac": frac,
            "host_frac": 0.0 if empty else max(0.0, 1.0 - frac)}
