"""falcon-mamba-7b [ssm] [arXiv:2410.05355;
unverified]: 64L pure Mamba-1, d_model=4096 (d_inner=8192), ssm_state=16,
vocab=65024.  Attention-free."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, vocab_size=65024,
    ssm_version=1, ssm_state=16,
)
