"""Architecture registry: ``--arch <id>`` resolution."""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, reduced

_ARCHS = {
    "whisper-base": "whisper_base",
    "gemma-7b": "gemma_7b",
    "qwen2-72b": "qwen2_72b",
    "qwen1.5-110b": "qwen15_110b",
    "minitron-4b": "minitron_4b",
    "zamba2-1.2b": "zamba2_1p2b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "internvl2-26b": "internvl2_26b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "deepseek-v2-236b": "deepseek_v2_236b",
}

ARCH_IDS = tuple(_ARCHS)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{_ARCHS[arch]}")
    return mod.CONFIG


def get_shape(shape: str) -> ShapeConfig:
    return SHAPES[shape]


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; long_500k only for sub-quadratic
    archs unless include_skipped."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape == "long_500k" and not cfg.supports_long_context:
                if include_skipped:
                    out.append((arch, shape, "SKIP(full-attention)"))
                continue
            out.append((arch, shape, "RUN") if include_skipped
                       else (arch, shape))
    return out
