"""Architecture + shape configuration schema."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    vocab_size: int
    # attention
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # mlp
    d_ff: int = 0
    mlp_act: str = "swiglu"          # swiglu | geglu | gelu | relu2
    # embeddings
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    shared_d_ff: int = 0
    first_k_dense: int = 0           # deepseek: leading dense layers
    dense_d_ff: int = 0
    capacity_factor: float = 1.25
    # MLA
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # SSM
    ssm_version: int = 0             # 0: none, 1: mamba1, 2: mamba2
    ssm_state: int = 0
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    ssm_heads: int = 0               # mamba2
    ssm_groups: int = 1              # mamba2 B/C groups
    # hybrid (zamba2): shared attention block applied every k SSM blocks
    attn_every: int = 0
    # enc-dec
    encoder_layers: int = 0
    cross_attention: bool = False
    # modality frontend stub
    frontend: str = "none"           # none | audio | vision
    frontend_len: int = 0
    # numerics
    dtype: str = "bfloat16"

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_dt_rank(self) -> int:
        return max(1, self.d_model // 16)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """long_500k runs only for sub-quadratic (SSM/hybrid) archs."""
        return self.family in ("ssm", "hybrid")

    @property
    def moe_enabled(self) -> bool:
        return self.num_experts > 0


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, layers: int = 2, d_model: int = 64,
            vocab: int = 512) -> ModelConfig:
    """Family-preserving reduced config for CPU smoke tests."""
    scale = d_model / max(cfg.d_model, 1)
    def sc(x, lo=1):
        return max(lo, int(round(x * scale)))
    heads = max(1, min(cfg.num_heads, 4))
    kvh = max(1, min(cfg.num_kv_heads, heads))
    while heads % kvh:
        kvh -= 1
    updates = dict(
        num_layers=layers,
        d_model=d_model,
        vocab_size=vocab,
        num_heads=heads,
        num_kv_heads=kvh,
        head_dim=16 if cfg.head_dim else 0,
        d_ff=4 * d_model if cfg.d_ff else 0,
        moe_d_ff=2 * d_model if cfg.moe_d_ff else 0,
        shared_d_ff=2 * d_model if cfg.shared_d_ff else 0,
        dense_d_ff=4 * d_model if cfg.dense_d_ff else 0,
        num_experts=min(cfg.num_experts, 8),
        experts_per_token=min(cfg.experts_per_token, 2),
        first_k_dense=min(cfg.first_k_dense, 1),
        q_lora_rank=32 if cfg.q_lora_rank else 0,
        kv_lora_rank=16 if cfg.kv_lora_rank else 0,
        qk_nope_head_dim=16 if cfg.qk_nope_head_dim else 0,
        qk_rope_head_dim=8 if cfg.qk_rope_head_dim else 0,
        v_head_dim=16 if cfg.v_head_dim else 0,
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        ssm_heads=min(cfg.ssm_heads, 4) if cfg.ssm_heads else 0,
        ssm_groups=1,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        frontend_len=min(cfg.frontend_len, 8) if cfg.frontend_len else 0,
        # generous capacity at smoke scale: no data-dependent expert drops
        capacity_factor=4.0,
        dtype="float32",
    )
    return dataclasses.replace(cfg, **updates)
