"""granite-moe-3b-a800m [moe]
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]: 32L, d_model=1536,
24H (GQA kv=8), expert d_ff=512, vocab=49155, 40 experts top-8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    head_dim=64, vocab_size=49155, mlp_act="swiglu",
    num_experts=40, experts_per_token=8, moe_d_ff=512,
    tie_embeddings=True,
)
