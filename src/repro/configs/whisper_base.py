"""whisper-base [audio]: enc-dec, conv frontend stubbed
[arXiv:2212.04356; unverified].  6L enc + 6L dec, d_model=512, 8H (kv=8),
d_ff=2048, vocab=51865.  The audio conv frontend is a STUB: input_specs()
provides precomputed 1500-frame encoder embeddings (30 s of audio)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    num_layers=6, encoder_layers=6, cross_attention=True,
    d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
    d_ff=2048, mlp_act="gelu", vocab_size=51865,
    tie_embeddings=True, frontend="audio", frontend_len=1500,
)
