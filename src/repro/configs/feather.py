"""FEATHER+ accelerator configurations (paper Tab. V).

The paper sweeps (AH, AW) in {(4, 4/16/64), (8, 8/32/128), (16, 16/64/256)}.
On-chip data SRAM scales with AH and is partitioned into streaming (40%),
stationary (40%) and output (20%) buffers.  A dedicated instruction buffer
(0.5 / 1 / 2 MB) is fed by a fixed off-chip instruction interface of
9 B/cycle.  Off-chip data bandwidth is AW B/cycle for inputs/weights and
4*AW B/cycle for outputs.  Datapath elements are INT8 (1 byte).
"""

from __future__ import annotations

import dataclasses
import math

MB = 1 << 20

# Per-AH on-chip capacities from Tab. V: (streaming, stationary, output,
# instruction) buffer bytes.  "StrB/StaB" are each 40% of data SRAM, OB 20%.
_CAPACITY_TABLE = {
    4: (int(1.6 * MB), int(1.6 * MB), int(0.8 * MB), int(0.5 * MB)),
    8: (int(6.4 * MB), int(6.4 * MB), int(3.2 * MB), int(1.0 * MB)),
    16: (int(25.6 * MB), int(25.6 * MB), int(12.8 * MB), int(2.0 * MB)),
}

#: The nine array configurations evaluated in the paper (§VI-A).
SWEEP = (
    (4, 4), (4, 16), (4, 64),
    (8, 8), (8, 32), (8, 128),
    (16, 16), (16, 64), (16, 256),
)


def _clog2(x: int) -> int:
    """ceil(log2(x)) for x >= 1."""
    if x <= 1:
        return 0
    return int(math.ceil(math.log2(x)))


@dataclasses.dataclass(frozen=True)
class FeatherConfig:
    """Static description of one FEATHER+ instance."""

    ah: int                      # NEST rows: per-PE dot-product length (VN size cap)
    aw: int                      # NEST columns (independent mapping units)
    str_bytes: int               # streaming buffer capacity
    sta_bytes: int               # stationary buffer capacity
    ob_bytes: int                # output buffer capacity
    instr_bytes: int             # instruction buffer capacity
    elem_bytes: int = 1          # INT8 datapath
    acc_bytes: int = 4           # partial-sum width in OB
    instr_bw: float = 9.0        # off-chip instruction interface, B/cycle
    # Micro-instruction calibration (see core/microinst.py for derivation).
    micro_pe_bits: float = 0.7   # unique per-PE control bits per cycle

    # ---- derived geometry -------------------------------------------------
    @property
    def in_bw(self) -> float:
        """Off-chip input/weight bandwidth, B/cycle."""
        return float(self.aw)

    @property
    def out_bw(self) -> float:
        """Off-chip output bandwidth, B/cycle."""
        return float(4 * self.aw)

    @property
    def d_str(self) -> int:
        """Streaming-buffer depth in rows of AW elements."""
        return self.str_bytes // (self.aw * self.elem_bytes)

    @property
    def d_sta(self) -> int:
        """Stationary-buffer depth in rows of AW elements."""
        return self.sta_bytes // (self.aw * self.elem_bytes)

    @property
    def d_ob(self) -> int:
        """Output-buffer depth per bank (AW banks of acc_bytes words)."""
        return self.ob_bytes // (self.aw * self.acc_bytes)

    @property
    def vn_capacity_str(self) -> int:
        """Max number of VNs resident in the streaming buffer."""
        return (self.d_str // self.ah) * self.aw

    @property
    def vn_capacity_sta(self) -> int:
        return (self.d_sta // self.ah) * self.aw

    @property
    def birrd_stages(self) -> int:
        """BIRRD (Benes-like) stage count: 2*ceil(log2(AW)) - 1."""
        return max(1, 2 * _clog2(self.aw) - 1)

    @property
    def birrd_switches(self) -> int:
        """2x2 switches per stage."""
        return self.aw // 2

    @property
    def pipeline_depth(self) -> int:
        """Cycles from first streamed element to first OB write."""
        return self.ah + self.birrd_stages + 2

    @property
    def peak_macs_per_cycle(self) -> int:
        return self.ah * self.aw

    # ---- ISA field widths (Fig. 3 / Fig. 5) -------------------------------
    # D refers to the stationary/streaming buffer depth in *elements per
    # column* (capacity/AW for 1-byte elements); D/AH is the number of VN
    # slots per column.
    @property
    def d_elems(self) -> int:
        """Per-column buffer depth D in elements (D_sta == D_str in Tab. V)."""
        return self.str_bytes // (self.aw * self.elem_bytes)

    @property
    def vn_slots_per_col(self) -> int:
        return max(1, self.d_elems // self.ah)

    @property
    def vn_slots_total(self) -> int:
        return self.vn_slots_per_col * self.aw

    def bits_set_layout(self) -> int:
        """Set*VNLayout width: OpCode(3) + Order(3) + L0(log2 AW)
        + L1/redL1 (log2(D/AH) each)."""
        return 3 + 3 + _clog2(self.aw) + 2 * _clog2(self.vn_slots_per_col)

    def bits_execute_mapping(self) -> int:
        """ExecuteMapping: OpCode(3) + G_r,G_c (log2 AW each)
        + r0,c0 (log2(D/AH * AW) each) + s_r,s_c (log2(D/AH) each)."""
        return (3 + 2 * _clog2(self.aw)
                + 2 * _clog2(self.vn_slots_total)
                + 2 * _clog2(self.vn_slots_per_col))

    def bits_execute_streaming(self) -> int:
        """ExecuteStreaming: OpCode(3) + df(1) + m0,s_m,T (log2(D/AH) each)
        + VN_SIZE (log2 AH).

        This formula reproduces Tab. V's E.Streaming column exactly for all
        nine configurations.
        """
        return 3 + 1 + 3 * _clog2(self.vn_slots_per_col) + _clog2(self.ah)

    def bits_load_store(self) -> int:
        """Load/Write: OpCode(3) + HBM address + length + target(1)."""
        hbm_bits = 33  # 8 GB addressable off-chip, paper leaves this open
        return 3 + hbm_bits + _clog2(self.d_elems * self.aw) + 1

    def bits_activation(self) -> int:
        """Activation: OpCode(3) + function-select(4) + target(1) + length."""
        return 3 + 4 + 1 + _clog2(self.d_elems * self.aw)


def feather_config(ah: int, aw: int, **overrides) -> FeatherConfig:
    if ah not in _CAPACITY_TABLE:
        # Off-table sizes (scalability studies): scale data SRAM ~ AH^2 like
        # the paper's table does (4->8->16 quadruples capacity).
        base = _CAPACITY_TABLE[16]
        scale = (ah / 16.0) ** 2
        caps = tuple(int(c * scale) for c in base[:3]) + (base[3],)
    else:
        caps = _CAPACITY_TABLE[ah]
    str_b, sta_b, ob_b, ins_b = caps
    return FeatherConfig(
        ah=ah, aw=aw, str_bytes=str_b, sta_bytes=sta_b,
        ob_bytes=ob_b, instr_bytes=ins_b, **overrides)


def sweep_configs() -> list[FeatherConfig]:
    return [feather_config(ah, aw) for ah, aw in SWEEP]
