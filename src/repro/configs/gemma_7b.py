"""gemma-7b [dense] [arXiv:2403.08295; hf]: 28L, d_model=3072,
16H (kv=16), head_dim=256, GeGLU d_ff=24576, vocab=256000, tied embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16,
    head_dim=256, d_ff=24576, mlp_act="geglu", vocab_size=256000,
    tie_embeddings=True,
)
