"""deepseek-v2-236b [moe] [arXiv:2405.04434; hf]:
60L, d_model=5120, 128H MLA (kv_lora=512, q_lora=1536, nope 128 + rope 64,
v 128), 2 shared + 160 routed experts top-6 (expert d_ff=1536), vocab=102400,
first layer dense (d_ff=12288)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    head_dim=128, vocab_size=102400, mlp_act="swiglu",
    mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    num_experts=160, experts_per_token=6, num_shared_experts=2,
    moe_d_ff=1536, shared_d_ff=3072,
    first_k_dense=1, dense_d_ff=12288,
)
