"""zamba2-1.2b [hybrid] [arXiv:2411.15242; hf]: 38 Mamba2
blocks, d_model=2048, shared attention block (32H kv=32, d_ff=8192) applied
every 6 blocks, ssm_state=64, vocab=32000.

Deviations noted in DESIGN.md: zamba2's shared-block input concatenation and
per-application LoRA deltas are simplified to per-application input norms."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, vocab_size=32000,
    num_heads=32, num_kv_heads=32, head_dim=64, d_ff=8192,
    mlp_act="gelu",
    ssm_version=2, ssm_state=64, ssm_heads=64, ssm_groups=1,
    attn_every=6,
)
