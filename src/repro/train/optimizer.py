"""AdamW with fp32 master weights, global-norm clipping and a
warmup+cosine schedule (pure JAX, optax-free)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (
        1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> dict:
    # copy=True: when params are already fp32, astype would alias the same
    # buffer and donation of (params, opt_state) would double-donate
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    return {
        "master": jax.tree.map(f32, params),
        "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


def update(cfg: OptimizerConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(master, mu, nu, g):
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        master = master - lr * (mhat / (jnp.sqrt(nhat) + cfg.eps)
                                + cfg.weight_decay * master)
        return master, mu, nu

    flat_m, tdef = jax.tree.flatten(state["master"])
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    flat_g = tdef.flatten_up_to(grads)
    out = [upd(m, u, n, g) for m, u, n, g in
           zip(flat_m, flat_mu, flat_nu, flat_g)]
    new_master = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    new_params = jax.tree.map(
        lambda m, p: m.astype(p.dtype), new_master, params)
    new_state = {"master": new_master, "mu": new_mu, "nu": new_nu,
                 "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


def state_axes(params_axes) -> dict:
    """Optimizer-state logical axes mirror the parameter axes."""
    return {"master": params_axes, "mu": params_axes, "nu": params_axes,
            "step": ()}
