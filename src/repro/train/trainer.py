"""train_step factory: value_and_grad + clip + AdamW, with optional
microbatch gradient accumulation (lax.scan) and cross-replica gradient
compression hooks.  Shardings are derived from logical axes, so the same
factory serves the 1-device smoke tests and the 512-device dry-run."""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist import sharding as shlib
from repro.models.api import Model
from repro.train import optimizer as optlib


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: optlib.OptimizerConfig = optlib.OptimizerConfig()
    grad_accum: int = 1
    remat: bool = True
    compress_grads: bool = False   # int8 cross-replica compression (dist/)


def make_loss_fn(model: Model, remat: bool):
    def loss_fn(params, batch):
        return model.loss(params, batch, remat=remat)
    return loss_fn


def make_train_step(model: Model, tcfg: TrainConfig):
    loss_fn = make_loss_fn(model, tcfg.remat)

    def train_step(params, opt_state, batch):
        if tcfg.grad_accum > 1:
            def micro(carry, mb):
                gsum, lsum = carry
                (loss, _), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return (gsum, lsum + loss), None

            mb_batch = jax.tree.map(
                lambda x: x.reshape((tcfg.grad_accum,
                                     x.shape[0] // tcfg.grad_accum)
                                    + x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)), mb_batch)
            grads = jax.tree.map(lambda g: g / tcfg.grad_accum, grads)
            loss = loss_sum / tcfg.grad_accum
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        if tcfg.compress_grads:
            from repro.dist.compression import fake_quantize_int8
            grads = jax.tree.map(fake_quantize_int8, grads)
        params, opt_state, opt_metrics = optlib.update(
            tcfg.opt, params, grads, opt_state)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return params, opt_state, metrics

    return train_step


def shardings_for(model: Model, mesh, batch_spec):
    """(params, opt_state, batch) shardings + abstract shapes."""
    params_axes = model.axes()
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_sh = shlib.tree_shardings(params_axes, params_shapes, mesh)
    opt_axes = optlib.state_axes(params_axes)
    opt_shapes = jax.eval_shape(optlib.init, params_shapes)
    o_sh = shlib.tree_shardings(
        {"master": params_axes, "mu": params_axes, "nu": params_axes},
        {"master": opt_shapes["master"], "mu": opt_shapes["mu"],
         "nu": opt_shapes["nu"]}, mesh)
    o_sh = {**o_sh, "step": shlib.replicated(mesh)}
    b_sh = shlib.batch_sharding(mesh, batch_spec)
    return (p_sh, o_sh, b_sh), (params_shapes, opt_shapes)
