"""Runtime fault injection + the tolerance primitives the scheduler uses.

Three pieces:

  :class:`FaultInjector`   executes a :class:`~repro.faults.plan.FaultPlan`
                           against a live run: the scheduler drives it
                           tick by tick (``begin_tick`` returns the due
                           scheduler-level events; launch windows arm
                           internally) and every injected / recovered /
                           skipped fault is counted, metered
                           (``faults_injected_total`` /
                           ``recoveries_total`` counters) and traced
                           onto a dedicated ``("fault", kind)`` swimlane.
  :class:`FaultyBackend`   a transparent wrapper over any ``Backend``:
                           each scheduler-visible launch entry point
                           consults the injector once and either raises
                           :class:`TransientLaunchError` (the launch
                           never happened) or poisons the finished
                           output with NaNs (the silent-corruption
                           case).  With no armed window the wrapper is a
                           delegating no-op.
  :class:`CircuitBreaker`  closed -> open after ``threshold`` consecutive
                           failures; half-open probe after ``cooldown``
                           ticks; one success closes it again.  The
                           scheduler consults it before admitting work
                           to the backend.

Everything here is deterministic: the injector consumes the plan's
windows in tick/launch order, so a seeded chaos run replays exactly.
"""

from __future__ import annotations

import dataclasses
import hashlib
import pickle

import numpy as np

from repro.faults.plan import LAUNCH_KINDS, FaultEvent, FaultPlan
from repro.obs import metrics as obs_metrics
from repro.obs.trace import trace


class FaultError(RuntimeError):
    """Base class of every injected (and injector-recognised) fault."""


class TransientLaunchError(FaultError):
    """An injected launch failure: the kernel never ran, no state was
    committed, and an identical retry is expected to succeed."""


class PoisonedOutputError(FaultError):
    """A launch completed but produced non-finite values (caught by the
    scheduler's finite guard before anything reaches the KV cache)."""


def check_finite(arr) -> bool:
    """True when every element of ``arr`` is finite (the post-launch
    numeric guard; NaN/Inf mean the output must not be committed)."""
    return bool(np.isfinite(np.asarray(arr, np.float32)).all())


class CircuitBreaker:
    """Consecutive-failure breaker over one site (the serving backend).

    closed     everything flows.
    open       ``allow`` is False until ``cooldown`` ticks after the trip
               -- the scheduler stops re-admitting work to the site.
    half-open  one probe is allowed; success closes, failure re-opens.
    """

    def __init__(self, threshold: int = 4, cooldown: int = 8):
        self.threshold = max(1, threshold)
        self.cooldown = max(1, cooldown)
        self.state = "closed"
        self.failures = 0            # consecutive
        self.opened_at = 0
        self.opens = 0

    def allow(self, tick: int) -> bool:
        if self.state == "closed":
            return True
        if tick - self.opened_at >= self.cooldown:
            self.state = "half_open"   # one probe through
            return True
        return False

    def record_failure(self, tick: int) -> None:
        self.failures += 1
        if self.state == "half_open" or self.failures >= self.threshold:
            if self.state != "open":
                self.opens += 1
                trace.instant("breaker_open", ("fault", "breaker"),
                              tick=tick, failures=self.failures)
            self.state = "open"
            self.opened_at = tick

    def record_success(self) -> None:
        self.failures = 0
        if self.state != "closed":
            trace.instant("breaker_close", ("fault", "breaker"))
        self.state = "closed"

    def stats(self) -> dict:
        return {"state": self.state, "opens": self.opens,
                "consecutive_failures": self.failures}


@dataclasses.dataclass
class _Window:
    """One armed launch-fault window (fires once per tick while active)."""
    event: FaultEvent
    fired_tick: int = -1

    def active(self, tick: int) -> bool:
        return (self.event.at_tick <= tick
                < self.event.at_tick + self.event.duration)


class FaultInjector:
    """Executes a FaultPlan against a live serving run."""

    def __init__(self, plan: FaultPlan, registry=None):
        self.plan = plan
        self.registry = (registry if registry is not None
                         else obs_metrics.REGISTRY)
        self.tick = 0
        self.injected: dict[str, int] = {}
        self.recovered: dict[str, int] = {}
        self.skipped: dict[str, int] = {}
        self._windows = [_Window(e) for e in plan.events
                         if e.kind in LAUNCH_KINDS]

    # -- the scheduler's tick hook -------------------------------------------
    def begin_tick(self, tick: int) -> tuple[FaultEvent, ...]:
        """Advance the injector clock; launch windows arm internally,
        scheduler-level events (array_down / kv_exhaust / cache_corrupt)
        are returned for the caller to apply."""
        self.tick = tick
        return tuple(e for e in self.plan.due(tick)
                     if e.kind not in LAUNCH_KINDS)

    # -- the backend wrapper's per-launch hook -------------------------------
    def launch_outcome(self) -> str | None:
        """Consulted once per guarded backend call: ``"transient"`` /
        ``"nan"`` while an armed window covers the current tick, None
        otherwise.  EVERY guarded launch of a covered tick gets the
        outcome -- the seam models "the backend is bad this tick", and
        chained streams keep interior state on-chip, so corrupting only
        one interior transfer would be invisible to the host.  The
        injection ledger still counts once per window per tick."""
        for w in self._windows:
            if w.active(self.tick):
                if w.fired_tick != self.tick:
                    w.fired_tick = self.tick
                    self.mark_injected(w.event.kind)
                return ("transient" if w.event.kind == "launch_transient"
                        else "nan")
        return None

    def wrap(self, backend) -> "FaultyBackend":
        return FaultyBackend(backend, self)

    # -- accounting ----------------------------------------------------------
    def mark_injected(self, kind: str, **attrs) -> None:
        self.injected[kind] = self.injected.get(kind, 0) + 1
        self.registry.counter(
            "faults_injected_total", "injected faults by kind").inc(
                1, kind=kind)
        trace.instant("fault", ("fault", kind), tick=self.tick, **attrs)

    def mark_recovered(self, kind: str, **attrs) -> None:
        self.recovered[kind] = self.recovered.get(kind, 0) + 1
        self.registry.counter(
            "recoveries_total", "recovered faults by kind").inc(
                1, kind=kind)
        trace.instant("recovery", ("fault", kind), tick=self.tick, **attrs)

    def mark_skipped(self, kind: str) -> None:
        """An event that was due but not applicable (e.g. ``array_down``
        on a single-array run) -- recorded, never counted as injected."""
        self.skipped[kind] = self.skipped.get(kind, 0) + 1

    def unrecovered(self) -> int:
        """Injected faults with no matching recovery (per kind, clamped
        -- extra recoveries never mask another kind's miss).  The chaos
        gate requires this to be zero."""
        kinds = set(self.injected) | set(self.recovered)
        return sum(max(0, self.injected.get(k, 0)
                       - self.recovered.get(k, 0)) for k in kinds)

    def summary(self) -> dict:
        return {"plan": self.plan.name, "seed": self.plan.seed,
                "injected": dict(self.injected),
                "recovered": dict(self.recovered),
                "skipped": dict(self.skipped),
                "unrecovered": self.unrecovered()}

    # -- disk corruption (the cache_corrupt seam) ----------------------------
    def corrupt_cache_file(self, path: str) -> bool:
        """Corrupt one persisted ProgramCache entry in place.

        The persisted payload keeps each entry as (pickled blob, sha256);
        flipping bytes inside a seeded entry's blob leaves the outer
        payload readable, so the next load exercises the *per-entry*
        integrity path: checksum mismatch -> quarantine -> miss.  Falls
        back to truncating the file (the torn-write shape) when the
        payload doesn't parse.  Returns True when something was
        corrupted."""
        rng = np.random.default_rng(self.plan.seed * 7_919 + self.tick)
        try:
            with open(path, "rb") as f:
                payload = pickle.load(f)
            tiers = payload.get("tiers", {})
            candidates = [(t, i) for t, entries in sorted(tiers.items())
                          for i in range(len(entries))]
            if not candidates:
                raise ValueError("no entries to corrupt")
            tier, idx = candidates[int(rng.integers(0, len(candidates)))]
            blob, digest = tiers[tier][idx]
            flipped = bytearray(blob)
            pos = int(rng.integers(0, max(1, len(flipped))))
            flipped[pos] ^= 0xFF
            tiers[tier][idx] = (bytes(flipped), digest)
            with open(path, "wb") as f:
                pickle.dump(payload, f, protocol=pickle.HIGHEST_PROTOCOL)
            return True
        except FaultError:
            raise
        except Exception:
            try:   # torn-write shape: drop the tail of the file
                with open(path, "rb") as f:
                    data = f.read()
                if not data:
                    return False
                with open(path, "wb") as f:
                    f.write(data[:max(1, len(data) // 2)])
                return True
            except OSError:
                return False


def _entry_digest(blob: bytes) -> str:
    """The per-entry content checksum the disk tier carries (shared with
    ``runtime.cache`` so inject/verify can never drift apart)."""
    return hashlib.sha256(blob).hexdigest()


class FaultyBackend:
    """Injection wrapper over any Backend: delegates everything, guarding
    the scheduler-visible launch entry points.

    One guard per call (a fused segment is one launch, a batched
    attention sweep is one launch), matching what ``Backend.n_launches``
    counts on the compiled backend.  Attribute access (``outputs``,
    ``n_launches``, ``reset`` ...) passes through, so schedulers and
    executables treat the wrapper exactly like the wrapped instance.
    """

    def __init__(self, inner, injector: FaultInjector):
        self._inner = inner
        self._injector = injector

    # -- guard ---------------------------------------------------------------
    def _guard(self, fn, out_name, *args, **kwargs):
        outcome = self._injector.launch_outcome()
        if outcome == "transient":
            raise TransientLaunchError(
                f"injected transient launch failure at tick "
                f"{self._injector.tick}")
        out = fn(*args, **kwargs)
        if outcome == "nan":
            out = self._poison(out, out_name)
        return out

    @staticmethod
    def _poison(out, out_name):
        """NaN-poison the launch's result (dict entry or raw array) --
        the injected copy never aliases backend state, mirroring a
        corrupted transfer of the real output."""
        if isinstance(out, dict):
            if out_name is not None and out_name in out:
                out = dict(out)
                out[out_name] = np.full_like(
                    np.asarray(out[out_name], np.float32), np.nan)
            return out
        poisoned = np.asarray(out, np.float32).copy()
        poisoned[...] = np.nan
        return poisoned

    # -- guarded launch entry points -----------------------------------------
    def run_program(self, program, tensors=None):
        return self._guard(self._inner.run_program,
                           getattr(program, "out_name", None),
                           program, tensors)

    def run_segment(self, segment, tensors=None):
        return self._guard(self._inner.run_segment,
                           getattr(segment, "out_name", None),
                           segment, tensors)

    def run_sharded(self, sharded, tensors=None):
        return self._guard(self._inner.run_sharded,
                           getattr(sharded, "out_name", None),
                           sharded, tensors)

    def run_batched_attention(self, programs, q, kT, v, lengths=None):
        return self._guard(self._inner.run_batched_attention, None,
                           programs, q, kT, v, lengths=lengths)

    def run_batched_attention_proj(self, programs, q, kT, v, wo, *,
                                   m_out, k_out, lengths=None):
        return self._guard(self._inner.run_batched_attention_proj, None,
                           programs, q, kT, v, wo, m_out=m_out,
                           k_out=k_out, lengths=lengths)

    # -- passthrough ---------------------------------------------------------
    def __getattr__(self, name):
        return getattr(self._inner, name)

    def __repr__(self) -> str:  # pragma: no cover
        return f"FaultyBackend({self._inner!r})"
