"""FaultPlan: a seeded, deterministic script of what fails, and when.

MINISA's thesis is that the *hardware* control path stops being the
fragile part; this package makes the *serving* stack prove the same
property under injected failure.  A :class:`FaultPlan` is a value object
-- a tuple of :class:`FaultEvent` entries pinned to scheduler ticks --
so a chaos run is exactly as reproducible as a fault-free one: the same
(plan seed, scheduler seed) pair replays the identical failure sequence,
and the recovery machinery can be regression-tested bit-for-bit
(``RequestReport.state_checksum`` against the fault-free trajectory).

Fault kinds, one per seam the runtime exposes:

  ``launch_transient``  a backend launch raises (the kernel never ran);
                        armed for ``duration`` ticks from ``at_tick``,
                        failing the first guarded launch of each tick in
                        the window.  A long window models a wedged
                        backend (deadline/timeout territory).
  ``launch_nan``        a backend launch *completes* but its output is
                        NaN/Inf-poisoned -- the silent-corruption case
                        the scheduler's finite guard must catch before
                        anything reaches the KV cache.
  ``array_down``        logical array ``site`` of the ArrayMesh goes
                        unhealthy: the scheduler fails over to a
                        degraded mesh (re-lowering in-flight programs).
  ``kv_exhaust``        a page-pressure spike: ``pages`` KV pages vanish
                        from the pool for ``duration`` ticks (admission
                        must stall, never crash).
  ``cache_corrupt``     the ProgramCache's persisted disk tier is
                        corrupted in place (one entry's bytes flipped);
                        the next load must quarantine, count a miss and
                        re-derive -- never raise mid-serve.

The module holds no injection machinery -- see ``faults.inject`` for the
runtime side (injector, backend wrapper, circuit breaker).
"""

from __future__ import annotations

import dataclasses

#: Every fault kind a plan may carry (order is the display order).
FAULT_KINDS = ("launch_transient", "launch_nan", "array_down",
               "kv_exhaust", "cache_corrupt")

#: Kinds armed as per-tick launch windows (consumed by the backend
#: wrapper) rather than applied once by the scheduler.
LAUNCH_KINDS = ("launch_transient", "launch_nan")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted failure.

    ``at_tick`` is the scheduler tick the event becomes due (tick
    numbering starts at 1, matching ``SchedulerReport.ticks``).
    ``site`` is the array index for ``array_down`` and unused otherwise;
    ``duration`` is the window length in ticks for launch faults and
    ``kv_exhaust``; ``pages`` the spike size for ``kv_exhaust`` (0 ==
    "everything free", the worst case)."""

    kind: str
    at_tick: int
    site: int = 0
    duration: int = 1
    pages: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {FAULT_KINDS})")
        if self.at_tick < 1:
            raise ValueError(f"at_tick must be >= 1, got {self.at_tick}")
        if self.duration < 1:
            raise ValueError(f"duration must be >= 1, got {self.duration}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, seeded failure script.

    Equality is structural, so two plans built from the same seed compare
    equal -- the determinism surface ``tests/test_faults.py`` regresses.
    """

    events: tuple[FaultEvent, ...] = ()
    seed: int = 0
    name: str = "faultplan"

    def __post_init__(self):
        # events sort by (tick, kind) so iteration order never depends on
        # construction order
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events,
                         key=lambda e: (e.at_tick, FAULT_KINDS.index(e.kind),
                                        e.site))))

    def due(self, tick: int) -> tuple[FaultEvent, ...]:
        """Events that become due exactly at ``tick``."""
        return tuple(e for e in self.events if e.at_tick == tick)

    def counts(self) -> dict[str, int]:
        out = {k: 0 for k in FAULT_KINDS}
        for e in self.events:
            out[e.kind] += 1
        return out

    @property
    def last_tick(self) -> int:
        return max((e.at_tick + e.duration for e in self.events), default=0)

    def summary(self) -> dict:
        return {"name": self.name, "seed": self.seed,
                "n_events": len(self.events), "counts": self.counts(),
                "last_tick": self.last_tick,
                "events": [dataclasses.asdict(e) for e in self.events]}

    # -- constructors --------------------------------------------------------
    @staticmethod
    def from_seed(seed: int, *, n_events: int = 6, n_ticks: int = 12,
                  n_arrays: int = 1, kinds: tuple[str, ...] | None = None,
                  name: str | None = None) -> "FaultPlan":
        """A random-but-reproducible plan: ``n_events`` draws over
        ``kinds`` (defaults to every kind applicable to ``n_arrays``)
        spread over ticks ``[1, n_ticks]``.  Same seed, same plan --
        byte-for-byte."""
        import numpy as np

        if kinds is None:
            kinds = tuple(k for k in FAULT_KINDS
                          if k != "array_down" or n_arrays > 1)
        rng = np.random.default_rng(seed)
        events = []
        for _ in range(n_events):
            kind = kinds[int(rng.integers(0, len(kinds)))]
            tick = int(rng.integers(1, max(2, n_ticks + 1)))
            dur = int(rng.integers(1, 4)) if kind != "cache_corrupt" else 1
            site = (int(rng.integers(1, n_arrays))
                    if kind == "array_down" and n_arrays > 1 else 0)
            pages = int(rng.integers(0, 4)) if kind == "kv_exhaust" else 0
            events.append(FaultEvent(kind=kind, at_tick=tick, site=site,
                                     duration=dur, pages=pages))
        return FaultPlan(events=tuple(events), seed=seed,
                         name=name or f"seeded-{seed}")

    @staticmethod
    def standard(seed: int = 0, *, n_arrays: int = 2) -> "FaultPlan":
        """The CI chaos plan: at least one of every fault kind, early
        enough that a short serving run exercises every recovery path
        (array failover at tick 2, a transient launch window at 3, a KV
        page spike over 4-6, a NaN-poisoned launch at 5 and a disk-tier
        corruption at 6)."""
        events = [
            FaultEvent("launch_transient", at_tick=3, duration=1),
            FaultEvent("launch_nan", at_tick=5, duration=1),
            FaultEvent("kv_exhaust", at_tick=4, duration=3, pages=0),
            FaultEvent("cache_corrupt", at_tick=6),
        ]
        if n_arrays > 1:
            events.append(FaultEvent("array_down", at_tick=2, site=1))
        return FaultPlan(events=tuple(events), seed=seed,
                         name=f"standard-{seed}")
