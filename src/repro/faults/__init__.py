"""Fault injection + fault tolerance for the serving runtime.

``plan``    the seeded, deterministic failure script (value objects).
``inject``  the runtime side: injector, backend wrapper, breaker.

See the README's "Resilience" section for the fault model and how the
scheduler recovers from each kind.
"""

from repro.faults.inject import (CircuitBreaker, FaultError, FaultInjector,
                                 FaultyBackend, PoisonedOutputError,
                                 TransientLaunchError, check_finite)
from repro.faults.plan import (FAULT_KINDS, LAUNCH_KINDS, FaultEvent,
                               FaultPlan)

__all__ = [
    "FAULT_KINDS", "LAUNCH_KINDS", "FaultEvent", "FaultPlan",
    "FaultInjector", "FaultyBackend", "CircuitBreaker",
    "FaultError", "TransientLaunchError", "PoisonedOutputError",
    "check_finite",
]
