"""Batched serving engine: prefill -> greedy/temperature decode loop with a
fixed-capacity KV cache (the decode_32k / long_500k cells lower exactly the
``decode_step`` this engine calls)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0   # 0 => greedy
    seed: int = 0


def expand_cache(model: Model, cache, batch: int, max_len: int):
    """Pad a prefill cache (seq == prompt length) out to max_len slots."""
    spec = model.cache_spec(batch, max_len)

    def pad(c, s):
        if c.shape == s.shape:
            return c.astype(s.dtype)
        widths = [(0, t - c_) for c_, t in zip(c.shape, s.shape)]
        return jnp.pad(c, widths).astype(s.dtype)

    return jax.tree.map(pad, cache, spec)


class Engine:
    def __init__(self, model: Model, params, scfg: ServeConfig):
        self.model = model
        self.params = params
        self.scfg = scfg
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)

    def generate(self, prompts: np.ndarray, steps: int,
                 frames=None, prefix_embeds=None) -> np.ndarray:
        """prompts: [B, P] int32; returns [B, steps] generated tokens."""
        b, p = prompts.shape
        kwargs = {}
        if frames is not None:
            kwargs["frames"] = frames
        if prefix_embeds is not None:
            kwargs["prefix_embeds"] = prefix_embeds
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      **kwargs)
        cache = expand_cache(self.model, cache, b, self.scfg.max_len)
        tok = self._sample(logits, 0)
        out = [tok]
        pos = jnp.full((b,), p, jnp.int32)
        for i in range(steps - 1):
            logits, cache = self._decode(self.params, tok[:, None], cache,
                                         pos)
            tok = self._sample(logits, i + 1)
            out.append(tok)
            pos = pos + 1
        return np.stack([np.asarray(t) for t in out], axis=1)

    def _sample(self, logits, step):
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        key = jax.random.fold_in(jax.random.PRNGKey(self.scfg.seed), step)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)
