"""Fig. 13: cycle breakdown (compute / load / out->stream / store) and
compute utilization for representative workloads on FEATHER+ 4x64, 16x64,
16x256.  Paper: >60% utilization on irregular FHE/ZKP shapes."""

from repro.configs.feather import feather_config
from repro.core import mapper

REP = [
    mapper.Gemm(m=65536, k=40, n=88, name="fhe-bconv-40x88"),
    mapper.Gemm(m=65536, k=30, n=112, name="fhe-bconv-30x112"),
    mapper.Gemm(m=64, k=1024, n=1024, name="fhe-ntt-1k"),
    mapper.Gemm(m=256, k=4096, n=4096, name="fhe-ntt-4k"),
    mapper.Gemm(m=512, k=16384, n=16384, name="zkp-ntt-16k"),
    mapper.Gemm(m=2048, k=2880, n=4096, name="gpt-oss-2880x4096"),
    mapper.Gemm(m=2048, k=64, n=2048, name="gpt-oss-64x2048"),
]

ARRAYS = [(4, 64), (16, 64), (16, 256)]


def run(verbose: bool = True) -> dict:
    rows = {}
    for ah, aw in ARRAYS:
        cfg = feather_config(ah, aw)
        for g in REP:
            plan = mapper.search(g, cfg)
            res = plan.perf_minisa
            b = res.breakdown()
            rows[(f"{ah}x{aw}", g.name)] = {
                "utilization": res.utilization,
                "cycles": res.cycles,
                **{k: v / max(res.cycles, 1e-9)
                   for k, v in b.items() if k != "total"},
            }
    if verbose:
        print("\n[Fig. 13] latency breakdown + utilization (MINISA)")
        print(f"{'array':>7} {'workload':>20} {'util':>7} {'compute':>8} "
              f"{'load':>7} {'o2s':>6} {'store':>6}")
        for (arr, name), r in rows.items():
            print(f"{arr:>7} {name:>20} {r['utilization']:7.1%} "
                  f"{r.get('compute', 0):8.1%} {r.get('load', 0):7.1%} "
                  f"{r.get('out2stream', 0):6.1%} {r.get('store', 0):6.1%}")
    return rows
