"""Serving-runtime benchmark: batched decode vs per-request fused decode.

    PYTHONPATH=src python -m benchmarks.serve_runtime [--quick]
        [--json PATH] [--merge] [--gate]

Runs the continuous-batching :class:`repro.runtime.Scheduler` over a
reduced (arch x shape) serving cell in three modes, all fed the identical
submission sequence (per-request ``state_checksum``s are asserted
bit-equal across the modes before any number is reported):

  interpreter         sequential per-request, per-layer Programs -- the
                      reference trajectory
  pallas_per_request  one fused-segment launch chain per request per
                      decode step (the prior serving fast path)
  pallas              cross-request batched decode: every active
                      request's token stacked along M, ONE launch per
                      segment per tick through the M-polymorphic
                      ``BatchPlan`` (+ flash-decode attention over the
                      paged per-request KV)

Per mode the table reports wall-clock tok/s, decode-phase tok/s,
time-to-first-token and end-to-end latency percentiles (TTFT is decode-
independent -- it measures queueing + prefill -- so it is reported
separately from decode throughput), kernel launches per decode tick and
ProgramCache reuse.  The headline ``decode_serving`` section records the
batched-vs-per-request decode speedup; ``--gate`` exits non-zero if the
batched path regresses below the per-request fused path.  ``--merge``
folds ``decode_serving`` into an existing ``BENCH_results.json`` (the CI
serving perf-smoke step); ``benchmarks/run.py`` also embeds the per-mode
summaries directly.

After the timed modes, the batched mode re-runs once under the ``obs``
tracer (untimed): its per-request ``state_checksum``s are asserted
bit-equal to the untraced run -- tracing must never perturb serving
numerics -- and the span buffer yields ``decode_tick_kernel_frac`` (the
measured fraction of a decode tick spent inside kernel launches vs host
scheduling) for the BENCH entry.  ``--trace PATH`` writes the
Chrome/Perfetto ``trace.json`` (per-request swimlanes) and
``--metrics PATH`` the Prometheus-style snapshot from that traced run.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

#: (mode name, Scheduler kwargs) -- identical submissions, three paths
MODES = (
    ("interpreter", dict(backend="interpreter", batch_decode=False,
                         use_fused=False)),
    ("pallas_per_request", dict(backend="pallas", batch_decode=False,
                                use_fused=True)),
    ("pallas", dict(backend="pallas", batch_decode=True, use_fused=True)),
)


def _serve(prefill, decode, n_requests, decode_steps, max_concurrent,
           **kw):
    from repro.runtime import Scheduler
    sched = Scheduler(prefill, decode, max_concurrent=max_concurrent,
                      **kw)
    for _ in range(n_requests):
        sched.submit(decode_steps=decode_steps)
    return sched.run()


def run(quick: bool = False, arch: str = "gemma-7b",
        n_requests: int = 8, decode_steps: int = 4,
        max_concurrent: int = 8) -> dict[str, dict]:
    from repro.configs.feather import feather_config
    from repro.runtime import ModelExecutable, ProgramCache, Scheduler

    if quick:
        decode_steps = 3
    cfg = feather_config(4, 16)
    cache = ProgramCache()   # one cache across every mode
    prefill = ModelExecutable.for_cell(arch, "prefill_tiny", cfg,
                                       cache=cache)
    decode = ModelExecutable.for_cell(arch, "decode_tiny", cfg,
                                      cache=cache)

    # Warm the pallas compile tiers (m=1 fused segments + the batched
    # bucket this concurrency hits) so both timed pallas modes measure
    # steady-state serving, not first-call trace cost.
    for _, kw in MODES[1:]:
        _serve(prefill, decode, n_requests=max_concurrent, decode_steps=1,
               max_concurrent=max_concurrent, **kw)

    out: dict[str, dict] = {}
    checksums: dict[str, dict] = {}
    print(f"{'mode':>19} {'tok/s':>9} {'decode tok/s':>13} "
          f"{'ttft_p50 ms':>12} {'p95 lat ms':>11} {'launch/tick':>12} "
          f"{'hit_rate':>9}")
    for mode, kw in MODES:
        before = cache.stats.snapshot()
        rep = _serve(prefill, decode, n_requests=n_requests,
                     decode_steps=decode_steps,
                     max_concurrent=max_concurrent, **kw)
        s = rep.summary()
        s["cache_delta"] = cache.stats.delta(before)
        s["arch"] = arch
        s["decode_steps"] = decode_steps
        out[mode] = s
        checksums[mode] = {r.rid: r.state_checksum for r in rep.requests}
        lpt = s["launches_per_decode_tick"]
        print(f"{mode:>19} {s['tokens_per_sec']:9.1f} "
              f"{s['decode_tokens_per_sec']:13.1f} "
              f"{s['ttft_p50_s'] * 1e3:12.2f} "
              f"{s['latency_p95_s'] * 1e3:11.2f} "
              f"{lpt if lpt else 0.0:12.1f} {s['cache_hit_rate']:9.2f}")

    ref = checksums["interpreter"]
    for mode, sums in checksums.items():
        assert sums == ref, (
            f"state_checksum divergence: {mode} vs interpreter")

    # Traced re-run of the batched mode (untimed): the tracing-on
    # checksums must equal the tracing-off ones, and the span buffer
    # yields the decode-tick kernel/host breakdown for the BENCH entry.
    from repro.obs import export as obs_export
    from repro.obs import metrics as obs_metrics
    from repro.obs.trace import trace
    obs_metrics.reset()
    trace.clear().enable()
    try:
        traced = _serve(prefill, decode, n_requests=n_requests,
                        decode_steps=decode_steps,
                        max_concurrent=max_concurrent, **dict(MODES[2][1]))
    finally:
        trace.disable()
    traced_sums = {r.rid: r.state_checksum for r in traced.requests}
    assert traced_sums == ref, (
        "tracing perturbed serving state: traced pallas checksums "
        "diverged from the untraced run")
    breakdown = obs_export.span_breakdown("decode_tick", {"launch"})

    per, bat = out["pallas_per_request"], out["pallas"]
    speedup = (bat["decode_tokens_per_sec"]
               / max(per["decode_tokens_per_sec"], 1e-9))
    out["decode_serving"] = {
        "arch": arch,
        "n_requests": n_requests,
        "max_concurrent": max_concurrent,
        "decode_steps": decode_steps,
        "decode_tok_s_per_request": per["decode_tokens_per_sec"],
        "decode_tok_s_batched": bat["decode_tokens_per_sec"],
        "batched_decode_speedup": speedup,
        "launches_per_decode_tick_per_request":
            per["launches_per_decode_tick"],
        "launches_per_decode_tick_batched":
            bat["launches_per_decode_tick"],
        "ttft_p50_s": bat["ttft_p50_s"],
        "ttft_p95_s": bat["ttft_p95_s"],
        "ttft_p99_s": bat["ttft_p99_s"],
        "latency_p50_s": bat["latency_p50_s"],
        "latency_p95_s": bat["latency_p95_s"],
        "latency_p99_s": bat["latency_p99_s"],
        "kv_high_water_pages": bat["kv"].get("high_water_pages", 0),
        "checksums_match": True,
        "traced_checksums_match": True,
        "decode_tick_kernel_frac": breakdown["child_frac"],
        "decode_tick_host_frac": breakdown["host_frac"],
        "decode_ticks_traced": breakdown["n_parents"],
    }
    print(f"batched decode speedup over per-request fused: "
          f"{speedup:.2f}x at {max_concurrent} concurrent "
          f"({bat['launches_per_decode_tick']} launches/tick vs "
          f"{per['launches_per_decode_tick']})")
    print(f"decode tick breakdown (traced): "
          f"{breakdown['child_frac'] * 100:.1f}% in kernel launches, "
          f"{breakdown['host_frac'] * 100:.1f}% host scheduling "
          f"over {breakdown['n_parents']} ticks")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI sizes")
    ap.add_argument("--arch", default="gemma-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--decode-steps", type=int, default=4)
    ap.add_argument("--concurrent", type=int, default=8)
    ap.add_argument("--json", default="", help="write results to PATH")
    ap.add_argument("--merge", action="store_true",
                    help="merge into an existing BENCH_results.json "
                         "instead of overwriting")
    ap.add_argument("--gate", action="store_true",
                    help="exit non-zero if batched decode tok/s falls "
                         "below the per-request fused path")
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="write the traced run's Chrome/Perfetto "
                         "trace.json (open in ui.perfetto.dev)")
    ap.add_argument("--metrics", default="", metavar="PATH",
                    help="write the traced run's Prometheus-style "
                         "metrics snapshot")
    args = ap.parse_args()
    result = run(quick=args.quick, arch=args.arch,
                 n_requests=args.requests,
                 decode_steps=args.decode_steps,
                 max_concurrent=args.concurrent)
    serving = result["decode_serving"]
    if args.trace:
        from repro.obs.export import write_chrome_trace
        print(f"wrote {write_chrome_trace(args.trace)}")
    if args.metrics:
        from repro.obs.export import write_metrics_snapshot
        print(f"wrote {write_metrics_snapshot(args.metrics)}")
    if args.json:
        payload = {}
        if args.merge and os.path.exists(args.json):
            with open(args.json) as f:
                payload = json.load(f)
        payload.setdefault("results", {})["decode_serving"] = {
            "derived": f"batched_decode_speedup="
                       f"{serving['batched_decode_speedup']:.3g}",
            **serving,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    if args.gate and serving["batched_decode_speedup"] < 1.0:
        print(f"FAIL: batched decode "
              f"({serving['decode_tok_s_batched']:.1f} tok/s) regressed "
              f"below per-request fused "
              f"({serving['decode_tok_s_per_request']:.1f} tok/s)")
        sys.exit(1)


if __name__ == "__main__":
    main()
