"""Serving-runtime benchmark: scheduler throughput + compile-cache reuse.

    PYTHONPATH=src python -m benchmarks.serve_runtime [--quick]

Runs the continuous-batching :class:`repro.runtime.Scheduler` over a
reduced (arch x shape) serving cell on both execution backends.  For each
backend the prefill/decode executables are compiled once through a shared
ProgramCache and then serve several concurrent requests; reported per
backend:

  tokens_per_sec     wall-clock serving throughput (prefill + decode)
  cache_hit_rate     ProgramCache hits / (hits + misses) across the
                     whole build+serve (plans, lowerings, compiles)
  searches/compiles  real mapper searches and backend compiles performed
                     (the second backend's build is expected to re-search
                     nothing: plans are backend-independent)
  minisa/micro bytes per-request instruction traffic from the same tile
                     streams perf.simulate consumes, plus stall fractions

``benchmarks/run.py`` merges these numbers into ``BENCH_results.json``.
"""

from __future__ import annotations

import argparse


def run(quick: bool = False, arch: str = "gemma-7b",
        n_requests: int = 4, decode_steps: int = 3,
        max_concurrent: int = 2) -> dict[str, dict]:
    from repro.configs.feather import feather_config
    from repro.runtime import ModelExecutable, ProgramCache, Scheduler

    if quick:
        n_requests, decode_steps = 2, 2
    cfg = feather_config(4, 16)
    cache = ProgramCache()   # one cache across both backends
    out: dict[str, dict] = {}
    print(f"{'backend':>12} {'tok/s':>10} {'hit_rate':>9} {'searches':>9} "
          f"{'compiles':>9} {'minisa_B/req':>13} {'instr_red':>10}")
    for backend in ("interpreter", "pallas"):
        before = cache.stats.snapshot()
        prefill = ModelExecutable.for_cell(arch, "prefill_tiny", cfg,
                                           cache=cache)
        decode = ModelExecutable.for_cell(arch, "decode_tiny", cfg,
                                          cache=cache)
        sched = Scheduler(prefill, decode, backend=backend,
                          max_concurrent=max_concurrent)
        for _ in range(n_requests):
            sched.submit(decode_steps=decode_steps)
        report = sched.run()
        s = report.summary()
        s["cache_delta"] = cache.stats.delta(before)
        s["arch"] = arch
        s["decode_steps"] = decode_steps
        out[backend] = s
        print(f"{backend:>12} {s['tokens_per_sec']:10.1f} "
              f"{s['cache_hit_rate']:9.2f} {s['cache_searches']:9d} "
              f"{s['cache_compiles']:9d} "
              f"{s['minisa_bytes_per_request']:13.0f} "
              f"{s['micro_bytes_per_request'] / max(s['minisa_bytes_per_request'], 1e-9):10.0f}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI sizes")
    ap.add_argument("--arch", default="gemma-7b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--decode-steps", type=int, default=3)
    args = ap.parse_args()
    run(quick=args.quick, arch=args.arch, n_requests=args.requests,
        decode_steps=args.decode_steps)


if __name__ == "__main__":
    main()
