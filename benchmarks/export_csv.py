"""Export the paper-appendix CSV artifacts (benchmark summary, instruction
comparison, utilization/reduction summaries, roofline) to ``csv/``.

    PYTHONPATH=src python -m benchmarks.export_csv
"""

from __future__ import annotations

import csv
import os

from benchmarks.common import sweep_plans
from benchmarks import roofline as rl
from repro.configs.feather import SWEEP


def main(outdir: str = "csv") -> None:
    os.makedirs(outdir, exist_ok=True)
    plans = sweep_plans()

    # 1) benchmark summary: every workload x array config
    with open(f"{outdir}/benchmark_summary.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["workload", "array", "df", "vn", "cycles_minisa",
                    "cycles_micro", "speedup", "utilization",
                    "stall_micro", "stall_minisa"])
        for key in SWEEP:
            for name, p in plans[key].items():
                s = p.summary()
                w.writerow([name, s["array"], s["df"], s["vn"],
                            f"{s['cycles_minisa']:.6g}",
                            f"{s['cycles_micro']:.6g}",
                            f"{s['speedup']:.4f}",
                            f"{s['util_minisa']:.4f}",
                            f"{s['stall_micro']:.4f}",
                            f"{s['stall_minisa']:.6f}"])

    # 2) instruction comparison
    with open(f"{outdir}/instruction_comparison.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["workload", "array", "instr_bytes_minisa",
                    "instr_bytes_micro", "reduction", "data_bytes",
                    "instr_to_data_minisa", "instr_to_data_micro"])
        for key in SWEEP:
            for name, p in plans[key].items():
                s = p.summary()
                w.writerow([name, s["array"],
                            f"{s['instr_bytes_minisa']:.6g}",
                            f"{s['instr_bytes_micro']:.6g}",
                            f"{s['instr_reduction']:.6g}",
                            s["data_bytes"],
                            f"{s['instr_bytes_minisa']/s['data_bytes']:.3e}",
                            f"{s['instr_bytes_micro']/s['data_bytes']:.3e}"])

    # 3) roofline per dry-run cell
    rows = rl.run(verbose=False)
    with open(f"{outdir}/roofline.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["arch", "shape", "mesh", "status", "t_compute",
                    "t_memory", "t_collective", "bottleneck",
                    "model_flops", "model_over_hlo"])
        for r in rows:
            if r.get("status") != "OK":
                w.writerow([r["arch"], r["shape"], r.get("mesh", "-"),
                            r["status"], "", "", "", "", "", ""])
            else:
                w.writerow([r["arch"], r["shape"], r["mesh"], "OK",
                            f"{r['t_compute']:.6g}",
                            f"{r['t_memory']:.6g}",
                            f"{r['t_collective']:.6g}",
                            r["bottleneck"],
                            f"{r['model_flops']:.6g}",
                            f"{r['model_over_hlo']:.4f}"])

    print(f"wrote {outdir}/benchmark_summary.csv, "
          f"{outdir}/instruction_comparison.csv, {outdir}/roofline.csv")


if __name__ == "__main__":
    main()
