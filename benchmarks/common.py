"""Shared benchmark infrastructure: one mapper sweep over the 58-GEMM
Tab. IV suite x 9 array configs, memoised and reused by every
table/figure module."""

from __future__ import annotations

import functools
import math
import time

from repro.configs.feather import SWEEP, feather_config
from repro.core import mapper, workloads


@functools.lru_cache(maxsize=None)
def sweep_plans(configs: tuple = SWEEP) -> dict:
    """{(ah, aw): {workload_name: Plan}}"""
    out = {}
    suite = workloads.suite()
    for ah, aw in configs:
        cfg = feather_config(ah, aw)
        plans = {}
        for g in suite:
            plans[g.name] = mapper.search(g, cfg)
        out[(ah, aw)] = plans
    return out


def geomean(xs):
    xs = [max(x, 1e-12) for x in xs]
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def timed(fn):
    t0 = time.time()
    result = fn()
    return result, (time.time() - t0) * 1e6


def csv_row(name: str, us: float, derived: str):
    print(f"{name},{us:.0f},{derived}")
