"""Shared benchmark infrastructure: one mapper sweep over the 58-GEMM
Tab. IV suite x 9 array configs, memoised (through the runtime's shared
ProgramCache) and reused by every table/figure module."""

from __future__ import annotations

import functools
import math
import time

from repro.configs.feather import SWEEP, feather_config
from repro.core import workloads
from repro.runtime.cache import ProgramCache

#: The sweep keeps its own unbounded cache instance: the 58 x 9 suite must
#: stay fully resident across figure modules (the process default LRU is
#: sized for serving-scale plans, not the full Tab. IV sweep).
SWEEP_CACHE = ProgramCache(max_plans=1 << 30)


@functools.lru_cache(maxsize=None)
def sweep_plans(configs: tuple = SWEEP) -> dict:
    """{(ah, aw): {workload_name: Plan}}"""
    out = {}
    suite = workloads.suite()
    for ah, aw in configs:
        cfg = feather_config(ah, aw)
        plans = {}
        for g in suite:
            plans[g.name] = SWEEP_CACHE.plan(g, cfg)
        out[(ah, aw)] = plans
    return out


def geomean(xs):
    xs = [max(x, 1e-12) for x in xs]
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def timed(fn):
    t0 = time.time()
    result = fn()
    return result, (time.time() - t0) * 1e6


def csv_row(name: str, us: float, derived: str):
    print(f"{name},{us:.0f},{derived}")
