"""Fig. 12: instruction-byte reduction (MINISA vs micro-instruction) and
instruction-to-data ratios.  Paper: geomean reduction 2e4x at 16x256
(35x .. 4.4e5x across sizes), micro instr:data up to ~100x, MINISA
negligible.

MINISA bytes come from each plan's lowered Program (the exact bit-sum of
its tiled instruction stream), not from a closed-form count."""

from benchmarks.common import geomean, sweep_plans
from repro.configs.feather import SWEEP


def run(verbose: bool = True) -> dict:
    plans = sweep_plans()
    rows = {}
    for key in SWEEP:
        red, i2d_u, i2d_m = [], [], []
        for p in plans[key].values():
            prog = p.program
            mb = prog.minisa_bytes()
            ub = prog.micro_storage_bytes()
            red.append(ub / max(mb, 1e-9))
            i2d_u.append(ub / p.gemm.data_bytes)
            i2d_m.append(mb / p.gemm.data_bytes)
        rows[key] = {
            "geomean_reduction": geomean(red),
            "max_reduction": max(red),
            "min_reduction": min(red),
            "max_instr_to_data_micro": max(i2d_u),
            "geomean_instr_to_data_minisa": geomean(i2d_m),
        }
    if verbose:
        print("\n[Fig. 12] instruction-traffic reduction")
        print(f"{'array':>8} {'geomean':>10} {'min':>9} {'max':>10} "
              f"{'i:d micro(max)':>15} {'i:d MINISA':>12}")
        for key, r in rows.items():
            print(f"{key[0]}x{key[1]:<5} {r['geomean_reduction']:10.2e} "
                  f"{r['min_reduction']:9.1f} {r['max_reduction']:10.2e} "
                  f"{r['max_instr_to_data_micro']:15.1f} "
                  f"{r['geomean_instr_to_data_minisa']:12.2e}")
    return rows
