"""MINISA plans for the 10 assigned architectures (framework integration):
per (arch x shape) instruction traffic, speedup, utilization on 16x256."""

from repro.configs.base import SHAPES
from repro.configs.feather import feather_config
from repro.configs.registry import ARCH_IDS, get_config
from repro.core.model_gemms import gemm_workloads
from repro.core.planner import plan_model

SHAPE_SET = ("train_4k", "decode_32k")


def run(verbose: bool = True) -> dict:
    cfg16 = feather_config(16, 256)
    rows = {}
    if verbose:
        print("\n[arch plans] MINISA on FEATHER+ 16x256")
        print(f"{'arch':>22} {'shape':>11} {'speedup':>8} {'util':>7} "
              f"{'instr-red':>10} {'i:d MINISA':>11}")
    for arch in ARCH_IDS:
        mcfg = get_config(arch)
        for shape_name in SHAPE_SET:
            ops = gemm_workloads(mcfg, SHAPES[shape_name])
            plan = plan_model(arch, shape_name, ops, cfg16)
            s = plan.summary()
            rows[(arch, shape_name)] = s
            if verbose:
                print(f"{arch:>22} {shape_name:>11} {s['speedup']:8.2f} "
                      f"{s['utilization']:7.1%} {s['instr_reduction']:10.1e} "
                      f"{s['instr_to_data_minisa']:11.2e}")
    return rows
