"""Joint mapper + measured autotune vs greedy-then-snap fused geometry.

    PYTHONPATH=src python -m benchmarks.mapper_autotune [--quick]
        [--json PATH] [--merge] [--gate]

For a chained segment per Tab. IV CI family (fhe-bconv / fhe-ntt /
zkp-ntt / gpt-oss shapes in a 3-layer MLP-style chain), compares the
fused-chain wall clock of

  untuned   the pre-frontier pipeline: per-GEMM ``mapper.search``
            winners chained, then ``fuse_segment``'s post-hoc snapping
            picks (bm, per-layer bk)
  tuned     the fusion-aware joint mapper: ``mapper.search_segment``'s
            Pareto frontier over {traffic, cycles, VMEM} measured by
            ``runtime.autotune`` against real launch spans, winner
            persisted in the ProgramCache tuned tier

Both modes run the SAME per-layer Programs -- only the launch geometry
differs -- and both are cross-checked against the einsum oracle before
timing.  After the sweep the whole pipeline re-runs against the warmed
cache and asserts ZERO mapper searches, ZERO joint searches and ZERO
kernel compiles (the serving-process contract: structurally identical
segments never re-tune).

``--gate`` fails unless tuned wall clock <= untuned on every chain
(small tolerance for timer noise) and the warm pass did no work.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import time

import numpy as np


def _time(fn, iters):
    fn()                                  # one extra warm call
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def _family_chains(quick: bool) -> list[tuple[str, tuple[int, int, int]]]:
    """One representative CI-extent GEMM per Tab. IV family; its (m, k,
    n) seeds a wired chain m x k -> n -> k (-> n)."""
    from repro.core import workloads

    fams: dict[str, tuple[int, int, int]] = {}
    for g in workloads.ci_suite():
        fam = g.name.rsplit("-", 2)[0]
        if fam.startswith("conv"):
            continue
        best = fams.get(fam)
        if best is None or g.macs > best[0] * best[1] * best[2]:
            fams[fam] = (g.m, g.k, g.n)
    chains = sorted(fams.items())
    return chains[:2] if quick else chains


def _build_chain(cfg, m, k, n, n_layers, cache):
    """Lower + chain an MLP-style stack over the family's (k, n) ranks."""
    from repro.core import program as programlib
    from repro.core.mapper import Gemm
    from repro.runtime.executable import ACTIVATIONS

    widths = [k] + [n if i % 2 == 0 else k for i in range(n_layers)]
    progs = []
    for i in range(n_layers):
        g = Gemm(m=m, k=widths[i], n=widths[i + 1], name=f"chain-l{i}")
        plan = cache.plan(g, cfg)
        act = "relu" if i < n_layers - 1 else "none"
        progs.append(cache.lower(
            plan.gemm, plan.choice, cfg,
            activation=ACTIVATIONS.get(act), act_name=act,
            out_name=f"O{i}"))
    return programlib.chain(progs, lower_fn=cache.lower), widths


def bench_chain(cfg, fam, shape, cache, be, quick: bool) -> dict:
    from repro.core import program as programlib
    from repro.runtime import autotune

    m, k, n = shape
    n_layers = 2 if quick else 3
    progs, widths = _build_chain(cfg, m, k, n, n_layers, cache)

    untuned = programlib.fuse_segment(progs)
    assert untuned is not None, f"{fam} chain must be fusion-legal"
    report = autotune.autotune_segment(
        progs, be, cache=cache,
        top_k=2 if quick else 4, iters=2 if quick else 3)
    assert report is not None, f"{fam} autotune found no frontier"
    w = report.winner
    tuned = programlib.fuse_segment(progs, bm=w.bm, layer_bks=w.layer_bks)
    assert tuned is not None

    rng = np.random.default_rng(1)
    x = rng.standard_normal((m, widths[0])).astype(np.float32)
    ws = [rng.standard_normal((widths[i], widths[i + 1]))
          .astype(np.float32) / np.sqrt(widths[i])
          for i in range(n_layers)]
    t = {"I": x, **{f"W{i}": w_ for i, w_ in enumerate(ws)}}

    # correctness before timing: both geometries == the einsum oracle
    ref = x.copy()
    for i, w_ in enumerate(ws):
        ref = ref @ w_
        if i < n_layers - 1:
            ref = np.maximum(ref, 0)
    out_u = be.run_segment(untuned, t)[untuned.out_name]
    out_t = be.run_segment(tuned, t)[tuned.out_name]
    np.testing.assert_allclose(out_u, ref, rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(out_t, ref, rtol=2e-4, atol=2e-3)

    iters = 3 if quick else 10
    same_geometry = (untuned.bm, untuned.layer_bks) == (tuned.bm,
                                                       tuned.layer_bks)
    us_untuned = _time(lambda: be.run_segment(untuned, t), iters)
    us_tuned = (us_untuned if same_geometry
                else _time(lambda: be.run_segment(tuned, t), iters))
    grid = lambda seg: seg.m_steps * sum(  # noqa: E731
        -(-p.gemm.k // bk) for p, bk in zip(seg.programs, seg.layer_bks))
    return {
        "family": fam,
        "m": m, "widths": widths, "n_layers": n_layers,
        "us_untuned": us_untuned,
        "us_tuned": us_tuned,
        "speedup": us_untuned / max(us_tuned, 1e-9),
        "bm_untuned": untuned.bm, "bm_tuned": tuned.bm,
        "bks_untuned": list(untuned.layer_bks),
        "bks_tuned": list(tuned.layer_bks),
        "grid_steps_untuned": grid(untuned),
        "grid_steps_tuned": grid(tuned),
        "vmem_untuned": untuned.vmem_highwater_bytes(),
        "vmem_tuned": tuned.vmem_highwater_bytes(),
        "hbm_untuned": untuned.kernel_hbm_bytes(),
        "hbm_tuned": tuned.kernel_hbm_bytes(),
        "kernel_frac_tuned": w.kernel_frac,
        "n_points_measured": w.n_points_measured,
        "autotune_cached": report.cached,
    }


def run(quick: bool = False) -> dict:
    from repro import backends
    from repro.configs.feather import feather_config
    from repro.runtime import ProgramCache, autotune

    cfg = feather_config(4, 16)
    cache = ProgramCache()
    be = backends.PallasBackend(cfg, compile_cache=cache)

    chains = []
    for fam, shape in _family_chains(quick):
        chains.append(bench_chain(cfg, fam, shape, cache, be, quick))

    # warm-cache contract: rebuilding + re-tuning every chain against
    # the same cache does zero searches, zero joint searches and zero
    # kernel compiles -- structurally identical segments never re-tune
    before = cache.stats.snapshot()
    for fam, shape in _family_chains(quick):
        m, k, n = shape
        progs, _ = _build_chain(cfg, m, k, n, 2 if quick else 3, cache)
        rep = autotune.autotune_segment(progs, be, cache=cache)
        assert rep is not None and rep.cached, \
            f"{fam}: warm autotune must serve the tuned tier"
    delta = cache.stats.delta(before)
    warm = {"searches": delta["plan_misses"],
            "joint_searches": delta["frontier_misses"],
            "compiles": delta["compile_misses"] + delta["fused_misses"],
            "tuned_hits": delta["tuned_hits"]}

    speedups = [c["speedup"] for c in chains]
    geomean = math.exp(sum(math.log(s) for s in speedups) / len(speedups))
    out = {"chains": chains, "geomean_speedup": geomean, "warm": warm,
           "cache": cache.summary()}

    print(f"{'family':>12} {'us untuned':>11} {'us tuned':>9} "
          f"{'speedup':>8} {'grid u/t':>9} {'bm u/t':>9}")
    for c in chains:
        print(f"{c['family']:>12} {c['us_untuned']:11.0f} "
              f"{c['us_tuned']:9.0f} {c['speedup']:8.2f} "
              f"{c['grid_steps_untuned']:>4}/{c['grid_steps_tuned']:<4} "
              f"{c['bm_untuned']:>4}/{c['bm_tuned']:<4}")
    print(f"geomean_speedup={geomean:.2f}x  warm: "
          f"searches={warm['searches']} "
          f"joint_searches={warm['joint_searches']} "
          f"compiles={warm['compiles']}")
    return out


def flat_metrics(result: dict) -> dict:
    """JSON-friendly flat view (merged into BENCH_results.json)."""
    out = {"geomean_speedup": result["geomean_speedup"],
           "warm_searches": result["warm"]["searches"],
           "warm_joint_searches": result["warm"]["joint_searches"],
           "warm_compiles": result["warm"]["compiles"]}
    for c in result["chains"]:
        fam = c["family"]
        for key in ("us_untuned", "us_tuned", "speedup",
                    "grid_steps_untuned", "grid_steps_tuned",
                    "vmem_untuned", "vmem_tuned"):
            out[f"{fam}.{key}"] = c[key]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI sizes")
    ap.add_argument("--json", default="", help="write results to PATH")
    ap.add_argument("--merge", action="store_true",
                    help="merge into an existing BENCH_results.json "
                         "instead of overwriting")
    ap.add_argument("--gate", action="store_true",
                    help="fail unless tuned <= untuned wall clock per "
                         "chain and the warm pass did zero work")
    args = ap.parse_args()
    result = run(quick=args.quick)
    if args.gate:
        for c in result["chains"]:
            assert c["us_tuned"] <= c["us_untuned"] * 1.05, \
                f"{c['family']}: tuned {c['us_tuned']:.0f}us > untuned " \
                f"{c['us_untuned']:.0f}us"
        w = result["warm"]
        assert w["searches"] == 0 and w["joint_searches"] == 0 \
            and w["compiles"] == 0, w
        print(f"gate ok: tuned <= untuned on every chain, warm pass "
              f"did zero searches/compiles "
              f"(geomean {result['geomean_speedup']:.2f}x)")
    if args.json:
        payload = {}
        if args.merge and os.path.exists(args.json):
            with open(args.json) as f:
                payload = json.load(f)
        payload.setdefault("results", {})["mapper_autotune"] = {
            "derived": f"geomean_speedup="
                       f"{result['geomean_speedup']:.3g}",
            **flat_metrics(result),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()


