"""Fig. 11 (modelled, not measured -- DESIGN.md §5): utilization of
FEATHER+ 16x256 vs fixed-granularity TPU-v6e-like (8x256x256 INT8 tiles)
and GPU-like (16x32x8) execution, across the Tab. IV suite.

The paper measures real devices; here both baselines are analytical
granularity models, so only the *shape-robustness* comparison is
reproduced: FEATHER+ sustains high utilization on irregular shapes where
padding starves the rigid pipelines."""

import math

from benchmarks.common import geomean, sweep_plans
from repro.core import workloads


def _padded_util(g, gm, gk, gn):
    pad = (math.ceil(g.m / gm) * gm * math.ceil(g.k / gk) * gk
           * math.ceil(g.n / gn) * gn)
    return g.macs / pad


def run(verbose: bool = True) -> dict:
    plans = sweep_plans()[(16, 256)]
    rows = {}
    for g in workloads.suite():
        rows[g.name] = {
            "feather_util": plans[g.name].perf_minisa.utilization,
            "tpu_util": _padded_util(g, 8, 256, 256),
            "gpu_util": _padded_util(g, 16, 32, 8),
        }
    agg = {k: geomean([r[k] for r in rows.values()])
           for k in ("feather_util", "tpu_util", "gpu_util")}
    irregular = [r for n, r in rows.items() if "bconv" in n]
    agg["feather_util_irregular"] = geomean(
        [r["feather_util"] for r in irregular])
    agg["tpu_util_irregular"] = geomean([r["tpu_util"] for r in irregular])
    if verbose:
        print("\n[Fig. 11 modelled] utilization geomeans")
        print(f"  FEATHER+ 16x256 : {agg['feather_util']:.1%} "
              f"(irregular BConv: {agg['feather_util_irregular']:.1%})")
        print(f"  TPU-v6e-like    : {agg['tpu_util']:.1%} "
              f"(irregular BConv: {agg['tpu_util_irregular']:.1%})")
        print(f"  GPU-like        : {agg['gpu_util']:.1%}")
    return agg
