"""Chaos-serving benchmark: seeded fault injection against the runtime.

    PYTHONPATH=src python -m benchmarks.chaos_serving [--quick]
        [--json PATH] [--merge] [--gate] [--fault-events PATH]

Runs the continuous-batching :class:`repro.runtime.Scheduler` through a
seeded :class:`repro.faults.FaultPlan` and holds it to the resilience
contract:

  * every injected fault kind (transient launch failure, NaN-poisoned
    launch output, KV page-pool exhaustion, corrupt disk-cache entry,
    and -- on a meshed run -- an array dropping out) is recovered:
    ``unrecovered == 0``;
  * every request finishes ``ok`` (no crashes, no unhandled faults);
  * every request's ``state_checksum`` is bit-identical to the same
    submission sequence served with faults off -- retries replay from
    the paged KV state, so chaos may cost time but never correctness.

Three legs, each paired with its own fault-free baseline: interpreter,
pallas (cross-request batched decode), and a 2-array mesh leg whose
``array_down`` event degrades the mesh mid-run (the stream re-lowers
onto the surviving array and keeps serving).  The chaos legs run under
the ``obs`` tracer; the fault swimlane events (injections, recoveries,
breaker transitions) become the ``--fault-events`` artifact CI uploads.

``--gate`` exits non-zero unless every leg recovered every fault with
fault-free-equal checksums; ``--merge`` folds the ``chaos_serving``
headline into an existing ``BENCH_results.json``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

#: (leg name, mesh arrays, Scheduler kwargs) -- chaos legs pair with a
#: fault-free baseline fed the identical submission sequence
#: breaker_threshold: at full concurrency one bad tick records one
#: failure per in-flight request; the default threshold (4) would trip
#: on the first launch window and eclipse the later ones, so the chaos
#: legs give the breaker headroom to exercise EVERY planned fault kind
LEGS = (
    ("interpreter", 1, dict(backend="interpreter", breaker_threshold=16)),
    ("pallas", 1, dict(backend="pallas", breaker_threshold=16)),
    ("interpreter_mesh2", 2, dict(backend="interpreter",
                                  breaker_threshold=16)),
)


def _serve(prefill, decode, n_requests, decode_steps, max_concurrent,
           **kw):
    from repro.runtime import Scheduler
    sched = Scheduler(prefill, decode, max_concurrent=max_concurrent,
                      **kw)
    for _ in range(n_requests):
        sched.submit(decode_steps=decode_steps)
    return sched.run()


def run(quick: bool = False, arch: str = "gemma-7b",
        n_requests: int = 6, decode_steps: int = 4,
        max_concurrent: int = 4, seed: int = 0) -> dict:
    from repro.configs.feather import feather_config
    from repro.dist import ArrayMesh
    from repro.faults import FaultInjector, FaultPlan
    from repro.obs import metrics as obs_metrics
    from repro.obs.export import fault_events
    from repro.obs.trace import trace
    from repro.runtime import ModelExecutable, ProgramCache

    if quick:
        n_requests, decode_steps = 4, 3
    cfg = feather_config(4, 16)
    out: dict = {"legs": {}, "fault_events": []}
    print(f"{'leg':>18} {'status':>8} {'injected':>9} {'recovered':>10} "
          f"{'retries':>8} {'decode tok/s':>13} {'checksums':>10}")
    with tempfile.TemporaryDirectory(prefix="chaos_cache.") as tmp:
        cache = ProgramCache(path=os.path.join(tmp, "cache.bin"))
        for leg, n_arrays, kw in LEGS:
            mesh = ArrayMesh(n_arrays) if n_arrays > 1 else None
            prefill = ModelExecutable.for_cell(arch, "prefill_tiny", cfg,
                                               cache=cache, mesh=mesh)
            decode = ModelExecutable.for_cell(arch, "decode_tiny", cfg,
                                              cache=cache, mesh=mesh)
            base = _serve(prefill, decode, n_requests, decode_steps,
                          max_concurrent, **kw)
            injector = FaultInjector(
                FaultPlan.standard(seed, n_arrays=n_arrays))
            obs_metrics.reset()
            trace.clear().enable()
            try:
                chaos = _serve(prefill, decode, n_requests, decode_steps,
                               max_concurrent, faults=injector, **kw)
            finally:
                trace.disable()
            out["fault_events"].extend(fault_events())

            ref = {r.rid: r.state_checksum for r in base.requests}
            got = {r.rid: r.state_checksum for r in chaos.requests
                   if r.status not in ("timed_out",)}
            expected = {k for k, n in injector.plan.counts().items()
                        if n > 0}
            ok = (injector.unrecovered() == 0
                  and expected <= set(injector.injected)
                  and all(r.status == "ok" for r in chaos.requests)
                  and got == ref)
            s = chaos.summary()
            out["legs"][leg] = {
                "arch": arch,
                "n_requests": n_requests,
                "decode_steps": decode_steps,
                "plan": injector.plan.name,
                "injected": dict(injector.injected),
                "recovered": dict(injector.recovered),
                "skipped": dict(injector.skipped),
                "unrecovered": injector.unrecovered(),
                "retries_total": s["retries_total"],
                "requests_ok": s["requests_ok"],
                "requests_timed_out": s["requests_timed_out"],
                "requests_failed": s["requests_failed"],
                "mesh_degraded": s["resilience"].get("mesh_degraded", 0),
                "breaker_opens": s["resilience"]["breaker"]["opens"],
                "decode_tok_s_chaos": s["decode_tokens_per_sec"],
                "decode_tok_s_fault_free":
                    base.summary()["decode_tokens_per_sec"],
                "checksums_match": got == ref,
                "recovered_all": ok,
            }
            n_inj = sum(injector.injected.values())
            n_rec = sum(injector.recovered.values())
            print(f"{leg:>18} {'PASS' if ok else 'FAIL':>8} "
                  f"{n_inj:>9} {n_rec:>10} {s['retries_total']:>8} "
                  f"{s['decode_tokens_per_sec']:>13.1f} "
                  f"{'equal' if got == ref else 'DIVERGED':>10}")
            assert ok, (f"chaos leg {leg!r} failed: "
                        f"{out['legs'][leg]}")
        cache.save()

    legs = out["legs"]
    all_injected: dict[str, int] = {}
    for leg in legs.values():
        for kind, n in leg["injected"].items():
            all_injected[kind] = all_injected.get(kind, 0) + n
    out["chaos_serving"] = {
        "arch": arch,
        "seed": seed,
        "n_requests": n_requests,
        "decode_steps": decode_steps,
        "legs": sorted(legs),
        "faults_injected_total": sum(all_injected.values()),
        "fault_kinds_injected": sorted(all_injected),
        "unrecovered_total": sum(g["unrecovered"] for g in legs.values()),
        "retries_total": sum(g["retries_total"] for g in legs.values()),
        "requests_failed": sum(g["requests_failed"]
                               for g in legs.values()),
        "mesh_degraded": sum(g["mesh_degraded"] for g in legs.values()),
        "checksums_match": all(g["checksums_match"]
                               for g in legs.values()),
        "recovered_all": all(g["recovered_all"] for g in legs.values()),
        "n_fault_events": len(out["fault_events"]),
    }
    head = out["chaos_serving"]
    print(f"chaos gate: {head['faults_injected_total']} faults over "
          f"{len(legs)} legs ({', '.join(head['fault_kinds_injected'])}), "
          f"{head['unrecovered_total']} unrecovered, checksums "
          f"{'equal' if head['checksums_match'] else 'DIVERGED'}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI sizes")
    ap.add_argument("--arch", default="gemma-7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--decode-steps", type=int, default=4)
    ap.add_argument("--concurrent", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0,
                    help="fault plan seed (the chaos run replays "
                         "deterministically for one seed)")
    ap.add_argument("--json", default="", help="write results to PATH")
    ap.add_argument("--merge", action="store_true",
                    help="merge into an existing BENCH_results.json "
                         "instead of overwriting")
    ap.add_argument("--gate", action="store_true",
                    help="exit non-zero unless every fault recovered "
                         "and every checksum matched fault-free")
    ap.add_argument("--fault-events", default="", metavar="PATH",
                    help="write the chaos legs' fault swimlane events "
                         "(injections/recoveries/breaker) as JSON")
    args = ap.parse_args()
    result = run(quick=args.quick, arch=args.arch,
                 n_requests=args.requests,
                 decode_steps=args.decode_steps,
                 max_concurrent=args.concurrent, seed=args.seed)
    head = result["chaos_serving"]
    if args.fault_events:
        with open(args.fault_events, "w") as f:
            json.dump({"fault_events": result["fault_events"]}, f,
                      indent=1)
        print(f"wrote {args.fault_events}")
    if args.json:
        payload = {}
        if args.merge and os.path.exists(args.json):
            with open(args.json) as f:
                payload = json.load(f)
        payload.setdefault("results", {})["chaos_serving"] = {
            "derived": f"unrecovered={head['unrecovered_total']} "
                       f"checksums_match={head['checksums_match']}",
            **head,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    if args.gate and not (head["recovered_all"]
                          and head["checksums_match"]
                          and head["unrecovered_total"] == 0):
        print("FAIL: chaos run left unrecovered faults or diverged "
              "from the fault-free checksums")
        sys.exit(1)


if __name__ == "__main__":
    main()
