"""Table I: instruction-fetch stall share of the micro-instruction baseline
for I[65536,40] x W[40,88], across the six published array sizes."""

from repro.configs.feather import feather_config
from repro.core import mapper

PAPER = {(4, 4): 0.0, (8, 8): 0.0, (4, 64): 0.753, (16, 16): 0.652,
         (8, 128): 0.904, (16, 256): 0.969}

TAB1 = mapper.Gemm(m=65536, k=40, n=88, name="tab1")


def run(verbose: bool = True) -> dict:
    rows = {}
    for (ah, aw), paper in PAPER.items():
        plan = mapper.search(TAB1, feather_config(ah, aw))
        rows[(ah, aw)] = (plan.perf_micro.stall_ifetch_frac, paper)
    if verbose:
        print("\n[Table I] micro-instruction fetch stalls")
        print(f"{'array':>8} {'model':>8} {'paper':>8}")
        for (ah, aw), (m, p) in rows.items():
            print(f"{ah}x{aw:>5} {m:8.1%} {p:8.1%}")
    return rows
