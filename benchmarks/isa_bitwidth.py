"""Tab. V: MINISA instruction bitwidths per array config (computed from the
Fig. 3/5 formulas; the paper's E.Streaming column is reproduced exactly,
Set*/E.Mapping within +-2 bits -- see DESIGN.md §5)."""

from repro.configs.feather import SWEEP, feather_config

PAPER = {  # (ah, aw): (set_layout, e_mapping, e_streaming)
    (4, 4): (42, 81, 57), (4, 16): (40, 83, 51), (4, 64): (38, 85, 45),
    (8, 8): (43, 86, 58), (8, 32): (41, 88, 52), (8, 128): (39, 90, 46),
    (16, 16): (44, 91, 59), (16, 64): (42, 93, 53), (16, 256): (40, 95, 47),
}


def run(verbose: bool = True) -> dict:
    rows = {}
    for ah, aw in SWEEP:
        cfg = feather_config(ah, aw)
        rows[(ah, aw)] = {
            "set_layout": cfg.bits_set_layout(),
            "e_mapping": cfg.bits_execute_mapping(),
            "e_streaming": cfg.bits_execute_streaming(),
            "paper": PAPER[(ah, aw)],
        }
    if verbose:
        print("\n[Tab. V] ISA bitwidths (model vs paper)")
        print(f"{'array':>8} {'Set*':>10} {'E.Map':>12} {'E.Stream':>12}")
        for (ah, aw), r in rows.items():
            p = r["paper"]
            print(f"{ah}x{aw:<5} {r['set_layout']:>4} vs {p[0]:<3} "
                  f"{r['e_mapping']:>5} vs {p[1]:<4} "
                  f"{r['e_streaming']:>5} vs {p[2]:<4}")
    return rows
