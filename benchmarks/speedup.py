"""Fig. 10: end-to-end MINISA speedup over the micro-instruction baseline,
geomean across the Tab. IV suite, per array config.  Paper anchors:
~1x (<=64 PEs), 1.9x @16x16, 7.5x @16x64, 31.6x @16x256."""

from benchmarks.common import geomean, sweep_plans
from repro.configs.feather import SWEEP

PAPER = {(16, 16): 1.9, (16, 64): 7.5, (16, 256): 31.6}


def run(verbose: bool = True) -> dict:
    plans = sweep_plans()
    rows = {}
    for key in SWEEP:
        sp = [p.speedup for p in plans[key].values()]
        st_mi = [p.perf_minisa.stall_ifetch_frac for p in plans[key].values()]
        st_u = [p.perf_micro.stall_ifetch_frac for p in plans[key].values()]
        rows[key] = {
            "geomean_speedup": geomean(sp),
            "max_speedup": max(sp),
            "mean_stall_micro": sum(st_u) / len(st_u),
            "mean_stall_minisa": sum(st_mi) / len(st_mi),
            "paper": PAPER.get(key),
        }
    if verbose:
        print("\n[Fig. 10] speedup vs array scale (geomean over 58 GEMMs)")
        print(f"{'array':>8} {'speedup':>9} {'max':>8} {'stall-u':>9} "
              f"{'stall-m':>9} {'paper':>7}")
        for key, r in rows.items():
            paper = f"{r['paper']:.1f}" if r["paper"] else "-"
            print(f"{key[0]}x{key[1]:<5} {r['geomean_speedup']:9.2f} "
                  f"{r['max_speedup']:8.1f} {r['mean_stall_micro']:9.1%} "
                  f"{r['mean_stall_minisa']:9.2%} {paper:>7}")
    return rows
