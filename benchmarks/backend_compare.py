"""Interpreter vs compiled-Pallas wall clock, next to the analytical model.

    PYTHONPATH=src python -m benchmarks.backend_compare [--quick]

For each GEMM the mapper picks its winning (mapping, layout) Plan once;
the same lowered Program then runs on both execution backends:

  interpreter  FEATHER+ functional machine, tile-by-tile MINISA replay
  pallas       one pl.pallas_call whose grid/BlockSpecs derive from the
               Program's tiling (interpret-mode on CPU, Mosaic on TPU)

Both outputs are checked against the einsum oracle before any number is
reported, and the analytical 5-engine cycle count for the identical tile
stream is printed alongside -- what the mapper's winning plan *costs* on
(real or interpret-mode) hardware vs what the model *predicts*.

The compiled backend is timed twice: cold (includes compile/trace time)
and warm (steady state, the number that matters for serving).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

QUICK_SIZES = ((256, 256, 256), (512, 512, 512))
FULL_SIZES = ((1024, 1024, 1024), (4096, 4096, 4096))


def compare_gemm(m: int, k: int, n: int, cfg=None, seed: int = 0) -> dict:
    """Search, lower once, execute on both backends, report wall clocks."""
    from repro import backends
    from repro.configs.feather import feather_config
    from repro.core import mapper

    cfg = cfg or feather_config(16, 256)
    g = mapper.Gemm(m=m, k=k, n=n, name=f"gemm-{m}x{k}x{n}")
    plan = mapper.search(g, cfg)
    prog = plan.program
    rng = np.random.default_rng(seed)
    i = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    oracle = i.astype(np.float64) @ w.astype(np.float64)
    tol = 1e-3 + 1e-5 * k

    def _timed(backend_name):
        be = backends.get_backend(backend_name, cfg)
        t0 = time.perf_counter()
        out = be.run_program(prog, {"I": i, "W": w})[prog.out_name]
        cold = (time.perf_counter() - t0) * 1e6
        np.testing.assert_allclose(np.asarray(out, np.float64), oracle,
                                   rtol=tol, atol=tol,
                                   err_msg=f"{backend_name} diverged")
        t0 = time.perf_counter()
        be.run_program(prog, {"I": i, "W": w})
        warm = (time.perf_counter() - t0) * 1e6
        return cold, warm

    us_pl_cold, us_pl_warm = _timed("pallas")
    us_it_cold, us_it_warm = _timed("interpreter")
    comp = backends.compile_program(prog)
    return {
        "name": g.name,
        "m": m, "k": k, "n": n, "macs": g.macs,
        "df": plan.choice.df.name,
        "tile": [prog.n_m, prog.n_n, prog.n_k],
        "kernel_grid": list(comp.grid),
        "kernel_blocks": [comp.bm, comp.bk, comp.bn],
        "us_interpreter": us_it_warm,
        "us_interpreter_cold": us_it_cold,
        "us_pallas": us_pl_warm,
        "us_pallas_cold": us_pl_cold,
        "wallclock_speedup": us_it_warm / max(us_pl_warm, 1e-9),
        "cycles_minisa": plan.perf_minisa.cycles,
        "cycles_micro": plan.perf_micro.cycles,
    }


def run(quick: bool = False, sizes=None) -> dict[str, dict]:
    sizes = sizes if sizes is not None else (QUICK_SIZES if quick
                                             else QUICK_SIZES + FULL_SIZES)
    print(f"{'gemm':>20} {'grid':>12} {'interp us':>12} {'pallas us':>12} "
          f"{'speedup':>8} {'model cyc':>12}")
    out = {}
    for m, k, n in sizes:
        row = compare_gemm(m, k, n)
        out[row["name"]] = row
        print(f"{row['name']:>20} {str(tuple(row['kernel_grid'])):>12} "
              f"{row['us_interpreter']:12.0f} {row['us_pallas']:12.0f} "
              f"{row['wallclock_speedup']:8.1f} "
              f"{row['cycles_minisa']:12.3g}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sizes only (CI)")
    ap.add_argument("--size", type=int, nargs="*", default=None,
                    help="cubic GEMM sizes, e.g. --size 1024 4096")
    args = ap.parse_args()
    sizes = ([(s, s, s) for s in args.size] if args.size
             else None if not args.quick else QUICK_SIZES)
    run(quick=args.quick, sizes=sizes)


if __name__ == "__main__":
    main()
