"""Roofline analysis (assignment §g): per (arch x shape x mesh) compute /
memory / collective terms from the compiled dry-run artifacts.

Reads the JSONL produced by ``python -m repro.launch.dryrun --all --json``
(dryrun_single.jsonl / dryrun_multi.jsonl at the repo root).  MODEL_FLOPS
uses the 6*N*D (train) / 2*N*D (inference) convention with N = active
parameters, so the MODEL/HLO ratio exposes remat recompute, attention
FLOPs and dispatch overheads.
"""

from __future__ import annotations

import json
import os

from repro.configs.base import SHAPES
from repro.configs.registry import get_config
from repro.models.api import build_model

FILES = ("dryrun_single.jsonl", "dryrun_single_fix.jsonl",
         "dryrun_multi.jsonl")


def _load() -> list[dict]:
    recs: dict[tuple, dict] = {}
    for fname in FILES:
        if not os.path.exists(fname):
            continue
        with open(fname) as f:
            for line in f:
                r = json.loads(line)
                key = (r["arch"], r["shape"], r.get("mesh", "?"))
                recs[key] = r          # later files override earlier
    return list(recs.values())


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = build_model(cfg).active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    tokens = shape.global_batch if shape.kind == "decode" else shape.tokens
    return 2.0 * n * tokens


def run(verbose: bool = True) -> list[dict]:
    rows = []
    for r in _load():
        if r.get("status") != "OK":
            if r.get("status", "").startswith("SKIP"):
                rows.append({"arch": r["arch"], "shape": r["shape"],
                             "mesh": r.get("mesh", "-"),
                             "status": r["status"]})
            continue
        mf = model_flops(r["arch"], r["shape"])
        hlo = r["hlo_flops_global"]
        terms = {k: r[k] for k in ("t_compute", "t_memory", "t_collective")}
        dom = max(terms, key=terms.get)
        total = sum(terms.values())
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "OK",
            **{k: terms[k] for k in terms},
            "bottleneck": dom,
            "roofline_fraction": terms[dom] / max(total, 1e-30),
            "model_flops": mf,
            "model_over_hlo": mf / max(hlo, 1e-30),
        })
    if verbose and rows:
        print("\n[roofline] terms in seconds/step (per-chip basis)")
        print(f"{'arch':>22} {'shape':>11} {'mesh':>9} {'compute':>10} "
              f"{'memory':>10} {'collective':>11} {'bottleneck':>12} "
              f"{'MODEL/HLO':>10}")
        for r in rows:
            if r["status"] != "OK":
                print(f"{r['arch']:>22} {r['shape']:>11} {r['mesh']:>9} "
                      f"{r['status']}")
                continue
            print(f"{r['arch']:>22} {r['shape']:>11} {r['mesh']:>9} "
                  f"{r['t_compute']:10.2e} {r['t_memory']:10.2e} "
                  f"{r['t_collective']:11.2e} "
                  f"{r['bottleneck'][2:]:>12} {r['model_over_hlo']:10.3f}")
    return rows
