"""Benchmark driver: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--json PATH]

Prints human-readable tables for each artifact, then the machine-readable
``name,us_per_call,derived`` CSV summary, and writes ``BENCH_results.json``
(name -> us_per_call + derived metrics) so the perf trajectory is tracked
across PRs (CI uploads it as a workflow artifact).
"""

from __future__ import annotations

import argparse
import json
import platform
import time


def _fmt(x):
    if isinstance(x, float):
        return f"{x:.4g}"
    return str(x)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the full 58x9 sweep-based figures")
    ap.add_argument("--json", default="BENCH_results.json",
                    help="machine-readable results path ('' disables)")
    args = ap.parse_args()

    from benchmarks import (arch_plans, backend_compare, breakdown,
                            instr_traffic, isa_bitwidth, roofline, scaling,
                            serve_runtime, speedup, stall_table,
                            tpu_gpu_compare)

    rows = []

    def bench(name, fn, derive, metrics=None):
        """metrics(result) -> flat dict of JSON-friendly derived numbers."""
        t0 = time.time()
        result = fn()
        us = (time.time() - t0) * 1e6
        extra = metrics(result) if metrics is not None else {}
        rows.append((name, us, derive(result), extra))
        return result

    bench("tabV_isa_bitwidths", isa_bitwidth.run,
          lambda r: "estream_exact=" + str(all(
              v["e_streaming"] == v["paper"][2] for v in r.values())))
    bench("tabI_stall_table", stall_table.run,
          lambda r: "stall_16x256=" + _fmt(r[(16, 256)][0]),
          lambda r: {"stall_16x256": r[(16, 256)][0]})
    if not args.quick:
        bench("fig10_speedup", speedup.run,
              lambda r: "geomean_16x256="
              + _fmt(r[(16, 256)]["geomean_speedup"]),
              lambda r: {"geomean_speedup_16x256":
                         r[(16, 256)]["geomean_speedup"]})
        bench("fig12_instr_traffic", instr_traffic.run,
              lambda r: "geomean_reduction_16x256="
              + _fmt(r[(16, 256)]["geomean_reduction"]),
              lambda r: {"geomean_reduction_16x256":
                         r[(16, 256)]["geomean_reduction"]})
        bench("fig11_tpu_gpu_modelled", tpu_gpu_compare.run,
              lambda r: "feather_vs_tpu_irregular=" + _fmt(
                  r["feather_util_irregular"]
                  / max(r["tpu_util_irregular"], 1e-9)))
    bench("fig13_breakdown", breakdown.run,
          lambda r: "min_util=" + _fmt(min(v["utilization"]
                                           for v in r.values())),
          lambda r: {"min_utilization": min(v["utilization"]
                                            for v in r.values())})
    bench("sec6d_scaling", scaling.run,
          lambda r: "aw64to256_speedup=" + _fmt(
              r[("AW", 64)]["geomean_cycles"]
              / r[("AW", 256)]["geomean_cycles"])
          + " mesh8_speedup=" + _fmt(r[("mesh", 8)]["speedup"]),
          lambda r: {f"mesh{n}.{key}": r[("mesh", n)][key]
                     for n in (1, 2, 4, 8)
                     for key in ("traffic_ratio", "speedup",
                                 "load_imbalance", "tokens_per_sec",
                                 "per_array_minisa_bytes")
                     if key in r[("mesh", n)]})
    bench("arch_plans_16x256", arch_plans.run,
          lambda r: "n_cells=" + str(len(r)))
    bench("roofline_from_dryrun", roofline.run,
          lambda r: "cells=" + str(sum(1 for x in r
                                       if x.get("status") == "OK")))
    bench("backend_compare",
          lambda: backend_compare.run(quick=args.quick),
          lambda r: "max_wallclock_speedup=" + _fmt(
              max(v["wallclock_speedup"] for v in r.values())),
          lambda r: {f"{name}.{key}": row[key]
                     for name, row in r.items()
                     for key in ("us_interpreter", "us_pallas",
                                 "us_pallas_cold", "wallclock_speedup",
                                 "cycles_minisa", "macs")})
    bench("serve_runtime",
          lambda: serve_runtime.run(quick=args.quick),
          lambda r: "batched_decode_speedup=" + _fmt(
              r["decode_serving"]["batched_decode_speedup"])
          + " tok_s_pallas=" + _fmt(r["pallas"]["tokens_per_sec"]),
          lambda r: {f"{name}.{key}": row[key]
                     for name, row in r.items()
                     for key in ("tokens_per_sec", "total_tokens",
                                 "decode_tokens_per_sec",
                                 "launches_per_decode_tick",
                                 "ttft_p50_s", "ttft_p95_s",
                                 "latency_p50_s", "latency_p95_s",
                                 "latency_p99_s", "batch_decode",
                                 "cache_hit_rate", "cache_searches",
                                 "cache_compiles",
                                 "minisa_bytes_per_request",
                                 "micro_bytes_per_request",
                                 "stall_minisa", "stall_micro",
                                 "decode_fused",
                                 "decode_fused_segments",
                                 "decode_hbm_elided_bytes",
                                 "batched_decode_speedup",
                                 "decode_tok_s_batched",
                                 "decode_tok_s_per_request")
                     if key in row})
    # fused-vs-per-layer kernels/serving live in benchmarks.fusion_compare;
    # CI runs it as its own perf-smoke step and --merges the results into
    # the BENCH_results.json written here (measuring it twice per CI run
    # would only duplicate the slowest serving benchmarks)

    def mapper_walltime():
        """Mapper-search wall clock, scalar vs vectorized prescore."""
        from repro.configs.feather import feather_config
        from repro.core import mapper, workloads
        cfg = feather_config(16, 256)
        suite = workloads.small_suite()
        if not args.quick:
            suite = suite + workloads.ci_suite()[:12]
        out = {}
        for mode, vec in (("scalar", False), ("vectorized", True)):
            t0 = time.time()
            for g in suite:
                mapper.search(g, cfg, vectorized=vec)
            out[f"us_{mode}"] = (time.time() - t0) / len(suite) * 1e6
        out["speedup"] = out["us_scalar"] / max(out["us_vectorized"], 1e-9)
        return out

    bench("mapper_search", mapper_walltime,
          lambda r: "prescore_speedup=" + _fmt(r["speedup"]),
          lambda r: dict(r))

    print("\nname,us_per_call,derived")
    for name, us, derived, _ in rows:
        print(f"{name},{us:.0f},{derived}")

    if args.json:
        payload = {
            "meta": {
                "quick": args.quick,
                "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
                "platform": platform.platform(),
            },
            "results": {
                name: {"us_per_call": us, "derived": derived, **extra}
                for name, us, derived, extra in rows
            },
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"\nwrote {args.json}")


if __name__ == "__main__":
    main()
