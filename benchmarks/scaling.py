"""§VI-D scalability ablations: AW scaling (near-linear speedup, stable
utilization) and AH scaling (2.6-4x with granularity sensitivity)."""

from benchmarks.common import geomean
from repro.configs.feather import feather_config
from repro.core import mapper, workloads

SUITE = [g for g in workloads.suite()][::6]   # every 6th workload


def run(verbose: bool = True) -> dict:
    rows = {}
    # AW scaling at AH=16: 64 -> 256
    for aw in (64, 128, 256):
        cfg = feather_config(16, aw)
        cyc = [mapper.search(g, cfg).perf_minisa for g in SUITE]
        rows[("AW", aw)] = {
            "geomean_cycles": geomean([c.cycles for c in cyc]),
            "mean_util": sum(c.utilization for c in cyc) / len(cyc),
        }
    # AH scaling at AW=64: 4 -> 16
    for ah in (4, 8, 16):
        cfg = feather_config(ah, 64)
        cyc = [mapper.search(g, cfg).perf_minisa for g in SUITE]
        rows[("AH", ah)] = {
            "geomean_cycles": geomean([c.cycles for c in cyc]),
            "mean_util": sum(c.utilization for c in cyc) / len(cyc),
        }
    if verbose:
        base_aw = rows[("AW", 64)]["geomean_cycles"]
        base_ah = rows[("AH", 4)]["geomean_cycles"]
        print("\n[§VI-D] scaling ablations")
        for (kind, v), r in rows.items():
            base = base_aw if kind == "AW" else base_ah
            print(f"  {kind}={v:<4} speedup-vs-base {base / r['geomean_cycles']:5.2f}x "
                  f"util {r['mean_util']:6.1%}")
    return rows
