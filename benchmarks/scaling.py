"""§VI-D scalability ablations: AW scaling (near-linear speedup, stable
utilization) and AH scaling (2.6-4x with granularity sensitivity), plus
the scale-OUT sweep: mesh sizes {1, 2, 4, 8} FEATHER+ arrays with
per-array MINISA traffic (conserved within tiling overhead), parallel
speedup, load imbalance, and serving tokens/sec from a tiny scheduler
run per mesh size."""

from benchmarks.common import geomean
from repro.configs.feather import feather_config
from repro.core import mapper, perf, program as programlib, workloads
from repro.dist import ArrayMesh

SUITE = [g for g in workloads.suite()][::6]   # every 6th workload

#: Mesh sweep inputs: one representative per Tab. IV family at full
#: extents (traffic/cycles are analytic -- no functional execution).
MESH_SUITE = [
    mapper.Gemm(m=65536, k=40, n=88, name="fhe-bconv-40x88"),
    mapper.Gemm(m=256, k=4096, n=4096, name="fhe-ntt-256x4096"),
    mapper.Gemm(m=2048, k=2880, n=4096, name="gpt-oss-2880x4096"),
]

MESH_SIZES = (1, 2, 4, 8)


def run_mesh(verbose: bool = True, serve: bool = True) -> dict:
    """Scale-out ablation over ArrayMesh sizes.

    Per (workload, mesh size): shard the lowered Program, report the
    chosen axis, summed per-array instruction bytes vs the single-array
    total (conservation), the parallel-makespan speedup and the load
    imbalance.  ``serve`` adds a tokens/sec row per mesh size from a
    2-request scheduler run on the interpreter backend (tiny serving
    cell; the executables/cache rebuild per mesh but share all plans).
    """
    cfg = feather_config(16, 64)
    rows: dict = {}
    plans = {g.name: mapper.search(g, cfg) for g in MESH_SUITE}
    for n_arrays in MESH_SIZES:
        mesh = ArrayMesh(n_arrays)
        ratios, speedups, imbalances = [], [], []
        per_array_bytes = [0.0] * n_arrays
        for g in MESH_SUITE:
            plan = plans[g.name]
            base_bytes = plan.program.minisa_bytes()
            base_cycles = plan.perf_minisa.cycles
            sh = programlib.shard_program(plan.program, mesh)
            mp = perf.simulate_sharded(sh, cfg)
            ratios.append(sh.minisa_bytes() / base_bytes)
            speedups.append(base_cycles / max(mp.cycles, 1e-9))
            imbalances.append(mp.load_imbalance)
            for i, b in enumerate(sh.per_array_minisa_bytes()):
                per_array_bytes[i] += b
        rows[("mesh", n_arrays)] = {
            "traffic_ratio": geomean(ratios),
            "speedup": geomean(speedups),
            "load_imbalance": max(imbalances),
            "per_array_minisa_bytes": per_array_bytes,
        }
    if serve:
        from repro.configs.feather import feather_config as fc
        from repro.runtime import ModelExecutable, ProgramCache, Scheduler
        serve_cfg = fc(4, 16)
        cache = ProgramCache()
        for n_arrays in MESH_SIZES:
            mesh = ArrayMesh(n_arrays) if n_arrays > 1 else None
            prefill = ModelExecutable.for_cell(
                "gemma-7b", "prefill_tiny", serve_cfg, cache=cache,
                mesh=mesh)
            decode = ModelExecutable.for_cell(
                "gemma-7b", "decode_tiny", serve_cfg, cache=cache,
                mesh=mesh)
            sched = Scheduler(prefill, decode, backend="interpreter",
                              max_concurrent=2)
            for _ in range(2):
                sched.submit(decode_steps=1)
            rep = sched.run()
            rows[("mesh", n_arrays)]["tokens_per_sec"] = rep.tokens_per_sec
            rows[("mesh", n_arrays)]["serve_load_imbalance"] = \
                rep.load_imbalance
    if verbose:
        print("\n[scale-out] ArrayMesh sweep "
              "(traffic ratio = sum-over-arrays / single-array)")
        for n_arrays in MESH_SIZES:
            r = rows[("mesh", n_arrays)]
            tok = r.get("tokens_per_sec")
            print(f"  arrays={n_arrays:<2} traffic x{r['traffic_ratio']:5.2f} "
                  f"speedup {r['speedup']:5.2f}x "
                  f"imbalance {r['load_imbalance']:4.2f}"
                  + (f" tok/s {tok:8.1f}" if tok is not None else ""))
    return rows


def run(verbose: bool = True) -> dict:
    rows = {}
    # AW scaling at AH=16: 64 -> 256
    for aw in (64, 128, 256):
        cfg = feather_config(16, aw)
        cyc = [mapper.search(g, cfg).perf_minisa for g in SUITE]
        rows[("AW", aw)] = {
            "geomean_cycles": geomean([c.cycles for c in cyc]),
            "mean_util": sum(c.utilization for c in cyc) / len(cyc),
        }
    # AH scaling at AW=64: 4 -> 16
    for ah in (4, 8, 16):
        cfg = feather_config(ah, 64)
        cyc = [mapper.search(g, cfg).perf_minisa for g in SUITE]
        rows[("AH", ah)] = {
            "geomean_cycles": geomean([c.cycles for c in cyc]),
            "mean_util": sum(c.utilization for c in cyc) / len(cyc),
        }
    if verbose:
        base_aw = rows[("AW", 64)]["geomean_cycles"]
        base_ah = rows[("AH", 4)]["geomean_cycles"]
        print("\n[§VI-D] scaling ablations")
        for (kind, v), r in rows.items():
            base = base_aw if kind == "AW" else base_ah
            print(f"  {kind}={v:<4} speedup-vs-base {base / r['geomean_cycles']:5.2f}x "
                  f"util {r['mean_util']:6.1%}")
    rows.update(run_mesh(verbose=verbose))
    return rows
