"""Fused-segment vs per-layer execution: kernel wall clock + decode tok/s.

    PYTHONPATH=src python -m benchmarks.fusion_compare [--quick]
        [--json PATH] [--merge]

Two measurements, both warm (the jit/pallas trace cost is paid before the
timed loop so the numbers are steady-state serving cost):

  chain kernels   a multi-layer ``program.chain`` segment executed (a) as
                  one compiled launch per layer (today's per-layer pallas
                  path) and (b) as ONE fused megakernel launch
                  (``PallasBackend.run_segment``), same tensors, outputs
                  cross-checked against the einsum oracle before timing;
                  reported with the modelled HBM bytes each mode ships
                  (the fused mode structurally elides every interior
                  activation round trip)
  block fusion    a gemma decode transformer block spanning former
                  ``adapt`` (head-split) breaks -- [wv, qk, pv, wo] --
                  executed per-layer vs as ONE streamed megakernel
                  launch (asserted via ``Backend.n_launches``), with the
                  streamed VMEM high-water vs the resident-weights
                  footprint
  decode serving  the continuous-batching Scheduler over a reduced
                  (arch x shape) cell with the batched decode fast path
                  off vs on (``use_fused``), reporting tok/s

``--merge`` folds the results into an existing ``BENCH_results.json``
(the CI perf-smoke step merges into the uploaded artifact);
``benchmarks/run.py`` also embeds these numbers directly.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _build_chain(cfg, dims, acts, cache):
    """Lower + chain an L-layer MLP-style stack; returns (programs, seg)."""
    from repro.core import program as programlib
    from repro.runtime.executable import ACTIVATIONS

    progs = []
    for i in range(len(dims) - 1):
        m, k, n = dims[0][0], dims[i][1], dims[i + 1][1]
        from repro.core.mapper import Gemm
        g = Gemm(m=m, k=k, n=n, name=f"chain-l{i}")
        plan = cache.plan(g, cfg)
        act = acts[i]
        progs.append(cache.lower(
            plan.gemm, plan.choice, cfg,
            activation=ACTIVATIONS.get(act), act_name=act,
            out_name=f"O{i}"))
    chained = programlib.chain(progs, lower_fn=cache.lower)
    seg = programlib.fuse_segment(chained)
    return chained, seg


def _time(fn, iters):
    fn()                                  # one extra warm call
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def bench_chain_kernels(quick: bool = False) -> dict:
    """Per-layer launches vs ONE fused launch over a chained MLP stack."""
    from repro import backends
    from repro.configs.feather import feather_config
    from repro.runtime import ProgramCache

    cfg = feather_config(4, 16)
    cache = ProgramCache()
    m = 64
    widths = [96, 128, 96, 64] if not quick else [64, 96, 64]
    dims = [(m, w) for w in widths]
    acts = ["relu"] * (len(widths) - 2) + ["none"]
    chained, seg = _build_chain(cfg, dims, acts, cache)
    assert seg is not None, "benchmark chain must be fusion-legal"

    rng = np.random.default_rng(0)
    x = rng.standard_normal((m, widths[0])).astype(np.float32)
    ws = [rng.standard_normal((widths[i], widths[i + 1]))
          .astype(np.float32) / np.sqrt(widths[i])
          for i in range(len(widths) - 1)]
    seg_t = {"I": x, **{f"W{i}": w for i, w in enumerate(ws)}}

    be = backends.PallasBackend(cfg, compile_cache=cache)

    def per_layer():
        for i, prog in enumerate(chained):
            t = {"W": ws[i]}
            if i == 0:
                t["I"] = x
            be.run_program(prog, t)
        return be.outputs[chained[-1].out_name]

    def fused():
        return be.run_segment(seg, seg_t)[seg.out_name]

    # correctness before timing: both modes == the einsum oracle
    ref = x.copy()
    for i, w in enumerate(ws):
        ref = ref @ w
        if acts[i] == "relu":
            ref = np.maximum(ref, 0)
    np.testing.assert_allclose(per_layer(), ref, rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(fused(), ref, rtol=2e-4, atol=2e-3)

    iters = 5 if quick else 20
    us_layer = _time(per_layer, iters)
    us_fused = _time(fused, iters)
    return {
        "n_layers": len(ws),
        "m": m,
        "widths": widths,
        "us_per_layer": us_layer,
        "us_fused": us_fused,
        "kernel_speedup": us_layer / max(us_fused, 1e-9),
        "hbm_bytes_per_layer": seg.per_layer_kernel_hbm_bytes(),
        "hbm_bytes_fused": seg.kernel_hbm_bytes(),
        "hbm_bytes_elided": seg.elided_hbm_bytes(),
        "n_launches_per_layer": len(ws),
        "n_launches_fused": 1,
        "vmem_highwater_bytes": seg.vmem_highwater_bytes(),
        "vmem_resident_bytes": seg.resident_vmem_bytes(),
    }


def bench_block_fusion(quick: bool = False, arch: str = "gemma-7b") -> dict:
    """A transformer block spanning former adapt breaks, per-layer vs
    ONE streamed launch.

    Picks the decode cell's adapt-spanning fused segment ([wv, qk
    softmax, pv, wo] -- attention with the head-split/merge permutations
    done in-kernel), cross-checks both modes, asserts the fused mode is
    exactly one ``pallas_call`` via ``Backend.n_launches``, and reports
    wall clock, launches per block, elided HBM bytes and the streamed
    VMEM high-water against the keep-every-weight-resident footprint.
    """
    from repro import backends
    from repro.backends.base import Backend
    from repro.configs.feather import feather_config
    from repro.runtime import ModelExecutable, ProgramCache

    cfg = feather_config(4, 16)
    ex = ModelExecutable.for_cell(arch, "decode_tiny", cfg,
                                  cache=ProgramCache())
    seg = next(s for s in ex.segments
               if s.fused is not None and any(s.fused.adapts))
    steps = [ex.steps[i] for i in seg.indices]
    env = ex.make_tensors(seed=3)
    rng = np.random.default_rng(7)
    g0 = steps[0].op.gemm
    t = {"I": rng.standard_normal((g0.m, g0.k)).astype(np.float32)}
    for j, s in enumerate(steps):
        t[f"W{j}"] = np.asarray(env[s.weight_name], np.float32)
    fused = seg.fused

    be = backends.get_backend("pallas", cfg)
    before = be.n_launches
    out = np.asarray(be.run_segment(fused, t)[fused.out_name])
    launches_fused = be.n_launches - before
    assert launches_fused == 1, \
        f"block fusion must be ONE launch, got {launches_fused}"
    per_be = backends.get_backend("pallas", cfg)
    # the base replay on a pallas instance = today's per-layer path
    before = per_be.n_launches
    ref = np.asarray(
        Backend.run_segment(per_be, fused, t)[fused.out_name])
    launches_per_layer = per_be.n_launches - before
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)

    iters = 5 if quick else 20
    us_layer = _time(lambda: Backend.run_segment(per_be, fused, t), iters)
    us_fused = _time(lambda: be.run_segment(fused, t), iters)
    return {
        "arch": arch,
        "n_layers": fused.n_layers,
        "adapts": list(fused.adapts),
        "launches_per_block_per_layer": launches_per_layer,
        "launches_per_block_fused": launches_fused,
        "us_per_layer": us_layer,
        "us_fused": us_fused,
        "block_speedup": us_layer / max(us_fused, 1e-9),
        "hbm_bytes_elided": fused.elided_hbm_bytes(),
        "vmem_highwater_bytes": fused.vmem_highwater_bytes(),
        "vmem_resident_bytes": fused.resident_vmem_bytes(),
        "max_layer_working_set_bytes": fused.max_layer_working_set_bytes(),
    }


def bench_decode_serving(quick: bool = False,
                         arch: str = "gemma-7b") -> dict:
    """Scheduler decode throughput with the fused fast path off vs on."""
    from repro.configs.feather import feather_config
    from repro.runtime import ModelExecutable, ProgramCache, Scheduler

    cfg = feather_config(4, 16)
    cache = ProgramCache()
    prefill = ModelExecutable.for_cell(arch, "prefill_tiny", cfg,
                                       cache=cache)
    decode = ModelExecutable.for_cell(arch, "decode_tiny", cfg,
                                      cache=cache)
    n_requests, decode_steps = (2, 2) if quick else (4, 4)

    def serve(use_fused: bool):
        sched = Scheduler(prefill, decode, backend="pallas",
                          max_concurrent=2, use_fused=use_fused)
        for _ in range(n_requests):
            sched.submit(decode_steps=decode_steps)
        return sched.run()

    serve(False), serve(True)             # warm both jit paths
    rep_layer = serve(False)
    rep_fused = serve(True)
    fusion = decode.fusion_stats()
    # traced re-run (untimed): checksum-gated span breakdown of where a
    # decode tick goes -- kernel launches vs host scheduling
    from repro.obs import export as obs_export
    from repro.obs.trace import trace
    trace.clear().enable()
    try:
        rep_traced = serve(True)
    finally:
        trace.disable()
    assert ([r.state_checksum for r in rep_traced.requests]
            == [r.state_checksum for r in rep_fused.requests]), \
        "tracing perturbed serving state"
    breakdown = obs_export.span_breakdown("decode_tick", {"launch"})
    return {
        "arch": arch,
        "tok_s_per_layer": rep_layer.tokens_per_sec,
        "tok_s_fused": rep_fused.tokens_per_sec,
        "decode_speedup": (rep_fused.tokens_per_sec
                           / max(rep_layer.tokens_per_sec, 1e-9)),
        "fused_segments": rep_fused.decode_fused_segments,
        "segments": rep_fused.decode_segments,
        "fused_steps": fusion["n_fused_steps"],
        "decode_hbm_elided_bytes": rep_fused.decode_hbm_elided_bytes,
        "decode_tick_kernel_frac": breakdown["child_frac"],
        "decode_tick_host_frac": breakdown["host_frac"],
        "state_checksums_equal": (
            [r.state_checksum for r in rep_layer.requests]
            == [r.state_checksum for r in rep_fused.requests]),
    }


def run(quick: bool = False) -> dict:
    out = {
        "chain_kernels": bench_chain_kernels(quick),
        "block_fusion": bench_block_fusion(quick),
        "decode_serving": bench_decode_serving(quick),
    }
    c, b, d = (out["chain_kernels"], out["block_fusion"],
               out["decode_serving"])
    print(f"{'mode':>12} {'us/chain':>10} {'HBM B':>8} "
          f"{'launch/blk':>10} {'VMEM B':>9}   {'tok/s':>8}")
    print(f"{'per-layer':>12} {c['us_per_layer']:10.0f} "
          f"{c['hbm_bytes_per_layer']:8.0f} "
          f"{b['launches_per_block_per_layer']:10d} "
          f"{b['vmem_resident_bytes']:9.0f}   "
          f"{d['tok_s_per_layer']:8.1f}")
    print(f"{'fused':>12} {c['us_fused']:10.0f} "
          f"{c['hbm_bytes_fused']:8.0f} "
          f"{b['launches_per_block_fused']:10d} "
          f"{b['vmem_highwater_bytes']:9.0f}   {d['tok_s_fused']:8.1f}")
    print(f"kernel_speedup={c['kernel_speedup']:.2f}x "
          f"block_speedup={b['block_speedup']:.2f}x "
          f"decode_speedup={d['decode_speedup']:.2f}x "
          f"elided={c['hbm_bytes_elided']:.0f}B/chain "
          f"checksums_equal={d['state_checksums_equal']}")
    return out


def flat_metrics(result: dict) -> dict:
    """JSON-friendly flat view (merged into BENCH_results.json)."""
    keep = {
        "chain_kernels": ("us_per_layer", "us_fused", "kernel_speedup",
                          "hbm_bytes_per_layer", "hbm_bytes_fused",
                          "hbm_bytes_elided", "vmem_highwater_bytes",
                          "vmem_resident_bytes"),
        "block_fusion": ("us_per_layer", "us_fused", "block_speedup",
                         "launches_per_block_per_layer",
                         "launches_per_block_fused", "hbm_bytes_elided",
                         "vmem_highwater_bytes", "vmem_resident_bytes",
                         "max_layer_working_set_bytes"),
        "decode_serving": ("tok_s_per_layer", "tok_s_fused",
                           "decode_speedup", "fused_segments",
                           "decode_hbm_elided_bytes",
                           "decode_tick_kernel_frac",
                           "decode_tick_host_frac"),
    }
    return {f"{section}.{key}": result[section][key]
            for section, keys in keep.items() for key in keys}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI sizes")
    ap.add_argument("--json", default="", help="write results to PATH")
    ap.add_argument("--merge", action="store_true",
                    help="merge into an existing BENCH_results.json "
                         "instead of overwriting")
    ap.add_argument("--gate", action="store_true",
                    help="fail unless the adapt-spanning block is ONE "
                         "launch with streamed VMEM below resident")
    args = ap.parse_args()
    result = run(quick=args.quick)
    if args.gate:
        b = result["block_fusion"]
        assert b["launches_per_block_fused"] == 1, b
        assert b["vmem_highwater_bytes"] < b["vmem_resident_bytes"], b
        print(f"gate ok: 1 launch/block "
              f"(vs {b['launches_per_block_per_layer']}), VMEM "
              f"{b['vmem_highwater_bytes']}B < "
              f"{b['vmem_resident_bytes']}B resident")
    if args.json:
        payload = {}
        if args.merge and os.path.exists(args.json):
            with open(args.json) as f:
                payload = json.load(f)
        payload.setdefault("results", {})["fusion_compare"] = {
            "derived": f"kernel_speedup="
                       f"{result['chain_kernels']['kernel_speedup']:.3g}",
            **flat_metrics(result),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
