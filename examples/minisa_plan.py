"""Plan FEATHER+ offload for every assigned architecture (decode_32k)
and print the instruction-traffic table -- the framework-level integration
of the paper (core/planner + core/model_gemms).

    PYTHONPATH=src python examples/minisa_plan.py [--check-backends]

``--check-backends`` additionally executes each architecture's planned
Programs (the ones small enough to run functionally) on both execution
backends -- interpreter and Pallas -- against the einsum oracle.
"""

import argparse

from repro.configs.base import SHAPES
from repro.configs.feather import feather_config
from repro.configs.registry import ARCH_IDS, get_config
from repro.core.model_gemms import gemm_workloads
from repro.core.planner import cross_check, plan_model

ap = argparse.ArgumentParser()
ap.add_argument("--check-backends", action="store_true",
                help="cross-validate planned Programs on the interpreter "
                     "and Pallas backends against the einsum oracle")
ap.add_argument("--max-check-macs", type=float, default=2e8,
                help="skip functional execution of GEMMs above this size")
args = ap.parse_args()

cfg = feather_config(16, 256)
print(f"{'arch':>22} {'speedup':>8} {'util':>7} {'instr-red':>10} "
      f"{'tiles':>6} {'elided-B':>9}")
for arch in ARCH_IDS:
    ops = gemm_workloads(get_config(arch), SHAPES["decode_32k"])
    plan = plan_model(arch, "decode_32k", ops, cfg)
    s = plan.summary()
    # every per-shape plan carries its lowered Program: the same tiled
    # artifact drives the backends, the perf model and these byte counts
    n_tiles = sum(p.program.n_tiles for p in plan.plans.values())
    print(f"{arch:>22} {s['speedup']:8.2f} {s['utilization']:7.1%} "
          f"{s['instr_reduction']:10.2e} {n_tiles:6d} "
          f"{s['elided_bytes']:9.1f}")
    if args.check_backends:
        errs = cross_check(plan, max_macs=args.max_check_macs)
        worst = max((e for d in errs.values() for e in d.values()),
                    default=0.0)
        print(f"{'':>22} backends OK on {len(errs)}/{len(plan.plans)} "
              f"unique GEMMs (max |err| {worst:.2e})")
