"""Plan FEATHER+ offload for every assigned architecture (decode_32k)
and print the instruction-traffic table -- the framework-level integration
of the paper (core/planner + core/model_gemms).

    PYTHONPATH=src python examples/minisa_plan.py
"""

from repro.configs.base import SHAPES
from repro.configs.feather import feather_config
from repro.configs.registry import ARCH_IDS, get_config
from repro.core.model_gemms import gemm_workloads
from repro.core.planner import plan_model

cfg = feather_config(16, 256)
print(f"{'arch':>22} {'speedup':>8} {'util':>7} {'instr-red':>10} "
      f"{'tiles':>6} {'elided-B':>9}")
for arch in ARCH_IDS:
    ops = gemm_workloads(get_config(arch), SHAPES["decode_32k"])
    plan = plan_model(arch, "decode_32k", ops, cfg)
    s = plan.summary()
    # every per-shape plan carries its lowered Program: the same tiled
    # artifact drives the machine, the perf model and these byte counts
    n_tiles = sum(p.program.n_tiles for p in plan.plans.values())
    print(f"{arch:>22} {s['speedup']:8.2f} {s['utilization']:7.1%} "
          f"{s['instr_reduction']:10.2e} {n_tiles:6d} "
          f"{s['elided_bytes']:9.1f}")
