"""Quickstart: the MINISA pipeline end-to-end on one GEMM.

    PYTHONPATH=src python examples/quickstart.py

1. mapper searches (mapping, layout) for a GEMM on FEATHER+ 8x8;
2. the plan lowers to a tiled Program (8-instruction MINISA ISA);
3. the Program executes on BOTH backends: the interpreter (functional
   FEATHER+ machine, tile by tile) and the Pallas compiler (one
   pallas_call derived from the Program's tiling);
4. both results are checked against the einsum oracle;
5. the analytical model reports cycles/stalls vs the micro-instruction
   baseline.
"""

import numpy as np

from repro import backends
from repro.configs.feather import feather_config
from repro.core import mapper
from repro.core.isa import trace_summary

cfg = feather_config(8, 8)
gemm = mapper.Gemm(m=96, k=40, n=88, name="quickstart")

plan = mapper.search(gemm, cfg)
print(f"chosen mapping: df={plan.choice.df.name} vn={plan.choice.vn} "
      f"tile=({plan.choice.m_t},{plan.choice.k_t},{plan.choice.n_t}) "
      f"groups=({plan.choice.n_kg},{plan.choice.n_nb}) dup={plan.choice.dup}")

prog = plan.program
print("\ntrace:", trace_summary(prog.instructions(), cfg))
print("pallas lowering:", backends.compile_program(prog).describe())

rng = np.random.default_rng(0)
i = rng.standard_normal((gemm.m, gemm.k)).astype(np.float32)
w = rng.standard_normal((gemm.k, gemm.n)).astype(np.float32)
oracle = i @ w
for backend in ("interpreter", "pallas"):
    out = plan.execute({"I": i, "W": w}, backend=backend)["O"]
    err = np.abs(out - oracle).max()
    print(f"functional check [{backend:>11}] vs oracle: "
          f"max |err| = {err:.2e}")
    assert err < 1e-3

s = plan.summary()
print(f"\nanalytical model: {s['cycles_minisa']:.0f} cycles (MINISA) vs "
      f"{s['cycles_micro']:.0f} (micro) -> {s['speedup']:.2f}x speedup")
print(f"utilization {s['util_minisa']:.1%}, instruction bytes "
      f"{s['instr_bytes_minisa']:.0f} vs {s['instr_bytes_micro']:.2e} "
      f"({s['instr_reduction']:.0f}x reduction)")
