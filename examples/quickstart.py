"""Quickstart: the MINISA pipeline end-to-end on one GEMM.

    PYTHONPATH=src python examples/quickstart.py

1. mapper searches (mapping, layout) for a GEMM on FEATHER+ 8x8;
2. the plan lowers to a MINISA trace (8-instruction ISA);
3. the functional FEATHER+ machine executes the trace in JAX;
4. the result is checked against the einsum oracle;
5. the analytical model reports cycles/stalls vs the micro-instruction
   baseline.
"""

import numpy as np

from repro.configs.feather import feather_config
from repro.core import machine, mapper, trace
from repro.core.isa import trace_summary

cfg = feather_config(8, 8)
gemm = mapper.Gemm(m=96, k=40, n=88, name="quickstart")

plan = mapper.search(gemm, cfg)
print(f"chosen mapping: df={plan.choice.df.name} vn={plan.choice.vn} "
      f"tile=({plan.choice.m_t},{plan.choice.k_t},{plan.choice.n_t}) "
      f"groups=({plan.choice.n_kg},{plan.choice.n_nb}) dup={plan.choice.dup}")

ops = trace.build_trace(plan)
print("\ntrace:", trace_summary([o.inst for o in ops], cfg))

rng = np.random.default_rng(0)
i = rng.standard_normal((gemm.m, gemm.k)).astype(np.float32)
w = rng.standard_normal((gemm.k, gemm.n)).astype(np.float32)
out = machine.run_trace(cfg, ops, {"I": i, "W": w})["O"]
err = np.abs(out - i @ w).max()
print(f"\nfunctional check vs oracle: max |err| = {err:.2e}")
assert err < 1e-3

s = plan.summary()
print(f"\nanalytical model: {s['cycles_minisa']:.0f} cycles (MINISA) vs "
      f"{s['cycles_micro']:.0f} (micro) -> {s['speedup']:.2f}x speedup")
print(f"utilization {s['util_minisa']:.1%}, instruction bytes "
      f"{s['instr_bytes_minisa']:.0f} vs {s['instr_bytes_micro']:.2e} "
      f"({s['instr_reduction']:.0f}x reduction)")
