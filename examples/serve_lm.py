"""Batched serving example: prefill + greedy decode on a reduced model.

    PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main(["--arch", "minitron-4b", "--reduced", "--batch", "4",
                "--prompt-len", "32", "--steps", "24"])
