"""Batched serving through the MINISA model runtime.

    PYTHONPATH=src python examples/serve_lm.py [--backend pallas]

Previously this example drove the JAX model engine directly, bypassing
the MINISA spine.  It now routes the serving cell's decode-step GEMMs
through the runtime: the arch's prefill/decode GEMM streams are compiled
once into chained Programs via the shared ProgramCache, and a
continuous-batching Scheduler serves concurrent requests against them on
a real execution backend -- reporting throughput, per-request MINISA vs
micro-instruction traffic, and the cache reuse that makes request #2
free of searches and compiles.

(The raw JAX engine path is still available via
``python -m repro.launch.serve``.)
"""

import argparse

from repro.configs.feather import feather_config
from repro.runtime import ModelExecutable, ProgramCache, Scheduler


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--backend", choices=("interpreter", "pallas"),
                    default="interpreter")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--steps", type=int, default=4,
                    help="decode steps per request")
    ap.add_argument("--concurrent", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = feather_config(4, 16)
    cache = ProgramCache()
    prefill = ModelExecutable.for_cell(args.arch, "prefill_tiny", cfg,
                                       cache=cache)
    decode = ModelExecutable.for_cell(args.arch, "decode_tiny", cfg,
                                      cache=cache)
    print(f"compiled {prefill.name}: {prefill.describe()}")
    print(f"compiled {decode.name}:  {decode.describe()}")
    print(f"cache after build: {cache.stats.summary()}")

    sched = Scheduler(prefill, decode, backend=args.backend,
                      max_concurrent=args.concurrent)
    for _ in range(args.requests):
        sched.submit(decode_steps=args.steps)
    report = sched.run()

    s = report.summary()
    print(f"\nserved {s['n_requests']} requests, {s['total_tokens']} tokens "
          f"in {s['wall_s']:.2f}s ({s['tokens_per_sec']:.1f} tok/s) "
          f"on {s['backend']}")
    print(f"cache hit rate {s['cache_hit_rate']:.1%} "
          f"(searches {s['cache_searches']}, compiles {s['cache_compiles']})")
    for r in report.requests:
        print(f"  req {r.rid}: {r.tokens} tok, "
              f"minisa {r.minisa_bytes:.0f} B vs micro "
              f"{r.micro_bytes:.3g} B ({r.instr_reduction:.0f}x), "
              f"stall {r.stall_minisa:.1%} vs {r.stall_micro:.1%}")


if __name__ == "__main__":
    main()
