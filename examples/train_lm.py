"""End-to-end training driver: a ~100M-parameter gemma-family model for a
few hundred steps on CPU, with checkpointing + resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt_quick")
    args = ap.parse_args()
    train_main([
        "--arch", "gemma-7b", "--reduced",
        "--reduced-layers", "8", "--reduced-dmodel", "512",
        "--steps", str(args.steps), "--batch", "8", "--seq", "256",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        "--resume", "auto", "--log-every", "20",
    ])
