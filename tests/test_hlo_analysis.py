"""HLO structural analyzer: trip-count recovery, dot FLOPs, collectives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze

N = 256


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_scan_flops_multiplied_by_trip_count():
    def f(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
        return y
    c = _compile(f, jax.ShapeDtypeStruct((N, N), jnp.float32),
                 jax.ShapeDtypeStruct((10, N, N), jnp.float32))
    r = analyze(c.as_text())
    assert r["dot_flops"] == pytest.approx(10 * 2 * N ** 3, rel=0.01)
    assert 10 in r["while_trips"]


def test_nested_scan_multipliers_compose():
    def f(x, ws):
        def outer(c, w):
            c2, _ = jax.lax.scan(lambda ci, _: (jnp.tanh(ci @ w), None),
                                 c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y
    c = _compile(f, jax.ShapeDtypeStruct((N, N), jnp.float32),
                 jax.ShapeDtypeStruct((10, N, N), jnp.float32))
    r = analyze(c.as_text())
    assert r["dot_flops"] == pytest.approx(30 * 2 * N ** 3, rel=0.01)
    assert sorted(r["while_trips"], reverse=True)[:2] == [10, 3]


def test_unrolled_matches_cost_analysis():
    def f(x, w):
        for _ in range(4):
            x = x @ w
        return x
    c = _compile(f, jax.ShapeDtypeStruct((N, N), jnp.float32),
                 jax.ShapeDtypeStruct((N, N), jnp.float32))
    r = analyze(c.as_text())
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert r["dot_flops"] == pytest.approx(float(ca["flops"]), rel=0.01)


def test_memory_proxy_lower_bounded_by_io():
    def f(x, w):
        return x @ w
    c = _compile(f, jax.ShapeDtypeStruct((N, N), jnp.float32),
                 jax.ShapeDtypeStruct((N, N), jnp.float32))
    r = analyze(c.as_text())
    io_bytes = 3 * N * N * 4
    assert r["tensor_bytes"] >= 0.9 * io_bytes
