"""Planner tests: model-graph GEMM extraction + MINISA plan aggregation."""

import pytest

from repro.configs.base import SHAPES
from repro.configs.feather import feather_config
from repro.configs.registry import ARCH_IDS, get_config
from repro.core.model_gemms import gemm_workloads
from repro.core.planner import plan_model
from repro.models import build_model


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_gemm_extraction_all_archs(arch):
    cfg = get_config(arch)
    for shape_name in ("train_4k", "decode_32k"):
        ops = gemm_workloads(cfg, SHAPES[shape_name])
        assert ops, (arch, shape_name)
        for op in ops:
            g = op.gemm
            assert g.m > 0 and g.k > 0 and g.n > 0 and g.count > 0


def test_gemm_macs_match_model_flops_dense():
    """Projection MACs for a dense arch are within 2x of 6*N*D/6 (=N*D):
    the GEMM stream covers ~all matmul FLOPs of the model."""
    arch = "qwen2-72b"
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    ops = gemm_workloads(cfg, shape)
    macs = sum(op.gemm.macs * op.gemm.count for op in ops
               if not op.gemm.name.startswith(("qk", "pv")))
    n_params = build_model(cfg).param_count()
    expect = n_params * shape.tokens  # fwd MACs ~= N*D
    assert 0.5 * expect < macs < 2.0 * expect


def test_attention_gemms_present_for_dynamic_operands():
    """FEATHER+'s headline case: both GEMM operands arrive at runtime."""
    cfg = get_config("gemma-7b")
    ops = gemm_workloads(cfg, SHAPES["prefill_32k"])
    names = {op.gemm.name.split("-")[0] for op in ops}
    assert any("qk" in op.gemm.name for op in ops)
    assert any("pv" in op.gemm.name for op in ops)


def test_ssm_arch_has_no_attention_gemms():
    """Arch-applicability: falcon-mamba is attention-free; the scan is not
    a GEMM (routed to Activation, DESIGN.md)."""
    cfg = get_config("falcon-mamba-7b")
    ops = gemm_workloads(cfg, SHAPES["train_4k"])
    assert not any("qk" in op.gemm.name or "pv" in op.gemm.name
                   for op in ops)
    assert any("ssm" in op.gemm.name for op in ops)


def test_plan_model_aggregates():
    cfg = get_config("granite-moe-3b-a800m")
    fcfg = feather_config(8, 32)
    ops = gemm_workloads(cfg, SHAPES["decode_32k"])
    plan = plan_model("granite-moe-3b-a800m", "decode_32k", ops, fcfg)
    s = plan.summary()
    assert s["speedup"] >= 1.0
    assert s["instr_reduction"] > 10
    assert s["instr_to_data_minisa"] < 0.01
    assert 0 < s["utilization"] <= 1.0
    assert s["elided_bytes"] > 0          # chained layers elide layouts


def test_plan_speedup_grows_with_array_scale():
    cfg = get_config("gemma-7b")
    ops = gemm_workloads(cfg, SHAPES["decode_32k"])
    sp = []
    for ah, aw in [(4, 4), (8, 32), (16, 256)]:
        plan = plan_model("gemma-7b", "decode_32k", ops, feather_config(ah, aw))
        sp.append(plan.speedup)
    assert sp[0] < 1.5
    assert sp[-1] > 5
    assert sp == sorted(sp)
