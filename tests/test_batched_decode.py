"""Cross-request batched decode: the flash-decode kernel against a
masked numpy oracle, M-polymorphic batch plans, one-launch-per-segment
ticks, paged KV admission, and the spine invariant -- per-request
``state_checksum``s are bit-identical across backends, batch
compositions and arrival interleavings."""

import numpy as np
import pytest

from repro.configs.feather import feather_config
from repro.core import program as programlib
from repro.core.mapper import Gemm
from repro.kernels import ops
from repro.runtime import ModelExecutable, ProgramCache, Scheduler

CFG = feather_config(4, 16)

#: mixed decode lengths (retire-mid-batch) and mixed prompt lengths
#: (chunked prefill): every batch composition the scheduler can hit
SUBMISSIONS = [(3, None), (1, None), (2, 64), (4, 32), (2, None)]


@pytest.fixture(scope="module")
def cache():
    return ProgramCache()


@pytest.fixture(scope="module")
def cell(cache):
    prefill = ModelExecutable.for_cell("gemma-7b", "prefill_tiny", CFG,
                                       cache=cache)
    decode = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                      cache=cache)
    return prefill, decode


# ---------------------------------------------------------------------------
# M buckets
# ---------------------------------------------------------------------------

def test_m_bucket_ladder():
    assert [programlib.m_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9, 16, 17)] \
        == [1, 2, 4, 4, 8, 8, 16, 16, 32]
    assert programlib.m_bucket(200) == 256       # doubles past the ladder
    with pytest.raises(ValueError):
        programlib.m_bucket(0)


def test_bucketed_gemm_scales_m_only():
    g = Gemm(m=2, k=16, n=64, name="wq")
    b = programlib.bucketed_gemm(g, 8)
    assert (b.m, b.k, b.n) == (16, 16, 64)
    assert b.name == "wq@mx8"


# ---------------------------------------------------------------------------
# flash-decode kernel vs masked numpy oracle
# ---------------------------------------------------------------------------

def _oracle(q, k, v, lengths):
    outs = []
    for b in range(q.shape[0]):
        s = q[b].astype(np.float32) @ k[b, :lengths[b]].T
        p = np.exp(s - s.max(axis=-1, keepdims=True))
        p /= p.sum(axis=-1, keepdims=True)
        outs.append(p @ v[b, :lengths[b]])
    return np.stack(outs)


def test_flash_decode_matches_masked_oracle():
    rng = np.random.default_rng(0)
    B, sq, skv, d = 4, 1, 16, 8
    q = rng.standard_normal((B, sq, d)).astype(np.float32)
    k = rng.standard_normal((B, skv, d)).astype(np.float32)
    v = rng.standard_normal((B, skv, d)).astype(np.float32)
    lengths = np.array([16, 5, 1, 9], dtype=np.int32)
    out = np.asarray(ops.flash_decode(q, k, v, lengths))
    np.testing.assert_allclose(out, _oracle(q, k, v, lengths),
                               rtol=1e-5, atol=1e-5)
    # default lengths == full width
    np.testing.assert_array_equal(
        np.asarray(ops.flash_decode(q, k, v)),
        np.asarray(ops.flash_decode(q, k, v,
                                    np.full(B, skv, np.int32))))


def test_flash_decode_ragged_kv_padding():
    """skv not a block multiple: the zero-padded tail must not leak."""
    rng = np.random.default_rng(1)
    B, sq, skv, d = 3, 2, 12, 8
    q = rng.standard_normal((B, sq, d)).astype(np.float32)
    k = rng.standard_normal((B, skv, d)).astype(np.float32)
    v = rng.standard_normal((B, skv, d)).astype(np.float32)
    lengths = np.array([12, 3, 7], dtype=np.int32)
    out = np.asarray(ops.flash_decode(q, k, v, lengths, bkv=8))
    np.testing.assert_allclose(out, _oracle(q, k, v, lengths),
                               rtol=1e-5, atol=1e-5)


def test_flash_decode_proj_matches_adapt_oracle():
    """Block-fused decode attention: the output projection folded into
    the kernel's last KV step equals flash_decode + host adapt + GEMM,
    across shrink/identity/growth adapt geometries and ragged lengths."""
    from repro.runtime.executable import adapt
    rng = np.random.default_rng(2)
    B, sq, skv, d = 3, 1, 16, 12
    q = rng.standard_normal((B, sq, d)).astype(np.float32)
    k = rng.standard_normal((B, skv, d)).astype(np.float32)
    v = rng.standard_normal((B, skv, d)).astype(np.float32)
    lengths = np.array([16, 7, 11], dtype=np.int32)
    ctx = np.asarray(ops.flash_decode(q, k, v, lengths))
    for m_out, k_out in [(1, 12), (2, 8), (3, 5)]:
        wo = rng.standard_normal((k_out, 6)).astype(np.float32)
        want = np.stack([adapt(ctx[r], m_out, k_out) @ wo
                         for r in range(B)])
        got = np.asarray(ops.flash_decode_proj(q, k, v, wo, lengths,
                                               m_out=m_out, k_out=k_out))
        assert got.shape == (B, m_out, 6)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_backend_batched_attention_proj_one_launch(cell):
    """attention + Wo for the whole batch is ONE pallas launch, and it
    matches the base backend's replay + host-adapt oracle."""
    _, decode = cell
    plan = decode.batch_plan(3)
    bseg = next(s for s in plan.segments if s.kind == "attention")
    nxt = decode.steps[bseg.indices[-1] + 1]
    assert nxt.input_mode == "adapt"
    g = nxt.op.gemm                      # the wo step's adapt geometry
    qk, pv = bseg.programs
    rng = np.random.default_rng(7)
    B = 3
    q = rng.standard_normal((B, qk.gemm.m, qk.gemm.k)).astype(np.float32)
    kT = rng.standard_normal((B, qk.gemm.k, qk.gemm.n)).astype(np.float32)
    v = rng.standard_normal((B, pv.gemm.k, pv.gemm.n)).astype(np.float32)
    wo = rng.standard_normal((g.k, g.n)).astype(np.float32)
    interp = decode.make_backend("interpreter")
    want = interp.run_batched_attention_proj(
        (qk, pv), q, kT, v, wo, m_out=g.m, k_out=g.k)
    pallas = decode.make_backend("pallas")
    l0 = pallas.n_launches
    got = pallas.run_batched_attention_proj(
        (qk, pv), q, kT, v, wo, m_out=g.m, k_out=g.k)
    assert pallas.n_launches - l0 == 1
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Batch plan: one launch per segment, no new mapper searches
# ---------------------------------------------------------------------------

def test_batch_plan_one_launch_per_segment(cell, cache):
    _, decode = cell
    plan = decode.batch_plan(5)
    assert plan.bucket == 8
    assert plan.launches_per_tick == len(plan.segments)
    kinds = [s.kind for s in plan.segments]
    assert "attention" in kinds and "perreq" not in kinds


def test_batch_plans_reuse_base_choices(cell, cache):
    """Bucketed re-lowering reuses each step's MappingChoice: growing the
    ladder costs lowerings, never mapper searches."""
    _, decode = cell
    snap = cache.stats.snapshot()
    for n in (1, 2, 3, 4, 8, 16):
        decode.batch_plan(n)
    delta = cache.stats.delta(snap)
    assert delta["plan_misses"] == 0, delta
    # bucket memoisation: same sizes again do zero work
    snap = cache.stats.snapshot()
    for n in (1, 2, 3, 4, 8, 16):
        decode.batch_plan(n)
    assert cache.stats.delta(snap)["lowered_misses"] == 0


def test_run_batch_matches_sequential(cell):
    """Stacked-M execution equals per-request runs on both backends."""
    _, decode = cell
    n = 5
    weights = decode.make_tensors(seed=0, kinds=("weight",))
    envs = []
    for r in range(n):
        env = dict(weights)
        env.update(decode.make_tensors(seed=10 + r, kinds=("dynamic",)))
        env.update(decode.make_tensors(seed=100 + r, kinds=("input",)))
        envs.append(env)
    seq = [decode.run("interpreter", tensors=e).final for e in envs]
    bi = decode.run_batch("interpreter", envs, fused=False)
    for r in range(n):
        np.testing.assert_allclose(bi[r], seq[r], rtol=1e-5, atol=1e-6)
    be = decode.make_backend("pallas")
    l0 = be.n_launches
    bp = decode.run_batch(be, envs, fused=True)
    assert be.n_launches - l0 == decode.batch_plan(n).launches_per_tick
    k_max = max(s.op.gemm.k for s in decode.steps)
    for r in range(n):
        np.testing.assert_allclose(bp[r], seq[r], rtol=2e-4,
                                   atol=2e-4 * k_max)


# ---------------------------------------------------------------------------
# Scheduler: batch-composition invariance (the spine invariant)
# ---------------------------------------------------------------------------

def _serve(prefill, decode, **kw):
    sched = Scheduler(prefill, decode, **kw)
    for steps, prompt in SUBMISSIONS:
        sched.submit(decode_steps=steps, prompt_tokens=prompt)
    rep = sched.run()
    assert len(rep.requests) == len(SUBMISSIONS)
    assert all(r.state_checksum for r in rep.requests)
    return {r.rid: r.state_checksum for r in rep.requests}, rep


@pytest.fixture(scope="module")
def oracle_checksums(cell):
    """Sequential per-request interpreter run: the reference trajectory."""
    prefill, decode = cell
    sums, _ = _serve(prefill, decode, backend="interpreter",
                     batch_decode=False, use_fused=False)
    return sums


@pytest.mark.parametrize("backend,batch,fused,conc", [
    ("interpreter", True, False, 5),     # batched, per-layer programs
    ("pallas", False, True, 5),          # sequential fused (PR 5 path)
    ("pallas", True, True, 5),           # batched fused + flash decode
    ("pallas", True, True, 2),           # different batch composition
    ("pallas", True, True, 3),           # retire/admit interleaving
])
def test_batched_checksums_match_sequential(cell, oracle_checksums,
                                            backend, batch, fused, conc):
    prefill, decode = cell
    sums, rep = _serve(prefill, decode, backend=backend,
                       batch_decode=batch, use_fused=fused,
                       max_concurrent=conc)
    assert sums == oracle_checksums
    assert rep.batch_decode == batch


def test_batched_decode_one_launch_per_segment_per_tick(cell):
    prefill, decode = cell
    _, rep = _serve(prefill, decode, backend="pallas", batch_decode=True,
                    max_concurrent=5)
    per_tick = decode.batch_plan(1).launches_per_tick
    assert rep.decode_ticks > 0
    assert rep.launches_per_decode_tick == per_tick
    assert rep.decode_launches == rep.decode_ticks * per_tick


def test_reports_carry_ttft_and_percentiles(cell):
    prefill, decode = cell
    _, rep = _serve(prefill, decode, backend="interpreter",
                    batch_decode=True, max_concurrent=3)
    s = rep.summary()
    for key in ("ttft_p50_s", "ttft_p95_s", "ttft_p99_s",
                "latency_p50_s", "latency_p95_s", "latency_p99_s"):
        assert s[key] > 0.0, key
    for r in rep.requests:
        assert 0.0 < r.ttft_s <= r.wall_s
    # chunked prompts did more prefill work than single-pass ones
    by_rid = {r.rid: r for r in rep.requests}
    assert by_rid[2].prefill_tokens > by_rid[0].prefill_tokens


def test_kv_pool_admission_stalls_not_oom(cell, oracle_checksums):
    """A pool holding one request serialises admission: everything still
    completes with the identical checksums, and the stats record the
    stalls and evictions."""
    prefill, decode = cell
    per_req = Scheduler(prefill, decode).kv_pool.pages_per_request
    sums, rep = _serve(prefill, decode, backend="interpreter",
                       batch_decode=False, use_fused=False,
                       max_concurrent=4, kv_pages=per_req)
    assert sums == oracle_checksums
    assert rep.kv["admit_stalls"] > 0
    assert rep.kv["evicted_pages"] == per_req * len(SUBMISSIONS)
    assert rep.kv["high_water_pages"] == per_req


def test_kv_pool_too_small_rejected(cell):
    prefill, decode = cell
    with pytest.raises(ValueError, match="kv_pages"):
        Scheduler(prefill, decode, kv_pages=0)


def test_token_budget_defers_prefill(cell, oracle_checksums):
    """A one-chunk-per-tick budget splits prompt work across ticks but
    cannot change any request's trajectory."""
    prefill, decode = cell
    chunk = prefill.tokens or 1
    sums, rep = _serve(prefill, decode, backend="interpreter",
                       batch_decode=True, max_concurrent=5,
                       token_budget=chunk)
    assert sums == oracle_checksums
    budgeted_ticks = rep.ticks
    _, rep_free = _serve(prefill, decode, backend="interpreter",
                         batch_decode=True, max_concurrent=5)
    assert budgeted_ticks > rep_free.ticks


# ---------------------------------------------------------------------------
# ProgramCache: disk-tier LRU bound
# ---------------------------------------------------------------------------

def test_cache_disk_tier_trims_to_lru_bound(tmp_path):
    path = tmp_path / "plans.pkl"
    cache = ProgramCache(path)
    shapes = [(8, 16, 16), (16, 16, 16), (8, 8, 32), (16, 8, 8)]
    for m, k, n in shapes:
        cache.plan(Gemm(m=m, k=k, n=n), CFG)
    cache.plan(Gemm(m=8, k=16, n=16), CFG)      # LRU touch on the oldest
    cache.max_plans = 2                          # tighten a live bound
    cache.save()
    assert cache.stats.disk_evictions == 2
    assert cache.stats.disk_bytes == path.stat().st_size > 0
    fresh = ProgramCache(path)
    assert len(fresh._plans) == 2
    assert fresh.stats.loaded_from_disk == 2
    # most-recently-used survived: the touched plan and the last insert
    kept = {k[:3] for k in fresh._plans}
    assert kept == {(8, 16, 16), (16, 8, 8)}
