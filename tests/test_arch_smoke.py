"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of the same family runs one forward/train step on CPU, asserting output
shapes + no NaNs; plus one decode step against an abstract cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry import ARCH_IDS, get_config
from repro.models import build_model
from repro.train import optimizer as optlib
from repro.train.trainer import TrainConfig, make_train_step

RNG = np.random.default_rng(0)
B, S = 2, 32


def _batch(cfg):
    batch = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        text = S - cfg.frontend_len
        batch["tokens"] = batch["tokens"][:, :text]
        batch["patches"] = jnp.asarray(
            RNG.standard_normal((B, cfg.frontend_len, cfg.d_model)),
            jnp.float32) * 0.02
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            RNG.standard_normal((B, cfg.frontend_len, cfg.d_model)),
            jnp.float32) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    tcfg = TrainConfig()
    step = make_train_step(model, tcfg)
    opt = optlib.init(params)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0
    # no NaNs anywhere in the updated tree
    for leaf in jax.tree.leaves(params2):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_decode(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg)
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["frames"] = batch["frames"]
    if cfg.family == "vlm":
        kwargs["prefix_embeds"] = batch["patches"]
    logits, cache = model.prefill(params, batch["tokens"], **kwargs)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    spec = model.cache_spec(B, S + 8)
    cache_full = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
    pos = jnp.full((B,), 3, jnp.int32)
    tok = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    dlogits, cache2 = model.decode_step(params, tok, cache_full, pos)
    assert dlogits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(dlogits, np.float32)).all()
    # cache shapes preserved
    jax.tree.map(lambda a, b: None if a.shape == b.shape else
                 pytest.fail("cache shape changed"), cache_full, cache2)


@pytest.mark.parametrize("arch", ["gemma-7b", "falcon-mamba-7b",
                                  "zamba2-1.2b", "deepseek-v2-236b"])
def test_decode_is_consistent_with_prefill(arch):
    """Greedy continuation: prefill(t_0..t_{n-1}) then decode(t_n) must give
    the same logits as prefill(t_0..t_n) -- the KV/state cache is exact."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, 12)), jnp.int32)

    logits_full, _ = model.prefill(params, toks)

    from repro.serve.engine import expand_cache
    logits_part, cache = model.prefill(params, toks[:, :-1])
    cache = expand_cache(model, cache, B, 12)
    pos = jnp.full((B,), 11, jnp.int32)
    logits_step, _ = model.decode_step(params, toks[:, -1:], cache, pos)
    np.testing.assert_allclose(np.asarray(logits_step, np.float32),
                               np.asarray(logits_full, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_param_counts_in_expected_band():
    """Full configs land near their nameplate sizes (sanity on the zoo)."""
    expected = {
        "gemma-7b": (7.8e9, 9.5e9),        # 8.5B with embeddings
        "qwen2-72b": (68e9, 80e9),
        "qwen1.5-110b": (105e9, 120e9),
        "minitron-4b": (3.5e9, 5e9),
        "falcon-mamba-7b": (6.5e9, 8.5e9),
        "deepseek-v2-236b": (200e9, 250e9),
        "granite-moe-3b-a800m": (2.5e9, 4.5e9),
        "zamba2-1.2b": (0.9e9, 1.6e9),
        "internvl2-26b": (18e9, 24e9),     # backbone only (ViT stubbed)
        "whisper-base": (0.05e9, 0.11e9),
    }
    for arch, (lo, hi) in expected.items():
        n = build_model(get_config(arch)).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e}, {hi:.1e}]"
