"""Fusion-aware joint mapper + measured autotune (ROADMAP item 3).

The joint-search invariants: frontier points dominate nothing on the
frontier, the measured winner's Program stream passes pallas ==
interpreter == oracle at CI extents, and serving with a tuned cache is
checksum-identical to untuned serving (the geometry changes the K-tile
walk, never the arithmetic the quantised recurrence sees).  Plus the
satellite regressions: memoised ``enumerate_choices``, the versioned
ProgramCache disk schema, and the tuned tier surviving a save/load
round trip into a fresh process's cache.
"""

import dataclasses

import numpy as np
import pytest

from repro import backends
from repro.configs.feather import feather_config
from repro.core import mapper, program, workloads
from repro.obs import export
from repro.obs.trace import trace
from repro.runtime import (ModelExecutable, ProgramCache, Scheduler,
                           autotune_segment, segment_key)
from repro.runtime.autotune import tuning_state
from repro.runtime.executable import ACTIVATIONS

CFG = feather_config(4, 16)


def _build_chain(m, widths, acts, cache=None, cfg=CFG):
    cache = cache or ProgramCache()
    progs = []
    for i in range(len(widths) - 1):
        g = mapper.Gemm(m=m, k=widths[i], n=widths[i + 1],
                        name=f"at-l{i}")
        plan = cache.plan(g, cfg)
        progs.append(cache.lower(
            plan.gemm, plan.choice, cfg,
            activation=ACTIVATIONS.get(acts[i]), act_name=acts[i],
            out_name=f"O{i}"))
    return program.chain(progs, lower_fn=cache.lower), cache


def _chain_tensors(m, widths, seed=3):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, widths[0])).astype(np.float32)
    ws = [(rng.standard_normal((widths[i], widths[i + 1]))
           / np.sqrt(widths[i])).astype(np.float32)
          for i in range(len(widths) - 1)]
    return x, ws


def _ci_chain_dims():
    """(m, widths) anchored on the fhe-ntt CI family extents."""
    g = next(g for g in workloads.ci_suite() if "fhe-ntt" in g.name)
    return g.m, [g.k, g.n, g.k]


# ---------------------------------------------------------------------------
# Joint search: frontier invariants
# ---------------------------------------------------------------------------

def test_frontier_is_non_dominated():
    """No frontier point Pareto-dominates another frontier point, every
    point fits the budget, and the greedy-snap geometry's metrics are
    matched-or-beaten on every axis by some frontier point."""
    m, widths = _ci_chain_dims()
    chained, _ = _build_chain(m, widths, ["relu", "none"])
    front = mapper.search_segment(chained)
    assert front is not None and front.points
    assert front.n_feasible <= front.n_enumerated
    for p in front.points:
        assert p.vmem_bytes <= front.vmem_budget
        assert p.choice.bm >= 1
        assert all(bk >= 1 for bk in p.choice.layer_bks)
    metrics = [p.metrics for p in front.points]
    for i, a in enumerate(metrics):
        for j, b in enumerate(metrics):
            if i != j:
                assert not mapper._dominates(a, b), (a, b)
    # cycles-ascending ordering is what .top(k) relies on
    cyc = [p.cycles for p in front.points]
    assert cyc == sorted(cyc)
    # the greedy-snap geometry is always IN the joint candidate space,
    # so the frontier can only match-or-beat it under the same pricing
    greedy = program.fuse_segment(chained)
    vecs = mapper._bk_vectors(chained, (False, False),
                              front.vmem_budget, "float32")
    assert greedy.layer_bks in vecs


def test_frontier_candidates_are_runnable_geometries():
    """Every frontier point round-trips through fuse_segment into a
    working launch geometry."""
    m, widths = _ci_chain_dims()
    chained, _ = _build_chain(m, widths, ["relu", "none"])
    front = mapper.search_segment(chained)
    for p in front.top(4):
        seg = program.fuse_segment(chained, bm=p.choice.bm,
                                   layer_bks=p.choice.layer_bks)
        assert seg is not None
        assert seg.vmem_highwater_bytes() <= front.vmem_budget


def test_pareto_frontier_drops_dominated():
    mk = lambda t, c, v: mapper.SegmentPoint(  # noqa: E731
        choice=mapper.SegmentChoice(bm=1, layer_bks=(1,)),
        traffic_bytes=t, cycles=c, vmem_bytes=v)
    a, b = mk(10, 10, 10), mk(20, 20, 20)       # a dominates b
    c = mk(5, 30, 30)                           # trades traffic for cycles
    front = mapper.pareto_frontier([b, a, c])
    assert [p.metrics for p in front] == [a.metrics, c.metrics]


# ---------------------------------------------------------------------------
# Measured winner: correctness across the execution spine (satellite 4)
# ---------------------------------------------------------------------------

def test_tuned_winner_matches_interpreter_and_oracle_ci():
    """The measured winner's geometry runs the SAME Programs: fused
    pallas at the tuned geometry == fused interpreter == per-layer
    interpreter == einsum oracle at CI extents."""
    m, widths = _ci_chain_dims()
    acts = ["relu", "none"]
    chained, cache = _build_chain(m, widths, acts)
    be = backends.PallasBackend(CFG, compile_cache=cache)
    rep = autotune_segment(chained, be, cache=cache, top_k=2, iters=1)
    assert rep is not None and not rep.cached
    w = rep.winner
    assert w.n_points_measured >= 1
    assert 0.0 <= w.kernel_frac <= 1.0
    tuned = program.fuse_segment(chained, bm=w.bm, layer_bks=w.layer_bks)
    assert tuned is not None

    x, ws = _chain_tensors(m, widths)
    t = {"I": x, **{f"W{i}": w_ for i, w_ in enumerate(ws)}}
    ref = x.copy()
    for i, w_ in enumerate(ws):
        ref = ref @ w_
        if acts[i] != "none":
            ref = np.asarray(ACTIVATIONS[acts[i]](ref))
    tol = dict(rtol=2e-4, atol=2e-4 + 2e-4 * max(widths))
    out_pallas = np.asarray(be.run_segment(tuned, t)[tuned.out_name])
    interp = backends.get_backend("interpreter", CFG)
    out_interp = np.asarray(interp.run_segment(tuned, t)[tuned.out_name])
    per_layer = backends.get_backend("interpreter", CFG)
    for i, prog in enumerate(chained):
        lt = {"W": ws[i]}
        if i == 0:
            lt["I"] = x
        per_layer.run_program(prog, lt)
    out_layers = np.asarray(per_layer.outputs[chained[-1].out_name])
    np.testing.assert_allclose(out_pallas, ref, err_msg="pallas", **tol)
    np.testing.assert_allclose(out_interp, ref, err_msg="interp", **tol)
    np.testing.assert_allclose(out_layers, ref, err_msg="layers", **tol)


def test_warm_cache_serves_tuned_without_work():
    """Second autotune of a structurally identical segment: zero joint
    searches, zero compiles, zero launches -- one tuned-tier lookup."""
    m, widths = _ci_chain_dims()
    chained, cache = _build_chain(m, widths, ["relu", "none"])
    be = backends.PallasBackend(CFG, compile_cache=cache)
    first = autotune_segment(chained, be, cache=cache, top_k=2, iters=1)
    assert not first.cached
    before = cache.stats.snapshot()
    launches = be.n_launches
    again = autotune_segment(chained, be, cache=cache, top_k=2, iters=1)
    assert again.cached
    assert again.winner == first.winner
    delta = cache.stats.delta(before)
    assert delta["frontier_misses"] == 0
    assert delta["fused_misses"] == 0 and delta["compile_misses"] == 0
    assert delta["tuned_hits"] == 1
    assert be.n_launches == launches


def test_executable_consumes_tuned_geometry():
    """A rebuilt ModelExecutable picks the persisted winner's geometry
    up through ``_fuse_with_tuned`` -- no explicit tuning plumbing."""
    cache = ProgramCache()
    exe = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                   cache=cache)
    segs = [s for s in exe.segments if s.fused is not None]
    assert segs, "decode_tiny must have at least one fused segment"
    be = exe.make_backend("pallas")
    tuned = {}
    for s in segs:
        rep = autotune_segment(list(s.fused.programs), be, cache=cache,
                               adapts=s.fused.adapts, top_k=1, iters=1)
        assert rep is not None
        tuned[tuple(s.indices)] = rep.winner
    rebuilt = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                       cache=cache)
    for s in rebuilt.segments:
        if s.fused is None:
            continue
        w = tuned[tuple(s.indices)]
        assert (s.fused.bm, s.fused.layer_bks) == (w.bm, w.layer_bks)


def test_tuned_serving_checksums_identical():
    """Serving from a tuned cache is bit-identical to untuned serving:
    the tuned geometry changes the K-tile walk of the fused launch, and
    the quantised recurrence absorbs the accumulation-order rounding."""
    def serve(cache):
        prefill = ModelExecutable.for_cell("gemma-7b", "prefill_tiny",
                                           CFG, cache=cache)
        decode = ModelExecutable.for_cell("gemma-7b", "decode_tiny",
                                          CFG, cache=cache)
        sched = Scheduler(prefill, decode, backend="pallas",
                          max_concurrent=2, seed=0)
        for steps, prompt in [(2, None), (1, 64)]:
            sched.submit(decode_steps=steps, prompt_tokens=prompt)
        rep = sched.run()
        return [r.state_checksum for r in rep.requests]

    untuned = serve(ProgramCache())

    cache = ProgramCache()
    for cell in ("prefill_tiny", "decode_tiny"):
        exe = ModelExecutable.for_cell("gemma-7b", cell, CFG,
                                       cache=cache)
        be = exe.make_backend("pallas")
        for s in exe.segments:
            if s.fused is not None:
                autotune_segment(list(s.fused.programs), be,
                                 cache=cache, adapts=s.fused.adapts,
                                 top_k=1, iters=1)
    assert serve(cache) == untuned
    assert all(untuned)


# ---------------------------------------------------------------------------
# Satellite 1: memoised enumerate_choices
# ---------------------------------------------------------------------------

def test_enumerate_choices_memoised():
    g1 = mapper.Gemm(m=24, k=36, n=40, name="a")
    g2 = mapper.Gemm(m=24, k=36, n=40, name="b")     # same structure
    g3 = mapper.Gemm(m=24, k=36, n=48, name="c")     # different shape
    c1 = mapper.enumerate_choices(g1, CFG)
    c2 = mapper.enumerate_choices(g2, CFG)
    assert c1 is c2                    # structural key ignores the name
    assert mapper.enumerate_choices(g3, CFG) is not c1
    assert list(c1) == list(mapper._enumerate_choices(g1, CFG))


def test_enumerate_choices_cache_bounded():
    mapper._ENUM_CACHE.clear()
    for i in range(mapper._ENUM_CACHE_MAX + 8):
        mapper.enumerate_choices(
            mapper.Gemm(m=4, k=4 + i, n=4, name="x"), CFG)
    assert len(mapper._ENUM_CACHE) <= mapper._ENUM_CACHE_MAX


# ---------------------------------------------------------------------------
# Satellite 2: versioned disk entries + tuned-tier round trip
# ---------------------------------------------------------------------------

def test_cache_roundtrip_carries_tuned_tier(tmp_path):
    path = str(tmp_path / "cache.pkl")
    m, widths = _ci_chain_dims()
    chained, cache = _build_chain(m, widths, ["relu", "none"])
    cache.path = path
    be = backends.PallasBackend(CFG, compile_cache=cache)
    rep = autotune_segment(chained, be, cache=cache, top_k=1, iters=1)
    assert not rep.cached                  # autotune saved to disk

    fresh = ProgramCache(path=path)
    assert fresh.stats.loaded_from_disk >= 1
    # the same structural segment in a new process: tuned-tier hit,
    # winner equal, and the executables' struct index is rebuilt
    chained2, _ = _build_chain(m, widths, ["relu", "none"], cache=fresh)
    be2 = backends.PallasBackend(CFG, compile_cache=fresh)
    rep2 = autotune_segment(chained2, be2, cache=fresh,
                            top_k=1, iters=1)
    assert rep2.cached
    assert rep2.winner == rep.winner
    assert fresh.tuned_geometry(chained2) == rep.winner


def test_cache_rejects_version_mismatch(tmp_path):
    import pickle
    path = str(tmp_path / "stale.pkl")
    with open(path, "wb") as f:
        pickle.dump({"version": 1, "plans": {}}, f)
    with pytest.raises(ValueError, match="version"):
        ProgramCache(path=path)


def test_cache_rejects_tier_schema_mismatch(tmp_path):
    import pickle
    from repro.runtime import cache as cachelib
    path = str(tmp_path / "schema.pkl")
    with open(path, "wb") as f:
        pickle.dump({"version": cachelib._PERSIST_VERSION,
                     "schema": {"plans": 99, "tuned": 99},
                     "plans": {}, "tuned": {}}, f)
    with pytest.raises(ValueError, match="schema"):
        ProgramCache(path=path)


def test_segment_key_distinguishes_tuning_state():
    m, widths = _ci_chain_dims()
    chained, _ = _build_chain(m, widths, ["relu", "none"])
    be = backends.PallasBackend(CFG)
    k1 = segment_key(chained, tuning=tuning_state(be))
    k2 = segment_key(chained, tuning=("pallas", True, 512))
    assert k1 != k2 and k1[:-1] == k2[:-1]
    assert k1 == segment_key(chained, tuning=tuning_state(be))


# ---------------------------------------------------------------------------
# Satellite 3: span_breakdown on zero-launch-span runs
# ---------------------------------------------------------------------------

def test_span_breakdown_empty_on_no_events():
    out = export.span_breakdown("tick", {"launch"}, events=[])
    assert out["empty"] is True
    assert out["n_parents"] == 0 and out["n_children"] == 0
    assert out["child_frac"] == 0.0 and out["host_frac"] == 0.0


def test_span_breakdown_empty_on_parent_without_launches():
    """A parent span that contains no child launches (interpreter-only
    run): explicit empty, not host_frac == 1.0."""
    trace.clear()
    trace.enable()
    try:
        with trace.span("tick"):
            pass
    finally:
        trace.disable()
    out = export.span_breakdown("tick", {"launch"}, trace.events())
    assert out["n_parents"] == 1
    assert out["empty"] is True
    assert out["child_frac"] == 0.0 and out["host_frac"] == 0.0
    trace.clear()
