"""Mapper + perf-model invariants, including property tests (hypothesis
when installed, a deterministic fallback sampler otherwise)."""

import math

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.configs.feather import SWEEP, feather_config
from repro.core import isa, machine, mapper, perf, workloads
from repro.core.microinst import MicroModel

RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# Program invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ah,aw", [(4, 4), (8, 32), (16, 256)])
def test_program_capacity_and_cycles(ah, aw):
    cfg = feather_config(ah, aw)
    g = mapper.Gemm(m=2048, k=512, n=1024)
    plan = mapper.search(g, cfg)
    p = plan.program
    ch = plan.choice
    assert min(ch.m_t, g.m) * min(ch.k_t, g.k) <= cfg.str_bytes
    assert min(ch.k_t, g.k) * min(ch.n_t, g.n) <= cfg.sta_bytes
    # compute cycles can never beat the MAC lower bound
    lower = g.macs / cfg.peak_macs_per_cycle
    assert p.compute_cycles >= lower * 0.99
    # utilization in (0, 1]
    assert 0 < plan.perf_minisa.utilization <= 1.0
    # the Program's tiles cover exactly the useful MACs
    assert p.macs == g.macs


def test_minisa_instruction_bytes_tiny_vs_micro():
    cfg = feather_config(16, 256)
    g = mapper.Gemm(m=65536, k=40, n=88)
    plan = mapper.search(g, cfg)
    p = plan.program
    assert p.minisa_bytes() < 1e5
    assert p.micro_storage_bytes() / p.minisa_bytes() > 1e3
    # MINISA keeps < 0.1% instruction-cycle fraction (paper abstract)
    assert plan.perf_minisa.stall_ifetch_frac < 1e-3


def test_stall_grows_with_scale():
    g = mapper.Gemm(m=65536, k=40, n=88)
    stalls = []
    for ah, aw in [(4, 4), (8, 8), (16, 16), (8, 128), (16, 256)]:
        plan = mapper.search(g, feather_config(ah, aw))
        stalls.append(plan.perf_micro.stall_ifetch_frac)
    assert stalls[0] < 0.05 and stalls[1] < 0.05          # Tab. I small arrays
    assert stalls[-1] > 0.9                               # 16x256
    assert all(b >= a - 0.15 for a, b in zip(stalls, stalls[1:]))


def test_speedup_at_16x256_in_paper_range():
    g = mapper.Gemm(m=65536, k=40, n=88)
    plan = mapper.search(g, feather_config(16, 256))
    assert 10 < plan.speedup < 100     # paper: up to 31.6x geomean


# ---------------------------------------------------------------------------
# Perf-model unit behaviour
# ---------------------------------------------------------------------------

def test_perf_engine_overlap():
    cfg = feather_config(4, 4)
    tiles = [perf.TileCost(fetch_bytes=0, load_bytes=0, compute_cycles=100,
                           macs=100 * 16)] * 10
    res = perf.simulate(tiles, cfg)
    assert res.cycles == pytest.approx(1000)
    assert res.utilization == pytest.approx(1.0)
    # fetch slower than compute -> fetch-bound
    tiles = [perf.TileCost(fetch_bytes=9 * 200, compute_cycles=100,
                           macs=0)] * 10
    res = perf.simulate(tiles, cfg)
    assert res.cycles == pytest.approx(2000, rel=0.1)
    assert res.stall_ifetch_frac == pytest.approx(0.5, abs=0.06)


def test_micro_model_monotone_in_array():
    g_bits = [MicroModel(feather_config(ah, aw)).storage_bits_per_cycle
              for ah, aw in SWEEP]
    assert all(b > 0 for b in g_bits)
    assert g_bits[-1] > g_bits[0]


# ---------------------------------------------------------------------------
# Workload suite (Tab. IV)
# ---------------------------------------------------------------------------

def test_workload_suite_instantiates_table_iv():
    by = workloads.by_domain()
    assert len(by["fhe-bconv"]) == 41
    assert len(by["fhe-ntt"]) == 6
    assert len(by["zkp-ntt"]) == 6
    assert len(by["gpt-oss"]) == 5
    for g in by["fhe-bconv"]:
        assert g.m == 65536 and 28 <= g.k <= 60 and 72 <= g.n <= 160
    for g in by["zkp-ntt"]:
        assert g.k == g.n and g.m in (g.k // 32, g.k // 16)


# ---------------------------------------------------------------------------
# Properties: end-to-end functional + conservation over the Program tiles
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 24),
    k=st.integers(1, 48),
    n=st.integers(1, 24),
    ah=st.sampled_from([2, 4, 8]),
    aw=st.sampled_from([4, 8]),
)
def test_property_machine_equals_oracle(m, k, n, ah, aw):
    cfg = feather_config(ah, aw)
    g = mapper.Gemm(m=m, k=k, n=n)
    plan = mapper.search(g, cfg)
    i = RNG.standard_normal((m, k)).astype(np.float32)
    w = RNG.standard_normal((k, n)).astype(np.float32)
    out = machine.run_program(cfg, plan.program, {"I": i, "W": w})["O"]
    np.testing.assert_allclose(out, i @ w, rtol=3e-4, atol=3e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 4096),
    k=st.integers(1, 4096),
    n=st.integers(1, 4096),
    idx=st.integers(0, len(SWEEP) - 1),
)
def test_property_program_conservation(m, k, n, idx):
    """For any shape and array: cycles >= MAC bound, instruction bytes
    positive, and the Program's tile stream covers all MACs/stores
    exactly once."""
    ah, aw = SWEEP[idx]
    cfg = feather_config(ah, aw)
    g = mapper.Gemm(m=m, k=k, n=n)
    plan = mapper.search(g, cfg)
    p = plan.program
    assert p.compute_cycles * cfg.peak_macs_per_cycle >= g.macs * 0.99
    tiles = p.tile_costs("minisa")
    assert sum(t.macs for t in tiles) == pytest.approx(g.macs)
    assert sum(t.store_bytes for t in tiles) == pytest.approx(
        g.m * g.n * cfg.elem_bytes)
    assert p.minisa_bits() > 0
