"""Optional-hypothesis shim.

``from tests._hypothesis_compat import given, settings, st`` gives the real
hypothesis decorators when the package is installed, and a small
deterministic fallback otherwise: ``@given`` replays the test body over a
fixed number of seeded random draws, so property tests still execute (with
reduced example counts) in minimal environments instead of failing
collection.
"""

from __future__ import annotations

import functools
import random

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda r: r.choice(items))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    _FALLBACK_EXAMPLES = 4

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(0xF417)
                for _ in range(_FALLBACK_EXAMPLES):
                    draw = {name: s.sample(rng)
                            for name, s in strategies.items()}
                    fn(*args, **kwargs, **draw)
            # pytest follows __wrapped__ when collecting the signature and
            # would demand the drawn arguments as fixtures
            del wrapper.__wrapped__
            return wrapper
        return deco
