"""Integration tests: multi-layer MINISA chains, MoE invariants,
gradient-compression sync, end-to-end training convergence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import backends
from repro.configs.feather import feather_config
from repro.core import mapper
from repro.core import program as programlib
from repro.models import moe as moelib
from repro.models.common import Maker


RNG = np.random.default_rng(11)


# ---------------------------------------------------------------------------
# Multi-layer MINISA chain (paper §IV-G: SetOVN(i) == SetIVN(i+1))
# ---------------------------------------------------------------------------

def test_two_layer_chain_with_activation():
    cfg = feather_config(4, 4)
    relu = lambda x: np.maximum(x, 0)
    i0 = RNG.standard_normal((10, 12)).astype(np.float32)
    w1 = RNG.standard_normal((12, 8)).astype(np.float32)
    w2 = RNG.standard_normal((8, 6)).astype(np.float32)

    g1 = mapper.Gemm(m=10, k=12, n=8)
    plan1 = mapper.search(g1, cfg)
    prog1 = programlib.lower(g1, plan1.choice, cfg, activation=relu,
                             act_name="relu")
    o1 = backends.run(prog1, {"I": i0, "W": w1})["O"]

    g2 = mapper.Gemm(m=10, k=8, n=6)
    plan2 = mapper.search(g2, cfg)
    o2 = plan2.execute({"I": o1, "W": w2})["O"]

    expect = relu(i0 @ w1) @ w2
    np.testing.assert_allclose(o2, expect, rtol=2e-4, atol=2e-4)


def test_chain_trace_per_layer_counts():
    cfg = feather_config(8, 8)
    plans = [mapper.search(mapper.Gemm(m=16, k=24, n=16), cfg),
             mapper.search(mapper.Gemm(m=16, k=16, n=12), cfg)]
    progs = programlib.chain([
        programlib.lower(p.gemm, p.choice, cfg, out_name=f"O{i}")
        for i, p in enumerate(plans)])
    assert len(progs) == 2
    for prog in progs:
        names = [type(op.inst).__name__ for op in prog.trace_ops()]
        assert names.count("SetOVNLayout") == 1
        assert "ExecuteMapping" in names and "ExecuteStreaming" in names


def test_on_chip_chain_commit_matches_oracle():
    """Paper §IV-G: layer i's Write commits on-chip; layer i+1 elides its
    SetIVNLayout + input Load and still matches the 3-layer oracle."""
    from repro.core import isa as isalib

    cfg = feather_config(4, 4)
    relu = lambda x: np.maximum(x, 0)
    gs = [mapper.Gemm(m=10, k=12, n=8), mapper.Gemm(m=10, k=8, n=6),
          mapper.Gemm(m=10, k=6, n=9)]
    acts = [(relu, "relu"), (relu, "relu"), (None, "none")]
    choice = mapper.MappingChoice(df=isalib.Dataflow.WOS, vn=4, m_t=16,
                                  k_t=16, n_t=16, n_kg=1, n_nb=1, dup=4)
    progs = programlib.chain([
        programlib.lower(g, choice, cfg, activation=act, act_name=name,
                         out_name=f"O{i}")
        for i, (g, (act, name)) in enumerate(zip(gs, acts))])
    i0 = RNG.standard_normal((10, 12)).astype(np.float32)
    w1 = RNG.standard_normal((12, 8)).astype(np.float32)
    w2 = RNG.standard_normal((8, 6)).astype(np.float32)
    w3 = RNG.standard_normal((6, 9)).astype(np.float32)
    m = backends.InterpreterBackend(cfg)
    m.run_program(progs[0], {"I": i0, "W": w1})
    m.run_program(progs[1], {"W": w2})   # input arrived via on-chip commit
    m.run_program(progs[2], {"W": w3})
    expect = relu(relu(i0 @ w1) @ w2) @ w3
    np.testing.assert_allclose(m.outputs["O2"], expect, rtol=2e-4,
                               atol=2e-4)
    names1 = [type(op.inst).__name__ for op in progs[1].trace_ops()]
    assert "SetIVNLayout" not in names1          # elided
    assert names1.count("Load") == 1             # weights only


# ---------------------------------------------------------------------------
# MoE invariants (hypothesis)
# ---------------------------------------------------------------------------

def _moe_setup(d=16, e=8, ff=8, kind="swiglu"):
    mk = Maker(mode="init", key=jax.random.PRNGKey(0))
    return moelib.moe_params(mk, d, ff, e, kind), d, e


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 3), s=st.integers(1, 24), topk=st.integers(1, 4),
       cf=st.floats(0.5, 4.0))
def test_moe_finite_and_shaped(b, s, topk, cf):
    p, d, e = _moe_setup()
    x = jnp.asarray(RNG.standard_normal((b, s, d)), jnp.float32)
    out, aux = moelib.moe(p, x, num_experts=e, top_k=topk, kind="swiglu",
                          capacity_factor=cf)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert np.isfinite(float(aux)) and float(aux) >= 0.0


def test_moe_generous_capacity_matches_dense_combination():
    """With no drops, the output must equal the explicit per-token mixture
    of expert FFNs."""
    p, d, e = _moe_setup()
    b, s, topk = 2, 6, 2
    x = jnp.asarray(RNG.standard_normal((b, s, d)), jnp.float32)
    out, _ = moelib.moe(p, x, num_experts=e, top_k=topk, kind="swiglu",
                        capacity_factor=float(e))  # no drops possible
    # dense reference
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, topk)
    gv = gv / gv.sum(-1, keepdims=True)
    # run every expert densely
    per_expert = moelib._expert_ffn(
        p, jnp.broadcast_to(x[:, None], (b, e, s, d)), "swiglu")
    expect = jnp.zeros_like(x)
    for kk in range(topk):
        sel = jnp.take_along_axis(
            per_expert, gi[..., kk][:, None, :, None], axis=1)[:, 0]
        expect = expect + gv[..., kk][..., None].astype(x.dtype) * sel
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_bounded():
    """Tokens beyond capacity contribute zero, never NaN/garbage."""
    p, d, e = _moe_setup()
    x = jnp.ones((1, 32, d), jnp.float32)  # identical tokens -> one expert
    out, _ = moelib.moe(p, x, num_experts=e, top_k=1, kind="swiglu",
                        capacity_factor=0.25)
    assert np.isfinite(np.asarray(out)).all()
    # at least capacity-many rows are non-zero, the rest dropped (zero)
    nz = np.abs(np.asarray(out[0])).sum(-1) > 0
    assert 0 < nz.sum() < 32


# ---------------------------------------------------------------------------
# Compressed DP all-reduce (shard_map path)
# ---------------------------------------------------------------------------

def test_compressed_dp_allreduce_single_device():
    from repro.dist.compression import compressed_dp_allreduce
    mesh = jax.make_mesh((1,), ("data",))
    grads = {"w": jnp.asarray(RNG.standard_normal((32, 8)), jnp.float32)}
    with mesh:
        synced = compressed_dp_allreduce(grads, mesh)
    # n=1 mean == int8 round-trip of itself
    err = np.abs(np.asarray(synced["w"]) - np.asarray(grads["w"])).max()
    assert err <= float(jnp.abs(grads["w"]).max()) / 127.0 + 1e-6


# ---------------------------------------------------------------------------
# Training convergence (end-to-end driver, reduced model)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["gemma-7b"])
def test_training_reduces_loss(arch):
    from repro.configs.base import ShapeConfig, reduced
    from repro.configs.registry import get_config
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models import build_model
    from repro.train import optimizer as optlib
    from repro.train.trainer import TrainConfig, make_train_step

    cfg = reduced(get_config(arch), layers=2, d_model=64, vocab=256)
    model = build_model(cfg)
    shape = ShapeConfig("t", 64, 4, "train")
    data = SyntheticLM(DataConfig(vocab_size=256), cfg, shape)
    tcfg = TrainConfig(opt=optlib.OptimizerConfig(
        peak_lr=1e-2, warmup_steps=2, total_steps=30))
    step = jax.jit(make_train_step(model, tcfg))
    params = model.init(jax.random.PRNGKey(0))
    opt = optlib.init(params)
    losses = []
    for i in range(30):
        batch = jax.tree.map(jnp.asarray, data.batch(i))
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_grad_accum_matches_full_batch():
    """Microbatch accumulation == one big batch (same grads modulo fp)."""
    from repro.configs.base import reduced
    from repro.configs.registry import get_config
    from repro.models import build_model
    from repro.train import optimizer as optlib
    from repro.train.trainer import TrainConfig, make_train_step

    cfg = reduced(get_config("minitron-4b"), layers=2, d_model=32,
                  vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(
        RNG.integers(0, 128, (4, 32)), jnp.int32)}
    o1 = optlib.init(params)
    o2 = optlib.init(params)
    s1 = make_train_step(model, TrainConfig(grad_accum=1))
    s2 = make_train_step(model, TrainConfig(grad_accum=2))
    p1, _, m1 = jax.jit(s1)(params, o1, batch)
    p2, _, m2 = jax.jit(s2)(params, o2, batch)
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-3
