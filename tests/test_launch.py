"""Launch-layer tests: input_specs, elastic resume (re-shard restore),
multimodal serving, trainer resume via the CLI driver."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, reduced
from repro.configs.registry import ARCH_IDS, get_config
from repro.models import build_model


# ---------------------------------------------------------------------------
# input_specs: abstract stand-ins for every cell (no device allocation)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["gemma-7b", "whisper-base",
                                  "internvl2-26b", "falcon-mamba-7b",
                                  "deepseek-v2-236b"])
@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k",
                                        "decode_32k"])
def test_input_specs_shapes(arch, shape_name):
    from repro.launch.dryrun import input_specs
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    spec = input_specs(arch, shape_name)
    batch = spec["batch"]
    assert all(isinstance(x, jax.ShapeDtypeStruct)
               for x in jax.tree.leaves(batch))
    if shape.kind == "decode":
        assert batch["tokens"].shape == (shape.global_batch, 1)
        assert "cache" in spec
        # cache leaves must be abstract too
        assert all(isinstance(x, jax.ShapeDtypeStruct)
                   for x in jax.tree.leaves(spec["cache"]))
    else:
        text = shape.seq_len
        if cfg.family == "vlm":
            text -= cfg.frontend_len
        assert batch["tokens"].shape == (shape.global_batch, text)
        if cfg.frontend != "none" and cfg.family in ("vlm", "encdec"):
            key = "patches" if cfg.family == "vlm" else "frames"
            assert batch[key].shape == (shape.global_batch,
                                        cfg.frontend_len, cfg.d_model)


def test_all_cells_enumerate():
    from repro.configs.registry import cells
    grid = cells(include_skipped=True)
    assert len(grid) == 40
    skips = [c for c in grid if c[2].startswith("SKIP")]
    assert len(skips) == 8
    assert all(c[1] == "long_500k" for c in skips)


# ---------------------------------------------------------------------------
# Elastic resume: restore onto explicit (different) shardings
# ---------------------------------------------------------------------------

def test_elastic_restore_onto_mesh(tmp_path):
    from repro.ckpt.manager import CheckpointManager
    from repro.dist import elastic

    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(64.0).reshape(8, 8),
            "b": jnp.ones((4,))}
    mgr.save(3, tree)

    mesh = jax.make_mesh((1,), ("data",))
    shardings = {"w": NamedSharding(mesh, P("data")),
                 "b": NamedSharding(mesh, P())}
    restored, step = elastic.resume(mgr, jax.eval_shape(lambda: tree),
                                    shardings)
    assert step == 3
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(tree["w"]))
    assert restored["w"].sharding == shardings["w"]


def test_elastic_resume_empty_dir(tmp_path):
    from repro.ckpt.manager import CheckpointManager
    from repro.dist import elastic
    mgr = CheckpointManager(str(tmp_path))
    tree, step = elastic.resume(mgr, {}, None)
    assert tree is None and step == 0


# ---------------------------------------------------------------------------
# Multimodal serving
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["whisper-base", "internvl2-26b"])
def test_engine_multimodal(arch):
    from repro.serve.engine import Engine, ServeConfig
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, ServeConfig(max_len=24))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["frames"] = np.asarray(rng.standard_normal(
            (2, cfg.frontend_len, cfg.d_model)), np.float32) * 0.02
    else:
        kwargs["prefix_embeds"] = np.asarray(rng.standard_normal(
            (2, cfg.frontend_len, cfg.d_model)), np.float32) * 0.02
    toks = engine.generate(prompts, steps=4, **kwargs)
    assert toks.shape == (2, 4)


# ---------------------------------------------------------------------------
# CLI trainer: checkpoint + resume continues from the saved step
# ---------------------------------------------------------------------------

def test_train_cli_resume(tmp_path):
    # run in subprocesses: the CLI owns donation + mesh state and must not
    # share a process with other jit caches (mirrors real usage)
    import os
    import subprocess
    import sys

    # strip the 512-fake-device flag that importing launch.dryrun (in the
    # input_specs tests above) leaves in this process's environ
    env = dict(os.environ, PYTHONPATH="src", XLA_FLAGS="")
    args = ["--arch", "minitron-4b", "--reduced", "--reduced-layers", "2",
            "--reduced-dmodel", "32", "--batch", "2", "--seq", "32",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
            "--log-every", "100"]

    def run(extra):
        return subprocess.run(
            [sys.executable, "-m", "repro.launch.train"] + args + extra,
            capture_output=True, text=True, env=env, cwd="/root/repo",
            timeout=240)

    r1 = run(["--steps", "4"])
    assert r1.returncode == 0, r1.stderr[-2000:]
    assert "step     0" in r1.stdout
    r2 = run(["--steps", "6", "--resume", "auto"])
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 4" in r2.stdout
    assert "step     0 " not in r2.stdout   # did not restart from scratch
