"""Fault injection + tolerance: seeded chaos runs recover every injected
fault and finish with state checksums bit-identical to the fault-free
run (both backends, flat and meshed); the checksummed disk cache
quarantines corrupt entries instead of raising; KV double-release is
idempotent; degraded-mesh re-lowering conserves traffic and matches the
einsum oracle; a chaos-killed serve resumes from an elastic snapshot
with unchanged checksums; and with faults off the resilience layer is
entirely inert."""

import os
import pickle

import numpy as np
import pytest

from repro import backends
from repro.configs.feather import feather_config
from repro.core import mapper
from repro.dist import ArrayMesh
from repro.dist.elastic import (load_serving_snapshot,
                                save_serving_snapshot)
from repro.faults import (FAULT_KINDS, CircuitBreaker, FaultEvent,
                          FaultInjector, FaultPlan, FaultyBackend,
                          TransientLaunchError, check_finite)
from repro.obs.export import fault_events, write_fault_events
from repro.obs.trace import trace
from repro.runtime import ModelExecutable, ProgramCache, Scheduler
from repro.runtime.scheduler import KVPool, PagedKV

CFG = feather_config(4, 16)


def _scheduler(cache, backend, *, mesh=None, seed=7, faults=None, **kw):
    prefill = ModelExecutable.for_cell("gemma-7b", "prefill_tiny", CFG,
                                       cache=cache, mesh=mesh)
    decode = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                      cache=cache, mesh=mesh)
    return Scheduler(prefill, decode, backend=backend, max_concurrent=2,
                     seed=seed, faults=faults, **kw)


def _serve(cache, backend, *, mesh=None, seed=7, faults=None,
           n_requests=3, decode_steps=4, **kw):
    sched = _scheduler(cache, backend, mesh=mesh, seed=seed,
                       faults=faults, **kw)
    for _ in range(n_requests):
        sched.submit(decode_steps=decode_steps)
    return sched.run()


def _checksums(report):
    return [r.state_checksum for r in report.requests]


# ---------------------------------------------------------------------------
# FaultPlan: seeded determinism + validation
# ---------------------------------------------------------------------------

def test_fault_plan_from_seed_deterministic():
    a = FaultPlan.from_seed(3)
    b = FaultPlan.from_seed(3)
    assert a.events == b.events and a.summary() == b.summary()
    assert a.events != FaultPlan.from_seed(4).events
    assert all(e.kind in FAULT_KINDS for e in a.events)
    # events are replayed in tick order and due() slices one tick
    ticks = [e.at_tick for e in a.events]
    assert ticks == sorted(ticks)
    for t in set(ticks):
        assert all(e.at_tick == t for e in a.due(t))


def test_fault_plan_standard_covers_every_kind():
    plan = FaultPlan.standard(0, n_arrays=2)
    assert all(plan.counts()[k] >= 1 for k in FAULT_KINDS)
    flat = FaultPlan.standard(0, n_arrays=1)
    assert flat.counts()["array_down"] == 0


def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(kind="meteor_strike", at_tick=1)
    with pytest.raises(ValueError):
        FaultEvent(kind="launch_nan", at_tick=0)
    with pytest.raises(ValueError):
        FaultEvent(kind="launch_nan", at_tick=1, duration=0)


# ---------------------------------------------------------------------------
# Chaos acceptance: every fault kind injected, every one recovered, and
# the surviving state checksums are bit-identical to the fault-free run
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def chaos_cache():
    return ProgramCache()


@pytest.mark.parametrize("backend", ["interpreter", "pallas"])
def test_chaos_run_matches_fault_free(chaos_cache, backend, tmp_path):
    cache = ProgramCache(path=tmp_path / "cache.bin")
    # share the warm in-memory tiers so the test only pays one search
    cache._plans.update(chaos_cache._plans)
    baseline = _serve(cache, backend)
    injector = FaultInjector(FaultPlan.standard(0, n_arrays=1))
    chaotic = _serve(cache, backend, faults=injector)
    assert set(injector.injected) == {"launch_transient", "launch_nan",
                                      "kv_exhaust", "cache_corrupt"}
    assert injector.unrecovered() == 0
    assert all(r.status == "ok" for r in chaotic.requests)
    assert any(r.retries > 0 for r in chaotic.requests)
    # no-commit-on-fault: replayed steps reproduce the exact state
    assert _checksums(chaotic) == _checksums(baseline)
    res = chaotic.summary()["resilience"]
    assert res["unrecovered"] == 0 and res["retries_total"] > 0
    assert baseline.summary()["resilience"] == {}
    chaos_cache._plans.update(cache._plans)


@pytest.mark.parametrize("backend", ["interpreter", "pallas"])
def test_chaos_mesh_failover_matches_fault_free(chaos_cache, backend):
    """array_down degrades the mesh mid-run; the re-lowered stream keeps
    serving and the request state trajectory is unchanged."""
    baseline = _serve(chaos_cache, backend, mesh=ArrayMesh(2))
    injector = FaultInjector(FaultPlan.standard(0, n_arrays=2))
    chaotic = _serve(chaos_cache, backend, mesh=ArrayMesh(2),
                     faults=injector)
    assert injector.injected.get("array_down") == 1
    assert injector.unrecovered() == 0
    assert chaotic.n_arrays == 1          # degraded 2 -> 1
    assert baseline.n_arrays == 2
    assert chaotic.summary()["resilience"]["mesh_degraded"] == 1
    assert all(r.status == "ok" for r in chaotic.requests)
    assert _checksums(chaotic) == _checksums(baseline)


def test_chaos_emits_fault_swimlane_and_artifact(chaos_cache, tmp_path):
    trace.clear().enable()
    try:
        _serve(chaos_cache, "interpreter",
               faults=FaultPlan.standard(0, n_arrays=1), n_requests=2)
        events = fault_events()
    finally:
        trace.disable()
    names = {e["name"] for e in events}
    assert {"fault", "recovery"} <= names
    kinds = {e["kind"] for e in events}
    assert {"launch_transient", "launch_nan", "kv_exhaust"} <= kinds
    path = write_fault_events(tmp_path / "faults.json")
    import json
    with open(path) as f:
        doc = json.load(f)
    assert doc["fault_events"] == events and len(events) > 0


# ---------------------------------------------------------------------------
# Retry / deadline / breaker state machine
# ---------------------------------------------------------------------------

def test_request_fails_after_max_retries(chaos_cache):
    """A launch window outlasting the retry budget turns the request
    ``failed`` (never an unhandled exception, never an infinite loop)."""
    plan = FaultPlan(events=(
        FaultEvent(kind="launch_transient", at_tick=1, duration=400),))
    rep = _serve(chaos_cache, "interpreter", faults=plan, n_requests=2,
                 max_retries=2, backoff_cap=1, breaker_cooldown=1)
    assert all(r.status == "failed" for r in rep.requests)
    assert all(r.retries >= 3 for r in rep.requests)
    res = rep.summary()["resilience"]
    assert res["failed"] == 2
    assert res["breaker"]["opens"] >= 1


def test_deadline_times_out(chaos_cache):
    sched = _scheduler(chaos_cache, "interpreter", finite_check=True)
    sched.submit(decode_steps=64, deadline_s=0.0)
    sched.submit(decode_steps=2)
    rep = sched.run()
    by_rid = {r.rid: r for r in rep.requests}
    assert by_rid[0].status == "timed_out"
    assert by_rid[1].status == "ok" and by_rid[1].state_checksum
    assert rep.summary()["resilience"]["timed_out"] == 1


def test_circuit_breaker_state_machine():
    br = CircuitBreaker(threshold=2, cooldown=3)
    assert br.allow(0) and br.state == "closed"
    br.record_failure(0)
    assert br.allow(1)                     # one strike: still closed
    br.record_failure(1)
    assert br.state == "open" and br.opens == 1
    assert not br.allow(2)                 # cooling
    assert br.allow(4) and br.state == "half_open"
    br.record_failure(4)                   # probe fails -> re-open
    assert br.state == "open" and not br.allow(5)
    assert br.allow(7)
    br.record_success()
    assert br.state == "closed" and br.failures == 0


def test_faulty_backend_guard_and_passthrough():
    class Dummy:
        n_launches = 5

        def run_program(self, program, tensors=None):
            return {"O": np.ones((2, 2), np.float32)}

    inj = FaultInjector(FaultPlan(events=(
        FaultEvent(kind="launch_transient", at_tick=1),
        FaultEvent(kind="launch_nan", at_tick=2))))
    fb = inj.wrap(Dummy())
    assert isinstance(fb, FaultyBackend) and fb.n_launches == 5

    class P:
        out_name = "O"
    inj.begin_tick(1)
    with pytest.raises(TransientLaunchError):
        fb.run_program(P())
    inj.begin_tick(2)
    out = fb.run_program(P())["O"]
    assert not check_finite(out)           # NaN-poisoned copy
    inj.begin_tick(3)
    assert check_finite(fb.run_program(P())["O"])
    assert inj.injected == {"launch_transient": 1, "launch_nan": 1}


def test_check_finite():
    assert check_finite(np.zeros(3))
    assert not check_finite(np.array([1.0, np.nan]))
    assert not check_finite(np.array([np.inf]))


# ---------------------------------------------------------------------------
# Checksummed disk cache: corruption quarantines, never raises mid-serve
# ---------------------------------------------------------------------------

def _saved_cache(tmp_path):
    path = str(tmp_path / "cache.bin")
    cache = ProgramCache(path=path)
    for m in (8, 12):
        cache.plan(mapper.Gemm(m=m, k=8, n=8), CFG)
    cache.save()
    return path, len(cache._plans)


def test_corrupt_entry_quarantined_not_raised(tmp_path):
    path, n_entries = _saved_cache(tmp_path)
    inj = FaultInjector(FaultPlan(events=(
        FaultEvent(kind="cache_corrupt", at_tick=1),), seed=5))
    assert inj.corrupt_cache_file(path)
    fresh = ProgramCache(path=path)        # auto-loads; must not raise
    assert fresh.stats.disk_corrupt == 1
    assert fresh.stats.loaded_from_disk == n_entries - 1
    qdir = fresh.quarantine_dir(path)
    assert os.path.isdir(qdir) and len(os.listdir(qdir)) == 1
    # the surviving entries still serve
    assert len(fresh._plans) == n_entries - 1


def test_torn_payload_quarantined_not_raised(tmp_path):
    path, _ = _saved_cache(tmp_path)
    with open(path, "rb") as f:
        data = f.read()
    with open(path, "wb") as f:
        f.write(data[:len(data) // 2])     # torn write shape
    fresh = ProgramCache(path=path)        # cold start, no raise
    assert fresh.stats.loaded_from_disk == 0
    assert fresh.stats.disk_corrupt == 1
    assert len(fresh._plans) == 0
    assert os.path.isdir(fresh.quarantine_dir(path))


def test_stale_layout_still_raises(tmp_path):
    """Version/schema mismatches are *format* errors (a deliberate
    rejection), not corruption -- they keep raising ValueError."""
    path, _ = _saved_cache(tmp_path)
    with open(path, "rb") as f:
        payload = pickle.load(f)
    for mutate in (lambda p: p.__setitem__("version", 1),
                   lambda p: p["schema"].__setitem__("plans", 99)):
        bad = pickle.loads(pickle.dumps(payload))
        mutate(bad)
        with open(path, "wb") as f:
            pickle.dump(bad, f)
        with pytest.raises(ValueError):
            ProgramCache(path=path)        # auto-load rejects the file


def test_save_is_atomic_no_temp_litter(tmp_path):
    path, _ = _saved_cache(tmp_path)
    assert [p for p in os.listdir(tmp_path) if p.endswith(".tmp")] == []
    # a second save atomically replaces, never appends
    cache = ProgramCache(path=path)
    cache.save()
    assert ProgramCache(path=path).stats.loaded_from_disk > 0


# ---------------------------------------------------------------------------
# KV pool: double release + exhaustion reserve/unreserve
# ---------------------------------------------------------------------------

def _pool(pages=8):
    # one dynamic tensor: shape (8, 4), time axis 0, 8 slots, width 4
    return KVPool({"K": ((8, 4), 0, 8, 4)}, 4, pages)


def test_kv_double_release_is_idempotent():
    pool = _pool()
    pages = pool.allocate()
    n_free = len(pool._free)
    pool.release(pages)
    assert len(pool._free) == n_free + len(pages)
    pool.release(pages)                    # regression: double release
    assert len(pool._free) == n_free + len(pages)
    assert pool.stats()["double_releases"] == len(pages)
    # freed pages can be re-allocated exactly once
    again = pool.allocate()
    assert sorted(again) == sorted(pages)


def test_paged_kv_release_idempotent():
    pool = _pool()
    kv = PagedKV(pool, pool.allocate())
    free0 = len(pool._free)
    kv.release()
    kv.release()
    assert len(pool._free) == free0 + 2
    assert pool.stats()["double_releases"] == 0


def test_kv_reserve_and_unreserve():
    pool = _pool()
    held = pool.reserve()                  # n<=0: grab everything free
    assert pool.allocate() is None         # exhausted
    assert pool.stats()["reserved_pages"] == len(held)
    pool.unreserve(held)
    assert pool.allocate() is not None
    assert pool.stats()["reserved_pages"] == 0


# ---------------------------------------------------------------------------
# Degraded-mesh property: re-lowering onto fewer arrays conserves MINISA
# traffic and matches the einsum oracle on both backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_from,n_down", [(4, 1), (4, 2), (2, 1)])
@pytest.mark.parametrize("seed", range(3))
def test_degraded_mesh_conserves_traffic_and_matches_oracle(
        seed, n_from, n_down):
    from repro.core import program as programlib
    rng = np.random.default_rng(100 * n_from + 10 * n_down + seed)
    g = mapper.Gemm(m=int(rng.integers(5, 40)),
                    k=int(rng.integers(5, 40)),
                    n=int(rng.integers(5, 40)))
    prog = mapper.search(g, CFG).program
    t = {"I": rng.standard_normal((g.m, g.k)).astype(np.float32),
         "W": rng.standard_normal((g.k, g.n)).astype(np.float32)}
    degraded = ArrayMesh(n_from).degraded(n_down)
    assert degraded.n_arrays == n_from - n_down
    for mesh in (ArrayMesh(n_from), degraded):
        sh = programlib.shard_program(prog, mesh)
        per = sh.per_array_minisa_bytes()
        assert len(per) == mesh.n_arrays
        assert sum(per) == sh.minisa_bytes()
        backends.cross_check(prog, t, mesh=mesh)


def test_mesh_degraded_floors_at_one():
    assert ArrayMesh(2).degraded(1).n_arrays == 1
    assert ArrayMesh(2).degraded(5).n_arrays == 1
    assert ArrayMesh(4).degraded(0).n_arrays == 4


# ---------------------------------------------------------------------------
# Elastic snapshot / resume: a chaos-killed serve finishes identically
# ---------------------------------------------------------------------------

def test_snapshot_resume_matches_uninterrupted(chaos_cache, tmp_path):
    full = _serve(chaos_cache, "interpreter", n_requests=4)
    # "crash" after two ticks, persist, resume in a fresh scheduler
    first = _scheduler(chaos_cache, "interpreter")
    for _ in range(4):
        first.submit(decode_steps=4)
    first.run(max_ticks=2)
    snap_path = tmp_path / "serve.snap"
    save_serving_snapshot(snap_path, first.snapshot())
    assert [p for p in os.listdir(tmp_path) if p.endswith(".tmp")] == []
    snap = load_serving_snapshot(snap_path)
    assert snap is not None
    resumed = _scheduler(chaos_cache, "interpreter")
    assert resumed.restore(snap) > 0
    rep = resumed.run()
    assert len(rep.requests) == 4
    assert _checksums(rep) == _checksums(full)


def test_snapshot_restore_validates(chaos_cache, tmp_path):
    sched = _scheduler(chaos_cache, "interpreter")
    sched.submit(decode_steps=2)
    snap = sched.snapshot()
    other = _scheduler(chaos_cache, "interpreter", seed=99)
    with pytest.raises(ValueError, match="seed"):
        other.restore(snap)
    with pytest.raises(ValueError, match="version"):
        _scheduler(chaos_cache, "interpreter").restore(
            {**snap, "version": 42})
    assert load_serving_snapshot(tmp_path / "missing.snap") is None


# ---------------------------------------------------------------------------
# Faults off: the tolerance layer is inert
# ---------------------------------------------------------------------------

def test_no_faults_means_no_wrapper_no_resilience(chaos_cache):
    sched = _scheduler(chaos_cache, "interpreter")
    assert sched.injector is None and not sched.resilient
    assert sched.breaker is None
    assert not isinstance(sched.backend, FaultyBackend)
    sched.submit(decode_steps=2)
    rep = sched.run()
    assert rep.resilience == {}
    assert rep.summary()["resilience"] == {}
    assert all(r.status == "ok" and r.retries == 0 for r in rep.requests)
