"""Multi-array sharding: ShardedProgram execution == unsharded Program ==
einsum oracle across mesh shapes and both backends, traffic conservation,
axis policy, activation hoisting, the mesh-aware runtime (executable +
scheduler determinism) and the ProgramCache sharded tier."""

import dataclasses

import numpy as np
import pytest

from repro import backends
from repro.configs.feather import feather_config
from repro.core import isa, mapper, perf, program, workloads
from repro.core.planner import GemmOp, plan_model
from repro.dist import ArrayMesh
from repro.dist.sharding import gemm_shard_axis
from repro.runtime import ModelExecutable, ProgramCache, Scheduler

RNG = np.random.default_rng(11)
CFG = feather_config(4, 16)


def _tensors(g):
    return {
        "I": RNG.standard_normal((g.m, g.k)).astype(np.float32),
        "W": RNG.standard_normal((g.k, g.n)).astype(np.float32),
    }


def _choice(df=isa.Dataflow.WOS, vn=4):
    return mapper.MappingChoice(df=df, vn=vn, m_t=8, k_t=8, n_t=8,
                                n_kg=1, n_nb=1, dup=4)


# ---------------------------------------------------------------------------
# Acceptance sweep: every ci_suite GEMM, 4-array mesh, both backends
# ---------------------------------------------------------------------------

_SWEEP_CACHE = ProgramCache(max_plans=1 << 20)


@pytest.mark.parametrize("gemm", workloads.ci_suite(),
                         ids=lambda g: g.name)
def test_sharded_equivalence_workload_sweep(gemm):
    """Sharded execution on a 4-array mesh matches the unsharded einsum
    oracle on both backends, for every Tab. IV (CI extents) workload."""
    plan = _SWEEP_CACHE.plan(gemm, CFG)
    backends.cross_check(plan.program, _tensors(gemm), mesh=ArrayMesh(4))


# ---------------------------------------------------------------------------
# Property sweep: random GEMMs x mesh {1, 2, 4} x every axis x backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("n_arrays", [1, 2, 4])
def test_sharded_matches_unsharded_and_oracle(seed, n_arrays):
    rng = np.random.default_rng(seed)
    g = mapper.Gemm(m=int(rng.integers(5, 40)),
                    k=int(rng.integers(5, 40)),
                    n=int(rng.integers(5, 40)))
    prog = mapper.search(g, CFG).program
    t = _tensors(g)
    mesh = ArrayMesh(n_arrays)
    unsharded = backends.run(prog, t)[prog.out_name]
    for axis in (None, "m", "n", "k"):
        for name in ("interpreter", "pallas"):
            out = backends.run_sharded(prog, t, mesh, backend=name,
                                       axis=axis)[prog.out_name]
            np.testing.assert_allclose(
                out, t["I"] @ t["W"], rtol=2e-4, atol=2e-4 + 2e-4 * g.k,
                err_msg=f"{name} axis={axis} n={n_arrays} on {g}")
            np.testing.assert_allclose(
                out, unsharded, rtol=2e-4, atol=2e-4 + 2e-4 * g.k)


def test_traffic_sums_to_single_array_total():
    """Per-array MINISA traffic is conserved: the sum over arrays equals
    the single-array total within tiling overhead (tight at scale where
    the Execute stream dominates, bounded on small problems)."""
    cfg = feather_config(16, 64)
    g = mapper.Gemm(m=65536, k=40, n=88, name="bconv-full")
    plan = _SWEEP_CACHE.plan(g, cfg)
    base = plan.program.minisa_bytes()
    for n_arrays in (2, 4, 8):
        sh = program.shard_program(plan.program, ArrayMesh(n_arrays))
        per = sh.per_array_minisa_bytes()
        assert len(per) == n_arrays and all(b > 0 for b in per)
        assert sum(per) == sh.minisa_bytes()
        ratio = sh.minisa_bytes() / base
        assert 0.95 <= ratio <= 1.25, (n_arrays, sh.axis, ratio)


def test_mesh_perf_parallel_speedup_and_imbalance():
    cfg = feather_config(16, 64)
    g = mapper.Gemm(m=65536, k=40, n=88)
    plan = _SWEEP_CACHE.plan(g, cfg)
    base_cycles = plan.perf_minisa.cycles
    sh = program.shard_program(plan.program, ArrayMesh(4))
    mp = perf.simulate_sharded(sh, cfg)
    assert len(mp.per_array) == 4
    assert 1.0 <= mp.load_imbalance <= 1.5
    # arrays run in parallel: the mesh makespan beats one array clearly
    assert base_cycles / mp.cycles > 2.0
    assert mp.macs == pytest.approx(plan.perf_minisa.macs)


# ---------------------------------------------------------------------------
# Axis policy + partition structure
# ---------------------------------------------------------------------------

def test_axis_policy_prefers_divisible_tensor_parallel():
    # N divisible -> tensor parallelism first
    assert gemm_shard_axis(64, 64, 64, 4) == "n"
    # N indivisible/narrow -> fall through to M
    assert gemm_shard_axis(64, 64, 3, 4) == "m"
    # only K can host the arrays
    assert gemm_shard_axis(2, 64, 3, 4) == "k"
    # tile counts gate replication-prone ranks: N fits one tile ->
    # splitting it would replicate the M-loop traffic on every array
    assert gemm_shard_axis(64, 64, 64, 4,
                           tiles={"m": 8, "n": 1, "k": 1}) == "m"
    assert gemm_shard_axis(64, 64, 64, 2) == "n"


def test_shard_slices_partition_the_problem():
    g = mapper.Gemm(m=20, k=12, n=18)
    prog = program.lower(g, _choice(), CFG)
    for axis, dim in (("m", g.m), ("n", g.n), ("k", g.k)):
        sh = program.shard_program(prog, ArrayMesh(4), axis=axis)
        spans = [(s.m1 - s.m0) * (s.n1 - s.n0) * (s.k1 - s.k0)
                 for s in sh.shards]
        assert sum(spans) == g.m * g.k * g.n   # disjoint cover
        assert sh.reduce == (axis == "k")
        assert sh.macs == g.macs


def test_single_array_mesh_is_the_program_itself():
    g = mapper.Gemm(m=10, k=8, n=6)
    prog = program.lower(g, _choice(), CFG)
    sh = program.shard_program(prog, ArrayMesh(1))
    assert sh.n_shards == 1
    assert sh.shards[0].program is prog
    assert sh.minisa_bytes() == prog.minisa_bytes()


def test_chained_programs_refuse_to_shard():
    g1 = mapper.Gemm(m=10, k=12, n=8)
    g2 = mapper.Gemm(m=10, k=8, n=6)
    p1 = program.lower(g1, _choice(), CFG, out_name="O0")
    p2 = program.lower(g2, _choice(), CFG, out_name="O1")
    chained = program.chain([p1, p2])
    with pytest.raises(ValueError, match="commit"):
        program.shard_program(chained[0], ArrayMesh(2))
    with pytest.raises(ValueError, match="elided"):
        program.shard_program(chained[1], ArrayMesh(2))


# ---------------------------------------------------------------------------
# Activation hoisting across the mesh boundary
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("axis", ["m", "n", "k"])
def test_elementwise_activation_sharded(axis):
    g = mapper.Gemm(m=12, k=10, n=14)
    act = lambda x: np.maximum(x, 0)  # noqa: E731
    prog = program.lower(g, _choice(), CFG, activation=act,
                         act_name="relu")
    sh = program.shard_program(prog, ArrayMesh(2), axis=axis)
    # K split hoists (partial sums are pre-activation); M/N keep it local
    assert (sh.epilogue_act is not None) == (axis == "k")
    backends.cross_check(prog, _tensors(g), mesh=ArrayMesh(2), axis=axis)


@pytest.mark.parametrize("axis", ["m", "n", "k"])
def test_row_wise_activation_sharded(axis):
    """softmax needs full output rows: only a WO-S M split keeps rows
    shard-local; N/K splits hoist it to the assembled output."""
    g = mapper.Gemm(m=8, k=10, n=12)
    from repro.runtime.executable import ACTIVATIONS
    prog = program.lower(g, mapper.MappingChoice(
        df=isa.Dataflow.WOS, vn=4, m_t=8, k_t=12, n_t=12,
        n_kg=1, n_nb=1, dup=4), CFG,
        activation=ACTIVATIONS["softmax"], act_name="softmax")
    sh = program.shard_program(prog, ArrayMesh(2), axis=axis)
    assert (sh.epilogue_act is None) == (axis == "m")
    backends.cross_check(prog, _tensors(g), mesh=ArrayMesh(2), axis=axis)


# ---------------------------------------------------------------------------
# Plan.execute / cache tier / planner mesh plumbing
# ---------------------------------------------------------------------------

def test_plan_execute_with_mesh():
    g = mapper.Gemm(m=17, k=24, n=21)
    plan = mapper.search(g, CFG)
    t = _tensors(g)
    for name in ("interpreter", "pallas"):
        out = plan.execute(t, backend=name, mesh=ArrayMesh(3))["O"]
        np.testing.assert_allclose(out, t["I"] @ t["W"],
                                   rtol=2e-4, atol=2e-4 + 2e-4 * g.k)


def test_cache_sharded_tier_memoises_per_mesh_shape():
    cache = ProgramCache()
    g = mapper.Gemm(m=16, k=16, n=16)
    plan = cache.plan(g, CFG)
    s2a = cache.sharded(plan.program, ArrayMesh(2))
    s2b = cache.sharded(plan.program, ArrayMesh(2))
    s4 = cache.sharded(plan.program, ArrayMesh(4))
    assert s2a is s2b and s2a is not s4
    assert cache.stats.sharded_hits == 1
    assert cache.stats.sharded_misses == 2
    # shard sub-lowerings flow through the shared lowered tier
    assert cache.stats.lowered_misses > 0
    assert "sharded" in cache.summary()["entries"]


def test_plan_model_mesh_aggregates():
    cache = ProgramCache()
    ops = [GemmOp(gemm=mapper.Gemm(m=64, k=32, n=48, name="fc1", count=2)),
           GemmOp(gemm=mapper.Gemm(m=64, k=48, n=32, name="fc2"),
                  chained=True)]
    single = plan_model("toy", "cell", ops, CFG, cache=cache)
    meshed = plan_model("toy", "cell", ops, CFG, cache=cache,
                        mesh=ArrayMesh(4))
    assert meshed.n_arrays == 4
    assert len(meshed.per_array_bytes) == 4
    assert sum(meshed.per_array_bytes) == pytest.approx(meshed.minisa_bytes)
    assert meshed.load_imbalance >= 1.0
    # parallel arrays: the meshed cell is faster than the single array
    assert meshed.cycles_minisa < single.cycles_minisa
    assert meshed.summary()["n_arrays"] == 4


# ---------------------------------------------------------------------------
# Mesh-aware runtime: executable + scheduler determinism
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mesh_cache():
    return ProgramCache()


@pytest.mark.parametrize("backend", ["interpreter", "pallas"])
def test_executable_sharded_matches_stream_oracle(mesh_cache, backend):
    ex = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                  cache=mesh_cache, mesh=ArrayMesh(4))
    assert ex.describe()["n_sharded"] == len(ex.steps)
    res = ex.run(backend, check=True)
    assert res.checked and len(res.outputs) == len(ex.steps)
    stats = ex.perf_stats()
    assert stats["n_arrays"] == 4
    assert len(stats["per_array_minisa_bytes"]) == 4
    assert sum(stats["per_array_minisa_bytes"]) == pytest.approx(
        stats["minisa_bytes"])
    assert stats["load_imbalance"] >= 1.0


def _sched_run(cache, backend, mesh=None, seed=0):
    prefill = ModelExecutable.for_cell("gemma-7b", "prefill_tiny", CFG,
                                       cache=cache, mesh=mesh)
    decode = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                      cache=cache, mesh=mesh)
    sched = Scheduler(prefill, decode, backend=backend, max_concurrent=2,
                      seed=seed)
    for _ in range(3):
        sched.submit(decode_steps=2)
    return sched.run()


def test_scheduler_run_bit_reproducible(mesh_cache):
    """Determinism regression: same submissions -> identical per-request
    state checksums run-to-run, across backends, and under a mesh;
    different scheduler seeds diverge."""
    a = _sched_run(mesh_cache, "interpreter")
    b = _sched_run(mesh_cache, "interpreter")
    c = _sched_run(mesh_cache, "pallas")
    assert [r.state_checksum for r in a.requests] \
        == [r.state_checksum for r in b.requests] \
        == [r.state_checksum for r in c.requests]
    assert all(r.state_checksum for r in a.requests)
    other = _sched_run(mesh_cache, "interpreter", seed=7)
    assert [r.state_checksum for r in other.requests] \
        != [r.state_checksum for r in a.requests]
    # traffic accounting is backend-independent byte-for-byte
    assert [r.minisa_bytes for r in a.requests] \
        == [r.minisa_bytes for r in c.requests]


def test_scheduler_mesh_report(mesh_cache):
    rep = _sched_run(mesh_cache, "interpreter", mesh=ArrayMesh(4))
    assert rep.n_arrays == 4
    assert len(rep.per_array_minisa_bytes) == 4
    assert all(b > 0 for b in rep.per_array_minisa_bytes)
    assert rep.load_imbalance >= 1.0
    s = rep.summary()
    assert s["n_arrays"] == 4 and len(s["per_array_cycles"]) == 4
    # sharded and unsharded serving agree on the request state trajectory
    flat = _sched_run(mesh_cache, "interpreter")
    assert [r.state_checksum for r in rep.requests] \
        == [r.state_checksum for r in flat.requests]


def test_scheduler_rejects_mismatched_meshes(mesh_cache):
    prefill = ModelExecutable.for_cell("gemma-7b", "prefill_tiny", CFG,
                                       cache=mesh_cache, mesh=ArrayMesh(4))
    decode = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                      cache=mesh_cache)
    with pytest.raises(ValueError, match="ArrayMesh"):
        Scheduler(prefill, decode)


# ---------------------------------------------------------------------------
# shard_map execution on a real device mesh (runs when devices exist)
# ---------------------------------------------------------------------------

def test_pallas_shard_map_path_when_devices_available():
    """With >= 2 JAX devices (the CI multi-device job fakes 8 via
    XLA_FLAGS), the Pallas backend executes the whole mesh as one
    shard_map-wrapped kernel; with 1 device it must fall back to the
    sequential path -- either way the numbers match the oracle."""
    import jax
    n_dev = len(jax.devices())
    mesh = ArrayMesh(min(max(n_dev, 2), 4))
    assert (mesh.jax_mesh() is not None) == (n_dev >= mesh.n_arrays)
    for df in (isa.Dataflow.WOS, isa.Dataflow.IOS):
        g = mapper.Gemm(m=24, k=16, n=20)
        prog = program.lower(g, _choice(df), CFG)
        t = _tensors(g)
        for axis in ("m", "n", "k"):
            out = backends.run_sharded(prog, t, mesh, backend="pallas",
                                       axis=axis)[prog.out_name]
            np.testing.assert_allclose(out, t["I"] @ t["W"],
                                       rtol=2e-4, atol=2e-4 + 2e-4 * g.k,
                                       err_msg=f"{df} axis={axis}")


def test_array_mesh_validation():
    with pytest.raises(ValueError):
        ArrayMesh(0)
    assert ArrayMesh(1).jax_mesh() is None
    assert ArrayMesh(2).shape == (2,)
    with pytest.raises(ValueError, match="axis"):
        g = mapper.Gemm(m=8, k=8, n=8)
        prog = program.lower(g, _choice(), CFG)
        program.shard_program(prog, ArrayMesh(2), axis="q")
