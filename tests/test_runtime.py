"""Runtime equivalence: ModelExecutable end-to-end == einsum oracle of
the same GEMM stream on both backends (including the dynamic-operand
attention GEMMs), compile-once semantics, and the continuous-batching
scheduler."""

import numpy as np
import pytest

from repro.configs.feather import feather_config
from repro.runtime import (ModelExecutable, ProgramCache, Scheduler,
                           TINY_SHAPES)

CFG = feather_config(4, 16)

#: Two (arch x shape) serving cells: GQA dense decode + MoE prefill.
CELLS = [("gemma-7b", "decode_tiny"), ("granite-moe-3b-a800m",
                                       "prefill_tiny")]


@pytest.fixture(scope="module")
def cache():
    return ProgramCache()


@pytest.fixture(scope="module")
def executables(cache):
    return {cell: ModelExecutable.for_cell(cell[0], cell[1], CFG,
                                           cache=cache)
            for cell in CELLS}


@pytest.mark.parametrize("cell", CELLS, ids=lambda c: f"{c[0]}-{c[1]}")
@pytest.mark.parametrize("backend", ["interpreter", "pallas"])
def test_executable_matches_stream_oracle(executables, cell, backend):
    """Acceptance: whole-cell execution equals the oracle replay of the
    identical stream, per step, on both backends."""
    ex = executables[cell]
    res = ex.run(backend, check=True)
    assert res.checked and res.final is not None
    assert len(res.outputs) == len(ex.steps)


@pytest.mark.parametrize("cell", CELLS, ids=lambda c: f"{c[0]}-{c[1]}")
def test_stream_contains_dynamic_attention(executables, cell):
    """FEATHER+'s headline case is actually executed: the score/value
    GEMMs are in the stream, flagged dynamic, and the score GEMM chains
    into the value GEMM."""
    ex = executables[cell]
    dyn = [s for s in ex.steps if s.op.dynamic]
    assert len(dyn) == 2
    qk, pv = dyn
    assert "qk" in qk.op.gemm.name and "pv" in pv.op.gemm.name
    assert pv.input_mode == "wired"   # scores feed values on-chip


def test_second_execution_zero_searches_zero_compiles(cache, executables):
    """Acceptance: re-building and re-running an already-served cell does
    no mapper searches and no backend compiles (cache stats prove it)."""
    arch, shape = CELLS[0]
    ex1 = executables[CELLS[0]]
    be = ex1.make_backend("pallas")
    ex1.run(be)               # warm the compiled tier
    snap = cache.stats.snapshot()
    ex2 = ModelExecutable.for_cell(arch, shape, CFG, cache=cache)
    ex2.run(ex2.make_backend("pallas"))
    ex2.run("interpreter")
    delta = cache.stats.delta(snap)
    assert delta["plan_misses"] == 0, delta
    assert delta["lowered_misses"] == 0, delta
    assert delta["compile_misses"] == 0, delta
    assert delta["plan_hits"] > 0 and delta["compile_hits"] > 0


def test_interpreter_and_pallas_agree(executables):
    """Same tensors through both backends: outputs agree step by step."""
    ex = executables[CELLS[0]]
    env = ex.make_tensors(seed=3)
    a = ex.run("interpreter", tensors=env)
    b = ex.run("pallas", tensors=env)
    for i, (x, y) in enumerate(zip(a.outputs, b.outputs)):
        np.testing.assert_allclose(x, y, rtol=2e-4,
                                   atol=2e-4 + 2e-4 * ex.steps[i].op.gemm.k,
                                   err_msg=f"step {i}")


def test_perf_stats_reps_weighted(executables):
    """Traffic accounting multiplies by layer/head multiplicity and the
    MINISA:micro ratio is the paper's direction (large reduction)."""
    ex = executables[CELLS[0]]
    stats = ex.perf_stats()
    assert stats["n_gemms"] == sum(s.reps for s in ex.steps) > len(ex.steps)
    assert stats["minisa_bytes"] > 0
    assert stats["instr_reduction"] > 10
    assert 0.0 <= stats["stall_minisa"] <= 1.0
    assert 0.0 <= stats["stall_micro"] <= 1.0


def test_tensor_specs_mark_dynamic_weights(executables):
    ex = executables[CELLS[0]]
    kinds = {k for _, k in ex.tensor_specs().values()}
    assert kinds == {"weight", "dynamic", "input"}
    dyn = [n for n, (_, k) in ex.tensor_specs().items() if k == "dynamic"]
    assert len(dyn) == 2
    # dynamic tensors are excluded from the static weight set
    weights = ex.make_tensors(kinds=("weight",))
    assert not any(n in weights for n in dyn)


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sched_report(cache):
    prefill = ModelExecutable.for_cell("gemma-7b", "prefill_tiny", CFG,
                                       cache=cache)
    decode = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                      cache=cache)
    sched = Scheduler(prefill, decode, backend="interpreter",
                      max_concurrent=2)
    for _ in range(3):
        sched.submit(decode_steps=2)
    return sched.run()


def test_scheduler_completes_all_requests(sched_report):
    rep = sched_report
    assert len(rep.requests) == 3
    assert all(r.decode_tokens == 2 for r in rep.requests)
    prefill_tokens = TINY_SHAPES["prefill_tiny"].tokens
    assert all(r.prefill_tokens == prefill_tokens for r in rep.requests)
    assert rep.total_tokens == 3 * (prefill_tokens + 2)
    assert rep.tokens_per_sec > 0
    # continuous batching: 3 requests through 2 slots needs > 2 ticks
    assert rep.ticks >= 2


def test_scheduler_per_request_traffic(sched_report):
    for r in sched_report.requests:
        assert r.minisa_bytes > 0
        assert r.instr_reduction > 10          # MINISA vs micro traffic
        assert 0.0 <= r.stall_minisa <= 1.0
        assert 0.0 <= r.stall_micro <= 1.0
        assert r.wall_s > 0


def test_scheduler_shares_weight_residency(cache):
    """All requests are served from one static weight set and one cached
    Program set: serving N requests does zero extra searches/compiles."""
    prefill = ModelExecutable.for_cell("gemma-7b", "prefill_tiny", CFG,
                                       cache=cache)
    decode = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                      cache=cache)
    sched = Scheduler(prefill, decode, backend="interpreter")
    snap = cache.stats.snapshot()
    sched.submit(decode_steps=1)
    sched.submit(decode_steps=1)
    sched.run()
    delta = cache.stats.delta(snap)
    assert delta["plan_misses"] == 0 and delta["compile_misses"] == 0


def test_scheduler_decode_is_a_recurrence(cache):
    """Decode steps feed on their own outputs and per-request KV state:
    two requests with different seeds produce different final tokensets,
    the same seed reproduces exactly."""
    prefill = ModelExecutable.for_cell("gemma-7b", "prefill_tiny", CFG,
                                       cache=cache)
    decode = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                      cache=cache)

    def final_for(seed):
        sched = Scheduler(prefill, decode, backend="interpreter")
        sched.submit(decode_steps=2, seed=seed)
        a = sched._admit(sched._pending.popleft())
        sched._decode_step(a)
        sched._decode_step(a)
        return a.carry

    f0, f0b, f1 = final_for(0), final_for(0), final_for(1)
    np.testing.assert_array_equal(f0, f0b)
    assert not np.allclose(f0, f1)
