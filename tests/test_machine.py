"""Functional machine vs einsum oracle: the RTL-equivalence claim.

Property-style sweep: random GEMM shapes x array configs x both dataflows,
plus direct ExecuteMapping semantics checks against Eq. 1 and the paper's
Fig. 4 / §IV-E case studies.  Forced mapping choices exercise the Program
lowering directly (no search)."""

import numpy as np
import pytest

from repro.configs.feather import feather_config
from repro.core import isa, machine, mapper, program
from repro.core.mapping import tile_indices


RNG = np.random.default_rng(42)


def _run(gemm, cfg, choice=None):
    if choice is None:
        prog = mapper.search(gemm, cfg).program
    else:
        prog = program.lower(gemm, choice, cfg)
    i = RNG.standard_normal((gemm.m, gemm.k)).astype(np.float32)
    w = RNG.standard_normal((gemm.k, gemm.n)).astype(np.float32)
    out = machine.run_program(cfg, prog, {"I": i, "W": w})["O"]
    np.testing.assert_allclose(out, i @ w, rtol=2e-4, atol=2e-4)
    return prog


@pytest.mark.parametrize("m,k,n", [
    (4, 4, 4), (8, 8, 8), (16, 16, 16),
    (5, 7, 3), (6, 10, 21), (1, 40, 88), (17, 40, 88), (32, 3, 50),
])
@pytest.mark.parametrize("ah,aw", [(4, 4), (4, 16), (8, 8)])
def test_machine_matches_oracle_searched(m, k, n, ah, aw):
    _run(mapper.Gemm(m=m, k=k, n=n), feather_config(ah, aw))


@pytest.mark.parametrize("df", [isa.Dataflow.WOS, isa.Dataflow.IOS])
@pytest.mark.parametrize("n_kg,n_nb", [(1, 1), (2, 1), (1, 2), (4, 1),
                                       (2, 2), (1, 4)])
def test_machine_matches_oracle_forced_grouping(df, n_kg, n_nb):
    """Sweep the mapping knobs explicitly (Fig. 4's three regimes and the
    mixed ones)."""
    cfg = feather_config(4, 4)
    gemm = mapper.Gemm(m=12, k=16, n=12)
    dup = (4 // n_kg) // n_nb
    choice = mapper.MappingChoice(
        df=df, vn=4, m_t=12, k_t=16, n_t=12,
        n_kg=n_kg, n_nb=n_nb, dup=dup)
    _run(gemm, cfg, choice)


@pytest.mark.parametrize("vn", [1, 2, 3, 4])
def test_machine_vn_sizes(vn):
    """VN_size < AH activates only vn rows (no double counting)."""
    cfg = feather_config(4, 4)
    gemm = mapper.Gemm(m=6, k=2 * vn + 1, n=9)
    choice = mapper.MappingChoice(
        df=isa.Dataflow.WOS, vn=vn, m_t=6, k_t=gemm.k, n_t=9,
        n_kg=1, n_nb=1, dup=4)
    _run(gemm, cfg, choice)


def test_eq1_indices():
    """Direct check of Eq. 1 + §IV-E streaming formulas."""
    em = isa.ExecuteMapping(r0=0, c0=0, g_r=2, g_c=1, s_r=1, s_c=0)
    es = isa.ExecuteStreaming(m0=0, s_m=3, t=3, vn_size=4)
    idx = tile_indices(em, es, ah=4, aw=4)
    # §IV-E case study: columns 0,1 -> j=0; columns 2,3 -> j=1
    np.testing.assert_array_equal(idx.r, [0, 0, 1, 1])
    # m = m0 + 3t + (a_w mod 2) // 1
    np.testing.assert_array_equal(idx.m[0], [0, 1, 0, 1])
    np.testing.assert_array_equal(idx.m[1], [3, 4, 3, 4])
    np.testing.assert_array_equal(idx.m[2], [6, 7, 6, 7])


def test_activation_and_chain():
    """Activation instruction applies on the drained output."""
    cfg = feather_config(4, 4)
    gemm = mapper.Gemm(m=6, k=8, n=5)
    plan = mapper.search(gemm, cfg)
    relu = lambda x: np.maximum(x, 0)
    prog = program.lower(gemm, plan.choice, cfg, activation=relu,
                         act_name="relu")
    i = RNG.standard_normal((6, 8)).astype(np.float32)
    w = RNG.standard_normal((8, 5)).astype(np.float32)
    out = machine.run_program(cfg, prog, {"I": i, "W": w})["O"]
    np.testing.assert_allclose(out, relu(i @ w), rtol=2e-4, atol=2e-4)


def test_layout_orders_do_not_change_semantics():
    """Any legal Tab. III order must produce the same result (layout is a
    performance knob, not a semantic one)."""
    cfg = feather_config(4, 4)
    gemm = mapper.Gemm(m=8, k=12, n=10)
    base = mapper.search(gemm, cfg).choice
    for o in range(6):
        choice = mapper.MappingChoice(
            **{**{f.name: getattr(base, f.name)
                  for f in base.__dataclass_fields__.values()},
               "order_w": o, "order_i": (o + 1) % 6, "order_o": (o + 2) % 6})
        _run(gemm, cfg, choice)


@pytest.mark.parametrize("df", [isa.Dataflow.WOS, isa.Dataflow.IOS])
@pytest.mark.parametrize("n_nb", [2, 4])
def test_strided_stationary_pattern(df, n_nb):
    """Tab. VII's strided c-pattern (s_r=G_c, s_c=1) covers the same
    output space as the block pattern and matches the oracle."""
    cfg = feather_config(4, 4)
    gemm = mapper.Gemm(m=8, k=8, n=16)
    dup = (4 // 1) // n_nb
    choice = mapper.MappingChoice(
        df=df, vn=4, m_t=8, k_t=8, n_t=16,
        n_kg=1, n_nb=n_nb, dup=dup, strided=True)
    _run(gemm, cfg, choice)


def test_fig4_mapping_regimes():
    """Fig. 4's three ExecuteMapping regimes on a 4x4 NEST: full
    replication, two groups, and per-column distinct W_VNs."""
    cfg = feather_config(4, 4)
    gemm = mapper.Gemm(m=16, k=16, n=16)
    for n_kg, n_nb in [(1, 1), (2, 1), (4, 1), (1, 4)]:
        dup = (4 // n_kg) // n_nb
        choice = mapper.MappingChoice(
            df=isa.Dataflow.WOS, vn=4, m_t=16, k_t=16, n_t=16,
            n_kg=n_kg, n_nb=n_nb, dup=dup)
        _run(gemm, cfg, choice)


def test_flat_trace_equals_program_execution():
    """machine.run over the flattened TraceOp stream == run_program (the
    flat trace is the same artifact, not a second lowering)."""
    cfg = feather_config(4, 16)
    gemm = mapper.Gemm(m=17, k=40, n=24)
    prog = mapper.search(gemm, cfg).program
    i = RNG.standard_normal((gemm.m, gemm.k)).astype(np.float32)
    w = RNG.standard_normal((gemm.k, gemm.n)).astype(np.float32)
    a = machine.run_program(cfg, prog, {"I": i, "W": w})["O"]
    b = machine.run_trace(cfg, list(prog.trace_ops()), {"I": i, "W": w})["O"]
    np.testing.assert_array_equal(a, b)
