"""Conv->GEMM lowering (paper Fig. 1) + layout-constrained search
(artifact item 6)."""

import numpy as np
import pytest

from repro.configs.feather import feather_config
from repro.core import machine, mapper
from repro.core.conv import Conv2D, conv2d_ref, im2col

RNG = np.random.default_rng(9)


@pytest.mark.parametrize("conv", [
    Conv2D(n=1, h=8, w=8, c_in=3, kh=3, kw=3, c_out=4),
    Conv2D(n=2, h=7, w=5, c_in=2, kh=3, kw=3, c_out=3, stride=2),
    Conv2D(n=1, h=6, w=6, c_in=4, kh=1, kw=1, c_out=8),
    Conv2D(n=1, h=9, w=9, c_in=2, kh=3, kw=3, c_out=5, padding="VALID"),
])
def test_conv_through_feather_machine(conv):
    """im2col conv == the MINISA-executed GEMM == direct conv reference."""
    x = RNG.standard_normal((conv.n, conv.h, conv.w, conv.c_in)) \
        .astype(np.float32)
    kern = RNG.standard_normal((conv.kh, conv.kw, conv.c_in, conv.c_out)) \
        .astype(np.float32)
    g = conv.to_gemm()
    cfg = feather_config(4, 4)
    plan = mapper.search(g, cfg)
    patches = im2col(x, conv)
    wmat = kern.reshape(-1, conv.c_out)
    out = plan.execute({"I": patches, "W": wmat})["O"]
    oh, ow = conv.out_hw
    got = out.reshape(conv.n, oh, ow, conv.c_out)
    expect = conv2d_ref(x, kern, conv)
    np.testing.assert_allclose(got, expect, rtol=2e-4, atol=2e-4)
    # naive direct conv cross-check on the smallest case
    if conv.stride == 1 and conv.padding == "VALID":
        direct = np.zeros_like(expect)
        for i in range(conv.kh):
            for j in range(conv.kw):
                direct += np.einsum(
                    "nhwc,co->nhwo",
                    x[:, i:i + oh, j:j + ow, :],
                    kern[i, j])
        np.testing.assert_allclose(expect, direct, rtol=1e-5, atol=1e-5)


def test_conv_im2col_oracle_on_both_backends():
    """The ci_suite conv workload: the planned Program's execution on
    both backends equals the im2col GEMM oracle AND the direct conv
    reference (the satellite's conv-through-the-spine contract)."""
    from repro.core import workloads

    conv = workloads.ci_conv()
    x = RNG.standard_normal((conv.n, conv.h, conv.w, conv.c_in)) \
        .astype(np.float32)
    kern = RNG.standard_normal((conv.kh, conv.kw, conv.c_in, conv.c_out)) \
        .astype(np.float32)
    patches = im2col(x, conv)
    wmat = kern.reshape(-1, conv.c_out)
    expect = conv2d_ref(x, kern, conv)
    oh, ow = conv.out_hw
    cfg = feather_config(4, 16)
    plan = mapper.search(conv.to_gemm(), cfg)
    for backend in ("interpreter", "pallas"):
        out = plan.execute({"I": patches, "W": wmat}, backend=backend)["O"]
        got = out.reshape(conv.n, oh, ow, conv.c_out)
        np.testing.assert_allclose(got, expect, rtol=2e-4,
                                   atol=2e-4 + 2e-4 * conv.to_gemm().k,
                                   err_msg=backend)


def test_planner_accepts_conv2d_directly():
    """GemmOp may carry a Conv2D: the planner (and the ProgramCache
    underneath) lowers it via to_gemm() and plans the im2col GEMM."""
    from repro.core.planner import GemmOp, plan_model
    from repro.core.workloads import ci_conv
    from repro.runtime import ProgramCache

    cfg = feather_config(4, 16)
    cache = ProgramCache()
    conv = ci_conv()
    g = conv.to_gemm()
    ap = plan_model("convnet", "ci", [GemmOp(gemm=conv, layer="conv")],
                    cfg, cache=cache)
    assert (g.m, g.k, g.n) in ap.plans
    assert ap.total_macs == g.macs
    assert ap.minisa_bytes > 0
    # the cache normalises too: planning the Conv2D and its GEMM is one
    # search problem
    snap = cache.stats.snapshot()
    assert cache.plan(conv, cfg) is cache.plan(g, cfg)
    assert cache.stats.delta(snap)["plan_misses"] == 0


def test_executable_accepts_conv2d_op():
    """A Conv2D-carrying GemmOp runs through the ModelExecutable (ops
    are normalised to their im2col GEMMs at construction)."""
    from repro.core.planner import GemmOp
    from repro.core.workloads import ci_conv
    from repro.runtime import ModelExecutable, ProgramCache

    cfg = feather_config(4, 16)
    conv = ci_conv()
    ex = ModelExecutable([GemmOp(gemm=conv, layer="conv")], cfg,
                         cache=ProgramCache())
    g = conv.to_gemm()
    assert ex.tensor_specs()[ex.steps[0].weight_name][0] == (g.k, g.n)
    res = ex.run("interpreter", check=True)
    assert res.checked and res.final.shape == (g.m, g.n)


def test_layout_constrained_search():
    """Artifact item 6: constrain the input layout (VN size + order) --
    the constrained plan respects it and still beats micro-instructions."""
    cfg = feather_config(8, 8)
    g = mapper.Gemm(m=64, k=40, n=48)
    free = mapper.search(g, cfg)
    constrained = mapper.search(g, cfg, fixed_input_vn=8,
                                fixed_input_order=0b100)
    assert constrained.choice.vn == 8
    assert constrained.choice.order_i == 0b100
    # constrained search can never beat the free one
    assert constrained.perf_minisa.cycles >= free.perf_minisa.cycles * 0.999
    # functional correctness preserved under the constraint (the Program
    # IS the plan artifact; no separate trace build)
    i = RNG.standard_normal((64, 40)).astype(np.float32)
    w = RNG.standard_normal((40, 48)).astype(np.float32)
    out = machine.run_program(cfg, constrained.program, {"I": i, "W": w})["O"]
    np.testing.assert_allclose(out, i @ w, rtol=2e-4, atol=2e-4)
