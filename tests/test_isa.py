"""ISA encoding, bitwidths (Tab. V) and layout addressing properties."""

import math
import random

import numpy as np
import pytest

from repro.configs.feather import SWEEP, feather_config
from repro.core import isa, layout as layoutlib
from tests._hypothesis_compat import given, settings, st


def test_opcodes_are_3bit_unique():
    codes = [int(o) for o in isa.Opcode]
    assert len(set(codes)) == 8
    assert all(0 <= c < 8 for c in codes)


@pytest.mark.parametrize("ah,aw", SWEEP)
def test_bitwidths_reasonable(ah, aw):
    cfg = feather_config(ah, aw)
    # Tab. V ranges: Set*VNLayout 38-44, E.Mapping 81-95, E.Streaming 45-59
    assert 30 <= cfg.bits_set_layout() <= 50
    assert 70 <= cfg.bits_execute_mapping() <= 105
    assert 40 <= cfg.bits_execute_streaming() <= 65


def test_execute_streaming_bitwidths_match_paper_exactly():
    # Fig. 5 formula reproduces the E.Streaming column of Tab. V
    expected = {(4, 4): 57, (4, 16): 51, (4, 64): 45,
                (8, 8): 58, (8, 32): 52, (8, 128): 46,
                (16, 16): 59, (16, 64): 53, (16, 256): 47}
    for (ah, aw), bits in expected.items():
        cfg = feather_config(ah, aw)
        assert cfg.bits_execute_streaming() == bits, (ah, aw)


def test_instruction_encode_roundtrip_widths():
    cfg = feather_config(8, 32)
    insts = [
        isa.SetWVNLayout(order=3, nr_l0=4, nr_l1=7, red_l1=9),
        isa.SetIVNLayout(order=0, nr_l0=1, nr_l1=2, red_l1=3),
        isa.SetOVNLayout(order=5, nr_l0=2, nr_l1=2, red_l1=2),
        isa.ExecuteMapping(r0=3, c0=17, g_r=4, g_c=2, s_r=1, s_c=8),
        isa.ExecuteStreaming(m0=5, s_m=2, t=100, vn_size=8,
                             df=isa.Dataflow.IOS),
        isa.Load(hbm_addr=1 << 20, length=4096,
                 target=isa.BufferTarget.STATIONARY),
        isa.Write(hbm_addr=0, length=128),
        isa.Activation(function=isa.ACTIVATION_FUNCS["gelu"], length=64),
    ]
    for inst in insts:
        word = inst.encode(cfg)
        assert 0 <= word < (1 << inst.bitwidth(cfg))
        # opcode occupies the top 3 bits
        assert word >> (inst.bitwidth(cfg) - 3) == int(inst.opcode)
        # spec-driven decode inverts encode exactly -- no field widths
        # re-derived by hand
        assert type(inst).decode(word, cfg) == inst
        assert isa.decode(word, inst.bitwidth(cfg), cfg) == inst


def _random_instruction(cls, cfg, rng: random.Random) -> isa.Instruction:
    """Draw every field uniformly over its *encodable* range, derived from
    the class's own spec: raw in [0, 2^width), value = raw + bias."""
    kwargs = {}
    for name, width, bias in cls.spec(cfg):
        if name == "opcode":
            continue
        raw = rng.randrange(1 << width) if width else 0
        value = raw + bias
        kwargs[name] = isa._FIELD_CASTS.get(name, int)(value)
    return cls(**kwargs)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       sweep_idx=st.integers(min_value=0, max_value=len(SWEEP) - 1))
def test_decode_inverts_encode_randomized(seed, sweep_idx):
    """Property: for every instruction class and every sweep config,
    decode(encode(inst)) == inst over randomized in-range fields -- both
    via the class decoder and the opcode-dispatching ``isa.decode``."""
    cfg = feather_config(*SWEEP[sweep_idx])
    rng = random.Random(seed)
    for cls in isa.OPCODE_TO_CLASS.values():
        inst = _random_instruction(cls, cfg, rng)
        nbits = inst.bitwidth(cfg)
        word = inst.encode(cfg)
        assert 0 <= word < (1 << nbits)
        assert cls.decode(word, cfg) == inst
        assert isa.decode(word, nbits, cfg) == inst


def test_decode_rejects_wrong_opcode():
    cfg = feather_config(4, 4)
    word = isa.Load(hbm_addr=1, length=2).encode(cfg)
    with pytest.raises(ValueError, match="opcode mismatch"):
        isa.Write.decode(word, cfg)


def test_load_write_share_encoding():
    """Load and Write are one MemAccess layout; only the opcode differs."""
    cfg = feather_config(4, 16)
    load = isa.Load(hbm_addr=77, length=123,
                    target=isa.BufferTarget.STATIONARY)
    write = isa.Write(hbm_addr=77, length=123,
                      target=isa.BufferTarget.STATIONARY)
    assert isinstance(load, isa.MemAccess) and isinstance(write, isa.MemAccess)
    assert load.spec(cfg)[1:] == write.spec(cfg)[1:]
    assert load.bitwidth(cfg) == write.bitwidth(cfg)
    # same payload bits under different opcodes
    mask = (1 << (load.bitwidth(cfg) - 3)) - 1
    assert load.encode(cfg) & mask == write.encode(cfg) & mask


def test_trace_accounting():
    cfg = feather_config(4, 4)
    trace = [isa.ExecuteMapping(), isa.ExecuteStreaming()]
    s = isa.trace_summary(trace, cfg)
    assert s["n_instructions"] == 2
    assert s["bits"] == (cfg.bits_execute_mapping()
                         + cfg.bits_execute_streaming())


# ---------------------------------------------------------------------------
# Layout addressing properties (property-based sweeps)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("order", sorted(layoutlib.ORDER_TABLE))
@pytest.mark.parametrize("nr_l0,nr_l1,red_l1,vn,aw", [
    (4, 2, 2, 4, 4), (3, 3, 5, 2, 8), (1, 7, 4, 3, 16), (8, 1, 1, 1, 8),
])
def test_layout_flatten_bijective(order, nr_l0, nr_l1, red_l1, vn, aw):
    lay = layoutlib.VNLayout(order=order, nr_l0=nr_l0, nr_l1=nr_l1,
                             red_l1=red_l1, vn_size=vn, aw=aw)
    r, c = np.meshgrid(np.arange(red_l1), np.arange(nr_l0 * nr_l1),
                       indexing="ij")
    l = lay.flatten(r, c)
    # bijective onto [0, num_vns)
    assert sorted(l.ravel().tolist()) == list(range(lay.num_vns))
    r2, c2 = lay.unflatten(l)
    np.testing.assert_array_equal(r, r2)
    np.testing.assert_array_equal(c, c2)


@pytest.mark.parametrize("order", sorted(layoutlib.ORDER_TABLE))
def test_layout_addresses_disjoint(order):
    lay = layoutlib.VNLayout(order=order, nr_l0=4, nr_l1=3, red_l1=5,
                             vn_size=3, aw=8)
    r, c = np.meshgrid(np.arange(5), np.arange(12), indexing="ij")
    row, col = lay.address(r, c)
    cells = set()
    for rr, cc in zip(row.ravel(), col.ravel()):
        for e in range(lay.vn_size):
            cell = (rr + e, cc)
            assert cell not in cells, "address collision"
            cells.add(cell)
    assert max(row.ravel()) + lay.vn_size <= lay.rows_needed


def test_place_gather_roundtrip():
    rng = np.random.default_rng(0)
    for order in layoutlib.ORDER_TABLE:
        lay = layoutlib.VNLayout(order=order, nr_l0=4, nr_l1=2, red_l1=3,
                                 vn_size=4, aw=4)
        vns = rng.standard_normal((3, 8, 4)).astype(np.float32)
        buf = layoutlib.place(vns, lay, depth=lay.rows_needed)
        r, c = np.meshgrid(np.arange(3), np.arange(8), indexing="ij")
        out = layoutlib.gather(buf, lay, r, c)
        np.testing.assert_allclose(out, vns)
        # out-of-extent reads are zero (paper: implicit zero padding)
        zero = layoutlib.gather(buf, lay, np.array([99]), np.array([0]))
        assert (zero == 0).all()
