"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(the kernels target TPU; interpret=True executes the kernel body on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import flash_attention as _fa
from repro.kernels import ops, ref

RNG = np.random.default_rng(3)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4),
                                       (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128), (256, 512, 128), (64, 64, 192),
    (100, 40, 88),          # BConv-like irregular
    (33, 17, 65),           # fully ragged
])
def test_nest_gemm(m, k, n, dtype, tol):
    x, w = _rand((m, k), dtype), _rand((k, n), dtype)
    out = ops.nest_gemm(x, w, interpret=True)
    expect = ref.nest_gemm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol * k)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (96, 64, 160)])
def test_nest_gemm_block_transposed_output(m, k, n):
    """BIRRD-style free output re-layout."""
    x, w = _rand((m, k), jnp.float32), _rand((k, n), jnp.float32)
    out = ops.nest_gemm(x, w, interpret=True, out_block_t=True)
    expect = ref.nest_gemm_ref(x, w, out_block_t=True)
    assert out.shape == (n, m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-2)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("b,s,h,d,causal", [
    (2, 128, 2, 64, True), (2, 128, 2, 64, False),
    (1, 256, 4, 32, True), (2, 192, 1, 128, True),
    (1, 320, 2, 64, False),     # ragged seq
])
def test_flash_attention(b, s, h, d, causal, dtype, tol):
    q = _rand((b, s, h, d), dtype) * 0.3
    k = _rand((b, s, h, d), dtype) * 0.3
    v = _rand((b, s, h, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    qf = jnp.transpose(q, (0, 2, 1, 3)).reshape(b * h, s, d)
    kf = jnp.transpose(k, (0, 2, 1, 3)).reshape(b * h, s, d)
    vf = jnp.transpose(v, (0, 2, 1, 3)).reshape(b * h, s, d)
    expect = ref.flash_attention_ref(qf, kf, vf, causal=causal)
    expect = expect.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("b,l,d,n", [(2, 64, 32, 16), (1, 128, 64, 8),
                                     (2, 256, 16, 4)])
def test_mamba_scan(b, l, d, n):
    da = jnp.asarray(RNG.uniform(0.7, 0.999, (b, l, d, n)), jnp.float32)
    dbx = _rand((b, l, d, n), jnp.float32) * 0.1
    c = _rand((b, l, n), jnp.float32)
    h0 = _rand((b, d, n), jnp.float32) * 0.1
    y, h = ops.mamba_scan(da, dbx, c, h0, interpret=True)
    yr, hr = ref.mamba_scan_ref(da, dbx, c, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Randomized oracle sweeps (ragged shapes the parametrized grids miss)
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(m=st.integers(1, 150), k=st.integers(1, 150), n=st.integers(1, 150))
def test_nest_gemm_randomized_ragged(m, k, n):
    """Arbitrary non-block-multiple shapes vs the einsum oracle (the
    zero-pad path of ops.nest_gemm must be exact, not approximate)."""
    x, w = _rand((m, k), jnp.float32), _rand((k, n), jnp.float32)
    out = ops.nest_gemm(x, w, interpret=True)
    expect = ref.nest_gemm_ref(x, w)
    assert out.shape == (m, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4 * max(k, 1))


@settings(max_examples=8, deadline=None)
@given(m=st.integers(2, 140), k=st.integers(2, 100), n=st.integers(2, 140))
def test_nest_gemm_out_block_t_randomized_ragged(m, k, n):
    """The BIRRD-style block-transposed output map on ragged shapes:
    per-block transposition at swapped block coordinates must equal the
    global transpose after the pad-slice round trip."""
    x, w = _rand((m, k), jnp.float32), _rand((k, n), jnp.float32)
    out = ops.nest_gemm(x, w, interpret=True, out_block_t=True)
    expect = ref.nest_gemm_ref(x, w, out_block_t=True)
    assert out.shape == (n, m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4 * max(k, 1))


@pytest.mark.parametrize("act", ["relu", "gelu", "silu"])
def test_nest_gemm_fused_activation(act):
    """Activation fused at the final-K store == oracle + host activation
    (the PallasBackend's lowering of elementwise Activation drains)."""
    import jax
    x, w = _rand((96, 72), jnp.float32), _rand((72, 80), jnp.float32)
    out = ops.nest_gemm(x, w, interpret=True, act=act)
    fn = {"relu": lambda v: jnp.maximum(v, 0.0), "gelu": jax.nn.gelu,
          "silu": jax.nn.silu}[act]
    expect = fn(ref.nest_gemm_ref(x, w))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-2)


@settings(max_examples=6, deadline=None)
@given(s=st.integers(2, 96), sk=st.integers(2, 200),
       d=st.sampled_from([16, 32, 64]))
def test_flash_attention_noncausal_padded_kv_randomized(s, sk, d):
    """Non-causal cross-attention with ragged (padded) KV: the docstring
    promises padded KV rows are masked -- randomized regression."""
    b, h = 1, 2
    q = _rand((b, s, h, d), jnp.float32) * 0.3
    k = _rand((b, sk, h, d), jnp.float32) * 0.3
    v = _rand((b, sk, h, d), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False, interpret=True)
    qf = jnp.transpose(q, (0, 2, 1, 3)).reshape(b * h, s, d)
    kf = jnp.transpose(k, (0, 2, 1, 3)).reshape(b * h, sk, d)
    vf = jnp.transpose(v, (0, 2, 1, 3)).reshape(b * h, sk, d)
    expect = ref.flash_attention_ref(qf, kf, vf, causal=False)
    expect = expect.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_blockaligned_padded_kv_does_not_leak():
    """Regression for the raw kernel's pad guard: a block-aligned kv_len
    shorter than the padded buffer (kv_len % bkv == 0) used to skip the
    mask entirely, letting whole padding blocks contribute.  Poison the
    pad region to make any leak loud."""
    bh, s, d, real_kv = 2, 64, 32, 64
    q = _rand((bh, s, d), jnp.float32) * 0.3
    k = _rand((bh, real_kv, d), jnp.float32) * 0.3
    v = _rand((bh, real_kv, d), jnp.float32)
    poison = jnp.full((bh, 64, d), 100.0, jnp.float32)
    k_pad = jnp.concatenate([k, poison], axis=1)     # padded to 128
    v_pad = jnp.concatenate([v, poison], axis=1)
    out = _fa.flash_attention(q, k_pad, v_pad, causal=False, bq=32, bkv=64,
                              kv_len=real_kv, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_mamba_scan_matches_model_recurrence():
    """Kernel semantics == the model's chunked associative scan."""
    from repro.models.ssm import _ssm_scan_chunked
    b, l, d, n = 2, 64, 8, 4
    da = jnp.asarray(RNG.uniform(0.5, 0.99, (b, l, d, n)), jnp.float32)
    dbx = _rand((b, l, d, n), jnp.float32)
    h0 = _rand((b, d, n), jnp.float32)
    h_seq, h_last = _ssm_scan_chunked(da, dbx, h0, chunk=16)
    c = _rand((b, l, n), jnp.float32)
    y_model = jnp.einsum("bldn,bln->bld", h_seq, c)
    y_kernel, h_kernel = ops.mamba_scan(da, dbx, c, h0, interpret=True)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_kernel), np.asarray(h_last),
                               rtol=1e-4, atol=1e-4)
