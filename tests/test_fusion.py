"""Fused-segment equivalence and fallback semantics.

The correctness spine for the fusion tentpole: a chained segment executed
as ONE fused pallas launch must equal the per-layer pallas path, the
interpreter, and the einsum oracle of the identical chain -- across the
Tab. IV CI workloads, random chain geometries, and whole model cells.
Fallback cases (``adapt`` boundaries, sharded streams, non-fusable
activations, VMEM budget) must cleanly take the per-Program path, and the
fused cache tier must make a rebuilt executable compile nothing.
"""

import dataclasses

import numpy as np
import pytest

from repro import backends
from repro.configs.feather import feather_config
from repro.core import isa, mapper, perf, program, workloads
from repro.runtime import ModelExecutable, ProgramCache
from repro.runtime.executable import ACTIVATIONS
from tests._hypothesis_compat import given, settings, st

CFG = feather_config(4, 16)
RNG = np.random.default_rng(11)


def _build_chain(dims, acts, cfg=CFG, cache=None):
    """Search+lower+chain an L-layer stack; dims = [(k0), n0, n1, ...]."""
    cache = cache or ProgramCache()
    m, widths = dims
    progs = []
    for i in range(len(widths) - 1):
        g = mapper.Gemm(m=m, k=widths[i], n=widths[i + 1],
                        name=f"chain-l{i}")
        plan = cache.plan(g, cfg)
        progs.append(cache.lower(
            plan.gemm, plan.choice, cfg,
            activation=ACTIVATIONS.get(acts[i]), act_name=acts[i],
            out_name=f"O{i}"))
    return program.chain(progs, lower_fn=cache.lower)


def _chain_tensors(m, widths, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, widths[0])).astype(np.float32)
    ws = [(rng.standard_normal((widths[i], widths[i + 1]))
           / np.sqrt(widths[i])).astype(np.float32)
          for i in range(len(widths) - 1)]
    return x, ws


def _oracle(x, ws, acts):
    out = np.asarray(x, np.float32)
    for w, act in zip(ws, acts):
        out = out @ w
        fn = ACTIVATIONS.get(act)
        if fn is not None:
            out = np.asarray(fn(out))
    return out


def _run_per_layer(backend_name, chained, x, ws):
    be = backends.get_backend(backend_name, CFG)
    for i, prog in enumerate(chained):
        t = {"W": ws[i]}
        if i == 0:
            t["I"] = x
        be.run_program(prog, t)
    return np.asarray(be.outputs[chained[-1].out_name])


def _run_fused(backend_name, seg, x, ws):
    be = backends.get_backend(backend_name, CFG)
    t = {"I": x, **{f"W{i}": w for i, w in enumerate(ws)}}
    return np.asarray(be.run_segment(seg, t)[seg.out_name])


def _assert_chain_equivalence(dims, acts, seed=0):
    m, widths = dims
    chained = _build_chain(dims, acts)
    seg = program.fuse_segment(chained)
    assert seg is not None, program.fusion_illegal_reason(chained)
    x, ws = _chain_tensors(m, widths, seed)
    ref = _oracle(x, ws, acts)
    k_max = max(widths)
    tol = dict(rtol=2e-4, atol=2e-4 + 2e-4 * k_max)
    outs = {
        "fused-pallas": _run_fused("pallas", seg, x, ws),
        "per-layer-pallas": _run_per_layer("pallas", chained, x, ws),
        "fused-interpreter": _run_fused("interpreter", seg, x, ws),
        "per-layer-interp": _run_per_layer("interpreter", chained, x, ws),
    }
    for name, out in outs.items():
        np.testing.assert_allclose(out, ref, err_msg=name, **tol)
    # fused pallas vs per-layer pallas: same kernel arithmetic, checked
    # at a tolerance an order tighter than against the oracle
    np.testing.assert_allclose(outs["fused-pallas"],
                               outs["per-layer-pallas"],
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# The spine: ci_suite-anchored multi-layer chains, all four executions
# ---------------------------------------------------------------------------

def _suite_samples():
    """One workload per Tab. IV family (+ the conv), chain-extended."""
    suite = {g.name.split("-")[0] + "-" + g.name.split("-")[1]: g
             for g in workloads.ci_suite()}
    picks = [suite[k] for k in ("fhe-bconv", "fhe-ntt", "zkp-ntt",
                                "gpt-oss", "conv-3x3s1")]
    return picks


@pytest.mark.parametrize("gemm", _suite_samples(), ids=lambda g: g.name)
def test_fused_equals_per_layer_equals_oracle_ci_suite(gemm):
    """fused pallas == per-layer pallas == interpreter == oracle on
    3-layer chains anchored on each CI workload family's shape."""
    widths = [gemm.k, gemm.n, 24, 16]
    _assert_chain_equivalence((gemm.m, widths), ["silu", "relu", "none"])


def test_fused_row_wise_activation_chain():
    """softmax inside a fused chain (the attention qk->pv pattern):
    legal because a fused block holds full output rows."""
    _assert_chain_equivalence((12, [16, 12, 8]), ["softmax", "none"])


@settings(max_examples=8, deadline=None)
@given(m=st.integers(2, 40), k0=st.integers(3, 40),
       n0=st.integers(2, 40), n1=st.integers(2, 40), n2=st.integers(2, 40),
       n_layers=st.integers(2, 4),
       act=st.sampled_from(["none", "relu", "gelu", "silu"]),
       seed=st.integers(0, 2 ** 16))
def test_fused_random_chain_property(m, k0, n0, n1, n2, n_layers, act,
                                     seed):
    """Property: any fusion-legal random chain geometry agrees with the
    oracle on both the fused and per-layer paths."""
    widths = [k0, n0, n1, n2][:n_layers + 1]
    acts = [act] * (len(widths) - 2) + ["none"]
    _assert_chain_equivalence((m, widths), acts, seed=seed)


# ---------------------------------------------------------------------------
# Legality predicate + fallbacks
# ---------------------------------------------------------------------------

def test_fusion_legality_reasons():
    chained = _build_chain((8, [12, 8, 6]), ["relu", "none"])
    assert program.fusable(chained)
    # fewer than 2 layers
    assert "fewer than 2" in program.fusion_illegal_reason(chained[:1])
    # shape break
    other = _build_chain((10, [12, 8, 6]), ["relu", "none"])
    assert "output" in program.fusion_illegal_reason([chained[0],
                                                      other[1]])
    # anonymous activation callable
    anon = dataclasses.replace(chained[0], activation=lambda x: x * 2,
                               act_name="none", _memo={})
    assert "anonymous" in program.fusion_illegal_reason([anon, chained[1]])
    # VMEM budget
    assert "budget" in program.fusion_illegal_reason(chained,
                                                     vmem_budget=10)
    assert program.fuse_segment(chained, vmem_budget=10) is None


def test_row_wise_activation_needs_wos():
    """A row-wise activation under IO-S (transposed accumulator) cannot
    fuse -- the block's rows are host columns there."""
    choice = mapper.MappingChoice(df=isa.Dataflow.IOS, vn=4, m_t=8,
                                  k_t=8, n_t=8, n_kg=1, n_nb=1, dup=4)
    g1 = mapper.Gemm(m=8, k=8, n=8)
    p1 = program.lower(g1, choice, CFG, out_name="O0",
                       activation=ACTIVATIONS["softmax"],
                       act_name="softmax")
    p2 = program.lower(mapper.Gemm(m=8, k=8, n=4), choice, CFG,
                       out_name="O1")
    reason = program.fusion_illegal_reason([p1, p2])
    assert reason is not None and "row-wise" in reason


def test_adapt_boundary_fuses_in_kernel():
    """The head-split reshape between projections and attention is an
    ``adapt`` step: the streamed megakernel lowers it to an in-kernel
    slab permutation, so fused segments SPAN it (one launch per block)
    instead of breaking on it."""
    ex = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                  cache=ProgramCache())
    covered = [i for seg in ex.segments for i in seg.indices]
    assert covered == list(range(len(ex.steps)))   # exact partition
    for seg in ex.segments:
        steps = [ex.steps[i] for i in seg.indices]
        assert all(s.input_mode in ("wired", "adapt") for s in steps[1:])
        assert steps[0].input_mode in ("fresh", "adapt")
        if seg.fused is not None:
            assert seg.n_steps >= 2
            assert seg.fused.adapts == tuple(
                i > 0 and s.input_mode == "adapt"
                for i, s in enumerate(steps))
            if any(seg.fused.adapts):
                # the in-kernel permutation needs the whole activation
                # resident in one M block
                assert seg.fused.m_steps == 1
    adapt_steps = [s.index for s in ex.steps if s.input_mode == "adapt"]
    assert adapt_steps, "cell should contain adapt boundaries"
    spanning = [seg for seg in ex.segments if seg.fused is not None
                and any(seg.fused.adapts)]
    assert spanning, "a fused segment should span an adapt boundary"
    # the attention block (qk/pv between projections) rides in one of them
    assert any(ex.steps[i].op.dynamic for seg in spanning
               for i in seg.indices)


def test_adapt_spanning_segment_is_one_launch():
    """A segment spanning former adapt breaks runs as ONE pallas_call,
    bit-comparable to the per-layer replay, with the streamed VMEM
    high-water below the resident-weights footprint."""
    ex = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                  cache=ProgramCache())
    seg = next(seg for seg in ex.segments if seg.fused is not None
               and any(seg.fused.adapts))
    steps = [ex.steps[i] for i in seg.indices]
    env = ex.make_tensors(seed=3)
    t = {"I": np.asarray(env[steps[0].input_name]
                         if steps[0].input_mode == "fresh"
                         else np.zeros((steps[0].op.gemm.m,
                                        steps[0].op.gemm.k), np.float32))}
    rng = np.random.default_rng(7)
    t["I"] = rng.standard_normal(t["I"].shape).astype(np.float32)
    for j, s in enumerate(steps):
        t[f"W{j}"] = env[s.weight_name]
    be = backends.get_backend("pallas", CFG)
    before = be.n_launches
    out = np.asarray(be.run_segment(seg.fused, t)[seg.fused.out_name])
    assert be.n_launches - before == 1       # the whole block, one launch
    ref_be = backends.get_backend("interpreter", CFG)
    ref = np.asarray(ref_be.run_segment(seg.fused, t)[seg.fused.out_name])
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)
    # streamed footprint beats keeping every layer's weight resident
    assert (seg.fused.vmem_highwater_bytes()
            < seg.fused.resident_vmem_bytes())


def test_sharded_stream_falls_back():
    """Mesh-sharded executables only fuse WITHIN arrays (per-array
    residency stops at the mesh boundary); streams the axis policy
    shards along N/K keep the per-Program path and still run
    end-to-end."""
    pytest.importorskip("jax")
    from repro.dist import ArrayMesh
    ex = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                  cache=ProgramCache(), mesh=ArrayMesh(2))
    for seg in ex.segments:
        assert seg.fused is None or isinstance(
            seg.fused, program.ShardedFusedSegment)
    res = ex.run("interpreter", fused=True, check=True)
    assert all(res.outputs[seg.indices[-1]] is not None
               for seg in ex.segments)


def test_sharded_program_not_fusable():
    from repro.dist import ArrayMesh
    g = mapper.Gemm(m=16, k=12, n=8)
    plan = mapper.search(g, CFG)
    sharded = program.shard_program(plan.program, ArrayMesh(2))
    reason = program.fusion_illegal_reason([sharded, sharded])
    assert reason is not None and "sharded" in reason


# ---------------------------------------------------------------------------
# Whole-cell fused execution (runtime path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell", [("gemma-7b", "decode_tiny"),
                                  ("granite-moe-3b-a800m", "prefill_tiny")],
                         ids=lambda c: f"{c[0]}-{c[1]}")
@pytest.mark.parametrize("backend", ["interpreter", "pallas"])
def test_cell_fused_run_matches_oracle(cell, backend):
    """run(fused=True) == the per-step einsum oracle (check=True) on both
    backends, and matches the per-layer final output."""
    ex = ModelExecutable.for_cell(cell[0], cell[1], CFG,
                                  cache=ProgramCache())
    env = ex.make_tensors(seed=5)
    fused = ex.run(backend, tensors=env, fused=True, check=True)
    plain = ex.run(backend, tensors=env, check=True)
    assert fused.checked and fused.fused_segments >= 1
    assert len(fused.outputs) == len(ex.steps)
    np.testing.assert_allclose(fused.final, plain.final,
                               rtol=2e-4, atol=2e-3)
    # interior fused steps stay on-chip: no materialised output
    fused_interior = {i for seg in ex.segments if seg.fused is not None
                     for i in seg.indices[:-1]}
    for i, out in enumerate(fused.outputs):
        assert (out is None) == (i in fused_interior)


# ---------------------------------------------------------------------------
# Traffic accounting: the fused stream elides the interior round trips
# ---------------------------------------------------------------------------

def test_fused_traffic_elision():
    chained = _build_chain((16, [12, 8, 6]), ["relu", "none"])
    seg = program.fuse_segment(chained)
    elem = CFG.elem_bytes
    # kernel-launch accounting: exactly one Write + one Load of every
    # interior activation is elided
    interior = sum(p.gemm.n for p in chained[:-1])
    assert seg.elided_hbm_bytes() == 2 * 16 * interior * elem
    # machine-model tile stream: fused ships no more than per-layer, and
    # interior stores are gone entirely
    fused_traffic = perf.hbm_traffic(seg.tile_costs())
    plain_traffic = perf.hbm_traffic(
        [t for p in chained for t in p.tile_costs()])
    assert fused_traffic["data_bytes"] <= plain_traffic["data_bytes"]
    interior_stores = sum(
        t.store_bytes for layer in range(seg.n_layers - 1)
        for t in seg.layer_tile_costs(layer))
    assert interior_stores == 0.0
    # the instruction stream is untouched by fusion
    assert seg.minisa_bits() == sum(p.minisa_bits() for p in chained)


def _fixed_choice_chain(widths, acts, m=8):
    """Chain lowered under ONE MappingChoice (equal vn -> guaranteed
    §IV-G elision, independent of per-layer search outcomes)."""
    choice = mapper.MappingChoice(df=isa.Dataflow.WOS, vn=4, m_t=8,
                                  k_t=8, n_t=8, n_kg=1, n_nb=1, dup=4)
    progs = [program.lower(mapper.Gemm(m=m, k=widths[i], n=widths[i + 1]),
                           choice, CFG,
                           activation=ACTIVATIONS.get(acts[i]),
                           act_name=acts[i], out_name=f"O{i}")
             for i in range(len(widths) - 1)]
    return program.chain(progs)


def test_commit_write_counts_on_chip():
    """A chained producer's committing Write is OB-commit cycles, not HBM
    store bytes -- the §IV-G semantics in the traffic model."""
    chained = _fixed_choice_chain([12, 8, 6], ["none", "none"])
    assert chained[1].input_elided
    plain = program.lower(chained[0].gemm, chained[0].choice, CFG,
                          out_name="O0")
    chained_store = sum(t.store_bytes for t in chained[0].tile_costs())
    plain_store = sum(t.store_bytes for t in plain.tile_costs())
    assert chained_store < plain_store

def test_fused_act_names_match_kernel_registry():
    from repro.kernels.fused_chain import FUSED_ACT_FNS
    from repro.kernels.nest_gemm import ACT_FNS
    assert program.FUSED_ELEMENTWISE_ACTS == set(ACT_FNS)
    assert (program.FUSED_ELEMENTWISE_ACTS
            | program.ROW_WISE_ACTIVATIONS) == set(FUSED_ACT_FNS)
    assert set(program.FUSED_ACT_ALIASES.values()) <= set(ACT_FNS)


def test_activation_registries_numerically_agree():
    """Three activation registries must stay numerically identical (same
    eps, same max-subtraction): the runtime's host ACTIVATIONS, the
    machine's device twins, and the fused kernel's FUSED_ACT_FNS --
    drift in any one silently breaks the cross-path state checksums."""
    import jax.numpy as jnp
    from repro.core.machine import _JNP_ACTS
    from repro.kernels.fused_chain import FUSED_ACT_FNS
    x = RNG.standard_normal((6, 10)).astype(np.float32) * 3
    for name, host_fn in ACTIVATIONS.items():
        if host_fn is None:
            continue
        ref = np.asarray(host_fn(x))
        mach = np.asarray(_JNP_ACTS[name](jnp.asarray(x)))
        np.testing.assert_allclose(mach, ref, rtol=1e-6, atol=1e-6,
                                   err_msg=f"machine twin {name}")
        kname = program.FUSED_ACT_ALIASES.get(name, name)
        if kname in FUSED_ACT_FNS:
            kern = np.asarray(FUSED_ACT_FNS[kname](jnp.asarray(x)))
            np.testing.assert_allclose(kern, ref, rtol=1e-6, atol=1e-6,
                                       err_msg=f"kernel twin {name}")


# ---------------------------------------------------------------------------
# Cache: fused tier hits, fewer compiles
# ---------------------------------------------------------------------------

def test_fused_tier_hits_and_reduced_compiles():
    cache = ProgramCache()
    ex1 = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                   cache=cache)
    n_fused_steps = sum(seg.n_steps for seg in ex1.segments
                        if seg.fused is not None)
    n_fused_segs = sum(1 for seg in ex1.segments if seg.fused is not None)
    assert n_fused_segs >= 2 and n_fused_steps > n_fused_segs

    # fused serving compiles ONE artifact per segment where the per-layer
    # path compiles one per GEMM (measured without the shared cache)
    be_layer = backends.PallasBackend(CFG)
    ex1.run(be_layer)
    be_fused = backends.PallasBackend(CFG)
    ex1.run(be_fused, fused=True)
    assert be_fused.n_compiles == (be_layer.n_compiles
                                   - n_fused_steps + n_fused_segs)
    assert be_fused.n_compiles < be_layer.n_compiles

    # fused tier: the first cached run misses once per segment...
    be1 = backends.PallasBackend(CFG, compile_cache=cache)
    ex1.run(be1, fused=True)
    assert cache.stats.fused_misses == n_fused_segs
    snap = cache.stats.snapshot()
    # ...and a REBUILT executable (fresh Program/FusedSegment objects) on
    # a fresh backend hits structurally: zero new compiles of any kind
    ex2 = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                   cache=cache)
    be2 = backends.PallasBackend(CFG, compile_cache=cache)
    ex2.run(be2, fused=True)
    delta = cache.stats.delta(snap)
    assert delta["fused_hits"] == n_fused_segs, delta
    assert delta["fused_misses"] == 0, delta
    assert delta["plan_misses"] == 0 and delta["compile_misses"] == 0
    assert be2.n_compiles == 0


# ---------------------------------------------------------------------------
# Interpreter chain residency (the drain-path satellite)
# ---------------------------------------------------------------------------

def test_interpreter_chain_stays_on_device():
    """The machine's operand buffers and committed chain state are device
    arrays end to end: a wired consumer reads the producer's commit
    without a host round trip."""
    import jax
    chained = _fixed_choice_chain([12, 8, 6], ["relu", "none"])
    assert chained[1].input_elided
    x, ws = _chain_tensors(8, [12, 8, 6])
    be = backends.InterpreterBackend(CFG)
    be.run_program(chained[0], {"I": x, "W": ws[0]})
    m = be.machine
    for role, buf in m._bufs.items():
        if buf is not None:
            assert isinstance(buf, jax.Array), role
    out = be.run_program(chained[1], {"W": ws[1]})[chained[-1].out_name]
    np.testing.assert_allclose(np.asarray(out),
                               _oracle(x, ws, ["relu", "none"]),
                               rtol=2e-4, atol=2e-3)


# ---------------------------------------------------------------------------
# Streamed fusion: adapt boundaries, dtype-aware budget, cache keys, mesh
# ---------------------------------------------------------------------------

def _build_adapt_chain(layer_dims, acts, cache=None):
    """Lower an adapt-broken stack: layer_dims = [(m, k, n), ...] where
    consecutive shapes need NOT chain -- every junction is an adapt.
    Row-wise acts ride in-program only where the winning mapping keeps
    full rows per tile (the runtime's gate); otherwise the layer drops
    to 'none' and the caller's act list is updated in place."""
    cache = cache or ProgramCache()
    progs = []
    for i, (m, k, n) in enumerate(layer_dims):
        g = mapper.Gemm(m=m, k=k, n=n, name=f"adapt-l{i}")
        plan = cache.plan(g, CFG)
        legal = acts[i] not in program.ROW_WISE_ACTIVATIONS or (
            plan.choice.df == isa.Dataflow.WOS and plan.program.n_n == 1)
        if not legal:
            acts[i] = "none"
        progs.append(cache.lower(
            plan.gemm, plan.choice, CFG,
            activation=ACTIVATIONS.get(acts[i]), act_name=acts[i],
            out_name=f"O{i}"))
    return progs


def _adapt_oracle(x, ws, layer_dims, acts, adapts):
    from repro.runtime.executable import adapt
    out = np.asarray(x, np.float32)
    for (m, k, n), w, act, ad in zip(layer_dims, ws, acts, adapts):
        if ad:
            out = adapt(out, m, k)
        out = out @ w
        fn = ACTIVATIONS.get(act)
        if fn is not None:
            out = np.asarray(fn(out))
    return out


@settings(max_examples=6, deadline=None)
@given(m0=st.integers(2, 24), k0=st.integers(3, 24),
       n0=st.integers(2, 24), m1=st.integers(2, 24),
       k1=st.integers(2, 24), n1=st.integers(2, 16),
       act=st.sampled_from(["none", "relu", "softmax", "rmsnorm"]),
       seed=st.integers(0, 2 ** 16))
def test_adapt_chain_property(m0, k0, n0, m1, k1, n1, act, seed):
    """Property: any random chain broken by an adapt reshape agrees
    across fused pallas (in-kernel permutation), the base per-layer
    replay (host-side adapt) and the numpy oracle."""
    layer_dims = [(m0, k0, n0), (m1, k1, n1)]
    acts = [act, "none"]
    adapts = (False, True)
    progs = _build_adapt_chain(layer_dims, acts)
    seg = program.fuse_segment(progs, adapts=adapts)
    assert seg is not None, program.fusion_illegal_reason(
        progs, adapts=adapts)
    assert seg.m_steps == 1
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m0, k0)).astype(np.float32)
    ws = [rng.standard_normal((k, n)).astype(np.float32) / np.sqrt(k)
          for (_, k, n) in layer_dims]
    ref = _adapt_oracle(x, ws, layer_dims, acts, adapts)
    for name in ("pallas", "interpreter"):
        out = _run_fused(name, seg, x, ws)
        np.testing.assert_allclose(
            out, ref, rtol=2e-4, atol=2e-4 + 2e-4 * max(k0, k1),
            err_msg=f"{name} adapt chain diverged")


def test_adapt_chain_with_row_wise_acts_three_layers():
    """Two adapt boundaries + softmax/rmsnorm drains, fused vs oracle."""
    layer_dims = [(6, 9, 7), (8, 5, 11), (3, 10, 5)]
    acts = ["softmax", "rmsnorm", "none"]
    adapts = (False, True, True)
    progs = _build_adapt_chain(layer_dims, acts)
    seg = program.fuse_segment(progs, adapts=adapts)
    assert seg is not None, program.fusion_illegal_reason(
        progs, adapts=adapts)
    rng = np.random.default_rng(2)
    x = rng.standard_normal((6, 9)).astype(np.float32)
    ws = [rng.standard_normal((k, n)).astype(np.float32) / np.sqrt(k)
          for (_, k, n) in layer_dims]
    ref = _adapt_oracle(x, ws, layer_dims, acts, adapts)
    for name in ("pallas", "interpreter"):
        np.testing.assert_allclose(
            _run_fused(name, seg, x, ws), ref,
            rtol=2e-4, atol=2e-3, err_msg=name)


def test_streamed_budget_is_dtype_aware():
    """The VMEM budget counts BYTES: the same geometry that busts the
    budget at fp32 fits at bf16/int8 (satellite: dtype-aware budget)."""
    chained = _build_chain((8, [12, 8, 6]), ["relu", "none"])
    f32 = program._streamed_footprint_bytes(
        8, 3, [(12, 8), (8, 6)], [3, 4], operand_dtype="float32")
    bf16 = program._streamed_footprint_bytes(
        8, 3, [(12, 8), (8, 6)], [3, 4], operand_dtype="bfloat16")
    int8 = program._streamed_footprint_bytes(
        8, 3, [(12, 8), (8, 6)], [3, 4], operand_dtype="int8")
    assert f32 > bf16 > int8          # window bytes scale with the dtype
    # pick a budget that fits the streamed bf16 geometry but not fp32
    lo = program.fusion_illegal_reason(chained, vmem_budget=0)
    assert "budget" in lo
    for budget in range(1, 1 << 20):
        seg16 = program.fuse_segment(chained, vmem_budget=budget,
                                     operand_dtype="bfloat16")
        seg32 = program.fuse_segment(chained, vmem_budget=budget)
        if seg16 is not None and seg32 is None:
            break
    else:
        pytest.fail("no budget separates fp32 from bf16 legality")
    assert seg16.operand_dtype == "bfloat16"
    assert seg16.vmem_budget == budget
    assert "dtype" in program.fusion_illegal_reason(
        chained, operand_dtype="fp4")
    assert program.fuse_segment(chained, operand_dtype="fp4") is None


def test_fused_key_includes_streaming_geometry():
    """Cache-key regression (satellite): a changed buffer depth, VMEM
    budget, adapt layout or operand dtype must MISS the fused tier --
    serving a stale kernel compiled for different streaming geometry
    would be silently wrong."""
    import dataclasses as dc
    from repro.runtime.cache import fused_key
    chained = _build_chain((8, [12, 8, 6]), ["relu", "none"])
    seg = program.fuse_segment(chained)
    base = fused_key(seg, 2048)
    assert fused_key(program.fuse_segment(chained), 2048) == base
    variants = [
        dc.replace(seg, buffer_depth=seg.buffer_depth + 1),
        dc.replace(seg, vmem_budget=seg.vmem_budget // 2),
        dc.replace(seg, adapts=(False, True)),
        dc.replace(seg, operand_dtype="bfloat16"),
        dc.replace(seg, layer_bks=tuple(b + 1 for b in seg.layer_bks)),
        dc.replace(seg, bm=seg.bm + 1),
    ]
    keys = [fused_key(v, 2048) for v in variants]
    assert len(set(keys + [base])) == len(keys) + 1, keys


@pytest.mark.parametrize("n_arrays", [2, 4])
def test_mesh_subchain_fused_within_arrays(n_arrays):
    """An M-sharded chained run fuses WITHIN each array: one streamed
    launch per array (n_launches == n_arrays), matching the oracle on
    both backends (satellite: 2/4-array mesh sub-chain)."""
    pytest.importorskip("jax")
    from repro.dist import ArrayMesh
    mesh = ArrayMesh(n_arrays)
    m, widths = 16, [12, 8, 6]
    acts = ["relu", "none"]
    progs = _build_adapt_chain([(m, widths[0], widths[1]),
                                (m, widths[1], widths[2])], acts)
    shardeds = [program.shard_program(p, mesh, axis="m") for p in progs]
    sfseg = program.fuse_sharded_segment(shardeds)
    assert sfseg is not None and sfseg.n_arrays == n_arrays
    assert sfseg.out_name == progs[-1].out_name
    x, ws = _chain_tensors(m, widths, seed=9)
    ref = _oracle(x, ws, acts)
    t = {"I": x, **{f"W{i}": w for i, w in enumerate(ws)}}
    be = backends.get_backend("pallas", CFG)
    before = be.n_launches
    out = np.asarray(be.run_segment(sfseg, t)[sfseg.out_name])
    assert be.n_launches - before == n_arrays   # one fused launch/array
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-3)
    bi = backends.get_backend("interpreter", CFG)
    out_i = np.asarray(bi.run_segment(sfseg, t)[sfseg.out_name])
    np.testing.assert_allclose(out_i, ref, rtol=2e-4, atol=2e-3)
    # the mesh still forbids fusing ACROSS arrays: a K-sharded step
    # breaks per-array row ownership, so the run is not fusable
    mixed = [program.shard_program(progs[0], mesh, axis="m"),
             program.shard_program(progs[1], mesh, axis="k")]
    assert program.fuse_sharded_segment(mixed) is None


def test_batch_plan_splits_fused_segments_at_adapt():
    """Batched decode cannot flatten across an adapt boundary (it would
    mix requests' rows): the plan re-splits the block-fused segments
    into batchable sub-runs and stays fully batched (no perreq)."""
    ex = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                  cache=ProgramCache())
    assert any(seg.fused is not None and any(seg.fused.adapts)
               for seg in ex.segments)
    plan = ex.batch_plan(4)
    covered = [i for bseg in plan.segments for i in bseg.indices]
    assert covered == list(range(len(ex.steps)))   # exact re-partition
    assert plan.launches_per_tick is not None      # nothing fell back
    for bseg in plan.segments:
        steps = [ex.steps[i] for i in bseg.indices]
        # no interior adapt, no dynamic/static mix inside one sub-run
        assert all(s.input_mode != "adapt" for s in steps[1:])
        assert len({s.op.dynamic for s in steps}) == 1
        if bseg.kind == "static" and len(bseg.programs) > 1:
            assert bseg.fused is not None
