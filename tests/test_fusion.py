"""Fused-segment equivalence and fallback semantics.

The correctness spine for the fusion tentpole: a chained segment executed
as ONE fused pallas launch must equal the per-layer pallas path, the
interpreter, and the einsum oracle of the identical chain -- across the
Tab. IV CI workloads, random chain geometries, and whole model cells.
Fallback cases (``adapt`` boundaries, sharded streams, non-fusable
activations, VMEM budget) must cleanly take the per-Program path, and the
fused cache tier must make a rebuilt executable compile nothing.
"""

import dataclasses

import numpy as np
import pytest

from repro import backends
from repro.configs.feather import feather_config
from repro.core import isa, mapper, perf, program, workloads
from repro.runtime import ModelExecutable, ProgramCache
from repro.runtime.executable import ACTIVATIONS
from tests._hypothesis_compat import given, settings, st

CFG = feather_config(4, 16)
RNG = np.random.default_rng(11)


def _build_chain(dims, acts, cfg=CFG, cache=None):
    """Search+lower+chain an L-layer stack; dims = [(k0), n0, n1, ...]."""
    cache = cache or ProgramCache()
    m, widths = dims
    progs = []
    for i in range(len(widths) - 1):
        g = mapper.Gemm(m=m, k=widths[i], n=widths[i + 1],
                        name=f"chain-l{i}")
        plan = cache.plan(g, cfg)
        progs.append(cache.lower(
            plan.gemm, plan.choice, cfg,
            activation=ACTIVATIONS.get(acts[i]), act_name=acts[i],
            out_name=f"O{i}"))
    return program.chain(progs, lower_fn=cache.lower)


def _chain_tensors(m, widths, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, widths[0])).astype(np.float32)
    ws = [(rng.standard_normal((widths[i], widths[i + 1]))
           / np.sqrt(widths[i])).astype(np.float32)
          for i in range(len(widths) - 1)]
    return x, ws


def _oracle(x, ws, acts):
    out = np.asarray(x, np.float32)
    for w, act in zip(ws, acts):
        out = out @ w
        fn = ACTIVATIONS.get(act)
        if fn is not None:
            out = np.asarray(fn(out))
    return out


def _run_per_layer(backend_name, chained, x, ws):
    be = backends.get_backend(backend_name, CFG)
    for i, prog in enumerate(chained):
        t = {"W": ws[i]}
        if i == 0:
            t["I"] = x
        be.run_program(prog, t)
    return np.asarray(be.outputs[chained[-1].out_name])


def _run_fused(backend_name, seg, x, ws):
    be = backends.get_backend(backend_name, CFG)
    t = {"I": x, **{f"W{i}": w for i, w in enumerate(ws)}}
    return np.asarray(be.run_segment(seg, t)[seg.out_name])


def _assert_chain_equivalence(dims, acts, seed=0):
    m, widths = dims
    chained = _build_chain(dims, acts)
    seg = program.fuse_segment(chained)
    assert seg is not None, program.fusion_illegal_reason(chained)
    x, ws = _chain_tensors(m, widths, seed)
    ref = _oracle(x, ws, acts)
    k_max = max(widths)
    tol = dict(rtol=2e-4, atol=2e-4 + 2e-4 * k_max)
    outs = {
        "fused-pallas": _run_fused("pallas", seg, x, ws),
        "per-layer-pallas": _run_per_layer("pallas", chained, x, ws),
        "fused-interpreter": _run_fused("interpreter", seg, x, ws),
        "per-layer-interp": _run_per_layer("interpreter", chained, x, ws),
    }
    for name, out in outs.items():
        np.testing.assert_allclose(out, ref, err_msg=name, **tol)
    # fused pallas vs per-layer pallas: same kernel arithmetic, checked
    # at a tolerance an order tighter than against the oracle
    np.testing.assert_allclose(outs["fused-pallas"],
                               outs["per-layer-pallas"],
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# The spine: ci_suite-anchored multi-layer chains, all four executions
# ---------------------------------------------------------------------------

def _suite_samples():
    """One workload per Tab. IV family (+ the conv), chain-extended."""
    suite = {g.name.split("-")[0] + "-" + g.name.split("-")[1]: g
             for g in workloads.ci_suite()}
    picks = [suite[k] for k in ("fhe-bconv", "fhe-ntt", "zkp-ntt",
                                "gpt-oss", "conv-3x3s1")]
    return picks


@pytest.mark.parametrize("gemm", _suite_samples(), ids=lambda g: g.name)
def test_fused_equals_per_layer_equals_oracle_ci_suite(gemm):
    """fused pallas == per-layer pallas == interpreter == oracle on
    3-layer chains anchored on each CI workload family's shape."""
    widths = [gemm.k, gemm.n, 24, 16]
    _assert_chain_equivalence((gemm.m, widths), ["silu", "relu", "none"])


def test_fused_row_wise_activation_chain():
    """softmax inside a fused chain (the attention qk->pv pattern):
    legal because a fused block holds full output rows."""
    _assert_chain_equivalence((12, [16, 12, 8]), ["softmax", "none"])


@settings(max_examples=8, deadline=None)
@given(m=st.integers(2, 40), k0=st.integers(3, 40),
       n0=st.integers(2, 40), n1=st.integers(2, 40), n2=st.integers(2, 40),
       n_layers=st.integers(2, 4),
       act=st.sampled_from(["none", "relu", "gelu", "silu"]),
       seed=st.integers(0, 2 ** 16))
def test_fused_random_chain_property(m, k0, n0, n1, n2, n_layers, act,
                                     seed):
    """Property: any fusion-legal random chain geometry agrees with the
    oracle on both the fused and per-layer paths."""
    widths = [k0, n0, n1, n2][:n_layers + 1]
    acts = [act] * (len(widths) - 2) + ["none"]
    _assert_chain_equivalence((m, widths), acts, seed=seed)


# ---------------------------------------------------------------------------
# Legality predicate + fallbacks
# ---------------------------------------------------------------------------

def test_fusion_legality_reasons():
    chained = _build_chain((8, [12, 8, 6]), ["relu", "none"])
    assert program.fusable(chained)
    # fewer than 2 layers
    assert "fewer than 2" in program.fusion_illegal_reason(chained[:1])
    # shape break
    other = _build_chain((10, [12, 8, 6]), ["relu", "none"])
    assert "output" in program.fusion_illegal_reason([chained[0],
                                                      other[1]])
    # anonymous activation callable
    anon = dataclasses.replace(chained[0], activation=lambda x: x * 2,
                               act_name="none", _memo={})
    assert "anonymous" in program.fusion_illegal_reason([anon, chained[1]])
    # VMEM budget
    assert "budget" in program.fusion_illegal_reason(chained,
                                                     vmem_budget=10)
    assert program.fuse_segment(chained, vmem_budget=10) is None


def test_row_wise_activation_needs_wos():
    """A row-wise activation under IO-S (transposed accumulator) cannot
    fuse -- the block's rows are host columns there."""
    choice = mapper.MappingChoice(df=isa.Dataflow.IOS, vn=4, m_t=8,
                                  k_t=8, n_t=8, n_kg=1, n_nb=1, dup=4)
    g1 = mapper.Gemm(m=8, k=8, n=8)
    p1 = program.lower(g1, choice, CFG, out_name="O0",
                       activation=ACTIVATIONS["softmax"],
                       act_name="softmax")
    p2 = program.lower(mapper.Gemm(m=8, k=8, n=4), choice, CFG,
                       out_name="O1")
    reason = program.fusion_illegal_reason([p1, p2])
    assert reason is not None and "row-wise" in reason


def test_adapt_boundary_breaks_fusion():
    """The head-split reshape between projections and attention is an
    ``adapt`` step: it starts a new segment, so no fused segment ever
    spans it."""
    ex = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                  cache=ProgramCache())
    covered = [i for seg in ex.segments for i in seg.indices]
    assert covered == list(range(len(ex.steps)))   # exact partition
    for seg in ex.segments:
        steps = [ex.steps[i] for i in seg.indices]
        assert all(s.input_mode == "wired" for s in steps[1:])
        assert steps[0].input_mode in ("fresh", "adapt")
        if seg.fused is not None:
            assert seg.n_steps >= 2
    adapt_steps = [s.index for s in ex.steps if s.input_mode == "adapt"]
    assert adapt_steps, "cell should contain adapt boundaries"
    seg_starts = {seg.indices[0] for seg in ex.segments}
    assert set(adapt_steps) <= seg_starts


def test_sharded_stream_falls_back():
    """Mesh-sharded executables never fuse (on-chip residency is
    per-array state) but still run end-to-end."""
    pytest.importorskip("jax")
    from repro.dist import ArrayMesh
    ex = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                  cache=ProgramCache(), mesh=ArrayMesh(2))
    assert all(seg.fused is None for seg in ex.segments)
    res = ex.run("interpreter", fused=True)
    assert res.fused_segments == 0
    assert all(o is not None for o in res.outputs)


def test_sharded_program_not_fusable():
    from repro.dist import ArrayMesh
    g = mapper.Gemm(m=16, k=12, n=8)
    plan = mapper.search(g, CFG)
    sharded = program.shard_program(plan.program, ArrayMesh(2))
    reason = program.fusion_illegal_reason([sharded, sharded])
    assert reason is not None and "sharded" in reason


# ---------------------------------------------------------------------------
# Whole-cell fused execution (runtime path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell", [("gemma-7b", "decode_tiny"),
                                  ("granite-moe-3b-a800m", "prefill_tiny")],
                         ids=lambda c: f"{c[0]}-{c[1]}")
@pytest.mark.parametrize("backend", ["interpreter", "pallas"])
def test_cell_fused_run_matches_oracle(cell, backend):
    """run(fused=True) == the per-step einsum oracle (check=True) on both
    backends, and matches the per-layer final output."""
    ex = ModelExecutable.for_cell(cell[0], cell[1], CFG,
                                  cache=ProgramCache())
    env = ex.make_tensors(seed=5)
    fused = ex.run(backend, tensors=env, fused=True, check=True)
    plain = ex.run(backend, tensors=env, check=True)
    assert fused.checked and fused.fused_segments >= 1
    assert len(fused.outputs) == len(ex.steps)
    np.testing.assert_allclose(fused.final, plain.final,
                               rtol=2e-4, atol=2e-3)
    # interior fused steps stay on-chip: no materialised output
    fused_interior = {i for seg in ex.segments if seg.fused is not None
                     for i in seg.indices[:-1]}
    for i, out in enumerate(fused.outputs):
        assert (out is None) == (i in fused_interior)


# ---------------------------------------------------------------------------
# Traffic accounting: the fused stream elides the interior round trips
# ---------------------------------------------------------------------------

def test_fused_traffic_elision():
    chained = _build_chain((16, [12, 8, 6]), ["relu", "none"])
    seg = program.fuse_segment(chained)
    elem = CFG.elem_bytes
    # kernel-launch accounting: exactly one Write + one Load of every
    # interior activation is elided
    interior = sum(p.gemm.n for p in chained[:-1])
    assert seg.elided_hbm_bytes() == 2 * 16 * interior * elem
    # machine-model tile stream: fused ships no more than per-layer, and
    # interior stores are gone entirely
    fused_traffic = perf.hbm_traffic(seg.tile_costs())
    plain_traffic = perf.hbm_traffic(
        [t for p in chained for t in p.tile_costs()])
    assert fused_traffic["data_bytes"] <= plain_traffic["data_bytes"]
    interior_stores = sum(
        t.store_bytes for layer in range(seg.n_layers - 1)
        for t in seg.layer_tile_costs(layer))
    assert interior_stores == 0.0
    # the instruction stream is untouched by fusion
    assert seg.minisa_bits() == sum(p.minisa_bits() for p in chained)


def _fixed_choice_chain(widths, acts, m=8):
    """Chain lowered under ONE MappingChoice (equal vn -> guaranteed
    §IV-G elision, independent of per-layer search outcomes)."""
    choice = mapper.MappingChoice(df=isa.Dataflow.WOS, vn=4, m_t=8,
                                  k_t=8, n_t=8, n_kg=1, n_nb=1, dup=4)
    progs = [program.lower(mapper.Gemm(m=m, k=widths[i], n=widths[i + 1]),
                           choice, CFG,
                           activation=ACTIVATIONS.get(acts[i]),
                           act_name=acts[i], out_name=f"O{i}")
             for i in range(len(widths) - 1)]
    return program.chain(progs)


def test_commit_write_counts_on_chip():
    """A chained producer's committing Write is OB-commit cycles, not HBM
    store bytes -- the §IV-G semantics in the traffic model."""
    chained = _fixed_choice_chain([12, 8, 6], ["none", "none"])
    assert chained[1].input_elided
    plain = program.lower(chained[0].gemm, chained[0].choice, CFG,
                          out_name="O0")
    chained_store = sum(t.store_bytes for t in chained[0].tile_costs())
    plain_store = sum(t.store_bytes for t in plain.tile_costs())
    assert chained_store < plain_store

def test_fused_act_names_match_kernel_registry():
    from repro.kernels.fused_chain import FUSED_ACT_FNS
    from repro.kernels.nest_gemm import ACT_FNS
    assert program.FUSED_ELEMENTWISE_ACTS == set(ACT_FNS)
    assert (program.FUSED_ELEMENTWISE_ACTS
            | program.ROW_WISE_ACTIVATIONS) == set(FUSED_ACT_FNS)
    assert set(program.FUSED_ACT_ALIASES.values()) <= set(ACT_FNS)


def test_activation_registries_numerically_agree():
    """Three activation registries must stay numerically identical (same
    eps, same max-subtraction): the runtime's host ACTIVATIONS, the
    machine's device twins, and the fused kernel's FUSED_ACT_FNS --
    drift in any one silently breaks the cross-path state checksums."""
    import jax.numpy as jnp
    from repro.core.machine import _JNP_ACTS
    from repro.kernels.fused_chain import FUSED_ACT_FNS
    x = RNG.standard_normal((6, 10)).astype(np.float32) * 3
    for name, host_fn in ACTIVATIONS.items():
        if host_fn is None:
            continue
        ref = np.asarray(host_fn(x))
        mach = np.asarray(_JNP_ACTS[name](jnp.asarray(x)))
        np.testing.assert_allclose(mach, ref, rtol=1e-6, atol=1e-6,
                                   err_msg=f"machine twin {name}")
        kname = program.FUSED_ACT_ALIASES.get(name, name)
        if kname in FUSED_ACT_FNS:
            kern = np.asarray(FUSED_ACT_FNS[kname](jnp.asarray(x)))
            np.testing.assert_allclose(kern, ref, rtol=1e-6, atol=1e-6,
                                       err_msg=f"kernel twin {name}")


# ---------------------------------------------------------------------------
# Cache: fused tier hits, fewer compiles
# ---------------------------------------------------------------------------

def test_fused_tier_hits_and_reduced_compiles():
    cache = ProgramCache()
    ex1 = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                   cache=cache)
    n_fused_steps = sum(seg.n_steps for seg in ex1.segments
                        if seg.fused is not None)
    n_fused_segs = sum(1 for seg in ex1.segments if seg.fused is not None)
    assert n_fused_segs >= 2 and n_fused_steps > n_fused_segs

    # fused serving compiles ONE artifact per segment where the per-layer
    # path compiles one per GEMM (measured without the shared cache)
    be_layer = backends.PallasBackend(CFG)
    ex1.run(be_layer)
    be_fused = backends.PallasBackend(CFG)
    ex1.run(be_fused, fused=True)
    assert be_fused.n_compiles == (be_layer.n_compiles
                                   - n_fused_steps + n_fused_segs)
    assert be_fused.n_compiles < be_layer.n_compiles

    # fused tier: the first cached run misses once per segment...
    be1 = backends.PallasBackend(CFG, compile_cache=cache)
    ex1.run(be1, fused=True)
    assert cache.stats.fused_misses == n_fused_segs
    snap = cache.stats.snapshot()
    # ...and a REBUILT executable (fresh Program/FusedSegment objects) on
    # a fresh backend hits structurally: zero new compiles of any kind
    ex2 = ModelExecutable.for_cell("gemma-7b", "decode_tiny", CFG,
                                   cache=cache)
    be2 = backends.PallasBackend(CFG, compile_cache=cache)
    ex2.run(be2, fused=True)
    delta = cache.stats.delta(snap)
    assert delta["fused_hits"] == n_fused_segs, delta
    assert delta["fused_misses"] == 0, delta
    assert delta["plan_misses"] == 0 and delta["compile_misses"] == 0
    assert be2.n_compiles == 0


# ---------------------------------------------------------------------------
# Interpreter chain residency (the drain-path satellite)
# ---------------------------------------------------------------------------

def test_interpreter_chain_stays_on_device():
    """The machine's operand buffers and committed chain state are device
    arrays end to end: a wired consumer reads the producer's commit
    without a host round trip."""
    import jax
    chained = _fixed_choice_chain([12, 8, 6], ["relu", "none"])
    assert chained[1].input_elided
    x, ws = _chain_tensors(8, [12, 8, 6])
    be = backends.InterpreterBackend(CFG)
    be.run_program(chained[0], {"I": x, "W": ws[0]})
    m = be.machine
    for role, buf in m._bufs.items():
        if buf is not None:
            assert isinstance(buf, jax.Array), role
    out = be.run_program(chained[1], {"W": ws[1]})[chained[-1].out_name]
    np.testing.assert_allclose(np.asarray(out),
                               _oracle(x, ws, ["relu", "none"]),
                               rtol=2e-4, atol=2e-3)
